//! Bench: Byzantine-fraction sweep — does the network survive misbehaving
//! participants, and do the defenses actually pay for themselves?
//!
//! A 3-region WAN (one requester + four servers per region) where a
//! fraction f ∈ {0%, 10%, 25%, 40%} of the 12 servers is replaced with
//! attackers from `wwwserve::policy::byzantine` (free-riders, result
//! fakers, a colluder and a latency liar at the higher fractions), spread
//! one-per-region so no region is spared. Each fraction runs twice: with
//! the defense stack off (no receipts, no reputation, no hearsay capping)
//! and with it armed.
//!
//! Asserted headline claims (the PR's acceptance bar):
//!
//! * attackers genuinely hurt: at 25% Byzantine the undefended SLO drops
//!   measurably below the attack-free undefended baseline;
//! * at 25% Byzantine, defenses-on SLO attainment AND mean honest-server
//!   revenue are strictly above defenses-off;
//! * the defenses visibly engage (receipt rejections > 0, quarantines > 0
//!   under attack) and never punish anyone in an attack-free world.
//!
//! Results land in `BENCH_byzantine.json` for the per-PR perf trajectory.
//! `--smoke` (or `BYZANTINE_SMOKE=1`) runs the {0%, 25%} fractions only —
//! the CI tier; the assertions all live inside that subset.

use wwwserve::backend::Profile;
use wwwserve::benchlib::{write_json_report, Table};
use wwwserve::policy::{ByzantineKind, NodePolicy};
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::topology::three_region_wan;
use wwwserve::types::CREDIT;
use wwwserve::util::json::Json;
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::NodeId;

const HORIZON: f64 = 600.0;
const DRAIN: f64 = 600.0;
const SEED: u64 = 2026;
const SERVERS_PER_REGION: usize = 4;
const NODES_PER_REGION: usize = SERVERS_PER_REGION + 1;
const N_SERVERS: usize = 3 * SERVERS_PER_REGION;

/// Attacker personalities installed in order as the fraction grows: the
/// sweep leads with the paper's headline free-rider economics, mixes in a
/// receipt forger at 25%, and adds the gossip-layer attackers at 40%.
const ATTACK_MIX: [ByzantineKind; 5] = [
    ByzantineKind::FreeRider,
    ByzantineKind::ResultFaker,
    ByzantineKind::FreeRider,
    ByzantineKind::Colluder,
    ByzantineKind::LatencyLiar,
];

fn lengths() -> LengthDist {
    LengthDist { output_mean: 600.0, output_sigma: 0.5, ..Default::default() }
}

/// Server slots (0..N_SERVERS, region-major) that turn Byzantine at this
/// fraction, spread evenly so every region gets its share of attackers.
fn attacker_slots(frac: f64) -> Vec<usize> {
    let k = (frac * N_SERVERS as f64).round() as usize;
    (0..k).map(|j| j * N_SERVERS / k.max(1)).collect()
}

/// One requester + `SERVERS_PER_REGION` servers per region; server slot
/// `s` (region-major) is handed its attacker kind when listed.
fn setups(frac: f64) -> Vec<NodeSetup> {
    let slots = attacker_slots(frac);
    let mut out = Vec::new();
    let mut server_slot = 0usize;
    for region in 0..3 {
        let requester_id = NodeId((region * NODES_PER_REGION) as u32);
        out.push(
            NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    latency_penalty: 50.0,
                    ..NodePolicy::requester_only()
                },
            )
            .with_generator(
                Generator::new(
                    requester_id,
                    vec![Phase::new(0.0, HORIZON, 1.5)],
                )
                .with_lengths(lengths()),
            ),
        );
        for _ in 0..SERVERS_PER_REGION {
            let mut s = NodeSetup::new(
                Profile::test(45.0, 24),
                NodePolicy {
                    stake: 20 * CREDIT,
                    accept_freq: 1.0,
                    latency_penalty: 50.0,
                    ..Default::default()
                },
            );
            if let Some(j) = slots.iter().position(|&x| x == server_slot) {
                s = s.with_byzantine(ATTACK_MIX[j % ATTACK_MIX.len()]);
            }
            out.push(s);
            server_slot += 1;
        }
    }
    out
}

struct ByzRun {
    slo: f64,
    completed: usize,
    /// Mean end-of-run profit (credits gained over genesis) per honest
    /// server, in CREDIT units. Negative means the run cost them money.
    honest_revenue: f64,
    receipt_rejects: u64,
    quarantines: u64,
    rtts_rejected: u64,
    rtts_capped: u64,
}

fn run(frac: f64, defended: bool) -> ByzRun {
    let mut cfg = WorldConfig {
        seed: SEED,
        topology: Some(three_region_wan(NODES_PER_REGION).build()),
        ..Default::default()
    };
    cfg.system.duel_rate = 0.0; // isolate the receipt/reputation defenses
    cfg.defenses.enabled = defended;
    let setups = setups(frac);
    let byzantine: Vec<bool> =
        setups.iter().map(|s| s.byzantine.is_some()).collect();
    let genesis = cfg.system.genesis_credits;
    let mut w = World::new(cfg, setups);
    w.run_until(HORIZON + DRAIN);

    // Honest servers: every non-requester node that isn't an attacker.
    let mut honest_profit = 0.0;
    let mut honest_n = 0usize;
    for i in 0..w.num_nodes() {
        if i % NODES_PER_REGION == 0 || byzantine[i] {
            continue;
        }
        honest_profit +=
            w.node(i).credits() as f64 - genesis as f64;
        honest_n += 1;
    }
    let sum = |f: &dyn Fn(&wwwserve::coordinator::Node) -> u64| -> u64 {
        (0..w.num_nodes()).map(|i| f(w.node(i))).sum()
    };
    ByzRun {
        slo: w.recorder.slo_attainment(),
        completed: w.recorder.len(),
        honest_revenue: honest_profit / honest_n as f64 / CREDIT as f64,
        receipt_rejects: sum(&|n| n.stats.receipt_rejects),
        quarantines: sum(&|n| n.stats.quarantines),
        rtts_rejected: sum(&|n| n.stats.rtts_rejected),
        rtts_capped: sum(&|n| n.stats.rtts_capped),
    }
}

fn run_json(r: &ByzRun) -> Json {
    Json::obj(vec![
        ("slo", Json::num(r.slo)),
        ("completed", Json::num(r.completed as f64)),
        ("honest_revenue_credits", Json::num(r.honest_revenue)),
        ("receipt_rejects", Json::num(r.receipt_rejects as f64)),
        ("quarantines", Json::num(r.quarantines as f64)),
        ("rtts_rejected", Json::num(r.rtts_rejected as f64)),
        ("rtts_capped", Json::num(r.rtts_capped as f64)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BYZANTINE_SMOKE")
            .is_ok_and(|v| !v.is_empty() && v != "0");
    let fractions: &[f64] =
        if smoke { &[0.0, 0.25] } else { &[0.0, 0.10, 0.25, 0.40] };
    println!(
        "# byzantine — attacker-fraction sweep, defenses off vs on{}\n",
        if smoke { " (smoke tier)" } else { "" }
    );

    let mut rows = Vec::new();
    for &frac in fractions {
        let off = run(frac, false);
        let on = run(frac, true);
        let kinds: Vec<&str> = attacker_slots(frac)
            .iter()
            .enumerate()
            .map(|(j, _)| ATTACK_MIX[j % ATTACK_MIX.len()].name())
            .collect();
        println!(
            "f={:>3.0}%  attackers: [{}]",
            frac * 100.0,
            kinds.join(", ")
        );
        rows.push((frac, off, on));
    }

    println!();
    let mut t = Table::new(&[
        "byz %", "SLO off", "SLO on", "rev off", "rev on",
        "rcpt-rej", "quarantines", "rtts rej/cap",
    ]);
    for (frac, off, on) in &rows {
        t.row(vec![
            format!("{:.0}", frac * 100.0),
            format!("{:.3}", off.slo),
            format!("{:.3}", on.slo),
            format!("{:+.2}", off.honest_revenue),
            format!("{:+.2}", on.honest_revenue),
            format!("{}", on.receipt_rejects),
            format!("{}", on.quarantines),
            format!("{}/{}", on.rtts_rejected, on.rtts_capped),
        ]);
    }
    t.print();

    let at = |f: f64| -> &(f64, ByzRun, ByzRun) {
        rows.iter()
            .find(|(x, _, _)| (x - f).abs() < 1e-9)
            .expect("fraction in sweep")
    };
    let (_, clean_off, clean_on) = at(0.0);
    let (_, off25, on25) = at(0.25);

    // Attack-free worlds: the defense machinery must find nobody to punish.
    assert_eq!(clean_on.receipt_rejects, 0, "honest receipts rejected");
    assert_eq!(clean_on.quarantines, 0, "honest node quarantined");
    assert!(clean_on.completed > 500, "sweep barely ran");

    // Attackers genuinely hurt an undefended network.
    assert!(
        off25.slo < clean_off.slo - 0.02,
        "25% Byzantine didn't dent the undefended SLO: {:.3} vs clean {:.3}",
        off25.slo,
        clean_off.slo
    );

    // The headline: at 25% Byzantine, defenses recover SLO attainment and
    // honest-server revenue — both strictly.
    assert!(
        on25.slo > off25.slo,
        "defenses failed to recover SLO at 25% Byzantine: on {:.3} vs \
         off {:.3}",
        on25.slo,
        off25.slo
    );
    assert!(
        on25.honest_revenue > off25.honest_revenue,
        "defenses failed to recover honest revenue at 25% Byzantine: \
         on {:+.2} vs off {:+.2}",
        on25.honest_revenue,
        off25.honest_revenue
    );

    // And they engaged for the right reasons: the faker was caught at
    // settlement, the free-riders were quarantined.
    assert!(on25.receipt_rejects > 0, "result faker never caught");
    assert!(on25.quarantines > 0, "free-riders never quarantined");
    assert_eq!(
        off25.receipt_rejects, 0,
        "undefended run verified receipts somehow"
    );

    println!(
        "\n25% Byzantine: SLO {:.3} -> {:.3}, honest revenue {:+.2} -> \
         {:+.2} credits with defenses on ✓",
        off25.slo, on25.slo, off25.honest_revenue, on25.honest_revenue
    );

    let report = Json::obj(vec![
        ("bench", Json::str("byzantine")),
        ("seed", Json::num(SEED as f64)),
        ("horizon_s", Json::num(HORIZON)),
        ("smoke", Json::Bool(smoke)),
        ("servers", Json::num(N_SERVERS as f64)),
        (
            "sweep",
            Json::Arr(
                rows.iter()
                    .map(|(frac, off, on)| {
                        Json::obj(vec![
                            ("byzantine_fraction", Json::num(*frac)),
                            ("defenses_off", run_json(off)),
                            ("defenses_on", run_json(on)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = "BENCH_byzantine.json";
    write_json_report(path, &report).expect("write bench json");
    println!("wrote {path}");
}
