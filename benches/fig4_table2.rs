//! Bench: regenerate Figure 4 (SLO attainment) + Table 2 (mean latency)
//! across Settings 1-4 for all three strategies, and time each cell.
//!
//! Shape assertions encode the paper's qualitative claims: decentralized
//! beats single-node and approaches centralized.

use wwwserve::benchlib::{bench, Table};
use wwwserve::repro;
use wwwserve::schedulers::Strategy;
use wwwserve::workload::SettingId;

fn main() {
    let seed = 2026;
    println!("# fig4_table2 — scheduling efficiency grid\n");

    let mut table = Table::new(&[
        "Setting", "Strategy", "SLO@1.0", "mean lat (s)", "p99 (s)", "reqs",
    ]);
    let mut cells = Vec::new();
    for id in SettingId::ALL {
        for strategy in
            [Strategy::Single, Strategy::Centralized, Strategy::Decentralized]
        {
            let name = format!("{}/{}", id.name(), strategy.name());
            // Time one full run of this cell.
            let mut out = None;
            bench(&name, 0, 3, 30.0, || {
                out = Some(repro::run_setting(id, strategy, seed));
            });
            let r = out.unwrap();
            table.row(vec![
                id.name().into(),
                strategy.name().into(),
                format!("{:.3}", r.slo_attainment),
                format!("{:.1}", r.mean_latency),
                format!("{:.1}", r.p99_latency),
                format!("{}", r.completed),
            ]);
            cells.push(r);
        }
    }
    println!();
    table.print();

    // Paper-shape checks (who wins, roughly by how much).
    let mut better_than_single = 0;
    for id in SettingId::ALL {
        let get = |s: Strategy| {
            cells
                .iter()
                .find(|r| r.setting == id && r.strategy == s)
                .unwrap()
        };
        let (si, de) = (get(Strategy::Single), get(Strategy::Decentralized));
        if de.slo_attainment >= si.slo_attainment
            && de.mean_latency <= si.mean_latency * 1.05
        {
            better_than_single += 1;
        }
    }
    println!(
        "\nshape check: decentralized ≥ single in {better_than_single}/4 settings"
    );
    assert!(
        better_than_single >= 3,
        "decentralized should dominate single-node in most settings"
    );
}
