//! Bench: Figure 5 — request latency under dynamic participation
//! (node joins in 5a, leaves in 5b), plus gossip-detection latency.

use wwwserve::benchlib::bench;
use wwwserve::repro;

fn phase_mean(series: &[(f64, f64)], lo: f64, hi: f64) -> f64 {
    let pts: Vec<f64> = series
        .iter()
        .filter(|(t, _)| *t >= lo && *t < hi)
        .map(|(_, l)| *l)
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    pts.iter().sum::<f64>() / pts.len() as f64
}

fn main() {
    let seed = 2026;
    println!("# fig5_dynamic — joins and leaves\n");

    let mut join = None;
    bench("fig5a join (2->4 nodes)", 0, 3, 30.0, || {
        join = Some(repro::fig5_join(seed));
    });
    let join = join.unwrap();
    let before = phase_mean(&join.windowed_latency, 100.0, 250.0);
    let after = phase_mean(&join.windowed_latency, 550.0, 750.0);
    println!(
        "join: mean latency before joins {before:.1}s -> after both joins {after:.1}s"
    );
    assert!(
        after < before,
        "joining capacity must reduce latency ({before:.1} -> {after:.1})"
    );

    let mut leave = None;
    bench("fig5b leave (4->2 nodes)", 0, 3, 30.0, || {
        leave = Some(repro::fig5_leave(seed));
    });
    let leave = leave.unwrap();
    let before = phase_mean(&leave.windowed_latency, 100.0, 250.0);
    let after = phase_mean(&leave.windowed_latency, 550.0, 750.0);
    println!(
        "leave: mean latency before leaves {before:.1}s -> after both leaves {after:.1}s"
    );
    assert!(
        after > before,
        "losing capacity must raise latency ({before:.1} -> {after:.1})"
    );
    println!("\nshape check OK (paper: latency falls on join, rises on leave)");
}
