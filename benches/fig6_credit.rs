//! Bench: Figure 6 — credit dynamics under heterogeneous node capabilities
//! (model capacity / quantization / serving efficiency / hardware).

use wwwserve::benchlib::{bench, Table};
use wwwserve::repro::{self, Fig6Variant};

fn main() {
    let seed = 2026;
    println!("# fig6_credit — quality incentivization\n");

    for variant in Fig6Variant::ALL {
        let mut run = None;
        bench(variant.name(), 0, 2, 60.0, || {
            run = Some(repro::fig6(variant, seed));
        });
        let run = run.unwrap();
        let mut t = Table::new(&["class", "served", "win-rate", "credits"]);
        for c in &run.classes {
            t.row(vec![
                c.label.clone(),
                format!("{}", c.served),
                format!("{:.2}", c.win_rate),
                format!("{:.1}", c.final_credits),
            ]);
        }
        t.print();
        println!("duels: {}\n", run.total_duels);

        let c = &run.classes;
        match variant {
            Fig6Variant::ModelCapacity | Fig6Variant::Quantization => {
                // Higher-quality class must win more duels and end richer.
                assert!(
                    c[0].win_rate > c[2].win_rate,
                    "{}: win rates not ordered: {:.2} vs {:.2}",
                    variant.name(),
                    c[0].win_rate,
                    c[2].win_rate
                );
                assert!(
                    c[0].final_credits > c[2].final_credits,
                    "{}: credits not ordered",
                    variant.name()
                );
            }
            Fig6Variant::ServingEfficiency | Fig6Variant::Hardware => {
                // Faster class serves more requests and ends richer; win
                // rates stay comparable (same model quality).
                assert!(
                    c[0].served > c[2].served,
                    "{}: served not ordered: {} vs {}",
                    variant.name(),
                    c[0].served,
                    c[2].served
                );
                assert!(
                    (c[0].win_rate - c[2].win_rate).abs() < 0.15,
                    "{}: win rates should be comparable",
                    variant.name()
                );
                assert!(c[0].final_credits > c[2].final_credits);
            }
        }
    }
    println!("shape checks OK (paper Fig. 6a-6d orderings reproduced)");
}
