//! Bench: Figure 7 + §7.1 — duel-and-judge overhead at duel rates
//! 5% / 10% / 25%, including the N·α·p_d·(1+k) formula check.

use wwwserve::benchlib::{bench, Table};
use wwwserve::repro;

fn main() {
    let seed = 2026;
    println!("# fig7_duel — duel-rate ablation (k = 2)\n");

    let mut runs = Vec::new();
    for p in [0.05, 0.10, 0.25] {
        let mut out = None;
        bench(&format!("duel rate {p:.2}"), 0, 2, 30.0, || {
            out = Some(repro::fig7(p, seed));
        });
        runs.push(out.unwrap());
    }

    let mut t = Table::new(&[
        "p_d", "SLO@1.0", "mean lat (s)", "p50 CDF@100s", "user reqs",
        "synthetic", "predicted N·α·p_d·(1+k)",
    ]);
    for r in &runs {
        let cdf100 = r
            .latency_cdf
            .iter()
            .find(|(x, _)| *x >= 100.0)
            .map(|(_, y)| *y)
            .unwrap_or(0.0);
        t.row(vec![
            format!("{:.2}", r.duel_rate),
            format!("{:.3}", r.slo_curve[3].1),
            format!("{:.1}", r.mean_latency),
            format!("{:.3}", cdf100),
            format!("{}", r.completed),
            format!("{}", r.synthetic),
            format!("{:.0}", r.delegated as f64 * r.duel_rate * 3.0),
        ]);
    }
    t.print();

    // Shape 1: latency/SLO stay near-identical across duel rates (paper).
    let base = runs[0].mean_latency;
    for r in &runs[1..] {
        let rel = (r.mean_latency - base).abs() / base.max(1.0);
        assert!(
            rel < 0.25,
            "duel rate {:.2} changed latency by {:.0}% (paper: minimal)",
            r.duel_rate,
            rel * 100.0
        );
    }
    // Shape 2: overhead grows with p_d and tracks the formula.
    assert!(runs[2].synthetic > runs[0].synthetic);
    for r in &runs {
        let predicted = r.delegated as f64 * r.duel_rate * 3.0;
        let rel = (r.synthetic as f64 - predicted).abs() / predicted.max(1.0);
        assert!(
            rel < 0.5,
            "overhead formula off by {:.0}% at p_d={}",
            rel * 100.0,
            r.duel_rate
        );
    }
    println!("\nshape checks OK (near-identical latency; overhead tracks formula)");
}
