//! Bench: Figure 8 — user-level policy ablations: stake (8a), acceptance
//! frequency (8b), offloading frequency (8c).

use wwwserve::benchlib::{bench, Table};
use wwwserve::repro;

fn main() {
    let seed = 2026;
    println!("# fig8_policy — user-level policy ablations\n");

    let mut a = None;
    bench("fig8a stakes 1/2/3/4", 0, 2, 30.0, || {
        a = Some(repro::fig8a(seed));
    });
    let a = a.unwrap();
    let mut t = Table::new(&["stake", "served", "share"]);
    for (s, n, f) in &a.rows {
        t.row(vec![format!("{s:.0}"), format!("{n}"), format!("{f:.2}")]);
    }
    t.print();
    // Share should rise with stake (PoS weighting) — compare extremes.
    assert!(
        a.rows[3].2 > a.rows[0].2,
        "stake-4 should out-serve stake-1: {:?}",
        a.rows
    );

    let mut b = None;
    bench("fig8b accept 0.25..1.0", 0, 2, 30.0, || {
        b = Some(repro::fig8b(seed));
    });
    let b = b.unwrap();
    let mut t = Table::new(&["accept freq", "served", "share"]);
    for (s, n, f) in &b.rows {
        t.row(vec![format!("{s:.2}"), format!("{n}"), format!("{f:.2}")]);
    }
    t.print();
    assert!(
        b.rows[3].2 > b.rows[0].2,
        "accept-1.0 should out-serve accept-0.25: {:?}",
        b.rows
    );

    let mut c = None;
    bench("fig8c offload 0.25..1.0", 0, 1, 60.0, || {
        c = Some(repro::fig8c(seed));
    });
    let c = c.unwrap();
    let mut t = Table::new(&["offload freq", "SLO", "mean lat (s)"]);
    for (f, slo, lat) in &c.rows {
        t.row(vec![
            format!("{f:.2}"),
            format!("{slo:.3}"),
            format!("{lat:.1}"),
        ]);
    }
    t.print();
    // More offloading helps under pressure, with saturating gains.
    assert!(
        c.rows[3].1 >= c.rows[0].1,
        "offload 1.0 should not be worse than 0.25: {:?}",
        c.rows
    );
    let gain_low = c.rows[1].1 - c.rows[0].1; // 0.25 -> 0.5
    let gain_high = c.rows[3].1 - c.rows[2].1; // 0.75 -> 1.0
    println!(
        "\nsaturation: gain 0.25->0.5 = {gain_low:.3}, gain 0.75->1.0 = {gain_high:.3}"
    );
    println!("shape checks OK (share tracks policy; offload gains saturate)");
}
