//! Bench: fleet-scale event-loop throughput and gossip traffic.
//!
//! Stands up 3-region (us/eu/asia) worlds of n ∈ {50, 200, 500, 1000}
//! nodes from the declarative `topology.fleet` config block — no node is
//! listed individually — and runs each twice: with **delta gossip** (the
//! default protocol: per-peer deltas + compact heartbeat pairs + periodic
//! full-digest anti-entropy) and with the **full-digest baseline**
//! (`anti_entropy_every = 1`, the seed protocol). Reports wall-clock,
//! events/sec, messages/bytes, and the gossip-specific share of traffic,
//! then writes the machine-readable perf trajectory to
//! `BENCH_fleet_scale.json` so future PRs can track regressions.
//!
//! On top of the paired sweep, a **10k tier** runs n = 10,000 delta-only
//! (the full-digest baseline is O(n²) rows per anti-entropy wave — it is
//! precisely what does not scale to 10k, so it has no 10k counterpart) and
//! a **chain-sync section** stands up blockchain-ledger worlds and
//! compares `ChainDelta` suffix shipping against the seed's full
//! `ChainSnapshot` replication, asserting the ≥ 5x byte cut.
//!
//! Asserts the headline numbers: delta gossip strictly beats the baseline
//! on gossip bytes at every size, and by ≥ 10x at 500 nodes. A final
//! section turns the flight recorder on (`observability.enabled`) and
//! asserts tracing at the default sample rate costs < 5% events/sec.
//!
//! `--smoke` (or `FLEET_SCALE_SMOKE=1`) restricts the paired sweep to
//! n = 50, caps the 10k tier's horizon, and runs the chain-sync section
//! at n = 50 — the CI tier.

use std::time::Instant;

use wwwserve::backend::Profile;
use wwwserve::benchlib::{write_json_report, Table};
use wwwserve::config::parse_experiment;
use wwwserve::coordinator::LedgerManager;
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{LedgerMode, NodeSetup, World, WorldConfig};
use wwwserve::topology::{LinkProfile, Topology};
use wwwserve::util::json::Json;
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::NodeId;

const SEED: u64 = 2027;
const HORIZON: f64 = 60.0;
/// Fleet-scale suspicion window (seconds). A 5 s window with 1 s gossip
/// rounds is not a sane failure detector at 1000 nodes — refreshing every
/// entry at every node that often costs Ω(n) bytes per node per round no
/// matter the protocol. 20 rounds is still far below WAN failover SLAs.
const SUSPECT_AFTER: f64 = 20.0;
/// Horizon for the n = 10,000 tier in the full run. Kept below
/// `anti_entropy_every` rounds on purpose: a 10k-node full-digest wave is
/// ~n² rows in flight at one simulated instant (every node ticks at the
/// same time), and the interesting 10k numbers — event-loop throughput
/// and steady-state delta traffic — are reached within one suspicion
/// window. The world itself holds ~n² dense membership entries (~6 GB);
/// see perf/README.md.
const TEN_K_HORIZON: f64 = 20.0;
/// The smoke (CI) cap for the 10k tier: a few gossip rounds prove the
/// world builds, runs, and stays delta-shaped without spending CI minutes
/// on a perf artifact nobody reads from a PR job.
const TEN_K_SMOKE_HORIZON: f64 = 3.0;
/// Chain-sync section horizon (both tiers — the section's cost scales
/// with the payment workload, which is fixed, not with n).
const CHAIN_HORIZON: f64 = 60.0;

fn fleet_config(n: usize, seed: u64, horizon: f64) -> String {
    let per = n / 3;
    let rest = n - 2 * per;
    let group = |region: &str, count: usize, offset: f64| {
        format!(
            r#"{{ "region": "{region}", "count": {count},
                 "node": {{ "profile": {{ "prefill_tok_s": 4000,
                                          "decode_tok_s": 45,
                                          "max_agg_decode_tok_s": 720,
                                          "max_batch": 16 }},
                            "policy": {{ "accept_freq": 1.0,
                                         "latency_penalty": 15.0 }} }},
                 "diurnal": {{ "period": 120, "peak_inter_arrival": 8,
                              "off_inter_arrival": 40, "offset": {offset} }},
                 "lengths": {{ "output_mean": 600, "output_sigma": 0.5 }} }}"#
        )
    };
    format!(
        r#"{{
            "seed": {seed},
            "horizon": {horizon},
            "system": {{ "duel_rate": 0.0 }},
            "topology": {{
                "regions": ["us", "eu", "asia"],
                "intra": {{ "latency": [0.0005, 0.002] }},
                "inter": {{ "latency": [0.040, 0.080], "jitter": 0.005 }},
                "fleet": [ {}, {}, {} ]
            }}
        }}"#,
        group("us", per, 0.0),
        group("eu", per, 40.0),
        group("asia", rest, 80.0),
    )
}

struct RunStats {
    nodes: usize,
    mode: &'static str,
    wall_s: f64,
    events: u64,
    events_per_sec: f64,
    messages: u64,
    bytes: u64,
    gossip_messages: u64,
    gossip_bytes: u64,
    gossip_bytes_per_round: f64,
    chain_sync_messages: u64,
    chain_sync_bytes: u64,
    completed: usize,
    dropped: u64,
    /// Mean fraction of peers each node believes alive at the end of the
    /// run — proves the byte savings are not bought with starved liveness
    /// dissemination (suspicion flapping).
    alive_frac: f64,
}

fn run_fleet(
    n: usize,
    mode: &'static str,
    anti_entropy_every: u64,
    horizon: f64,
) -> RunStats {
    run_fleet_obs(n, mode, anti_entropy_every, horizon, false)
}

fn run_fleet_obs(
    n: usize,
    mode: &'static str,
    anti_entropy_every: u64,
    horizon: f64,
    traced: bool,
) -> RunStats {
    let e = parse_experiment(&fleet_config(n, SEED, horizon))
        .expect("fleet config parses");
    let mut cfg = e.world;
    cfg.gossip.suspect_after = SUSPECT_AFTER;
    cfg.gossip.anti_entropy_every = anti_entropy_every;
    if traced {
        cfg.observability = wwwserve::obs::ObservabilityConfig {
            enabled: true,
            ..Default::default()
        };
    }
    let rounds = e.horizon / cfg.gossip.interval;
    let mut w = World::new(cfg, e.setups);
    // detlint:allow(D002) reason="bench harness measures wall-clock events/sec; the World under test never sees it"
    let t0 = Instant::now();
    w.run_until(e.horizon);
    let wall_s = t0.elapsed().as_secs_f64();
    let now = w.now();
    let alive_frac = (0..n)
        .map(|i| w.node(i).view.alive_peers(now).len() as f64)
        .sum::<f64>()
        / (n as f64 * (n - 1) as f64);
    RunStats {
        nodes: n,
        mode,
        wall_s,
        events: w.events_processed,
        events_per_sec: w.events_processed as f64 / wall_s.max(1e-9),
        messages: w.messages_sent,
        bytes: w.bytes_sent,
        gossip_messages: w.gossip_messages_sent,
        gossip_bytes: w.gossip_bytes_sent,
        gossip_bytes_per_round: w.gossip_bytes_sent as f64 / rounds,
        chain_sync_messages: w.chain_sync_messages_sent,
        chain_sync_bytes: w.chain_sync_bytes_sent,
        completed: w.recorder.user_records().count(),
        dropped: w.messages_dropped,
        alive_frac,
    }
}

fn stats_json(s: &RunStats) -> Json {
    Json::obj(vec![
        ("nodes", Json::num(s.nodes as f64)),
        ("gossip", Json::str(s.mode)),
        ("wall_s", Json::num(s.wall_s)),
        ("events", Json::num(s.events as f64)),
        ("events_per_sec", Json::num(s.events_per_sec)),
        ("messages_sent", Json::num(s.messages as f64)),
        ("bytes_sent", Json::num(s.bytes as f64)),
        ("gossip_messages_sent", Json::num(s.gossip_messages as f64)),
        ("gossip_bytes_sent", Json::num(s.gossip_bytes as f64)),
        ("gossip_bytes_per_round", Json::num(s.gossip_bytes_per_round)),
        (
            "chain_sync_messages_sent",
            Json::num(s.chain_sync_messages as f64),
        ),
        ("chain_sync_bytes_sent", Json::num(s.chain_sync_bytes as f64)),
        ("completed_user_requests", Json::num(s.completed as f64)),
        ("messages_dropped", Json::num(s.dropped as f64)),
        ("alive_frac", Json::num(s.alive_frac)),
    ])
}

struct ChainStats {
    messages: u64,
    bytes: u64,
    chain_len: usize,
}

/// A blockchain-ledger world for the chain-sync comparison: all `n` nodes
/// replicate and vote, but only six (two per region) generate paying
/// requests — proposer concurrency stays at the level the ledger tests
/// exercise while the replica count scales. One non-generator node sits
/// out the first sixth of the run and rejoins, guaranteeing at least one
/// genuine catch-up sync in both protocols.
fn run_chain(n: usize, delta_sync: bool) -> ChainStats {
    assert!(n >= 9, "chain section needs at least 3 nodes per region");
    let per = n / 3;
    let rest = n - 2 * per;
    let topo = Topology::builder()
        .region("us")
        .region("eu")
        .region("asia")
        .default_intra(LinkProfile::new(0.0005, 0.002))
        .default_inter(LinkProfile::new(0.040, 0.080))
        .nodes("us", per)
        .nodes("eu", per)
        .nodes("asia", rest)
        .build();
    let mut cfg = WorldConfig {
        seed: SEED,
        ledger: LedgerMode::Blockchain,
        topology: Some(topo),
        chain_delta_sync: delta_sync,
        ..Default::default()
    };
    cfg.gossip.suspect_after = SUSPECT_AFTER;
    let generators = [0, 1, per, per + 1, 2 * per, 2 * per + 1];
    let late_joiner = n - 1;
    let setups: Vec<NodeSetup> = (0..n)
        .map(|i| {
            let s = NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            );
            if generators.contains(&i) {
                s.with_generator(
                    Generator::new(
                        NodeId(i as u32),
                        vec![Phase::new(0.0, CHAIN_HORIZON, 2.0)],
                    )
                    .with_lengths(LengthDist {
                        output_mean: 120.0,
                        output_sigma: 0.4,
                        ..Default::default()
                    }),
                )
            } else if i == late_joiner {
                s.offline()
            } else {
                s
            }
        })
        .collect();
    let mut w = World::new(cfg, setups);
    w.schedule_join(late_joiner, CHAIN_HORIZON / 6.0);
    w.run_until(CHAIN_HORIZON);
    let chain_len = match w.node(0).ledger() {
        LedgerManager::Chain(r) => r.chain.len(),
        LedgerManager::Shared(_) => panic!("blockchain mode expected"),
    };
    ChainStats {
        messages: w.chain_sync_messages_sent,
        bytes: w.chain_sync_bytes_sent,
        chain_len,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("FLEET_SCALE_SMOKE")
            .is_ok_and(|v| !v.is_empty() && v != "0");
    let sizes: &[usize] =
        if smoke { &[50] } else { &[50, 200, 500, 1000] };
    println!(
        "# fleet_scale — 3-region fleets, delta gossip vs full-digest \
         baseline{}\n",
        if smoke { " (smoke tier)" } else { "" }
    );

    let mut table = Table::new(&[
        "nodes", "gossip", "wall", "events/s", "msgs", "gossip KB/round",
        "completed",
    ]);
    let mut runs: Vec<RunStats> = Vec::new();
    for &n in sizes {
        for (mode, ae) in [("full", 1u64), ("delta", 0u64)] {
            // ae == 0 means "use the default cadence".
            let ae = if ae == 0 {
                wwwserve::gossip::GossipConfig::default().anti_entropy_every
            } else {
                ae
            };
            let s = run_fleet(n, mode, ae, HORIZON);
            table.row(vec![
                format!("{}", s.nodes),
                s.mode.to_string(),
                format!("{:.2}s", s.wall_s),
                format!("{:.0}", s.events_per_sec),
                format!("{}", s.messages),
                format!("{:.1}", s.gossip_bytes_per_round / 1e3),
                format!("{}", s.completed),
            ]);
            runs.push(s);
        }
    }

    // The 10k tier: delta-only — the full-digest baseline at 10k would put
    // O(n²) digest rows in flight per anti-entropy wave, which is the
    // failure mode this PR-series exists to remove, so it has no paired
    // baseline run. Smoke caps the horizon; the full run holds a whole
    // suspicion window. No alive_frac floor is asserted here: the capped
    // horizons end before a full heartbeat refresh cycle completes.
    let ae_default =
        wwwserve::gossip::GossipConfig::default().anti_entropy_every;
    let ten_k_horizon =
        if smoke { TEN_K_SMOKE_HORIZON } else { TEN_K_HORIZON };
    let ten_k = run_fleet(10_000, "delta", ae_default, ten_k_horizon);
    table.row(vec![
        format!("{}", ten_k.nodes),
        format!("{} ({}s)", ten_k.mode, ten_k_horizon),
        format!("{:.2}s", ten_k.wall_s),
        format!("{:.0}", ten_k.events_per_sec),
        format!("{}", ten_k.messages),
        format!("{:.1}", ten_k.gossip_bytes_per_round / 1e3),
        format!("{}", ten_k.completed),
    ]);
    table.print();
    assert!(ten_k.events > 0, "10k world processed no events");
    assert_eq!(
        ten_k.dropped, 0,
        "healthy WAN dropped messages at n=10000"
    );
    println!(
        "n=10000 ({}s horizon): {:.0} events/s, gossip {} bytes, \
         alive frac {:.3}",
        ten_k_horizon, ten_k.events_per_sec, ten_k.gossip_bytes,
        ten_k.alive_frac
    );

    // Invariants the perf trajectory is built on (paired sizes only — the
    // 10k tier has no full-digest counterpart by design).
    let mut headline_ratio = None;
    for pair in runs.chunks(2) {
        let (full, delta) = (&pair[0], &pair[1]);
        assert_eq!(full.nodes, delta.nodes);
        assert!(
            delta.gossip_bytes < full.gossip_bytes,
            "delta gossip must strictly cut gossip bytes at n={}: {} vs {}",
            full.nodes,
            delta.gossip_bytes,
            full.gossip_bytes
        );
        assert!(
            delta.completed > 0 && full.completed > 0,
            "n={}: workload did not run",
            full.nodes
        );
        assert_eq!(
            delta.dropped, 0,
            "healthy WAN dropped messages at n={}",
            delta.nodes
        );
        // The byte cut must not come from starved liveness: delta-mode
        // views stay (nearly) as fresh as the full-digest baseline's.
        assert!(
            delta.alive_frac >= 0.90
                && delta.alive_frac >= full.alive_frac - 0.10,
            "delta gossip starved liveness at n={}: alive {:.3} vs full {:.3}",
            delta.nodes,
            delta.alive_frac,
            full.alive_frac
        );
        let ratio =
            full.gossip_bytes as f64 / delta.gossip_bytes.max(1) as f64;
        println!(
            "n={}: gossip bytes {} -> {} ({ratio:.1}x lower), \
             events/s {:.0} -> {:.0}",
            full.nodes,
            full.gossip_bytes,
            delta.gossip_bytes,
            full.events_per_sec,
            delta.events_per_sec,
        );
        if full.nodes == 500 {
            headline_ratio = Some(ratio);
            assert!(
                ratio >= 10.0,
                "delta gossip must cut gossip bytes >= 10x at 500 nodes, \
                 got {ratio:.1}x"
            );
        }
    }

    // Chain-sync section: blockchain-ledger worlds, full-replica
    // `ChainSnapshot` shipping (the seed protocol) vs anchored `ChainDelta`
    // suffixes. The counters cover the state-shipping responses only —
    // the constant-rate 48-byte `ChainRequest` probes cost the same under
    // either protocol (see `World::chain_sync_bytes_sent`).
    let chain_n = if smoke { 50 } else { 500 };
    let chain_full = run_chain(chain_n, false);
    let chain_delta = run_chain(chain_n, true);
    let chain_ratio =
        chain_full.bytes as f64 / chain_delta.bytes.max(1) as f64;
    println!(
        "\nchain sync at n={chain_n}: full-snapshot {} bytes \
         ({} msgs, {} blocks) -> delta {} bytes ({} msgs, {} blocks), \
         {chain_ratio:.1}x lower",
        chain_full.bytes,
        chain_full.messages,
        chain_full.chain_len,
        chain_delta.bytes,
        chain_delta.messages,
        chain_delta.chain_len,
    );
    for (mode, s) in [("full", &chain_full), ("delta", &chain_delta)] {
        assert!(
            s.chain_len > 10,
            "chain-sync section ({mode}): chain barely grew ({} blocks)",
            s.chain_len
        );
        assert!(
            s.messages > 0,
            "chain-sync section ({mode}): no sync responses at all"
        );
    }
    assert!(
        chain_ratio >= 5.0,
        "delta chain sync must cut shipping bytes >= 5x at n={chain_n}, \
         got {chain_ratio:.1}x ({} vs {})",
        chain_full.bytes,
        chain_delta.bytes
    );

    // Tracing overhead: the flight recorder + metrics registry at the
    // default sample rate must cost < 5% events/sec. Interleaved
    // best-of-3 pairs at the CI size keep wall-clock noise out of the
    // verdict; identical event counts re-prove replay neutrality at
    // bench scale.
    const OVERHEAD_N: usize = 50;
    let ae = wwwserve::gossip::GossipConfig::default().anti_entropy_every;
    let mut untraced_best = 0f64;
    let mut traced_best = 0f64;
    let mut events_pair = (0u64, 0u64);
    for _ in 0..3 {
        let u = run_fleet_obs(OVERHEAD_N, "delta", ae, HORIZON, false);
        let t = run_fleet_obs(OVERHEAD_N, "delta", ae, HORIZON, true);
        untraced_best = untraced_best.max(u.events_per_sec);
        traced_best = traced_best.max(t.events_per_sec);
        events_pair = (u.events, t.events);
    }
    assert_eq!(
        events_pair.0, events_pair.1,
        "tracing changed the event stream"
    );
    let overhead = 1.0 - traced_best / untraced_best;
    println!(
        "\ntracing overhead at n={OVERHEAD_N}: {:.0} -> {:.0} events/s \
         ({:+.1}%)",
        untraced_best,
        traced_best,
        -overhead * 100.0
    );
    assert!(
        traced_best >= untraced_best * 0.95,
        "tracing overhead exceeds 5%: {untraced_best:.0} -> \
         {traced_best:.0} events/s ({:.1}%)",
        overhead * 100.0
    );

    runs.push(ten_k);
    let mut report = vec![
        ("bench", Json::str("fleet_scale")),
        ("seed", Json::num(SEED as f64)),
        ("horizon_s", Json::num(HORIZON)),
        ("ten_k_horizon_s", Json::num(ten_k_horizon)),
        ("suspect_after_s", Json::num(SUSPECT_AFTER)),
        ("smoke", Json::Bool(smoke)),
        (
            "runs",
            Json::Arr(runs.iter().map(stats_json).collect()),
        ),
    ];
    if let Some(r) = headline_ratio {
        report.push(("n500_gossip_bytes_ratio", Json::num(r)));
    }
    report.push((
        "chain_sync",
        Json::obj(vec![
            ("nodes", Json::num(chain_n as f64)),
            ("horizon_s", Json::num(CHAIN_HORIZON)),
            ("full_messages", Json::num(chain_full.messages as f64)),
            ("full_bytes", Json::num(chain_full.bytes as f64)),
            ("full_chain_len", Json::num(chain_full.chain_len as f64)),
            ("delta_messages", Json::num(chain_delta.messages as f64)),
            ("delta_bytes", Json::num(chain_delta.bytes as f64)),
            ("delta_chain_len", Json::num(chain_delta.chain_len as f64)),
            ("bytes_ratio", Json::num(chain_ratio)),
        ]),
    ));
    report.push((
        "tracing_overhead",
        Json::obj(vec![
            ("nodes", Json::num(OVERHEAD_N as f64)),
            ("untraced_events_per_sec", Json::num(untraced_best)),
            ("traced_events_per_sec", Json::num(traced_best)),
            ("overhead_frac", Json::num(overhead)),
        ]),
    ));
    let path = "BENCH_fleet_scale.json";
    write_json_report(path, &Json::obj(report)).expect("write bench json");
    println!("\nwrote {path}");
}
