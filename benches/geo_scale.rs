//! Bench: geo-distributed 3-region WAN scenario (us / eu / asia).
//!
//! Three parts:
//!
//! 1. **Backward compatibility** — a flat-latency world and an explicit
//!    single-region topology must replay bit-identically (the seed benches
//!    depend on the flat model's RNG stream).
//! 2. **Follow-the-sun** — per-region diurnal load with offset peaks;
//!    region-blind vs locality-aware dispatch compared on per-region SLO
//!    attainment and p99 latency.
//! 3. **Partition tolerance** — the same world with a trans-continental
//!    us<->asia partition at t=250 healed at t=450. Locality-aware dispatch
//!    wastes fewer probes on the dead ocean link, so the peaking regions
//!    keep more of their SLO. The partitioned run must also replay
//!    deterministically under a fixed seed.

use wwwserve::backend::Profile;
use wwwserve::benchlib::{bench, Table};
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::topology::{three_region_wan, LinkChange, Topology};
use wwwserve::types::CREDIT;
use wwwserve::workload::{diurnal_phases, Generator, LengthDist, Phase};
use wwwserve::NodeId;

const HORIZON: f64 = 750.0;
const DRAIN: f64 = 3000.0;
const PERIOD: f64 = 300.0;
const SEED: u64 = 2026;

fn lengths() -> LengthDist {
    LengthDist { output_mean: 900.0, output_sigma: 0.5, ..Default::default() }
}

/// One region: a small requester node carrying the diurnal user load plus
/// two larger servers. Node order matches `three_region_wan` placement.
fn geo_setups(latency_penalty: f64) -> Vec<NodeSetup> {
    let mut setups = Vec::new();
    for region in 0..3 {
        // Follow the sun: each region's rush hour starts a third of a
        // cycle after the previous region's.
        let offset = region as f64 * (PERIOD / 3.0);
        let requester_id = NodeId((setups.len()) as u32);
        setups.push(
            NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    stake: 2 * CREDIT,
                    target_utilization: 0.5,
                    offload_freq: 1.0,
                    accept_freq: 0.0,
                    latency_penalty,
                    ..Default::default()
                },
            )
            .with_generator(
                Generator::new(
                    requester_id,
                    diurnal_phases(HORIZON, PERIOD, 2.5, 25.0, offset),
                )
                .with_lengths(lengths()),
            ),
        );
        for _ in 0..2 {
            setups.push(NodeSetup::new(
                Profile::test(45.0, 24),
                NodePolicy {
                    stake: 20 * CREDIT,
                    accept_freq: 1.0,
                    latency_penalty,
                    ..Default::default()
                },
            ));
        }
    }
    setups
}

fn geo_topology(partition: bool) -> Topology {
    let mut b = three_region_wan(3);
    if partition {
        b = b
            .event("us", "asia", 250.0, LinkChange::Partition)
            .event("us", "asia", 450.0, LinkChange::Heal);
    }
    b.build()
}

struct GeoRun {
    /// (region, slo, p99, completed)
    regions: Vec<(String, f64, f64, usize)>,
    overall_slo: f64,
    dropped: u64,
    fingerprint: (usize, u64, u64, Vec<u64>),
}

fn run_geo(latency_penalty: f64, partition: bool) -> GeoRun {
    let mut cfg = WorldConfig {
        seed: SEED,
        topology: Some(geo_topology(partition)),
        ..Default::default()
    };
    cfg.system.duel_rate = 0.0; // isolate dispatch effects
    let mut w = World::new(cfg, geo_setups(latency_penalty));
    w.run_until(HORIZON + DRAIN);
    GeoRun {
        regions: w.region_summary(),
        overall_slo: w.recorder.slo_attainment(),
        dropped: w.messages_dropped,
        fingerprint: (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_dropped,
            w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect(),
        ),
    }
}

/// Part 1: the flat network and an explicit one-region topology replay the
/// same simulation, message for message.
fn backward_compat_check() {
    let fingerprint = |topology: Option<Topology>| {
        let mut cfg = WorldConfig { seed: 7, topology, ..Default::default() };
        cfg.system.duel_rate = 0.1;
        let setups: Vec<NodeSetup> = (0..4)
            .map(|i| {
                NodeSetup::new(
                    Profile::test(40.0, 16),
                    NodePolicy { accept_freq: 1.0, ..Default::default() },
                )
                .with_generator(
                    Generator::new(
                        NodeId(i as u32),
                        vec![Phase::new(0.0, 300.0, 4.0)],
                    )
                    .with_lengths(lengths()),
                )
            })
            .collect();
        let mut w = World::new(cfg, setups);
        w.run_until(1200.0);
        (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_sent,
            w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect::<Vec<_>>(),
        )
    };
    let flat = fingerprint(None);
    let single = fingerprint(Some(Topology::single_region((0.02, 0.08))));
    assert_eq!(
        flat, single,
        "single-region topology diverged from the flat-latency model"
    );
    println!(
        "backward-compat: flat == single-region topology \
         ({} records, {} msgs) ✓\n",
        flat.0, flat.2
    );
}

fn print_comparison(title: &str, blind: &GeoRun, aware: &GeoRun) {
    println!("## {title}\n");
    let mut t = Table::new(&[
        "Region", "SLO (blind)", "SLO (aware)", "p99 (blind)", "p99 (aware)",
        "reqs",
    ]);
    for (b, a) in blind.regions.iter().zip(&aware.regions) {
        t.row(vec![
            b.0.clone(),
            format!("{:.3}", b.1),
            format!("{:.3}", a.1),
            format!("{:.1}", b.2),
            format!("{:.1}", a.2),
            format!("{}", b.3),
        ]);
    }
    t.print();
    println!(
        "overall SLO: blind {:.3} vs aware {:.3}; dropped msgs: \
         blind {} aware {}\n",
        blind.overall_slo, aware.overall_slo, blind.dropped, aware.dropped
    );
}

fn main() {
    println!("# geo_scale — 3-region WAN, follow-the-sun + partition\n");

    backward_compat_check();

    // Part 2: follow-the-sun, healthy WAN.
    let mut blind = None;
    bench("geo/follow-the-sun blind", 0, 3, 60.0, || {
        blind = Some(run_geo(0.0, false));
    });
    let mut aware = None;
    bench("geo/follow-the-sun aware(p=50)", 0, 3, 60.0, || {
        aware = Some(run_geo(50.0, false));
    });
    let (blind, aware) = (blind.unwrap(), aware.unwrap());
    print_comparison("Follow-the-sun (healthy WAN)", &blind, &aware);
    assert!(
        blind.dropped == 0 && aware.dropped == 0,
        "healthy WAN dropped messages"
    );

    // Part 3: trans-continental partition (us<->asia down 250s..450s).
    let blind_p = run_geo(0.0, true);
    let aware_p = run_geo(50.0, true);
    print_comparison("us<->asia partition at 250s, heal at 450s", &blind_p, &aware_p);
    assert!(blind_p.dropped > 0, "partition had no effect");

    // Locality-aware dispatch keeps more SLO through the partition in the
    // regions whose rush hour overlaps it (us and asia peaks sit inside
    // the 250-450s window).
    let slo_of = |r: &GeoRun, name: &str| {
        r.regions.iter().find(|x| x.0 == name).expect("region").1
    };
    let blind_affected = (slo_of(&blind_p, "us") + slo_of(&blind_p, "asia")) / 2.0;
    let aware_affected = (slo_of(&aware_p, "us") + slo_of(&aware_p, "asia")) / 2.0;
    println!(
        "partition-affected regions (us+asia mean SLO): blind {blind_affected:.3} \
         vs aware {aware_affected:.3}"
    );
    assert!(
        aware_affected + 0.02 >= blind_affected,
        "locality-aware dispatch lost SLO vs region-blind under partition: \
         aware {aware_affected:.3} < blind {blind_affected:.3}"
    );

    // Determinism: the partitioned world replays exactly under its seed.
    let replay = run_geo(0.0, true);
    assert_eq!(
        blind_p.fingerprint, replay.fingerprint,
        "partition/heal run is not deterministic"
    );
    println!("\npartition/heal replay deterministic ✓");
}
