//! Bench: geo-distributed 3-region WAN scenario (us / eu / asia).
//!
//! Four parts:
//!
//! 1. **Backward compatibility** — a flat-latency world and an explicit
//!    single-region topology must replay bit-identically (the seed benches
//!    depend on the flat model's RNG stream).
//! 2. **Follow-the-sun** — per-region diurnal load with offset peaks;
//!    region-blind vs locality-aware dispatch compared on per-region SLO
//!    attainment and p99 latency.
//! 3. **Partition tolerance** — the same world with a trans-continental
//!    us<->asia partition at t=250 healed at t=450. Locality-aware dispatch
//!    wastes fewer probes on the dead ocean link, so the peaking regions
//!    keep more of their SLO. The partitioned run must also replay
//!    deterministically under a fixed seed.
//! 4. **Reroute** — steady always-delegating requesters under the same
//!    us<->asia partition, with gossip liveness aging pinned off: live
//!    latency estimation must shed the partitioned region within
//!    K = 20 gossip intervals and re-admit it after the heal, while the
//!    static expected-latency-matrix baseline
//!    (`latency_estimation.enabled = false`) keeps delegating into the
//!    dead link for the whole outage. Asserted, and written to
//!    `BENCH_geo_scale.json` so the SLO/latency numbers join the per-PR
//!    perf trajectory. The live run is flight-recorded
//!    (`observability.enabled`) and exported as `TRACE_geo_scale.json` —
//!    a Chrome trace-event file of every request's hop chain through the
//!    partition, viewable in Perfetto; CI uploads it as an artifact.
//! 5. **Mixed-policy fleet** — one scenario, three provider personalities
//!    (`default` / `greedy_local` / `selective`) plus `requester_only`
//!    consumers, all selected via the declarative `topology.fleet`
//!    `policy` key; reports per-policy-group SLO attainment and served
//!    counts (asserted structural + behavioural invariants).
//! 6. **Elastic replica placement** — each region declares one committed
//!    server plus standby replicas behind a `capacity` block; the
//!    reactive controller rides the follow-the-sun diurnal wave,
//!    spawning standbys into each region's rush hour and retiring them
//!    after. Asserted: peak-window SLO attainment within 5 points of
//!    static peak provisioning (the same fleet held online for the whole
//!    run) at ≥ 25% fewer server node-hours over the diurnal cycle — and
//!    a `capacity: {policy: "static"}` declaration replays the
//!    no-capacity-block trace fingerprint exactly.
//! 7. **Streaming sessions** — the same follow-the-sun fleet driving
//!    multi-turn chat sessions (TTFT budgets per turn). KV-affine
//!    dispatch (`streaming.affinity_bonus = 1`) pins a session's turns to
//!    the executor already holding its KV cache; the affinity-blind
//!    baseline (`= 0`) re-draws every turn and ships the session cache
//!    across the WAN each time it moves (the `KvTransfer` wire size rides
//!    the links' finite bandwidth). Asserted: affinity-aware TTFT SLO
//!    attainment ≥ blind while moving ≥ 3x fewer KV bytes.
//!
//! `--smoke` (or `GEO_SCALE_SMOKE=1`) runs single-iteration timings — the
//! CI tier.

use wwwserve::backend::Profile;
use wwwserve::benchlib::{bench, write_json_report, Table};
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::streaming::StreamingConfig;
use wwwserve::topology::{three_region_wan, LinkChange, Topology};
use wwwserve::types::CREDIT;
use wwwserve::util::json::Json;
use wwwserve::workload::{
    diurnal_phases, Generator, LengthDist, Phase, SessionProfile,
};
use wwwserve::NodeId;

const HORIZON: f64 = 750.0;
const DRAIN: f64 = 3000.0;
const PERIOD: f64 = 300.0;
const SEED: u64 = 2026;

fn lengths() -> LengthDist {
    LengthDist { output_mean: 900.0, output_sigma: 0.5, ..Default::default() }
}

/// One region: a small requester node carrying the diurnal user load plus
/// two larger servers. Node order matches `three_region_wan` placement.
fn geo_setups(latency_penalty: f64) -> Vec<NodeSetup> {
    let mut setups = Vec::new();
    for region in 0..3 {
        // Follow the sun: each region's rush hour starts a third of a
        // cycle after the previous region's.
        let offset = region as f64 * (PERIOD / 3.0);
        let requester_id = NodeId((setups.len()) as u32);
        setups.push(
            NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    stake: 2 * CREDIT,
                    target_utilization: 0.5,
                    offload_freq: 1.0,
                    accept_freq: 0.0,
                    latency_penalty,
                    ..Default::default()
                },
            )
            .with_generator(
                Generator::new(
                    requester_id,
                    diurnal_phases(HORIZON, PERIOD, 2.5, 25.0, offset),
                )
                .with_lengths(lengths()),
            ),
        );
        for _ in 0..2 {
            setups.push(NodeSetup::new(
                Profile::test(45.0, 24),
                NodePolicy {
                    stake: 20 * CREDIT,
                    accept_freq: 1.0,
                    latency_penalty,
                    ..Default::default()
                },
            ));
        }
    }
    setups
}

fn geo_topology(partition: bool) -> Topology {
    let mut b = three_region_wan(3);
    if partition {
        b = b
            .event("us", "asia", 250.0, LinkChange::Partition)
            .event("us", "asia", 450.0, LinkChange::Heal);
    }
    b.build()
}

struct GeoRun {
    /// (region, slo, p99, completed)
    regions: Vec<(String, f64, f64, usize)>,
    overall_slo: f64,
    dropped: u64,
    fingerprint: (usize, u64, u64, Vec<u64>),
}

fn run_geo(latency_penalty: f64, partition: bool) -> GeoRun {
    let mut cfg = WorldConfig {
        seed: SEED,
        topology: Some(geo_topology(partition)),
        ..Default::default()
    };
    cfg.system.duel_rate = 0.0; // isolate dispatch effects
    let mut w = World::new(cfg, geo_setups(latency_penalty));
    w.run_until(HORIZON + DRAIN);
    GeoRun {
        regions: w.region_summary(),
        overall_slo: w.recorder.slo_attainment(),
        dropped: w.messages_dropped,
        fingerprint: (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_dropped,
            w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect(),
        ),
    }
}

/// Part 1: the flat network and an explicit one-region topology replay the
/// same simulation, message for message.
fn backward_compat_check() {
    let fingerprint = |topology: Option<Topology>| {
        let mut cfg = WorldConfig { seed: 7, topology, ..Default::default() };
        cfg.system.duel_rate = 0.1;
        let setups: Vec<NodeSetup> = (0..4)
            .map(|i| {
                NodeSetup::new(
                    Profile::test(40.0, 16),
                    NodePolicy { accept_freq: 1.0, ..Default::default() },
                )
                .with_generator(
                    Generator::new(
                        NodeId(i as u32),
                        vec![Phase::new(0.0, 300.0, 4.0)],
                    )
                    .with_lengths(lengths()),
                )
            })
            .collect();
        let mut w = World::new(cfg, setups);
        w.run_until(1200.0);
        (
            w.recorder.len(),
            (w.recorder.mean_latency() * 1e9) as u64,
            w.messages_sent,
            w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect::<Vec<_>>(),
        )
    };
    let flat = fingerprint(None);
    let single = fingerprint(Some(Topology::single_region((0.02, 0.08))));
    assert_eq!(
        flat, single,
        "single-region topology diverged from the flat-latency model"
    );
    println!(
        "backward-compat: flat == single-region topology \
         ({} records, {} msgs) ✓\n",
        flat.0, flat.2
    );
}

// ---------------------------------------------------------------------------
// Part 4: live-estimation reroute under partition
// ---------------------------------------------------------------------------

const T_PART: f64 = 250.0;
/// K = 20 one-second gossip intervals of convergence grace after the
/// partition before delegation into the dead region must be ~0.
const T_CONVERGED: f64 = 270.0;
const T_HEAL: f64 = 450.0;
const T_READMIT: f64 = 510.0;

struct RerouteRun {
    /// us<->asia Probe+Delegate sends: before the partition, in the
    /// post-convergence outage window, and after heal + re-admission grace.
    pre: u64,
    part: u64,
    recovered: u64,
    overall_slo: f64,
    regions: Vec<(String, f64, f64, usize)>,
}

/// Steady always-delegating requesters (one per region, two servers each);
/// `suspect_after` pinned huge so gossip liveness aging never sheds the far
/// side — whatever rerouting happens is the latency estimator's doing.
fn run_reroute(live: bool) -> RerouteRun {
    let topo = three_region_wan(3)
        .event("us", "asia", T_PART, LinkChange::Partition)
        .event("us", "asia", T_HEAL, LinkChange::Heal)
        .build();
    let mut cfg = WorldConfig {
        seed: SEED,
        topology: Some(topo),
        ..Default::default()
    };
    cfg.system.duel_rate = 0.0;
    cfg.gossip.suspect_after = 1e4;
    cfg.latency_estimation.enabled = live;
    // Penalized estimates must not decay back to the prior mid-outage.
    cfg.latency_estimation.decay_after = 600.0;
    // Flight-record the live run: the reroute scenario (partition, probe
    // timeouts, cross-region fallbacks, heal) is the reference trace the
    // CI geo-smoke job exports for chrome://tracing / Perfetto triage.
    // Purely observational — the frozen baseline run stays untraced and
    // the comparison below is unaffected either way.
    if live {
        cfg.observability = wwwserve::obs::ObservabilityConfig {
            enabled: true,
            ring_capacity: 16384,
            ..Default::default()
        };
    }

    let mut setups = Vec::new();
    for region in 0..3 {
        let requester_id = NodeId((region * 3) as u32);
        setups.push(
            NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    latency_penalty: 50.0,
                    ..NodePolicy::requester_only()
                },
            )
            .with_generator(
                Generator::new(
                    requester_id,
                    vec![Phase::new(0.0, HORIZON, 1.0)],
                )
                .with_lengths(lengths()),
            ),
        );
        for _ in 0..2 {
            setups.push(NodeSetup::new(
                Profile::test(45.0, 24),
                NodePolicy {
                    stake: 20 * CREDIT,
                    accept_freq: 1.0,
                    latency_penalty: 50.0,
                    ..Default::default()
                },
            ));
        }
    }

    let mut w = World::new(cfg, setups);
    let cross = |w: &World| w.dispatch_sends(0, 2) + w.dispatch_sends(2, 0);
    w.run_until(T_PART);
    let pre = cross(&w);
    w.run_until(T_CONVERGED);
    let at_converged = cross(&w);
    w.run_until(T_HEAL);
    let part = cross(&w) - at_converged;
    w.run_until(T_READMIT);
    let at_readmit = cross(&w);
    w.run_until(HORIZON + 200.0);
    let recovered = cross(&w) - at_readmit;
    if live {
        let path = "TRACE_geo_scale.json";
        let trees = w.span_trees();
        assert!(!trees.is_empty(), "reroute run recorded no traces");
        w.write_trace(path).expect("write trace json");
        println!("wrote {path} ({} span trees)", trees.len());
    }
    RerouteRun {
        pre,
        part,
        recovered,
        overall_slo: w.recorder.slo_attainment(),
        regions: w.region_summary(),
    }
}

// ---------------------------------------------------------------------------
// Part 5: mixed-policy fleet (heterogeneous participation populations)
// ---------------------------------------------------------------------------

/// Per-fleet-group outcome of the mixed-policy run. Requester groups carry
/// the user-facing SLO numbers; provider groups carry the served counts.
struct GroupStat {
    label: String,
    policy: &'static str,
    nodes: usize,
    completed: usize,
    slo: f64,
    p99: f64,
    delegated_in: u64,
    delegated_out: u64,
    served_local: u64,
}

/// One scenario, three provider personalities: us servers run the classic
/// `default` policy, eu servers are `greedy_local` sinks (serve own users
/// locally, hoover up delegations), asia servers are `selective`
/// cherry-pickers (short jobs only, strict headroom) — all selected
/// declaratively via the `topology.fleet` `policy` key, one requester
/// population per region driving load into the market.
fn mixed_policy_config() -> String {
    let requester = |region: &str| {
        format!(
            r#"{{ "region": "{region}", "count": 1,
                 "policy": "requester_only",
                 "name": "{region}-requesters",
                 "node": {{
                   "profile": {{ "prefill_tok_s": 2000, "decode_tok_s": 40,
                                 "max_agg_decode_tok_s": 160,
                                 "max_batch": 4 }},
                   "policy": {{ "latency_penalty": 15.0 }} }},
                 "schedule": [ {{ "from": 0, "to": {HORIZON},
                                  "inter_arrival": 2.0 }} ],
                 "lengths": {{ "output_mean": 500,
                               "output_sigma": 0.5 }} }}"#
        )
    };
    let servers = |region: &str, policy: &str, own_load: bool| {
        // Provider groups optionally carry a light user load of their own
        // — the greedy_local group gets one so "serves its own users
        // locally, never offloads" is observable, not vacuous.
        let load = if own_load {
            format!(
                r#""schedule": [ {{ "from": 0, "to": {HORIZON},
                                   "inter_arrival": 10.0 }} ],
                   "lengths": {{ "output_mean": 400,
                                 "output_sigma": 0.5 }},"#
            )
        } else {
            String::new()
        };
        format!(
            r#"{{ "region": "{region}", "count": 2, "policy": "{policy}",
                 "name": "{region}-{policy}", {load}
                 "node": {{
                   "profile": {{ "prefill_tok_s": 4000, "decode_tok_s": 45,
                                 "max_agg_decode_tok_s": 1080,
                                 "max_batch": 24 }},
                   "policy": {{ "stake": 20, "accept_freq": 1.0,
                                "latency_penalty": 15.0 }} }} }}"#
        )
    };
    format!(
        r#"{{
            "seed": {SEED},
            "horizon": {HORIZON},
            "system": {{ "duel_rate": 0.0 }},
            "topology": {{
                "regions": ["us", "eu", "asia"],
                "intra": {{ "latency": [0.002, 0.010] }},
                "inter": {{ "latency": [0.040, 0.080], "jitter": 0.005 }},
                "fleet": [ {}, {}, {}, {}, {}, {} ]
            }}
        }}"#,
        requester("us"),
        servers("us", "default", false),
        requester("eu"),
        servers("eu", "greedy_local", true),
        requester("asia"),
        servers("asia", "selective", false),
    )
}

fn run_mixed_policy() -> (Vec<GroupStat>, f64) {
    let e = wwwserve::config::parse_experiment(&mixed_policy_config())
        .expect("mixed-policy config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON + DRAIN);

    // Group nodes by fleet label (declaration order preserved).
    let mut labels: Vec<String> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    for (i, s) in e.setups.iter().enumerate() {
        let label = s.group.clone().unwrap_or_else(|| "ungrouped".into());
        match labels.iter().position(|l| *l == label) {
            Some(g) => members[g].push(i),
            None => {
                labels.push(label);
                members.push(vec![i]);
            }
        }
    }
    let stats = labels
        .iter()
        .zip(&members)
        .map(|(label, nodes)| {
            let mut lat: Vec<f64> = Vec::new();
            let mut met = 0usize;
            for rec in w.recorder.all().iter().filter(|r| !r.synthetic) {
                let origin = rec.origin.0 as usize;
                if nodes.contains(&origin) {
                    met += rec.slo_met() as usize;
                    lat.push(rec.latency());
                }
            }
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let n = lat.len();
            let p99 = if n == 0 {
                0.0
            } else {
                lat[((n - 1) as f64 * 0.99).round() as usize]
            };
            GroupStat {
                label: label.clone(),
                policy: w.node(nodes[0]).participation().name(),
                nodes: nodes.len(),
                completed: n,
                slo: if n == 0 { 0.0 } else { met as f64 / n as f64 },
                p99,
                delegated_in: nodes
                    .iter()
                    .map(|i| w.node(*i).stats.delegated_in)
                    .sum(),
                delegated_out: nodes
                    .iter()
                    .map(|i| w.node(*i).stats.delegated_out)
                    .sum(),
                served_local: nodes
                    .iter()
                    .map(|i| w.node(*i).stats.served_local)
                    .sum(),
            }
        })
        .collect();
    (stats, w.recorder.slo_attainment())
}

fn mixed_policy_part() -> Json {
    let (groups, overall) = run_mixed_policy();
    println!("\n## Mixed-policy fleet (per-policy-group SLO)\n");
    let mut t = Table::new(&[
        "group", "policy", "nodes", "completed", "SLO", "p99",
        "delegated-in", "delegated-out", "served-local",
    ]);
    for g in &groups {
        t.row(vec![
            g.label.clone(),
            g.policy.to_string(),
            format!("{}", g.nodes),
            format!("{}", g.completed),
            format!("{:.3}", g.slo),
            format!("{:.1}", g.p99),
            format!("{}", g.delegated_in),
            format!("{}", g.delegated_out),
            format!("{}", g.served_local),
        ]);
    }
    t.print();
    println!("overall SLO: {overall:.3}");

    // Structural + behavioural invariants of the heterogeneous fleet.
    let by_policy = |p: &str| -> Vec<&GroupStat> {
        groups.iter().filter(|g| g.policy == p).collect()
    };
    let distinct: std::collections::BTreeSet<&str> =
        groups.iter().map(|g| g.policy).collect();
    assert!(
        distinct.len() >= 3,
        "mixed fleet must mix policies: {distinct:?}"
    );
    for g in by_policy("requester_only") {
        assert!(
            g.completed > 0,
            "requester group {} completed nothing",
            g.label
        );
        assert_eq!(
            g.delegated_in, 0,
            "requester group {} served delegated work",
            g.label
        );
    }
    let default_served: u64 =
        by_policy("default").iter().map(|g| g.delegated_in).sum();
    let greedy_served: u64 =
        by_policy("greedy_local").iter().map(|g| g.delegated_in).sum();
    assert!(default_served > 0, "default servers never served");
    assert!(greedy_served > 0, "greedy_local servers never served");
    for g in by_policy("greedy_local") {
        // The greedy group carries its own user load: it must complete it
        // strictly locally — zero successful offloads out of the group.
        assert!(
            g.completed > 0 && g.served_local > 0,
            "greedy_local group {} ran no own load",
            g.label
        );
        assert_eq!(
            g.delegated_out, 0,
            "greedy_local group {} offloaded its own users",
            g.label
        );
    }
    assert!(overall > 0.0, "mixed fleet met no SLOs at all");

    Json::obj(vec![
        ("overall_slo", Json::num(overall)),
        (
            "groups",
            Json::Arr(
                groups
                    .iter()
                    .map(|g| {
                        Json::obj(vec![
                            ("group", Json::str(g.label.clone())),
                            ("policy", Json::str(g.policy)),
                            ("nodes", Json::num(g.nodes as f64)),
                            ("completed", Json::num(g.completed as f64)),
                            ("slo", Json::num(g.slo)),
                            ("p99_s", Json::num(g.p99)),
                            (
                                "delegated_in",
                                Json::num(g.delegated_in as f64),
                            ),
                            (
                                "delegated_out",
                                Json::num(g.delegated_out as f64),
                            ),
                            (
                                "served_local",
                                Json::num(g.served_local as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// ---------------------------------------------------------------------------
// Part 6: elastic per-region replica placement against the diurnal wave
// ---------------------------------------------------------------------------

/// Server-fleet provisioning for the part-6 scenario. Every mode declares
/// the same per-region commitment of `1 + ELASTIC_STANDBY` replicas; they
/// differ in how much of it is held online.
#[derive(Clone, Copy, PartialEq)]
enum Provisioning {
    /// The whole commitment online for the whole run (no capacity block).
    StaticPeak,
    /// 1 committed + standbys behind a reactive `capacity` block.
    Elastic,
    /// Committed servers only, with an inert `capacity: {policy:"static"}`
    /// declaration (replay-equivalence check).
    StaticBlock,
    /// Committed servers only, no capacity block at all (the fingerprint
    /// baseline for `StaticBlock`).
    NoBlock,
}

const ELASTIC_STANDBY: usize = 2;

/// One requester + a server group per region; requesters ride offset
/// diurnal waves (each region's rush hour a third of a cycle apart) with
/// short outputs, so the 30 s SLO floor leaves slack for WAN detours but
/// not for sustained undersupply.
fn elastic_config(mode: Provisioning) -> String {
    let server_count = match mode {
        Provisioning::StaticPeak => 1 + ELASTIC_STANDBY,
        _ => 1,
    };
    let capacity = match mode {
        Provisioning::Elastic => format!(
            r#", "capacity": {{ "policy": "reactive",
                 "standby": {ELASTIC_STANDBY},
                 "scale_up_util": 0.75, "scale_down_util": 0.25,
                 "slo_target": 0.9, "cooldown": 6, "eval_every": 2,
                 "online_cost_per_hour": 1.0,
                 "standby_cost_per_hour": 0.1 }}"#
        ),
        Provisioning::StaticBlock => {
            r#", "capacity": { "policy": "static" }"#.to_string()
        }
        _ => String::new(),
    };
    let mut groups = Vec::new();
    for (region, offset) in [("us", 0.0), ("eu", 100.0), ("asia", 200.0)] {
        groups.push(format!(
            r#"{{ "region": "{region}", "count": 1,
                 "policy": "requester_only", "name": "req-{region}",
                 "node": {{
                   "profile": {{ "prefill_tok_s": 2000, "decode_tok_s": 40,
                                 "max_agg_decode_tok_s": 160,
                                 "max_batch": 4 }},
                   "policy": {{ "latency_penalty": 50.0 }} }},
                 "diurnal": {{ "period": {PERIOD}, "peak_inter_arrival": 2.5,
                               "off_inter_arrival": 25,
                               "offset": {offset} }},
                 "lengths": {{ "output_mean": 300,
                               "output_sigma": 0.5 }} }}"#
        ));
        groups.push(format!(
            r#"{{ "region": "{region}", "count": {server_count},
                 "name": "srv-{region}",
                 "node": {{
                   "profile": {{ "prefill_tok_s": 4000, "decode_tok_s": 40,
                                 "max_agg_decode_tok_s": 80,
                                 "max_batch": 2 }},
                   "policy": {{ "stake": 20, "accept_freq": 1.0,
                                "latency_penalty": 50.0 }} }}{capacity} }}"#
        ));
    }
    format!(
        r#"{{
            "seed": {SEED},
            "horizon": {HORIZON},
            "system": {{ "duel_rate": 0.0 }},
            "topology": {{
                "regions": ["us", "eu", "asia"],
                "intra": {{ "latency": [0.002, 0.010] }},
                "inter": {{ "latency": [0.040, 0.080], "jitter": 0.005 }},
                "fleet": [ {} ]
            }}
        }}"#,
        groups.join(", ")
    )
}

struct ElasticRun {
    /// SLO attainment of requests submitted inside their origin region's
    /// diurnal peak windows.
    peak_slo: f64,
    overall_slo: f64,
    /// Server node-hours over the diurnal cycle ([0, HORIZON]).
    server_node_hours: f64,
    scale_events: u64,
    credits_charged: f64,
    /// Per-standby online seconds (empty outside Elastic mode).
    standby_online_secs: Vec<f64>,
}

/// Diurnal peak membership: requester of region r has offset r * 100 and
/// alternating 150 s peak / off windows.
fn in_peak(t: f64, region: usize) -> bool {
    let offset = region as f64 * (PERIOD / 3.0);
    (t - offset).rem_euclid(PERIOD) < PERIOD / 2.0
}

fn run_elastic(mode: Provisioning) -> ElasticRun {
    let e = wwwserve::config::parse_experiment(&elastic_config(mode))
        .expect("elastic config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    let server_idx: Vec<usize> = e
        .setups
        .iter()
        .enumerate()
        .filter(|(_, s)| {
            s.group.as_deref().is_some_and(|g| g.starts_with("srv-"))
        })
        .map(|(i, _)| i)
        .collect();
    // Node-hours are judged over the diurnal cycle itself; the drain
    // phase afterwards only flushes in-flight completions for the SLO
    // numbers.
    w.run_until(HORIZON);
    let server_node_hours: f64 = server_idx
        .iter()
        .map(|&i| w.node_seconds_online(i))
        .sum::<f64>()
        / 3600.0;
    let standby_online_secs: Vec<f64> = w
        .capacity_groups()
        .iter()
        .flat_map(|g| g.standby.clone())
        .map(|i| w.node_seconds_online(i))
        .collect();
    w.run_until(HORIZON + 400.0);
    let (mut met, mut total) = (0usize, 0usize);
    for rec in w.recorder.all().iter().filter(|r| !r.synthetic) {
        let region = w.topology().region_of(rec.origin.0 as usize);
        if in_peak(rec.submitted_at, region) {
            met += rec.slo_met() as usize;
            total += 1;
        }
    }
    assert!(total > 100, "peak windows barely ran: {total} records");
    ElasticRun {
        peak_slo: met as f64 / total as f64,
        overall_slo: w.recorder.slo_attainment(),
        server_node_hours,
        scale_events: w.scale_events,
        credits_charged: w.capacity_credits_charged as f64
            / wwwserve::types::CREDIT as f64,
        standby_online_secs,
    }
}

/// Full-trace fingerprint for the static-block ≡ no-block equivalence
/// check (same shape as `rust/tests/replay_equivalence.rs`).
fn elastic_fingerprint(mode: Provisioning) -> (usize, u64, u64, u64, Vec<u64>) {
    let e = wwwserve::config::parse_experiment(&elastic_config(mode))
        .expect("config parses");
    let mut w = World::new(e.world.clone(), e.setups.clone());
    w.run_until(HORIZON + 400.0);
    (
        w.recorder.len(),
        (w.recorder.mean_latency() * 1e9) as u64,
        w.messages_sent,
        w.events_processed,
        w.credit_totals().iter().map(|c| (c * 1e6) as u64).collect(),
    )
}

fn elastic_part() -> Json {
    let stat = run_elastic(Provisioning::StaticPeak);
    let elastic = run_elastic(Provisioning::Elastic);
    println!(
        "\n## Elastic replica placement (1 committed + {ELASTIC_STANDBY} \
         standby per region vs the same commitment held online)\n"
    );
    let mut t = Table::new(&[
        "provisioning", "peak-window SLO", "overall SLO",
        "server node-hours", "scale events", "credits burned",
    ]);
    for (name, r) in [("static peak", &stat), ("elastic", &elastic)] {
        t.row(vec![
            name.to_string(),
            format!("{:.3}", r.peak_slo),
            format!("{:.3}", r.overall_slo),
            format!("{:.2}", r.server_node_hours),
            format!("{}", r.scale_events),
            format!("{:.3}", r.credits_charged),
        ]);
    }
    t.print();
    let saving = 1.0 - elastic.server_node_hours / stat.server_node_hours;
    println!(
        "node-hour saving: {:.1}% (elastic {:.2} vs static {:.2}); \
         standby online secs: {:?}",
        saving * 100.0,
        elastic.server_node_hours,
        stat.server_node_hours,
        elastic
            .standby_online_secs
            .iter()
            .map(|s| *s as u64)
            .collect::<Vec<_>>()
    );

    // The headline claim, asserted: elasticity keeps the rush-hour SLO
    // within a few points of peak provisioning at materially fewer
    // node-hours.
    assert!(
        elastic.peak_slo + 0.05 >= stat.peak_slo,
        "elastic fleet lost the peak-window SLO: elastic {:.3} vs \
         static {:.3}",
        elastic.peak_slo,
        stat.peak_slo
    );
    assert!(
        elastic.server_node_hours <= 0.75 * stat.server_node_hours,
        "elastic fleet saved under 25% node-hours: elastic {:.2} vs \
         static {:.2}",
        elastic.server_node_hours,
        stat.server_node_hours
    );
    // The controller genuinely worked the wave: standbys were spawned
    // (and not simply left running for the whole cycle), and holding
    // costs were assessed.
    assert!(elastic.scale_events > 0, "no scale events at all");
    assert!(
        elastic.standby_online_secs.iter().any(|&s| s > 0.0),
        "no standby ever came online"
    );
    assert!(
        elastic
            .standby_online_secs
            .iter()
            .all(|&s| s < 0.9 * HORIZON),
        "standbys never retired: {:?}",
        elastic.standby_online_secs
    );
    assert!(elastic.credits_charged > 0.0, "no holding cost accrued");

    // The Static capacity policy is an inert declaration: bit-identical
    // to not declaring capacity at all.
    assert_eq!(
        elastic_fingerprint(Provisioning::StaticBlock),
        elastic_fingerprint(Provisioning::NoBlock),
        "capacity {{policy: static}} diverged from the no-block trace"
    );
    println!("static capacity block replays the no-block trace ✓");

    Json::obj(vec![
        (
            "static_peak",
            Json::obj(vec![
                ("peak_slo", Json::num(stat.peak_slo)),
                ("overall_slo", Json::num(stat.overall_slo)),
                ("server_node_hours", Json::num(stat.server_node_hours)),
            ]),
        ),
        (
            "elastic",
            Json::obj(vec![
                ("peak_slo", Json::num(elastic.peak_slo)),
                ("overall_slo", Json::num(elastic.overall_slo)),
                ("server_node_hours", Json::num(elastic.server_node_hours)),
                ("scale_events", Json::num(elastic.scale_events as f64)),
                ("credits_charged", Json::num(elastic.credits_charged)),
                (
                    "standby_online_secs",
                    Json::Arr(
                        elastic
                            .standby_online_secs
                            .iter()
                            .map(|s| Json::num(*s))
                            .collect(),
                    ),
                ),
            ]),
        ),
        ("node_hour_saving", Json::num(saving)),
    ])
}

// ---------------------------------------------------------------------------
// Part 7: streaming sessions — KV-affine vs affinity-blind dispatch
// ---------------------------------------------------------------------------

struct StreamingRun {
    ttft_attainment: f64,
    overall_slo: f64,
    kv_transfers: u64,
    kv_bytes: u64,
    session_turns: usize,
}

/// Session-heavy follow-the-sun fleet: one requester per region drives
/// multi-turn chat sessions (think-time gaps, per-turn TTFT budgets) into
/// the six-server market. Both runs stream; they differ only in
/// `affinity_bonus` — 1.0 pins every turn to the session's KV home, 0.0
/// re-draws the executor every turn, paying a `KvTransfer` of the grown
/// session cache over the WAN's finite bandwidth whenever it moves.
fn run_streaming(affinity_bonus: f64) -> StreamingRun {
    let mut cfg = WorldConfig {
        seed: SEED,
        topology: Some(three_region_wan(3).build()),
        ..Default::default()
    };
    cfg.system.duel_rate = 0.0;
    cfg.streaming = StreamingConfig {
        enabled: true,
        affinity_bonus,
        ..Default::default()
    };
    let mut setups = Vec::new();
    for region in 0..3 {
        let offset = region as f64 * (PERIOD / 3.0);
        let requester_id = NodeId((setups.len()) as u32);
        setups.push(
            NodeSetup::new(
                Profile::test(40.0, 4),
                NodePolicy {
                    stake: 2 * CREDIT,
                    target_utilization: 0.5,
                    offload_freq: 1.0,
                    accept_freq: 0.0,
                    latency_penalty: 15.0,
                    ..Default::default()
                },
            )
            .with_generator(
                Generator::new(
                    requester_id,
                    // Session *starts* ride the diurnal wave; each start
                    // fans out into a handful of turns spaced by think
                    // time, so the turn rate is ~turns_mean higher.
                    diurnal_phases(HORIZON, PERIOD, 6.0, 30.0, offset),
                )
                .with_lengths(LengthDist {
                    output_mean: 400.0,
                    output_sigma: 0.5,
                    ..Default::default()
                })
                .with_sessions(SessionProfile::default()),
            ),
        );
        for _ in 0..2 {
            setups.push(NodeSetup::new(
                Profile::test(45.0, 24),
                NodePolicy {
                    stake: 20 * CREDIT,
                    accept_freq: 1.0,
                    latency_penalty: 15.0,
                    ..Default::default()
                },
            ));
        }
    }
    let mut w = World::new(cfg, setups);
    w.run_until(HORIZON + DRAIN);
    let session_turns = w
        .recorder
        .all()
        .iter()
        .filter(|r| !r.synthetic && r.session != 0)
        .count();
    StreamingRun {
        ttft_attainment: w.recorder.ttft_attainment(),
        overall_slo: w.recorder.slo_attainment(),
        kv_transfers: w.kv_transfer_count,
        kv_bytes: w.kv_transfer_bytes,
        session_turns,
    }
}

fn streaming_part() -> Json {
    let aware = run_streaming(1.0);
    let blind = run_streaming(0.0);
    println!("\n## Streaming sessions (KV-affine vs affinity-blind)\n");
    let mut t = Table::new(&[
        "dispatch", "session turns", "TTFT attainment", "overall SLO",
        "KV transfers", "KV GB moved",
    ]);
    for (name, r) in [("affine", &aware), ("blind", &blind)] {
        t.row(vec![
            name.to_string(),
            format!("{}", r.session_turns),
            format!("{:.3}", r.ttft_attainment),
            format!("{:.3}", r.overall_slo),
            format!("{}", r.kv_transfers),
            format!("{:.2}", r.kv_bytes as f64 / 1e9),
        ]);
    }
    t.print();

    // Both runs replay the identical session trace; only dispatch differs.
    assert_eq!(
        aware.session_turns, blind.session_turns,
        "session trace diverged between the affine and blind runs"
    );
    assert!(
        aware.session_turns > 200,
        "session scenario barely ran: {} turns",
        aware.session_turns
    );
    assert!(
        blind.kv_bytes > 0,
        "affinity-blind dispatch never shipped a KV cache — the \
         comparison is vacuous"
    );
    // The headline claims, asserted: pinning turns to the KV home keeps
    // the TTFT SLO at least as well as re-drawing every turn, while
    // moving a small fraction of the cache bytes.
    assert!(
        aware.ttft_attainment >= blind.ttft_attainment,
        "KV-affine dispatch lost TTFT attainment: affine {:.3} vs \
         blind {:.3}",
        aware.ttft_attainment,
        blind.ttft_attainment
    );
    assert!(
        blind.kv_bytes >= 3 * aware.kv_bytes,
        "KV-affine dispatch did not cut KV motion 3x: affine {} bytes vs \
         blind {} bytes",
        aware.kv_bytes,
        blind.kv_bytes
    );
    println!(
        "\nstreaming: affine TTFT {:.3} >= blind {:.3}, KV bytes \
         {:.2} GB vs {:.2} GB ✓",
        aware.ttft_attainment,
        blind.ttft_attainment,
        aware.kv_bytes as f64 / 1e9,
        blind.kv_bytes as f64 / 1e9
    );

    let run_json = |r: &StreamingRun| {
        Json::obj(vec![
            ("ttft_attainment", Json::num(r.ttft_attainment)),
            ("overall_slo", Json::num(r.overall_slo)),
            ("kv_transfers", Json::num(r.kv_transfers as f64)),
            ("kv_bytes", Json::num(r.kv_bytes as f64)),
            ("session_turns", Json::num(r.session_turns as f64)),
        ])
    };
    Json::obj(vec![
        ("affine", run_json(&aware)),
        ("blind", run_json(&blind)),
    ])
}

fn regions_json(regions: &[(String, f64, f64, usize)]) -> Json {
    Json::Arr(
        regions
            .iter()
            .map(|(name, slo, p99, n)| {
                Json::obj(vec![
                    ("region", Json::str(name.clone())),
                    ("slo", Json::num(*slo)),
                    ("p99_s", Json::num(*p99)),
                    ("completed", Json::num(*n as f64)),
                ])
            })
            .collect(),
    )
}

fn reroute_json(r: &RerouteRun) -> Json {
    Json::obj(vec![
        ("cross_sends_pre_partition", Json::num(r.pre as f64)),
        ("cross_sends_outage_window", Json::num(r.part as f64)),
        ("cross_sends_after_heal", Json::num(r.recovered as f64)),
        ("overall_slo", Json::num(r.overall_slo)),
        ("regions", regions_json(&r.regions)),
    ])
}

fn print_comparison(title: &str, blind: &GeoRun, aware: &GeoRun) {
    println!("## {title}\n");
    let mut t = Table::new(&[
        "Region", "SLO (blind)", "SLO (aware)", "p99 (blind)", "p99 (aware)",
        "reqs",
    ]);
    for (b, a) in blind.regions.iter().zip(&aware.regions) {
        t.row(vec![
            b.0.clone(),
            format!("{:.3}", b.1),
            format!("{:.3}", a.1),
            format!("{:.1}", b.2),
            format!("{:.1}", a.2),
            format!("{}", b.3),
        ]);
    }
    t.print();
    println!(
        "overall SLO: blind {:.3} vs aware {:.3}; dropped msgs: \
         blind {} aware {}\n",
        blind.overall_slo, aware.overall_slo, blind.dropped, aware.dropped
    );
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("GEO_SCALE_SMOKE")
            .is_ok_and(|v| !v.is_empty() && v != "0");
    let iters = if smoke { 1 } else { 3 };
    println!(
        "# geo_scale — 3-region WAN, follow-the-sun + partition + reroute{}\n",
        if smoke { " (smoke tier)" } else { "" }
    );

    backward_compat_check();

    // Part 2: follow-the-sun, healthy WAN.
    let mut blind = None;
    bench("geo/follow-the-sun blind", 0, iters, 60.0, || {
        blind = Some(run_geo(0.0, false));
    });
    let mut aware = None;
    bench("geo/follow-the-sun aware(p=50)", 0, iters, 60.0, || {
        aware = Some(run_geo(50.0, false));
    });
    let (blind, aware) = (blind.unwrap(), aware.unwrap());
    print_comparison("Follow-the-sun (healthy WAN)", &blind, &aware);
    assert!(
        blind.dropped == 0 && aware.dropped == 0,
        "healthy WAN dropped messages"
    );

    // Part 3: trans-continental partition (us<->asia down 250s..450s).
    let blind_p = run_geo(0.0, true);
    let aware_p = run_geo(50.0, true);
    print_comparison("us<->asia partition at 250s, heal at 450s", &blind_p, &aware_p);
    assert!(blind_p.dropped > 0, "partition had no effect");

    // Locality-aware dispatch keeps more SLO through the partition in the
    // regions whose rush hour overlaps it (us and asia peaks sit inside
    // the 250-450s window).
    let slo_of = |r: &GeoRun, name: &str| {
        r.regions.iter().find(|x| x.0 == name).expect("region").1
    };
    let blind_affected = (slo_of(&blind_p, "us") + slo_of(&blind_p, "asia")) / 2.0;
    let aware_affected = (slo_of(&aware_p, "us") + slo_of(&aware_p, "asia")) / 2.0;
    println!(
        "partition-affected regions (us+asia mean SLO): blind {blind_affected:.3} \
         vs aware {aware_affected:.3}"
    );
    assert!(
        aware_affected + 0.02 >= blind_affected,
        "locality-aware dispatch lost SLO vs region-blind under partition: \
         aware {aware_affected:.3} < blind {blind_affected:.3}"
    );

    // Determinism: the partitioned world replays exactly under its seed.
    let replay = run_geo(0.0, true);
    assert_eq!(
        blind_p.fingerprint, replay.fingerprint,
        "partition/heal run is not deterministic"
    );
    println!("\npartition/heal replay deterministic ✓");

    // Part 4: live-estimation reroute. Liveness aging is pinned off, so
    // only measured latency can steer dispatch away from the dead link.
    let live = run_reroute(true);
    let frozen = run_reroute(false);
    println!("\n## Reroute (us<->asia partition {T_PART}s..{T_HEAL}s)\n");
    let mut t = Table::new(&[
        "estimator", "pre-partition", "outage window", "after heal", "SLO",
    ]);
    for (name, r) in [("live", &live), ("static", &frozen)] {
        t.row(vec![
            name.to_string(),
            format!("{}", r.pre),
            format!("{}", r.part),
            format!("{}", r.recovered),
            format!("{:.3}", r.overall_slo),
        ]);
    }
    t.print();
    assert!(live.pre > 0 && frozen.pre > 0, "no cross traffic at all");
    assert!(
        frozen.part >= 15,
        "static baseline unexpectedly shed the partitioned region \
         ({} cross sends in outage window)",
        frozen.part
    );
    assert!(
        live.part <= 12 && live.part * 3 <= frozen.part,
        "live estimation failed to shed the partition within \
         {} gossip intervals: live {} vs static {}",
        (T_CONVERGED - T_PART) as u64,
        live.part,
        frozen.part
    );
    assert!(
        live.recovered > 0,
        "live estimation never re-admitted the healed region"
    );
    println!(
        "\nreroute: shed within {} intervals ({} -> {} cross sends, static \
         baseline {}), re-admitted after heal ({} sends) ✓",
        (T_CONVERGED - T_PART) as u64,
        live.pre,
        live.part,
        frozen.part,
        live.recovered
    );

    // Part 5: heterogeneous participation populations, selected per fleet
    // group via the declarative `policy` key.
    let mixed = mixed_policy_part();

    // Part 6: elastic replica placement riding the diurnal wave vs the
    // same commitment statically peak-provisioned.
    let elastic = elastic_part();

    // Part 7: streaming sessions — KV-affine dispatch vs re-drawing the
    // executor (and shipping the session cache) every turn.
    let streaming = streaming_part();

    // Machine-readable trajectory: the per-region SLO/p99 of every part
    // plus the reroute window counts (CI uploads this artifact).
    let report = Json::obj(vec![
        ("bench", Json::str("geo_scale")),
        ("seed", Json::num(SEED as f64)),
        ("horizon_s", Json::num(HORIZON)),
        ("smoke", Json::Bool(smoke)),
        (
            "follow_the_sun",
            Json::obj(vec![
                ("blind_slo", Json::num(blind.overall_slo)),
                ("aware_slo", Json::num(aware.overall_slo)),
                ("blind_regions", regions_json(&blind.regions)),
                ("aware_regions", regions_json(&aware.regions)),
            ]),
        ),
        (
            "partition",
            Json::obj(vec![
                ("blind_slo", Json::num(blind_p.overall_slo)),
                ("aware_slo", Json::num(aware_p.overall_slo)),
                ("blind_regions", regions_json(&blind_p.regions)),
                ("aware_regions", regions_json(&aware_p.regions)),
                ("blind_dropped", Json::num(blind_p.dropped as f64)),
            ]),
        ),
        (
            "reroute",
            Json::obj(vec![
                ("partition_at_s", Json::num(T_PART)),
                ("converged_by_s", Json::num(T_CONVERGED)),
                ("heal_at_s", Json::num(T_HEAL)),
                ("readmit_by_s", Json::num(T_READMIT)),
                ("live", reroute_json(&live)),
                ("static", reroute_json(&frozen)),
            ]),
        ),
        ("mixed_policy", mixed),
        ("elastic", elastic),
        ("streaming", streaming),
    ]);
    let path = "BENCH_geo_scale.json";
    write_json_report(path, &report).expect("write bench json");
    println!("\nwrote {path}");
}
