//! Bench: gossip protocol — rounds to full-membership convergence vs
//! network size and fanout (epidemic diffusion should be O(log N)), plus
//! per-round merge throughput.

use wwwserve::benchlib::{bench, Table};
use wwwserve::gossip::{GossipConfig, PeerView};
use wwwserve::util::rng::Rng;
use wwwserve::NodeId;

/// Rounds until every node knows every node (ring bootstrap).
fn rounds_to_convergence(n: usize, fanout: usize, seed: u64) -> usize {
    let cfg = GossipConfig {
        interval: 1.0,
        fanout,
        suspect_after: 1e9,
        ..Default::default()
    };
    let mut views: Vec<PeerView> = (0..n)
        .map(|i| PeerView::new(NodeId(i as u32), cfg, 0.0))
        .collect();
    for (i, v) in views.iter_mut().enumerate() {
        v.add_seed(NodeId(((i + 1) % n) as u32), 0, 0, 0.0);
    }
    let mut rng = Rng::new(seed);
    for round in 1..=200 {
        let now = round as f64;
        for v in views.iter_mut() {
            v.heartbeat(now);
        }
        for i in 0..n {
            for t in views[i].pick_targets(&mut rng, now) {
                let d = views[i].digest();
                views[t.0 as usize].merge(&d, now);
                let back = views[t.0 as usize].digest();
                views[i].merge(&back, now);
            }
        }
        if views.iter().all(|v| v.known() == n) {
            return round;
        }
    }
    usize::MAX
}

fn main() {
    println!("# gossip_convergence — epidemic diffusion\n");

    let mut t = Table::new(&["nodes", "fanout", "rounds (median of 5)"]);
    for n in [8usize, 16, 32, 64, 128] {
        for fanout in [1usize, 2, 4] {
            let mut rounds: Vec<usize> = (0..5)
                .map(|s| rounds_to_convergence(n, fanout, s as u64))
                .collect();
            rounds.sort_unstable();
            t.row(vec![
                format!("{n}"),
                format!("{fanout}"),
                format!("{}", rounds[2]),
            ]);
        }
    }
    t.print();

    // Sub-linear scaling: going 8 -> 128 nodes (16x) costs far fewer than
    // 16x the rounds (epidemic diffusion; full-membership convergence has a
    // coupon-collector tail on top of the log N core, so we bound the
    // median ratio rather than asserting a pure log).
    let median = |n: usize| -> usize {
        let mut r: Vec<usize> =
            (0..5).map(|s| rounds_to_convergence(n, 2, s)).collect();
        r.sort_unstable();
        r[2]
    };
    let (r8, r128) = (median(8), median(128));
    println!("\nN=8 median {r8} rounds; N=128 median {r128} rounds");
    assert!(
        r128 < r8 * 16,
        "convergence should scale sub-linearly, got {r8} -> {r128}"
    );

    // Merge throughput on a large digest.
    let cfg = GossipConfig::default();
    let big_digest: Vec<(NodeId, u64, bool, u64, u32)> =
        (0..1000).map(|i| (NodeId(i), 5, true, 0, 0)).collect();
    bench("merge 1000-entry digest (cold)", 10, 2_000, 5.0, || {
        let mut v = PeerView::new(NodeId(9999), cfg, 0.0);
        v.merge(&big_digest, 1.0)
    });
    let mut warm = PeerView::new(NodeId(9999), cfg, 0.0);
    warm.merge(&big_digest, 1.0);
    bench("merge 1000-entry digest (warm, no-op)", 10, 5_000, 5.0, || {
        warm.merge(&big_digest, 2.0).len()
    });
    bench("digest of 1000-entry view", 10, 5_000, 5.0, || {
        warm.digest().len()
    });
}
