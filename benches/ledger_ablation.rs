//! Bench: ablation (DESIGN.md §4) — SharedLedger vs full Credit Block Chain.
//!
//! The paper ran its experiments with a shared ledger (Appendix C); this
//! bench quantifies what the full §4.1 blockchain mode costs in message
//! volume and whether serving behaviour is unaffected.

use wwwserve::backend::Profile;
use wwwserve::benchlib::{bench, Table};
use wwwserve::ledger::{Block, Chain, CreditOp, OpReason};
use wwwserve::crypto::{KeyStore, NodeKey};
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{LedgerMode, NodeSetup, World, WorldConfig};
use wwwserve::workload::{Generator, Phase};
use wwwserve::NodeId;

fn run_mode(ledger: LedgerMode, seed: u64) -> (f64, f64, u64, usize) {
    let horizon = 400.0;
    let setups: Vec<NodeSetup> = (0..4)
        .map(|i| {
            NodeSetup::new(
                Profile::test(40.0, 16),
                NodePolicy { accept_freq: 1.0, ..Default::default() },
            )
            .with_generator(Generator::new(
                NodeId(i as u32),
                vec![Phase::new(0.0, horizon, if i == 0 { 2.0 } else { 15.0 })],
            ))
        })
        .collect();
    let cfg = WorldConfig { seed, ledger, ..Default::default() };
    let mut w = World::new(cfg, setups);
    w.run_until(horizon + 2000.0);
    (
        w.recorder.slo_attainment(),
        w.recorder.mean_latency(),
        w.messages_sent,
        w.recorder.user_records().count(),
    )
}

fn main() {
    let seed = 2026;
    println!("# ledger_ablation — shared vs blockchain credit ledger\n");

    let mut shared = None;
    bench("world/shared ledger", 0, 3, 30.0, || {
        shared = Some(run_mode(LedgerMode::Shared, seed));
    });
    let mut chain = None;
    bench("world/blockchain ledger", 0, 3, 30.0, || {
        chain = Some(run_mode(LedgerMode::Blockchain, seed));
    });
    let (s, c) = (shared.unwrap(), chain.unwrap());

    let mut t = Table::new(&["mode", "SLO", "mean lat (s)", "messages", "reqs"]);
    t.row(vec!["shared".into(), format!("{:.3}", s.0), format!("{:.1}", s.1),
               format!("{}", s.2), format!("{}", s.3)]);
    t.row(vec!["blockchain".into(), format!("{:.3}", c.0), format!("{:.1}", c.1),
               format!("{}", c.2), format!("{}", c.3)]);
    t.print();
    println!(
        "\nblockchain message overhead: {:.2}x",
        c.2 as f64 / s.2 as f64
    );

    // Serving behaviour must be essentially unchanged (consensus is off the
    // request path).
    assert!((s.0 - c.0).abs() < 0.1, "SLO diverged between ledger modes");
    assert!(c.2 > s.2, "blockchain mode must cost extra messages");

    // Micro: raw chain ops.
    let keys = KeyStore::for_network(1, 4);
    let key = NodeKey::derive(1, NodeId(0));
    bench("block create+sign (8 ops)", 100, 20_000, 5.0, || {
        let ops: Vec<CreditOp> = (0..8)
            .map(|i| CreditOp::Mint {
                to: NodeId(i % 4),
                amount: 10,
                reason: OpReason::Genesis,
            })
            .collect();
        Block::create(wwwserve::crypto::Hash256::ZERO, 1.0, ops, &key)
    });
    bench("chain validate+commit (8-op block)", 100, 10_000, 5.0, || {
        let mut chain = Chain::new();
        let ops: Vec<CreditOp> = (0..8)
            .map(|i| CreditOp::Mint {
                to: NodeId(i % 4),
                amount: 10,
                reason: OpReason::Genesis,
            })
            .collect();
        let b = Block::create(chain.head(), 1.0, ops, &key);
        chain.commit_block(b, &keys).unwrap();
        chain.len()
    });
}
