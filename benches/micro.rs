//! Micro-benchmarks of the L3 hot paths (the §Perf working set):
//! PoS sampling (linear vs alias), SimBackend event processing, world
//! event throughput, message codec, crypto primitives.

use wwwserve::backend::{Backend, Profile, SimBackend};
use wwwserve::benchlib::bench;
use wwwserve::coordinator::Message;
use wwwserve::crypto::{sha256, KeyStore, NodeKey};
use wwwserve::policy::NodePolicy;
use wwwserve::pos::StakeSnapshot;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::types::{ExecKind, Request, RequestId};
use wwwserve::util::json::Json;
use wwwserve::util::rng::Rng;
use wwwserve::workload::{Generator, Phase};
use wwwserve::NodeId;

fn stakes(n: usize) -> Vec<(NodeId, u64)> {
    (0..n).map(|i| (NodeId(i as u32), 1 + (i as u64 * 37) % 100)).collect()
}

fn main() {
    println!("# micro — L3 hot paths\n");

    // --- PoS sampling: linear scan vs alias table -------------------------
    for n in [8usize, 64, 512, 4096] {
        let table = stakes(n);
        let snap = StakeSnapshot::new(&table, None);
        let mut rng = Rng::new(1);
        bench(&format!("pos/linear n={n}"), 100, 200_000, 2.0, || {
            snap.sample_linear(&mut rng)
        });
        let mut prepared = snap.clone();
        prepared.prepare();
        let mut rng = Rng::new(1);
        bench(&format!("pos/alias  n={n}"), 100, 200_000, 2.0, || {
            prepared.sample(&mut rng)
        });
        let mut rng = Rng::new(1);
        bench(&format!("pos/alias build+1 n={n}"), 100, 50_000, 2.0, || {
            let mut s = snap.clone();
            s.prepare();
            s.sample(&mut rng)
        });
    }

    // --- SimBackend: submit+advance cycle ----------------------------------
    bench("simbackend/100 reqs lifecycle", 10, 2_000, 3.0, || {
        let mut b = SimBackend::new(Profile::test(40.0, 16));
        for i in 0..100u64 {
            b.submit(
                Request {
                    id: RequestId { origin: NodeId(0), seq: i },
                    prompt_tokens: 100,
                    output_tokens: 200,
                    submitted_at: i as f64 * 0.5,
                    slo_deadline: 1e9,
                    synthetic: false,
                    payload: vec![],
                },
                ExecKind::Local,
                i as f64 * 0.5,
            );
        }
        b.advance(1e6).len()
    });

    // --- whole-world event throughput --------------------------------------
    bench("world/setting-like 200s, 4 nodes", 1, 50, 10.0, || {
        let setups: Vec<NodeSetup> = (0..4)
            .map(|i| {
                NodeSetup::new(Profile::test(40.0, 16), NodePolicy::default())
                    .with_generator(Generator::new(
                        NodeId(i as u32),
                        vec![Phase::new(0.0, 200.0, 3.0)],
                    ))
            })
            .collect();
        let mut w =
            World::new(WorldConfig { seed: 7, ..Default::default() }, setups);
        w.run_until(1000.0);
        w.recorder.len()
    });

    // --- message codec ------------------------------------------------------
    let msg = Message::Delegate {
        request: Request {
            id: RequestId { origin: NodeId(3), seq: 99 },
            prompt_tokens: 512,
            output_tokens: 2048,
            submitted_at: 12.5,
            slo_deadline: 200.0,
            synthetic: false,
            payload: (0..512).collect(),
        },
        duel: false,
    };
    bench("codec/delegate to_json", 100, 50_000, 2.0, || {
        msg.to_json().to_string().len()
    });
    let text = msg.to_json().to_string();
    bench("codec/delegate parse+from_json", 100, 50_000, 2.0, || {
        Message::from_json(&Json::parse(&text).unwrap()).unwrap().kind()
    });

    // --- crypto -------------------------------------------------------------
    let key = NodeKey::derive(1, NodeId(0));
    let mut ks = KeyStore::new();
    ks.register(&key);
    let digest = sha256(b"some block content hash");
    bench("crypto/sha256 1KiB", 100, 100_000, 2.0, || {
        sha256(&[0u8; 1024])
    });
    bench("crypto/sign", 100, 100_000, 2.0, || key.sign(&digest));
    let sig = key.sign(&digest);
    bench("crypto/verify", 100, 100_000, 2.0, || {
        ks.verify(NodeId(0), &digest, &sig)
    });

    // --- rng ----------------------------------------------------------------
    let mut rng = Rng::new(5);
    bench("rng/next_u64", 100, 1_000_000, 1.0, || rng.next_u64());
    bench("rng/poisson(8)", 100, 200_000, 1.0, || rng.poisson(8.0));
    bench("rng/lognormal", 100, 200_000, 1.0, || {
        rng.lognormal_mean(2000.0, 0.7)
    });
}
