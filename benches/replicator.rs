//! Bench: Theorem 5.8 — replicator-dynamics convergence to the
//! high-quality equilibrium, and integrator throughput.

use wwwserve::benchlib::bench;
use wwwserve::gametheory::{NodeParams, Replicator, SystemParams};

fn mk(n_high: usize, n_low: usize) -> Replicator {
    let mut nodes = Vec::new();
    for _ in 0..n_high {
        nodes.push(NodeParams { quality: 0.85, cost: 0.3, stake0: 1.0 });
    }
    for _ in 0..n_low {
        nodes.push(NodeParams { quality: 0.45, cost: 0.3, stake0: 1.0 });
    }
    // Duel economics under which low quality is strictly unprofitable
    // (otherwise total stake inflates and convergence is logarithmic).
    let sys = SystemParams { duel_rate: 0.4, duel_penalty: 3.0, ..Default::default() };
    Replicator::new(nodes, sys)
}

fn main() {
    println!("# replicator — Section 5 dynamics\n");

    // Convergence table.
    println!("t      p_high (2 high vs 4 low quality nodes)");
    let mut r = mk(2, 4);
    let hq = [0usize, 1];
    let (times, traj) = r.integrate(80.0, 0.002, 10.0);
    for (k, t) in times.iter().enumerate() {
        let ph = traj[0][k] + traj[1][k];
        println!("{t:<6.0} {ph:.4}");
    }
    let final_share = r.group_share(&hq);
    println!("final high-quality share: {final_share:.4}");
    assert!(final_share > 0.8, "Theorem 5.8: share should approach 1");

    // Monotonicity along the trajectory (Proposition 5.7 corollary).
    for w in (0..times.len()).collect::<Vec<_>>().windows(2) {
        let a = traj[0][w[0]] + traj[1][w[0]];
        let b = traj[0][w[1]] + traj[1][w[1]];
        assert!(b >= a - 1e-9, "group share must be monotone");
    }

    // Integrator throughput at population scale.
    for n in [10usize, 100, 1000] {
        bench(
            &format!("euler step, {n} nodes"),
            10,
            1000,
            5.0,
            || {
                let mut r = mk(n / 2, n / 2);
                for _ in 0..10 {
                    r.step(0.01);
                }
                r.shares()[0]
            },
        );
    }
    println!("\nshape checks OK");
}
