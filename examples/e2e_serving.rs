//! END-TO-END VALIDATION: the full three-layer stack on a real workload.
//!
//! ```bash
//! make artifacts   # once
//! cargo run --release --example e2e_serving
//! ```
//!
//! What runs:
//! * L1/L2 — the Pallas flash-decode kernel inside the JAX transformer,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! * Runtime — each node's model manager loads the artifacts via PJRT and
//!   serves real continuous-batched token generation (`PjrtBackend`).
//! * L3 — three WWW.Serve nodes on **real TCP sockets** (localhost):
//!   gossip membership, PoS routing, probe/delegate/response, credit
//!   payments — Python nowhere on the request path.
//!
//! Node 0 is overloaded (it receives all user prompts and offloads
//! aggressively); nodes 1-2 sell their capacity. The run reports
//! latency/throughput and the credit flow, and is recorded in
//! EXPERIMENTS.md §E2E.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use wwwserve::backend::PjrtBackend;
use wwwserve::coordinator::{LedgerManager, Node};
use wwwserve::gossip::GossipConfig;
use wwwserve::ledger::{Ledger, SharedLedger};
use wwwserve::net::{NodeRunner, TcpTransport};
use wwwserve::policy::{NodePolicy, SystemPolicy};
use wwwserve::runtime::Engine;
use wwwserve::types::{Request, RequestId, RequestRecord};
use wwwserve::{NodeId, CREDIT};

const N_NODES: usize = 3;
const N_REQUESTS: usize = 32;
const MAX_NEW_TOKENS: u32 = 48;

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let shared = Arc::new(Mutex::new(SharedLedger::new()));
    let epoch = Instant::now();
    let done = Arc::new(AtomicUsize::new(0));
    // Engines compile at different speeds; nobody serves (or submits) until
    // every node is up, then a short gossip warmup marks everyone alive.
    let ready = Arc::new(Barrier::new(N_NODES));
    let records: Arc<Mutex<Vec<RequestRecord>>> = Arc::new(Mutex::new(vec![]));

    // Bind every transport up front (ephemeral ports), then cross-register
    // all addresses before any node thread starts.
    let transports: Vec<TcpTransport> = (0..N_NODES)
        .map(|i| TcpTransport::bind(NodeId(i as u32), "127.0.0.1:0").unwrap())
        .collect();
    let real_addrs: Vec<std::net::SocketAddr> =
        transports.iter().map(|t| t.local_addr).collect();
    for t in &transports {
        for (j, a) in real_addrs.iter().enumerate() {
            if NodeId(j as u32) != t.me {
                t.register_peer(NodeId(j as u32), *a);
            }
        }
    }

    println!("== WWW.Serve e2e: {N_NODES} nodes over TCP, PJRT inference ==");
    for (i, a) in real_addrs.iter().enumerate() {
        println!("  node {i} @ {a}");
    }

    let mut handles = Vec::new();
    for (i, transport) in transports.into_iter().enumerate() {
        let shared = shared.clone();
        let done = done.clone();
        let records = records.clone();
        let ready = ready.clone();
        handles.push(std::thread::spawn(move || {
            // Engine is constructed inside the thread (PJRT handles are
            // not Send); ~1 s compile per node.
            let engine = Engine::load("artifacts").expect("load artifacts");
            let backend = PjrtBackend::new(engine, 0.7 + 0.05 * i as f64);
            let policy = if i == 0 {
                NodePolicy {
                    // The hot node: offload from the first sign of pressure.
                    target_utilization: 0.2,
                    offload_freq: 1.0,
                    accept_freq: 0.5,
                    ..Default::default()
                }
            } else {
                NodePolicy { accept_freq: 1.0, ..Default::default() }
            };
            let system = SystemPolicy {
                duel_rate: 0.15,
                ..Default::default()
            };
            let mut node = Node::new(
                NodeId(i as u32),
                policy,
                system,
                Box::new(backend),
                LedgerManager::shared(shared),
                GossipConfig { interval: 0.5, ..Default::default() },
                42 + i as u64,
                0.0,
            );
            for j in 0..N_NODES {
                if j != i {
                    node.view.add_seed(NodeId(j as u32), 0, 0, 0.0);
                }
            }
            let mut runner = NodeRunner::new(node, transport, epoch);

            // Wait for the whole network, then gossip-warm for 2 s.
            ready.wait();
            let warmup_until = Instant::now() + Duration::from_secs(2);
            while Instant::now() < warmup_until {
                runner.pump();
                std::thread::sleep(Duration::from_millis(5));
            }

            // Node 0 submits the user workload in bursts (8 requests every
            // 400 ms — well above one node's throughput, so the router has
            // real pressure to offload).
            let mut submitted = 0usize;
            let mut last_submit = Instant::now() - Duration::from_secs(1);
            let deadline = Instant::now() + Duration::from_secs(120);
            loop {
                let busy = runner.pump();
                if i == 0
                    && submitted < N_REQUESTS
                    && last_submit.elapsed() > Duration::from_millis(400)
                {
                    last_submit = Instant::now();
                    for _ in 0..8 {
                        if submitted >= N_REQUESTS {
                            break;
                        }
                        let prompt: Vec<u32> = format!(
                            "Solve problem #{submitted}: what is {submitted} squared?"
                        )
                        .bytes()
                        .map(|b| b as u32)
                        .collect();
                        let now = runner.now();
                        runner.submit(Request {
                            id: RequestId {
                                origin: NodeId(0),
                                seq: submitted as u64,
                            },
                            prompt_tokens: prompt.len() as u32,
                            output_tokens: MAX_NEW_TOKENS,
                            submitted_at: now,
                            slo_deadline: 30.0,
                            synthetic: false,
                            payload: prompt,
                        });
                        submitted += 1;
                    }
                }
                // Harvest completion records.
                if !runner.records.is_empty() {
                    let mut recs = records.lock().unwrap();
                    for r in runner.records.drain(..) {
                        if !r.synthetic {
                            done.fetch_add(1, Ordering::SeqCst);
                        }
                        recs.push(r);
                    }
                }
                if done.load(Ordering::SeqCst) >= N_REQUESTS
                    || Instant::now() > deadline
                {
                    break;
                }
                if !busy {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            runner.node.stats
        }));
    }

    let stats: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let elapsed = epoch.elapsed().as_secs_f64();
    let recs = records.lock().unwrap();
    let user: Vec<&RequestRecord> = recs.iter().filter(|r| !r.synthetic).collect();

    println!("\n== results ==");
    println!("completed user requests : {}/{N_REQUESTS}", user.len());
    println!("wall time               : {elapsed:.1} s");
    println!(
        "throughput              : {:.2} req/s ({:.0} tok/s generated)",
        user.len() as f64 / elapsed,
        user.len() as f64 * MAX_NEW_TOKENS as f64 / elapsed
    );
    if !user.is_empty() {
        let mut lats: Vec<f64> = user.iter().map(|r| r.latency()).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = lats.iter().sum::<f64>() / lats.len() as f64;
        println!(
            "latency mean/p50/p99    : {:.2} / {:.2} / {:.2} s",
            mean,
            lats[lats.len() / 2],
            lats[lats.len() - 1]
        );
    }
    let delegated = user.iter().filter(|r| r.executor != r.origin).count();
    println!("served remotely          : {delegated}/{}", user.len());
    println!("\nper-node stats:");
    for (i, s) in stats.iter().enumerate() {
        let l = shared.lock().unwrap();
        println!(
            "  node {i}: delegated-in {:>3}, delegated-out {:>3}, judge-evals {:>2}, credits {:.2}",
            s.delegated_in,
            s.delegated_out,
            s.judge_evals,
            (l.balance(NodeId(i as u32)) + l.stake(NodeId(i as u32))) as f64
                / CREDIT as f64,
        );
    }
    assert!(
        user.len() >= N_REQUESTS / 2,
        "too few completions — the stack did not compose"
    );
    assert!(delegated > 0, "no request was served remotely (routing dead?)");
    println!("\ne2e OK: all three layers composed (TCP + PoS routing + PJRT inference).");
}
