//! Section-5 game theory, executable: replicator-dynamics ODE + an
//! agent-based cross-check of Theorem 5.8 (the network converges to a
//! high-quality equilibrium).
//!
//! ```bash
//! cargo run --release --example game_theory
//! ```

use wwwserve::backend::Profile;
use wwwserve::gametheory::{NodeParams, Replicator, SystemParams};
use wwwserve::policy::{NodePolicy, SystemPolicy};
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::workload::{Generator, Phase};
use wwwserve::{NodeId, CREDIT};

fn ode_side() {
    println!("== Replicator dynamics (Propositions 5.6/5.7, Theorem 5.8) ==");
    let nodes = vec![
        NodeParams { quality: 0.85, cost: 0.3, stake0: 1.0 },
        NodeParams { quality: 0.85, cost: 0.3, stake0: 1.0 },
        NodeParams { quality: 0.55, cost: 0.3, stake0: 1.0 },
        NodeParams { quality: 0.55, cost: 0.3, stake0: 1.0 },
        NodeParams { quality: 0.30, cost: 0.3, stake0: 1.0 },
        NodeParams { quality: 0.30, cost: 0.3, stake0: 1.0 },
    ];
    // Duel economics strong enough that low-quality operation is strictly
    // unprofitable (see Section 5: Δ_i < 0 phases a node out).
    let sys = SystemParams {
        duel_rate: 0.4,
        duel_penalty: 3.0,
        ..Default::default()
    };
    let mut r = Replicator::new(nodes, sys);
    let hq = [0usize, 1];
    let lq = [4usize, 5];
    println!("t      p_high   p_mid    p_low");
    let (times, traj) = r.integrate(120.0, 0.005, 12.0);
    for (k, t) in times.iter().enumerate() {
        let ph: f64 = traj[0][k] + traj[1][k];
        let pm: f64 = traj[2][k] + traj[3][k];
        let pl: f64 = traj[4][k] + traj[5][k];
        println!("{t:<6.1} {ph:<8.3} {pm:<8.3} {pl:<8.3}");
    }
    let (dh, dnh) = r.group_payoffs(&hq);
    println!("final: high-quality group share {:.3} (payoff {:.3} vs others {:.3})",
             r.group_share(&hq), dh, dnh);
    println!("       low-quality group share  {:.3}\n", r.group_share(&lq));
    assert!(r.group_share(&hq) > 0.6, "Theorem 5.8 violated in ODE");
}

fn agent_side() {
    println!("== Agent-based cross-check (full WWW.Serve stack) ==");
    // Six serving nodes in three quality tiers + one requester flooding the
    // market; every delegation can duel. High-quality nodes should end with
    // more credits (the discrete analogue of stake-share growth).
    let mut setups = vec![NodeSetup::new(
        Profile::test(1.0, 1),
        NodePolicy::requester_only(),
    )
    .with_generator(Generator::new(
        NodeId(0),
        vec![Phase::new(0.0, 600.0, 1.0)],
    ))];
    let tiers = [0.88, 0.88, 0.70, 0.70, 0.45, 0.45];
    for q in tiers {
        setups.push(NodeSetup::new(
            Profile::test(60.0, 16).with_quality(q),
            NodePolicy { accept_freq: 1.0, ..Default::default() },
        ));
    }
    let cfg = WorldConfig {
        seed: 11,
        system: SystemPolicy {
            duel_rate: 0.5,
            duel_reward: CREDIT / 2,
            duel_penalty: CREDIT / 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.run_until(2400.0);

    let totals = w.credit_totals();
    println!("node  quality  credits  duel-win-rate");
    for i in 1..=6usize {
        println!(
            "n{i}    {:.2}     {:>7.2}  {:.2}",
            tiers[i - 1],
            totals[i],
            w.duel_stats.win_rate(NodeId(i as u32))
        );
    }
    let high = totals[1] + totals[2];
    let low = totals[5] + totals[6];
    println!("high-tier total {high:.1} vs low-tier total {low:.1}");
    assert!(
        high > low,
        "agent-based run contradicts Theorem 5.8: {high} <= {low}"
    );
    println!("OK: credit accumulation favours high-quality providers.");
}

fn main() {
    ode_side();
    agent_side();
}
