//! The open compute market: heterogeneous providers with *different
//! strategies* trading capacity — the paper's intro scenario ("overloaded
//! nodes outsource requests while underutilized nodes capitalize on idle
//! resources").
//!
//! ```bash
//! cargo run --release --example market
//! ```
//!
//! Five provider archetypes, all simultaneously:
//! * "hyperscaler"  — big capacity, high stake, sells aggressively
//! * "startup"      — medium capacity, cheap+fast model (lower quality)
//! * "enterprise"   — busy with its own users, offloads its overflow
//! * "hobbyist"     — small GPU, joins to earn credits at night
//! * "freeloader"   — tiny stake, poor quality; the duel mechanism should
//!                    keep its earnings down
//!
//! Also demonstrates the full blockchain ledger mode: every payment is a
//! proposed, voted, committed block.

use wwwserve::backend::Profile;
use wwwserve::coordinator::LedgerManager;
use wwwserve::policy::{NodePolicy, SystemPolicy};
use wwwserve::sim::{LedgerMode, NodeSetup, World, WorldConfig};
use wwwserve::workload::{Generator, LengthDist, Phase};
use wwwserve::{NodeId, CREDIT};

fn main() {
    let horizon = 750.0;
    let lengths = LengthDist { output_mean: 1200.0, ..Default::default() };
    let gen = |i: u32, phases: Vec<Phase>| {
        Generator::new(NodeId(i), phases).with_lengths(lengths)
    };

    let setups = vec![
        // 0: hyperscaler — strong backend, big stake, always accepts.
        NodeSetup::new(
            Profile::test(60.0, 64).with_quality(0.80),
            NodePolicy {
                stake: 40 * CREDIT,
                accept_freq: 1.0,
                offload_freq: 0.2,
                ..Default::default()
            },
        )
        .with_generator(gen(0, vec![Phase::new(0.0, horizon, 25.0)])),
        // 1: startup — fast but lower-quality model.
        NodeSetup::new(
            Profile::test(80.0, 32).with_quality(0.60),
            NodePolicy {
                stake: 20 * CREDIT,
                accept_freq: 1.0,
                ..Default::default()
            },
        )
        .with_generator(gen(1, vec![Phase::new(0.0, horizon, 30.0)])),
        // 2: enterprise — overloaded by its own users, offloads overflow.
        NodeSetup::new(
            Profile::test(40.0, 16).with_quality(0.78),
            NodePolicy {
                stake: 10 * CREDIT,
                offload_freq: 1.0,
                target_utilization: 0.5,
                accept_freq: 0.3,
                ..Default::default()
            },
        )
        .with_generator(gen(2, vec![
            Phase::new(0.0, 400.0, 2.5),
            Phase::new(400.0, horizon, 10.0),
        ])),
        // 3: hobbyist — small GPU, evening hours only (joins at t=300).
        NodeSetup::new(
            Profile::test(25.0, 8).with_quality(0.75),
            NodePolicy {
                stake: 5 * CREDIT,
                accept_freq: 1.0,
                ..Default::default()
            },
        )
        .offline(),
        // 4: freeloader — minimal stake, poor quality.
        NodeSetup::new(
            Profile::test(50.0, 16).with_quality(0.35),
            NodePolicy {
                stake: 2 * CREDIT,
                accept_freq: 1.0,
                ..Default::default()
            },
        ),
    ];

    let cfg = WorldConfig {
        seed: 7,
        ledger: LedgerMode::Blockchain, // full §4.1 machinery
        system: SystemPolicy {
            duel_rate: 0.25,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut w = World::new(cfg, setups);
    w.schedule_join(3, 300.0);
    w.run_until(horizon + 4000.0);

    let names = ["hyperscaler", "startup", "enterprise", "hobbyist", "freeloader"];
    println!("== WWW.Serve open market ({} blocks ledgered) ==\n", {
        match w.node(0).ledger() {
            LedgerManager::Chain(r) => r.chain.len(),
            _ => 0,
        }
    });
    println!(
        "requests completed {}  SLO {:.1}%  mean latency {:.1}s  duels {}",
        w.recorder.user_records().count(),
        w.recorder.slo_attainment() * 100.0,
        w.recorder.mean_latency(),
        w.duel_stats.total_duels(),
    );
    println!("\nrole          served  deleg-in  deleg-out  win-rate  credits (Δ from 100)");
    let served = w.recorder.served_by();
    let totals = w.credit_totals();
    for i in 0..5 {
        let s = w.node(i).stats;
        println!(
            "{:<12} {:>7} {:>9} {:>10} {:>9.2} {:>8.1} ({:+.1})",
            names[i],
            served.get(&NodeId(i as u32)).copied().unwrap_or(0),
            s.delegated_in,
            s.delegated_out,
            w.duel_stats.win_rate(NodeId(i as u32)),
            totals[i],
            totals[i] - 100.0,
        );
    }

    // Ledger replicas agree (decentralized consistency check).
    let head_lens: Vec<usize> = (0..5)
        .map(|i| match w.node(i).ledger() {
            LedgerManager::Chain(r) => r.chain.len(),
            _ => 0,
        })
        .collect();
    println!("\nchain lengths per replica: {head_lens:?}");

    // Market-shape assertions.
    let hyper = totals[0];
    let free = totals[4];
    assert!(
        hyper > 100.0,
        "the hyperscaler should profit (got {hyper:.1})"
    );
    assert!(
        w.duel_stats.win_rate(NodeId(4)) < 0.45,
        "freeloader should lose duels"
    );
    println!("\nmarket OK: capacity sellers profit, low quality loses duels.");
    let _ = free;
}
