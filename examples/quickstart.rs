//! Quickstart: a 4-node WWW.Serve market, simulated.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the whole public API surface in ~60 lines: profiles, policies,
//! workload generators, the deterministic World, and the metrics you get
//! back (SLO attainment, latency percentiles, credits, duel stats).

use wwwserve::backend::{Gpu, ModelClass, Profile, ServingStack};
use wwwserve::policy::NodePolicy;
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::workload::{Generator, Phase};
use wwwserve::NodeId;

fn main() {
    // Three provider tiers (Table-3 style) + defaults from Appendix C.
    let profiles = [
        Profile::derive(ModelClass::Qwen3_8B, Gpu::Ada6000, ServingStack::SgLang),
        Profile::derive(ModelClass::Qwen3_8B, Gpu::L40S, ServingStack::SgLang),
        Profile::derive(ModelClass::Qwen3_4B, Gpu::Rtx4090, ServingStack::SgLang),
        Profile::derive(ModelClass::Qwen3_4B, Gpu::Rtx3090, ServingStack::Vllm),
    ];

    // Node 0 gets a burst for the first 300 s (1/λ = 4 s), everyone else a
    // light trickle — the exact imbalance decentralized offload fixes.
    let setups: Vec<NodeSetup> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let phases = if i == 0 {
                vec![Phase::new(0.0, 300.0, 4.0), Phase::new(300.0, 750.0, 20.0)]
            } else {
                vec![Phase::new(0.0, 750.0, 20.0)]
            };
            NodeSetup::new(*p, NodePolicy::default())
                .with_generator(Generator::new(NodeId(i as u32), phases))
        })
        .collect();

    let mut world = World::new(WorldConfig { seed: 42, ..Default::default() }, setups);
    world.run_until(3000.0); // run past the 750 s schedule so queues drain

    let rec = &world.recorder;
    println!("== WWW.Serve quickstart (4 nodes, 750 s schedule) ==");
    println!("user requests completed : {}", rec.user_records().count());
    println!("SLO attainment          : {:.1}%", rec.slo_attainment() * 100.0);
    println!("mean latency            : {:.1} s", rec.mean_latency());
    println!("p50 / p99 latency       : {:.1} / {:.1} s",
             rec.latency_percentile(0.5).unwrap_or(f64::NAN),
             rec.latency_percentile(0.99).unwrap_or(f64::NAN));
    println!("duels settled           : {}", world.duel_stats.total_duels());
    println!("messages exchanged      : {}", world.messages_sent);

    println!("\nper-node outcomes:");
    let served = rec.served_by();
    for i in 0..world.num_nodes() {
        let node = world.node(i);
        println!(
            "  node {i}: served {:>4} (delegated-in {:>3}, offloaded {:>3})  credits {:>7.2}  win-rate {:.2}",
            served.get(&NodeId(i as u32)).copied().unwrap_or(0),
            node.stats.delegated_in,
            node.stats.delegated_out,
            world.credit_totals()[i],
            world.duel_stats.win_rate(NodeId(i as u32)),
        );
    }
}
