//! Regenerate every figure and table of the paper's evaluation.
//!
//! ```bash
//! cargo run --release --example reproduce -- all
//! cargo run --release --example reproduce -- fig4   # or table2, fig5..fig8
//! ```
//!
//! Paper-vs-measured comparisons are recorded in EXPERIMENTS.md; this
//! binary prints the measured side.

use wwwserve::benchlib::Table;
use wwwserve::repro::{self, Fig6Variant, SLO_SCALES};
use wwwserve::schedulers::Strategy;
use wwwserve::workload::SettingId;

const SEED: u64 = 2026;

fn fig4_table2() {
    println!("\n===== Figure 4 + Table 2: scheduling efficiency =====");
    let runs = repro::fig4_table2(SEED);

    println!("\n-- Figure 4: SLO attainment (at deadline scale 1.0) --");
    let mut t = Table::new(&["Setting", "Single", "Centralized", "Decentralized", "dec/single"]);
    for id in SettingId::ALL {
        let get = |s: Strategy| {
            runs.iter()
                .find(|r| r.setting == id && r.strategy == s)
                .unwrap()
        };
        let (si, ce, de) = (
            get(Strategy::Single),
            get(Strategy::Centralized),
            get(Strategy::Decentralized),
        );
        t.row(vec![
            id.name().into(),
            format!("{:.3}", si.slo_attainment),
            format!("{:.3}", ce.slo_attainment),
            format!("{:.3}", de.slo_attainment),
            format!("{:.2}x", de.slo_attainment / si.slo_attainment.max(1e-9)),
        ]);
    }
    t.print();

    println!("\n-- Figure 4 curves: SLO attainment vs deadline scale --");
    for id in SettingId::ALL {
        println!("{}:", id.name());
        for s in [Strategy::Single, Strategy::Centralized, Strategy::Decentralized] {
            let r = runs
                .iter()
                .find(|r| r.setting == id && r.strategy == s)
                .unwrap();
            let pts: Vec<String> = SLO_SCALES
                .iter()
                .zip(r.slo_curve.iter())
                .map(|(x, (_, y))| format!("{x:.2}:{y:.2}"))
                .collect();
            println!("  {:<14} {}", s.name(), pts.join("  "));
        }
    }

    println!("\n-- Table 2: average request latency (s) --");
    let mut t = Table::new(&["Setting", "Single", "Centralized", "Decentralized"]);
    for id in SettingId::ALL {
        let get = |s: Strategy| {
            runs.iter()
                .find(|r| r.setting == id && r.strategy == s)
                .unwrap()
                .mean_latency
        };
        t.row(vec![
            id.name().into(),
            format!("{:.1}", get(Strategy::Single)),
            format!("{:.1}", get(Strategy::Centralized)),
            format!("{:.1}", get(Strategy::Decentralized)),
        ]);
    }
    t.print();
    println!("(paper: decentralized ≈/≤ centralized, up to ~27.6% below single)");
}

fn fig5() {
    println!("\n===== Figure 5: dynamic participation =====");
    for (label, run) in [
        ("5a: join (2 -> 4 nodes)", repro::fig5_join(SEED)),
        ("5b: leave (4 -> 2 nodes)", repro::fig5_leave(SEED)),
    ] {
        println!("\n-- {label} --  events: {:?}", run.events);
        println!("  t(s)    mean latency (25 s windows)");
        for (t, l) in &run.windowed_latency {
            if *t <= 800.0 {
                let bar_len = (*l / 4.0).min(60.0) as usize;
                println!("  {t:>6.0}  {l:>8.1}  {}", "#".repeat(bar_len));
            }
        }
        println!("  completed: {}", run.completed);
    }
    println!("(paper: latency falls after joins, rises after leaves)");
}

fn fig6() {
    println!("\n===== Figure 6: quality incentivization =====");
    for variant in Fig6Variant::ALL {
        let run = repro::fig6(variant, SEED);
        println!("\n-- {} --  ({} duels settled)", variant.name(), run.total_duels);
        let mut t = Table::new(&["Class", "served", "win-rate", "final credits"]);
        for c in &run.classes {
            t.row(vec![
                c.label.clone(),
                format!("{}", c.served),
                format!("{:.2}", c.win_rate),
                format!("{:.1}", c.final_credits),
            ]);
        }
        t.print();
        // Compact credit trajectories (5 samples per class).
        for c in &run.classes {
            let n = c.credit_curve.len();
            if n == 0 {
                continue;
            }
            let pick: Vec<String> = (0..5)
                .map(|i| {
                    let (t, v) = c.credit_curve[(i * (n - 1)) / 4];
                    format!("{:.0}s:{v:.0}", t)
                })
                .collect();
            println!("  {:<12} credits over time: {}", c.label, pick.join("  "));
        }
    }
    println!("\n(paper 6a win rates 0.57/0.53/0.39; 6b 0.54/0.49/0.47; 6c served 788/786/426; 6d served 1717/1195/1088)");
}

fn fig7() {
    println!("\n===== Figure 7: duel-rate ablation (k = 2 judges) =====");
    let runs: Vec<_> = [0.05, 0.10, 0.25]
        .iter()
        .map(|p| repro::fig7(*p, SEED))
        .collect();

    println!("\n-- latency CDF --");
    print!("  latency(s)");
    for r in &runs {
        print!("   p_d={:.2}", r.duel_rate);
    }
    println!();
    for i in (0..40).step_by(4) {
        print!("  {:>9.0}", runs[0].latency_cdf[i].0);
        for r in &runs {
            print!("   {:>7.3}", r.latency_cdf[i].1);
        }
        println!();
    }

    println!("\n-- SLO attainment + overhead --");
    let mut t = Table::new(&[
        "duel rate", "SLO@1.0", "mean lat (s)", "user reqs", "synthetic",
        "predicted extra",
    ]);
    for r in &runs {
        let predicted = r.delegated as f64 * r.duel_rate * 3.0;
        t.row(vec![
            format!("{:.2}", r.duel_rate),
            format!("{:.3}", r.slo_curve[3].1),
            format!("{:.1}", r.mean_latency),
            format!("{}", r.completed),
            format!("{}", r.synthetic),
            format!("{:.0}", predicted),
        ]);
    }
    t.print();
    println!("(paper: near-identical CDFs/SLO across 5/10/25%; extra = N·α·p_d·(1+k))");
}

fn fig8() {
    println!("\n===== Figure 8: user-level policies =====");
    let a = repro::fig8a(SEED);
    println!("\n-- 8a: stake amounts 1/2/3/4 --");
    let mut t = Table::new(&["stake", "served", "share"]);
    for (s, n, f) in &a.rows {
        t.row(vec![format!("{s:.0}"), format!("{n}"), format!("{f:.2}")]);
    }
    t.print();

    let b = repro::fig8b(SEED);
    println!("\n-- 8b: acceptance frequencies 0.25/0.5/0.75/1.0 --");
    let mut t = Table::new(&["accept freq", "served", "share"]);
    for (s, n, f) in &b.rows {
        t.row(vec![format!("{s:.2}"), format!("{n}"), format!("{f:.2}")]);
    }
    t.print();

    let c = repro::fig8c(SEED);
    println!("\n-- 8c: offloading frequencies under pressure --");
    let mut t = Table::new(&["offload freq", "SLO attainment", "mean latency (s)"]);
    for (f, slo, lat) in &c.rows {
        t.row(vec![
            format!("{f:.2}"),
            format!("{slo:.3}"),
            format!("{lat:.1}"),
        ]);
    }
    t.print();
    println!("(paper: share tracks stake/accept-freq; offload gains saturate ≥0.5)");
}

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let t0 = std::time::Instant::now();
    match arg.as_str() {
        "fig4" | "table2" => fig4_table2(),
        "fig5" => fig5(),
        "fig6" => fig6(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "all" => {
            fig4_table2();
            fig5();
            fig6();
            fig7();
            fig8();
        }
        other => {
            eprintln!("unknown target '{other}' (fig4|fig5|fig6|fig7|fig8|all)");
            std::process::exit(2);
        }
    }
    eprintln!("\n[reproduce] done in {:.1}s", t0.elapsed().as_secs_f64());
}
