"""AOT compile path: lower the L2 model to HLO text artifacts for Rust.

Runs ONCE at build time (``make artifacts``); Python is never on the request
path. Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/load_hlo and its README for the full gotcha
list.

Outputs (``artifacts/``):

* ``decode_b{B}.hlo.txt``   — one decode step at batch size B
* ``prefill_b{B}_s{S}.hlo.txt`` — prefill at batch B, padded prompt length S
* ``params.bin``            — all parameters, f32 little-endian, in
  ``model.param_spec`` order
* ``manifest.json``         — model config, parameter spec, artifact table
  (argument/result shapes in call order), seed

Rust's ``runtime::Engine`` reads the manifest, memory-loads ``params.bin``
and compiles each HLO module once at startup.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DECODE_BATCH_SIZES = (1, 2, 4, 8)
PREFILL_SHAPES = ((1, 64), (4, 64))  # (batch, padded prompt length)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract(params):
    return [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]


def lower_decode(cfg: M.ModelConfig, params, batch: int) -> str:
    def fn(params, k_cache, v_cache, tokens, lens):
        return M.decode_step(cfg, params, k_cache, v_cache, tokens, lens)

    L, H, D, S = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.max_seq
    kv = jax.ShapeDtypeStruct((L, batch, H, S, D), jnp.float32)
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(fn).lower(_abstract(params), kv, kv, tok, lens)
    return to_hlo_text(lowered)


def lower_prefill(cfg: M.ModelConfig, params, batch: int, seq: int) -> str:
    def fn(params, tokens, lens):
        return M.prefill(cfg, params, tokens, lens)

    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lowered = jax.jit(fn).lower(_abstract(params), tok, lens)
    return to_hlo_text(lowered)


def artifact_entry(kind: str, cfg: M.ModelConfig, batch: int, seq: int | None,
                   path: str) -> dict:
    """Manifest row describing one compiled executable's calling convention."""
    L, H, D, S = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.max_seq
    n_params = len(M.param_spec(cfg))
    if kind == "decode":
        extra_args = [
            {"name": "k_cache", "shape": [L, batch, H, S, D], "dtype": "f32"},
            {"name": "v_cache", "shape": [L, batch, H, S, D], "dtype": "f32"},
            {"name": "tokens", "shape": [batch], "dtype": "i32"},
            {"name": "lens", "shape": [batch], "dtype": "i32"},
        ]
        results = [
            {"name": "logits", "shape": [batch, cfg.vocab], "dtype": "f32"},
            {"name": "k_cache", "shape": [L, batch, H, S, D], "dtype": "f32"},
            {"name": "v_cache", "shape": [L, batch, H, S, D], "dtype": "f32"},
        ]
    else:
        extra_args = [
            {"name": "tokens", "shape": [batch, seq], "dtype": "i32"},
            {"name": "lens", "shape": [batch], "dtype": "i32"},
        ]
        results = [
            {"name": "logits", "shape": [batch, cfg.vocab], "dtype": "f32"},
            {"name": "k_cache", "shape": [L, batch, H, S, D], "dtype": "f32"},
            {"name": "v_cache", "shape": [L, batch, H, S, D], "dtype": "f32"},
        ]
    return {
        "kind": kind,
        "batch": batch,
        "seq": seq,
        "path": path,
        "num_param_args": n_params,
        "extra_args": extra_args,
        "results": results,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--config", choices=["tiny", "test", "large"],
                    default="tiny")
    ap.add_argument("--decode-batches", type=int, nargs="*",
                    default=list(DECODE_BATCH_SIZES))
    ap.add_argument("--skip-prefill", action="store_true")
    args = ap.parse_args()

    cfg = getattr(M.ModelConfig, args.config)()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    params = M.init_params(cfg, seed=args.seed)
    print(f"[aot] config={args.config} params={M.num_params(cfg):,}")

    # Parameters: one contiguous f32 LE blob in param_spec order.
    blob = np.concatenate(
        [np.asarray(p, dtype="<f4").reshape(-1) for p in params])
    blob.tofile(os.path.join(args.out_dir, "params.bin"))
    print(f"[aot] params.bin {blob.nbytes / 1e6:.1f} MB")

    artifacts = []
    for b in args.decode_batches:
        name = f"decode_b{b}.hlo.txt"
        text = lower_decode(cfg, params, b)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        artifacts.append(artifact_entry("decode", cfg, b, None, name))
        print(f"[aot] {name} {len(text) / 1e3:.0f} kB")

    if not args.skip_prefill:
        for b, s in PREFILL_SHAPES:
            s = min(s, cfg.max_seq)  # padded prompt cannot exceed the cache
            name = f"prefill_b{b}_s{s}.hlo.txt"
            text = lower_prefill(cfg, params, b, s)
            with open(os.path.join(args.out_dir, name), "w") as f:
                f.write(text)
            artifacts.append(artifact_entry("prefill", cfg, b, s, name))
            print(f"[aot] {name} {len(text) / 1e3:.0f} kB")

    manifest = {
        "model": {
            "config": args.config,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_head": cfg.d_head,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "num_params": M.num_params(cfg),
            "seed": args.seed,
        },
        "param_spec": [
            {"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)
        ],
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] done in {time.time() - t0:.1f}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
