"""L1 Pallas kernel: fused single-query (decode) attention with blocked KV.

This is the serving hot-spot of WWW.Serve's local-execution path (the paper's
Model Manager executes inference on the node's own backend; our backend is the
AOT-compiled transformer in ``python/compile/model.py``, whose decode step
calls this kernel).

Design (TPU idioms — see DESIGN.md §Hardware-Adaptation):

* Grid is ``(batch, heads, S // block_s)``: the KV sequence is tiled into
  VMEM-sized blocks via ``BlockSpec``; this expresses the HBM->VMEM schedule
  a CUDA kernel would write with threadblocks + shared memory.
* Online softmax: running max ``m``, normalizer ``l`` and weighted
  accumulator ``acc`` live in VMEM scratch that persists across the
  sequential KV-block grid steps on a core (flash-attention-2 decode
  pattern). The final grid step writes ``acc / l``.
* Head dim (default 64) and block_s (default 128) keep the q·K^T and p·V
  contractions MXU-shaped (128x128 systolic tiles, bf16-friendly).
* Per-batch valid lengths ``lens`` mask out cache slots beyond the current
  position, so one compiled kernel serves a continuous batch of requests at
  different decode positions.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; correctness is validated against ``ref.py`` and real-TPU
performance is estimated analytically (DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 128
NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() exact-zero
# without generating nan via (-inf) - (-inf) in the rescale step.


def _decode_attn_kernel(
    lens_ref,  # [1]      int32   valid length for this batch row
    q_ref,     # [1,1,D]  f32     query for (b, h)
    k_ref,     # [1,1,Bs,D] f32   KV block j
    v_ref,     # [1,1,Bs,D] f32
    o_ref,     # [1,1,D]  f32     output for (b, h)
    m_ref,     # [1]      f32     scratch: running max
    l_ref,     # [1]      f32     scratch: running normalizer
    acc_ref,   # [D]      f32     scratch: running weighted sum
    *,
    block_s: int,
    num_blocks: int,
    sm_scale: float,
):
    j = pl.program_id(2)

    # Reset the online-softmax state at the first KV block of each (b, h).
    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :]          # [D]
    k = k_ref[0, 0, :, :]       # [Bs, D]
    v = v_ref[0, 0, :, :]       # [Bs, D]

    # Scores for this KV block: q . k^T  -> [Bs]
    s = jnp.dot(k, q) * sm_scale

    # Mask cache slots at or beyond the valid length.
    length = lens_ref[0]
    positions = j * block_s + jax.lax.iota(jnp.int32, block_s)
    s = jnp.where(positions < length, s, NEG_INF)

    valid = positions < length
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    # Explicitly zero masked lanes: in a fully-masked block m_new == NEG_INF
    # and exp(s - m_new) would otherwise evaluate to exp(0) == 1.
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # [Bs]
    alpha = jnp.exp(m_prev - m_new)             # rescale of old state

    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[0] = m_new

    # Last block: normalize and emit.
    @pl.when(j == num_blocks - 1)
    def _finalize():
        # Guard against length == 0 (no valid slots): emit zeros.
        denom = jnp.where(l_ref[0] > 0.0, l_ref[0], 1.0)
        o_ref[0, 0, :] = acc_ref[...] / denom


@functools.partial(jax.jit, static_argnames=("block_s", "sm_scale"))
def flash_decode_attention(
    q: jax.Array,     # [B, H, D]
    k: jax.Array,     # [B, H, S, D]  KV cache (padded to S)
    v: jax.Array,     # [B, H, S, D]
    lens: jax.Array,  # [B] int32     valid entries per batch row
    *,
    block_s: int = DEFAULT_BLOCK_S,
    sm_scale: float | None = None,
) -> jax.Array:
    """Single-query attention over a padded KV cache.

    Returns [B, H, D]. Entries of ``k``/``v`` at positions >= ``lens[b]`` are
    ignored. Rows with ``lens[b] == 0`` return zeros.
    """
    B, H, D = q.shape
    S = k.shape[2]
    if S % block_s != 0:
        # Pad the cache to a whole number of blocks; masking handles the rest.
        pad = block_s - S % block_s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S = S + pad
    num_blocks = S // block_s
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)

    grid = (B, H, num_blocks)
    kernel = functools.partial(
        _decode_attn_kernel,
        block_s=block_s,
        num_blocks=num_blocks,
        sm_scale=sm_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),               # lens
            pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),     # q
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu_scratch((1,), jnp.float32),
            pltpu_scratch((1,), jnp.float32),
            pltpu_scratch((D,), jnp.float32),
        ],
        interpret=True,
    )(lens.astype(jnp.int32), q, k, v)


def pltpu_scratch(shape, dtype):
    """VMEM scratch allocation (portable: falls back off-TPU)."""
    from jax.experimental.pallas import tpu as pltpu

    try:
        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - older/newer API fallback
        return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Analytical TPU performance model (interpret=True gives no TPU timings).
# ---------------------------------------------------------------------------

def vmem_footprint_bytes(D: int, block_s: int, dtype_bytes: int = 4) -> int:
    """Per-core VMEM resident set of one grid step.

    q block + k block + v block + output + scratch(m, l, acc).
    """
    q = D * dtype_bytes
    kv = 2 * block_s * D * dtype_bytes
    out = D * dtype_bytes
    scratch = (1 + 1 + D) * dtype_bytes
    return q + kv + out + scratch


def mxu_utilization_estimate(D: int, block_s: int) -> float:
    """Fraction of MXU 128x128 tile lanes doing useful work.

    The two contractions per block are [1,D]x[D,Bs] and [1,Bs]x[Bs,D]:
    single-query decode keeps only 1 of 128 MXU rows busy unless batched;
    utilization = (D/128 ceil-efficiency) * (Bs/128 ceil-efficiency) / 128
    for a naive mapping, so the practical schedule packs (B*H) programs.
    Reported per DESIGN.md §7 for the default D=64, Bs=128 tiling.
    """
    import math

    def tile_eff(n: int) -> float:
        return n / (math.ceil(n / 128) * 128)

    return tile_eff(D) * tile_eff(block_s)
