"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every kernel in this package must match its reference here to float32
tolerance across the hypothesis sweep in ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,     # [B, H, D]
    k: jax.Array,     # [B, H, S, D]
    v: jax.Array,     # [B, H, S, D]
    lens: jax.Array,  # [B] int32
    *,
    sm_scale: float | None = None,
) -> jax.Array:
    """Masked single-query attention, computed the naive stable way."""
    B, H, D = q.shape
    S = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)

    s = jnp.einsum("bhd,bhsd->bhs", q, k) * sm_scale          # [B, H, S]
    mask = jnp.arange(S)[None, :] < lens[:, None]             # [B, S]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)

    # Stable softmax that yields all-zeros for fully-masked rows.
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.where(denom > 0.0, denom, 1.0)
    return jnp.einsum("bhs,bhsd->bhd", p / denom, v)


def causal_attention_ref(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, S, H, D]
    v: jax.Array,  # [B, S, H, D]
    *,
    sm_scale: float | None = None,
) -> jax.Array:
    """Full causal self-attention (prefill path oracle)."""
    B, S, H, D = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bihd,bjhd->bhij", q, k) * sm_scale
    causal = jnp.tril(jnp.ones((S, S), dtype=bool))
    s = jnp.where(causal[None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhij,bjhd->bihd", p, v)
