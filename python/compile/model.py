"""L2: JAX transformer (decoder-only) served by WWW.Serve nodes.

Build-time only — lowered AOT to HLO text by ``aot.py`` and executed from the
Rust model manager via PJRT. The decode step's attention is the L1 Pallas
kernel (``kernels.flash_decode``); prefill uses a fused jnp causal attention
(prefill is compute-bound and XLA fuses it well; decode is the per-token hot
path the kernel targets).

Interchange contract with Rust (see ``aot.py`` manifest):

* Parameters are a *flat list* of f32 arrays in the order produced by
  ``param_spec`` — Rust loads them from ``artifacts/params.bin``.
* ``prefill(params, tokens[B,S], lens[B])`` -> ``(logits[B,V], k, v)``
  where ``k``/``v`` are ``[L, B, H, Smax, D]`` caches padded to ``max_seq``.
* ``decode_step(params, k, v, tokens[B], lens[B])`` -> same triple; writes
  each row's new KV at position ``lens[b]`` and attends over ``lens[b]+1``
  entries. The caller owns the length bookkeeping.

Rows are independent: a continuous batcher can pack unrelated requests at
different positions into one call (this is exactly what the Rust
``runtime::Batcher`` does).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.flash_decode import flash_decode_attention
from .kernels.ref import causal_attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyperparameters.

    The default ("tiny", ~3.6 M params) is the serving model for tests and
    the e2e example; ``large()`` (~124 M params) exists to prove the compile
    path scales and for the training-scale shape checks.
    """

    vocab: int = 512
    d_model: int = 256
    n_heads: int = 4
    d_head: int = 64
    n_layers: int = 4
    d_ff: int = 1024
    max_seq: int = 256

    @staticmethod
    def tiny() -> "ModelConfig":
        return ModelConfig()

    @staticmethod
    def test() -> "ModelConfig":
        """2-layer micro config for fast unit tests."""
        return ModelConfig(vocab=64, d_model=32, n_heads=2, d_head=16,
                           n_layers=2, d_ff=64, max_seq=32)

    @staticmethod
    def large() -> "ModelConfig":
        """GPT-2-small-ish scale (~117 M params)."""
        return ModelConfig(vocab=16384, d_model=768, n_heads=12, d_head=64,
                           n_layers=12, d_ff=3072, max_seq=512)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) for every parameter array, in interchange order."""
    d, h, dh, ff, v, s = (cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ff,
                          cfg.vocab, cfg.max_seq)
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (v, d)),
        ("pos_embed", (s, d)),
    ]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}.ln1_scale", (d,)),
            (f"l{i}.ln1_bias", (d,)),
            (f"l{i}.wq", (d, h * dh)),
            (f"l{i}.wk", (d, h * dh)),
            (f"l{i}.wv", (d, h * dh)),
            (f"l{i}.wo", (h * dh, d)),
            (f"l{i}.ln2_scale", (d,)),
            (f"l{i}.ln2_bias", (d,)),
            (f"l{i}.w1", (d, ff)),
            (f"l{i}.b1", (ff,)),
            (f"l{i}.w2", (ff, d)),
            (f"l{i}.b2", (d,)),
        ]
    spec += [
        ("lnf_scale", (d,)),
        ("lnf_bias", (d,)),
        ("lm_head", (d, v)),
    ]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Scaled-normal initialization, deterministic in ``seed``."""
    key = jax.random.PRNGKey(seed)
    params: List[jax.Array] = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base.startswith("ln") or base in ("b1", "b2"):
            if "scale" in base:
                params.append(jnp.ones(shape, jnp.float32))
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def _unpack(cfg: ModelConfig, params: Sequence[jax.Array]):
    """Name-indexed view over the flat parameter list."""
    names = [n for n, _ in param_spec(cfg)]
    return dict(zip(names, params))


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Sequence[jax.Array],
            tokens: jax.Array, lens: jax.Array):
    """Process padded prompts; build KV caches and last-token logits.

    tokens: [B, S] int32 (padded; entries >= lens[b] ignored)
    lens:   [B] int32 actual prompt lengths (>= 1)
    Returns (logits[B, V], k_cache[L,B,H,Smax,D], v_cache[L,B,H,Smax,D]).
    """
    p = _unpack(cfg, params)
    B, S = tokens.shape
    L, H, D, Smax = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.max_seq

    x = p["embed"][tokens] + p["pos_embed"][:S][None, :, :]   # [B, S, d]

    k_cache = jnp.zeros((L, B, H, Smax, D), jnp.float32)
    v_cache = jnp.zeros((L, B, H, Smax, D), jnp.float32)

    for i in range(L):
        h_in = _layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
        q = (h_in @ p[f"l{i}.wq"]).reshape(B, S, H, D)
        k = (h_in @ p[f"l{i}.wk"]).reshape(B, S, H, D)
        v = (h_in @ p[f"l{i}.wv"]).reshape(B, S, H, D)
        attn = causal_attention_ref(q, k, v)                   # [B, S, H, D]
        x = x + attn.reshape(B, S, H * D) @ p[f"l{i}.wo"]
        h2 = _layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
        x = x + jax.nn.gelu(h2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"]) @ p[f"l{i}.w2"] + p[f"l{i}.b2"]

        # [B, S, H, D] -> [B, H, S, D], padded into the Smax cache.
        k_cache = k_cache.at[i, :, :, :S, :].set(k.transpose(0, 2, 1, 3))
        v_cache = v_cache.at[i, :, :, :S, :].set(v.transpose(0, 2, 1, 3))

    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    # Logits at each row's last valid position.
    idx = jnp.clip(lens - 1, 0, S - 1)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0, :]
    logits = last @ p["lm_head"]                               # [B, V]
    return logits, k_cache, v_cache


# --------------------------------------------------------------------------
# Decode step (the request-path hot spot; attention = Pallas kernel)
# --------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: Sequence[jax.Array],
                k_cache: jax.Array, v_cache: jax.Array,
                tokens: jax.Array, lens: jax.Array):
    """One token for every row of a continuous batch.

    tokens: [B] int32 — current input token per row
    lens:   [B] int32 — number of KV entries already in the cache per row;
            the new token's KV is written at position ``lens[b]``.
    Returns (logits[B, V], k_cache', v_cache').
    """
    p = _unpack(cfg, params)
    B = tokens.shape[0]
    L, H, D = cfg.n_layers, cfg.n_heads, cfg.d_head

    pos = jnp.clip(lens, 0, cfg.max_seq - 1)
    x = p["embed"][tokens] + p["pos_embed"][pos]               # [B, d]

    batch_idx = jnp.arange(B)

    for i in range(L):
        h_in = _layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
        q = (h_in @ p[f"l{i}.wq"]).reshape(B, H, D)
        k = (h_in @ p[f"l{i}.wk"]).reshape(B, H, D)
        v = (h_in @ p[f"l{i}.wv"]).reshape(B, H, D)

        # Scatter this step's K/V into each row's slot ``pos[b]``.
        k_cache = k_cache.at[i, batch_idx, :, pos, :].set(k)
        v_cache = v_cache.at[i, batch_idx, :, pos, :].set(v)

        attn = flash_decode_attention(
            q, k_cache[i], v_cache[i], lens + 1,
            block_s=min(128, cfg.max_seq),
        )                                                       # [B, H, D]
        x = x + attn.reshape(B, H * D) @ p[f"l{i}.wo"]
        h2 = _layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
        x = x + jax.nn.gelu(h2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"]) @ p[f"l{i}.w2"] + p[f"l{i}.b2"]

    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    logits = x @ p["lm_head"]                                  # [B, V]
    return logits, k_cache, v_cache


# --------------------------------------------------------------------------
# Pure-jnp full-sequence oracle (tests: decode chain == one-shot forward)
# --------------------------------------------------------------------------

def forward_full(cfg: ModelConfig, params: Sequence[jax.Array],
                 tokens: jax.Array):
    """All-position logits [B, S, V] computed without any cache."""
    p = _unpack(cfg, params)
    B, S = tokens.shape
    H, D = cfg.n_heads, cfg.d_head
    x = p["embed"][tokens] + p["pos_embed"][:S][None, :, :]
    for i in range(cfg.n_layers):
        h_in = _layer_norm(x, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
        q = (h_in @ p[f"l{i}.wq"]).reshape(B, S, H, D)
        k = (h_in @ p[f"l{i}.wk"]).reshape(B, S, H, D)
        v = (h_in @ p[f"l{i}.wv"]).reshape(B, S, H, D)
        attn = causal_attention_ref(q, k, v)
        x = x + attn.reshape(B, S, H * D) @ p[f"l{i}.wo"]
        h2 = _layer_norm(x, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
        x = x + jax.nn.gelu(h2 @ p[f"l{i}.w1"] + p[f"l{i}.b1"]) @ p[f"l{i}.w2"] + p[f"l{i}.b2"]
    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    return x @ p["lm_head"]
