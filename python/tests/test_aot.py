"""AOT path: lowered HLO text is parseable, manifest is consistent, and the
compiled module (via jax itself) agrees with the eager model."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig.test()
PARAMS = M.init_params(CFG, seed=0)


class TestLowering:
    def test_decode_lowers_to_hlo_text(self):
        text = aot.lower_decode(CFG, PARAMS, batch=1)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_prefill_lowers_to_hlo_text(self):
        text = aot.lower_prefill(CFG, PARAMS, batch=1, seq=8)
        assert text.startswith("HloModule")

    def test_decode_param_count_in_entry(self):
        """Entry signature must carry exactly the manifest's argument count:
        params + k_cache + v_cache + tokens + lens."""
        text = aot.lower_decode(CFG, PARAMS, batch=2)
        n_expected = len(M.param_spec(CFG)) + 4
        entry = text.split("entry_computation_layout={(")[1]
        entry = entry.split(")->")[0]
        # Count top-level array types (f32[...] / s32[...]) at depth 0.
        depth, count = 0, 1
        for ch in entry:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            elif ch == "," and depth == 0:
                count += 1
        assert count == n_expected

    def test_lowering_is_deterministic(self):
        a = aot.lower_decode(CFG, PARAMS, batch=1)
        b = aot.lower_decode(CFG, PARAMS, batch=1)
        assert a == b


class TestEndToEnd:
    def test_aot_main_writes_artifacts(self, tmp_path):
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
             "--config", "test", "--decode-batches", "1"],
            check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["model"]["config"] == "test"
        assert (out / "params.bin").exists()
        blob = np.fromfile(out / "params.bin", dtype="<f4")
        assert blob.size == manifest["model"]["num_params"]
        for art in manifest["artifacts"]:
            assert (out / art["path"]).exists()
            text = (out / art["path"]).read_text()
            assert text.startswith("HloModule")

    def test_manifest_param_spec_order(self, tmp_path):
        """params.bin slices, reshaped per manifest, reproduce init_params."""
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
             "--config", "test", "--decode-batches", "1",
             "--skip-prefill"],
            check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
        manifest = json.loads((out / "manifest.json").read_text())
        blob = np.fromfile(out / "params.bin", dtype="<f4")
        offset = 0
        for entry, param in zip(manifest["param_spec"], PARAMS):
            n = int(np.prod(entry["shape"]))
            got = blob[offset:offset + n].reshape(entry["shape"])
            np.testing.assert_allclose(got, np.asarray(param), rtol=1e-6)
            offset += n
        assert offset == blob.size
