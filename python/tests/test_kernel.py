"""L1 correctness: Pallas flash-decode kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel — the hypothesis sweep
covers shapes, block sizes, masking lengths and scale factors.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.flash_decode import (
    flash_decode_attention,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _check(B, H, S, D, lens, block_s, sm_scale=None, rtol=2e-5, atol=2e-5):
    q = _rand(0, (B, H, D))
    k = _rand(1, (B, H, S, D))
    v = _rand(2, (B, H, S, D))
    lens = jnp.asarray(lens, jnp.int32)
    out = flash_decode_attention(q, k, v, lens, block_s=block_s,
                                 sm_scale=sm_scale)
    ref = decode_attention_ref(q, k, v, lens, sm_scale=sm_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=rtol, atol=atol)


class TestBasic:
    def test_full_length(self):
        _check(2, 4, 128, 64, [128, 128], 128)

    def test_partial_lengths(self):
        _check(3, 2, 256, 32, [1, 100, 256], 128)

    def test_zero_length_rows(self):
        _check(3, 2, 128, 16, [0, 0, 0], 64)

    def test_mixed_zero(self):
        _check(4, 1, 64, 8, [0, 1, 63, 64], 32)

    def test_block_not_dividing_seq(self):
        # S=200 with block 128 forces the pad path.
        _check(2, 2, 200, 16, [137, 200], 128)

    def test_single_block(self):
        _check(1, 1, 32, 8, [17], 32)

    def test_block_larger_than_seq(self):
        _check(1, 2, 16, 8, [9], 64)

    def test_custom_scale(self):
        _check(2, 2, 64, 16, [40, 64], 32, sm_scale=0.25)

    def test_batch_one(self):
        _check(1, 8, 256, 64, [255], 128)

    def test_output_dtype_and_shape(self):
        q = _rand(0, (2, 3, 16))
        k = _rand(1, (2, 3, 64, 16))
        v = _rand(2, (2, 3, 64, 16))
        out = flash_decode_attention(q, k, v, jnp.array([5, 64]), block_s=32)
        assert out.shape == (2, 3, 16)
        assert out.dtype == jnp.float32

    def test_rows_independent(self):
        """Perturbing one batch row must not change the others."""
        q = _rand(0, (3, 2, 16))
        k = _rand(1, (3, 2, 64, 16))
        v = _rand(2, (3, 2, 64, 16))
        lens = jnp.array([10, 20, 30])
        base = flash_decode_attention(q, k, v, lens, block_s=32)
        q2 = q.at[1].set(q[1] * 3.0 + 1.0)
        pert = flash_decode_attention(q2, k, v, lens, block_s=32)
        np.testing.assert_allclose(np.asarray(base[0]), np.asarray(pert[0]))
        np.testing.assert_allclose(np.asarray(base[2]), np.asarray(pert[2]))
        assert not np.allclose(np.asarray(base[1]), np.asarray(pert[1]))

    def test_masked_tail_ignored(self):
        """Garbage beyond lens must not affect the result."""
        q = _rand(0, (1, 2, 16))
        k = _rand(1, (1, 2, 64, 16))
        v = _rand(2, (1, 2, 64, 16))
        lens = jnp.array([20])
        base = flash_decode_attention(q, k, v, lens, block_s=32)
        k2 = k.at[:, :, 20:, :].set(1e6)
        v2 = v.at[:, :, 20:, :].set(-1e6)
        pert = flash_decode_attention(q, k2, v2, lens, block_s=32)
        np.testing.assert_allclose(np.asarray(base), np.asarray(pert))


@hypothesis.settings(max_examples=40, deadline=None,
                     suppress_health_check=[hypothesis.HealthCheck.too_slow])
@hypothesis.given(
    B=st.integers(1, 4),
    H=st.integers(1, 4),
    S_blocks=st.integers(1, 4),
    D=st.sampled_from([8, 16, 32, 64]),
    block_s=st.sampled_from([16, 32, 64, 128]),
    data=st.data(),
)
def test_kernel_matches_ref_sweep(B, H, S_blocks, D, block_s, data):
    S = S_blocks * 32
    lens = data.draw(
        st.lists(st.integers(0, S), min_size=B, max_size=B), label="lens")
    _check(B, H, S, D, lens, block_s)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    B=st.integers(1, 3),
    scale_exp=st.integers(-3, 3),
    data=st.data(),
)
def test_kernel_scale_invariance_sweep(B, scale_exp, data):
    """Large/small magnitudes still match (online softmax stability)."""
    S, H, D = 64, 2, 16
    lens = data.draw(st.lists(st.integers(1, S), min_size=B, max_size=B))
    scale = 10.0 ** scale_exp
    q = _rand(0, (B, H, D)) * scale
    k = _rand(1, (B, H, S, D))
    v = _rand(2, (B, H, S, D))
    L = jnp.asarray(lens, jnp.int32)
    out = flash_decode_attention(q, k, v, L, block_s=32)
    ref = decode_attention_ref(q, k, v, L)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)


class TestPerfModel:
    """Analytical TPU estimates (DESIGN.md §7) stay within VMEM budgets."""

    def test_default_tiling_fits_vmem(self):
        # 16 MB VMEM per TensorCore; default tile must be far below it.
        assert vmem_footprint_bytes(64, 128) < 1 << 20

    def test_footprint_monotone_in_block(self):
        sizes = [vmem_footprint_bytes(64, b) for b in (64, 128, 256, 512)]
        assert sizes == sorted(sizes)

    def test_mxu_utilization_bounds(self):
        for d in (8, 64, 128):
            for b in (32, 128, 256):
                u = mxu_utilization_estimate(d, b)
                assert 0.0 < u <= 1.0

    def test_default_tiling_mxu(self):
        # D=64, Bs=128: 64/128 * 128/128 = 0.5 tile efficiency.
        assert abs(mxu_utilization_estimate(64, 128) - 0.5) < 1e-9
