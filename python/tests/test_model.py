"""L2 correctness: prefill/decode vs the cache-free full forward pass."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig.test()
PARAMS = M.init_params(CFG, seed=0)


def _tokens(key, B, S):
    return jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, CFG.vocab)


class TestShapes:
    def test_param_spec_count(self):
        spec = M.param_spec(CFG)
        assert len(spec) == 3 + 2 + 12 * CFG.n_layers

    def test_num_params_matches_init(self):
        total = sum(int(np.prod(p.shape)) for p in PARAMS)
        assert total == M.num_params(CFG)

    def test_prefill_shapes(self):
        B, S = 3, 16
        logits, kc, vc = M.prefill(CFG, PARAMS, _tokens(0, B, S),
                                   jnp.full((B,), S, jnp.int32))
        assert logits.shape == (B, CFG.vocab)
        assert kc.shape == (CFG.n_layers, B, CFG.n_heads, CFG.max_seq,
                            CFG.d_head)
        assert vc.shape == kc.shape

    def test_decode_shapes(self):
        B = 2
        _, kc, vc = M.prefill(CFG, PARAMS, _tokens(0, B, 8),
                              jnp.full((B,), 8, jnp.int32))
        logits, kc2, vc2 = M.decode_step(
            CFG, PARAMS, kc, vc,
            jnp.zeros((B,), jnp.int32), jnp.full((B,), 8, jnp.int32))
        assert logits.shape == (B, CFG.vocab)
        assert kc2.shape == kc.shape

    def test_init_deterministic(self):
        p2 = M.init_params(CFG, seed=0)
        for a, b in zip(PARAMS, p2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_init_seed_sensitivity(self):
        p2 = M.init_params(CFG, seed=1)
        assert any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(PARAMS, p2))

    def test_large_config_spec(self):
        large = M.ModelConfig.large()
        n = M.num_params(large)
        assert 100_000_000 < n < 160_000_000  # ~GPT-2-small scale


class TestConsistency:
    def test_prefill_matches_full_forward(self):
        B, S = 2, 12
        toks = _tokens(1, B, S)
        lens = jnp.array([7, 12], jnp.int32)
        logits, _, _ = M.prefill(CFG, PARAMS, toks, lens)
        full = M.forward_full(CFG, PARAMS, toks)
        for b, l in enumerate([7, 12]):
            np.testing.assert_allclose(
                np.asarray(logits[b]), np.asarray(full[b, l - 1]),
                rtol=1e-4, atol=1e-4)

    def test_decode_chain_matches_full_forward(self):
        """prefill + N decode steps == one-shot forward on the whole text."""
        B, S0, steps = 1, 6, 5
        toks = _tokens(2, B, S0)
        lens = jnp.full((B,), S0, jnp.int32)
        logits, kc, vc = M.prefill(CFG, PARAMS, toks, lens)
        seq = [int(t) for t in np.asarray(toks[0])]
        for step in range(steps):
            nxt = int(np.argmax(np.asarray(logits[0])))
            seq.append(nxt)
            logits, kc, vc = M.decode_step(
                CFG, PARAMS, kc, vc,
                jnp.array([nxt], jnp.int32), lens)
            lens = lens + 1
        full = M.forward_full(CFG, PARAMS, jnp.array([seq], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(full[0, -1]),
            rtol=1e-3, atol=1e-3)

    def test_batch_rows_independent(self):
        """Decode on a packed batch == decode on each row alone."""
        toks = _tokens(3, 2, 8)
        lens = jnp.array([5, 8], jnp.int32)
        _, kc, vc = M.prefill(CFG, PARAMS, toks, lens)
        nxt = jnp.array([1, 2], jnp.int32)
        packed, _, _ = M.decode_step(CFG, PARAMS, kc, vc, nxt, lens)
        for b in range(2):
            _, kc1, vc1 = M.prefill(CFG, PARAMS, toks[b:b + 1],
                                    lens[b:b + 1])
            solo, _, _ = M.decode_step(CFG, PARAMS, kc1, vc1,
                                       nxt[b:b + 1], lens[b:b + 1])
            np.testing.assert_allclose(np.asarray(packed[b]),
                                       np.asarray(solo[0]),
                                       rtol=1e-4, atol=1e-4)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    B=st.integers(1, 3),
    S=st.integers(2, 16),
    seed=st.integers(0, 10),
)
def test_prefill_full_forward_sweep(B, S, seed):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, CFG.vocab)
    lens = jnp.full((B,), S, jnp.int32)
    logits, _, _ = M.prefill(CFG, PARAMS, toks, lens)
    full = M.forward_full(CFG, PARAMS, toks)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
