//! Minimal Rust lexer for `detlint`.
//!
//! Not a real Rust front end — a single-pass state machine that does the two
//! things the rule engine needs and nothing more:
//!
//! 1. **Strip comments and literal contents** so rules never match inside a
//!    doc comment or a string fixture. Two views come back, both with the
//!    exact line structure of the input: `code` (comments stripped AND
//!    string/char literal contents blanked — what most rules scan) and
//!    `code_with_strings` (comments stripped, string literals kept — what
//!    the Debug-format rule D005 scans, since `{:?}` lives inside format
//!    string literals).
//! 2. **Extract `detlint:allow` annotations** from the comments it strips,
//!    before throwing the comment text away. An annotation suppresses
//!    findings on its own line (trailing comment) or the line directly
//!    below (annotation-only line above the offending statement), and its
//!    `reason="…"` is mandatory and non-empty — a reasonless allow is
//!    reported as malformed and suppresses nothing.
//!
//! Handled literal forms: line + nested block comments, `"…"` strings with
//! escapes, raw strings `r"…"` / `r#"…"#` (any hash count, byte/`br`
//! prefixes), char literals incl. escapes, and the `'a` lifetime-vs-char
//! ambiguity (a quote not closed within two chars and not opening an escape
//! is a lifetime and stays in code).

/// One parsed `// detlint:allow(D00x[,D00y]) reason="…"` annotation.
#[derive(Debug, Clone, PartialEq)]
pub struct Allow {
    /// 0-based line the annotation appears on.
    pub line: usize,
    /// Rule ids this annotation exempts, e.g. `["D002"]`.
    pub rules: Vec<String>,
    /// The mandatory free-text justification.
    pub reason: String,
}

/// A `detlint:allow` that failed to parse (bad rule list, missing or empty
/// reason). These never suppress and are themselves reported.
#[derive(Debug, Clone, PartialEq)]
pub struct MalformedAllow {
    /// 0-based line of the broken annotation.
    pub line: usize,
    /// What was wrong, for the report.
    pub what: String,
}

/// Lexer output: two stripped views of the source plus the annotations.
#[derive(Debug, Default)]
pub struct LexedFile {
    /// Comments stripped, string/char contents blanked. One entry per line.
    pub code: Vec<String>,
    /// Comments stripped, string literals kept. One entry per line.
    pub code_with_strings: Vec<String>,
    /// Well-formed `detlint:allow` annotations, in line order.
    pub allows: Vec<Allow>,
    /// Annotations that failed to parse.
    pub malformed: Vec<MalformedAllow>,
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Lex `source` into the stripped views + annotations.
pub fn lex(source: &str) -> LexedFile {
    let chars: Vec<char> = source.chars().collect();
    let mut code = String::with_capacity(source.len());
    let mut with_strings = String::with_capacity(source.len());
    // Comment segments as (start_line, text) for annotation extraction.
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut cur_comment = String::new();
    let mut cur_comment_line = 0usize;
    let mut line = 0usize;
    let mut state = State::Code;
    let mut i = 0usize;

    // Pushes one source char to both output views, substituting blanks as
    // the state demands. Newlines always pass through to keep line counts.
    macro_rules! emit {
        ($c:expr, $in_code:expr, $in_ws:expr) => {{
            let c = $c;
            if c == '\n' {
                code.push('\n');
                with_strings.push('\n');
                line += 1;
            } else {
                code.push(if $in_code { c } else { ' ' });
                with_strings.push(if $in_ws { c } else { ' ' });
            }
        }};
    }

    while i < chars.len() {
        let c = chars[i];
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    cur_comment.clear();
                    cur_comment_line = line;
                    emit!(c, false, false);
                    emit!('/', false, false);
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(1);
                    cur_comment.clear();
                    cur_comment_line = line;
                    emit!(c, false, false);
                    emit!('*', false, false);
                    i += 2;
                } else if c == '"' {
                    // Raw string? Look back over `#`s to an `r` (or `br`)
                    // that is not the tail of an identifier.
                    let mut hashes = 0usize;
                    let mut j = i;
                    while j > 0 && chars[j - 1] == '#' {
                        hashes += 1;
                        j -= 1;
                    }
                    let mut is_raw = false;
                    if j > 0 && chars[j - 1] == 'r' {
                        let before_r = if j >= 2 { Some(chars[j - 2]) } else { None };
                        let prefix_ok = match before_r {
                            Some('b') => !ident_char(chars.get(j.wrapping_sub(3)).copied()),
                            Some(p) => !ident_char(Some(p)),
                            None => true,
                        };
                        if prefix_ok {
                            is_raw = true;
                        }
                    }
                    if is_raw {
                        state = State::RawStr(hashes as u32);
                    } else {
                        state = State::Str;
                    }
                    emit!(c, true, true);
                    i += 1;
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    match chars.get(i + 1) {
                        Some('\\') => {
                            // Escaped char literal: consume to closing quote.
                            emit!(c, true, false);
                            i += 1;
                            while i < chars.len() {
                                let d = chars[i];
                                if d == '\\' {
                                    emit!(d, false, false);
                                    if let Some(&e) = chars.get(i + 1) {
                                        emit!(e, false, false);
                                    }
                                    i += 2;
                                } else if d == '\'' {
                                    emit!(d, true, false);
                                    i += 1;
                                    break;
                                } else {
                                    emit!(d, false, false);
                                    i += 1;
                                }
                            }
                        }
                        Some(_) if chars.get(i + 2) == Some(&'\'') => {
                            // Plain one-char literal like 'x' (or '"').
                            emit!(c, true, false);
                            emit!(chars[i + 1], false, false);
                            emit!('\'', true, false);
                            i += 3;
                        }
                        _ => {
                            // Lifetime (or stray quote): stays in code.
                            emit!(c, true, true);
                            i += 1;
                        }
                    }
                } else {
                    emit!(c, true, true);
                    i += 1;
                }
            }
            State::LineComment => {
                if c == '\n' {
                    comments.push((cur_comment_line, cur_comment.clone()));
                    state = State::Code;
                    emit!(c, true, true);
                } else {
                    cur_comment.push(c);
                    emit!(c, false, false);
                }
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(depth + 1);
                    cur_comment.push_str("/*");
                    emit!(c, false, false);
                    emit!('*', false, false);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    if depth == 1 {
                        comments.push((cur_comment_line, cur_comment.clone()));
                        state = State::Code;
                    } else {
                        state = State::BlockComment(depth - 1);
                        cur_comment.push_str("*/");
                    }
                    emit!(c, false, false);
                    emit!('/', false, false);
                    i += 2;
                } else {
                    cur_comment.push(c);
                    emit!(c, false, false);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    emit!(c, false, true);
                    if let Some(&e) = chars.get(i + 1) {
                        emit!(e, false, true);
                    }
                    i += 2;
                } else if c == '"' {
                    state = State::Code;
                    emit!(c, true, true);
                    i += 1;
                } else {
                    emit!(c, false, true);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let n = hashes as usize;
                    let closes = (0..n).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closes {
                        emit!(c, true, true);
                        for k in 0..n {
                            emit!(chars[i + 1 + k], true, true);
                        }
                        i += 1 + n;
                        state = State::Code;
                    } else {
                        emit!(c, false, true);
                        i += 1;
                    }
                } else {
                    emit!(c, false, true);
                    i += 1;
                }
            }
        }
    }
    // EOF inside a line comment still carries its annotation.
    if let State::LineComment = state {
        comments.push((cur_comment_line, cur_comment.clone()));
    }

    let mut out = LexedFile {
        code: code.split('\n').map(str::to_string).collect(),
        code_with_strings: with_strings.split('\n').map(str::to_string).collect(),
        allows: Vec::new(),
        malformed: Vec::new(),
    };
    for (start_line, text) in &comments {
        for (off, cline) in text.split('\n').enumerate() {
            parse_allows(start_line + off, cline, &mut out.allows, &mut out.malformed);
        }
    }
    out
}

fn ident_char(c: Option<char>) -> bool {
    matches!(c, Some(c) if c.is_alphanumeric() || c == '_')
}

const MARKER: &str = "detlint:allow";

/// Parse one comment line as an annotation, if it is one.
///
/// An annotation must be the comment's own content: the marker has to open
/// the comment line (after whitespace and doc/block decoration chars
/// `/ ! *`). A marker mentioned mid-comment — prose documenting the syntax,
/// like this very sentence's references to the annotation — is ignored
/// entirely rather than reported as malformed.
fn parse_allows(
    line: usize,
    text: &str,
    allows: &mut Vec<Allow>,
    malformed: &mut Vec<MalformedAllow>,
) {
    let head = text.trim_start_matches([' ', '\t', '/', '!', '*']);
    if head.starts_with(MARKER) {
        let rest = &head[MARKER.len()..];
        let Some(open) = rest.find('(') else {
            malformed.push(MalformedAllow {
                line,
                what: "missing rule list: expected detlint:allow(D00x)".into(),
            });
            return;
        };
        if !rest[..open].trim().is_empty() {
            malformed.push(MalformedAllow {
                line,
                what: "text between detlint:allow and '('".into(),
            });
            return;
        }
        let Some(close_rel) = rest[open..].find(')') else {
            malformed.push(MalformedAllow {
                line,
                what: "unclosed rule list".into(),
            });
            return;
        };
        let close = open + close_rel;
        let rules: Vec<String> = rest[open + 1..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let well_formed = !rules.is_empty()
            && rules.iter().all(|r| {
                r.len() == 4
                    && r.starts_with('D')
                    && r[1..].chars().all(|c| c.is_ascii_digit())
            });
        if !well_formed {
            malformed.push(MalformedAllow {
                line,
                what: format!("bad rule list {:?}: expected D-prefixed ids like D001", &rest[open + 1..close]),
            });
            return;
        }
        // Mandatory reason="…" after the rule list.
        let tail = rest[close + 1..].trim_start();
        let reason = tail
            .strip_prefix("reason=\"")
            .and_then(|t| t.find('"').map(|q| t[..q].trim().to_string()));
        match reason {
            Some(r) if !r.is_empty() => allows.push(Allow { line, rules, reason: r }),
            _ => malformed.push(MalformedAllow {
                line,
                what: "missing or empty reason: every allow needs reason=\"…\"".into(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_and_block_comments() {
        let src = "let a = 1; // trailing note\n/* block\nspanning */ let b = 2;\n";
        let lx = lex(src);
        assert_eq!(lx.code[0].trim_end(), "let a = 1;");
        assert!(!lx.code[0].contains("trailing"));
        assert!(!lx.code[1].contains("block"));
        assert!(lx.code[2].contains("let b = 2;"));
        assert!(!lx.code[2].contains("spanning"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ let x = 1;\n";
        let lx = lex(src);
        assert!(lx.code[0].contains("let x = 1;"));
        assert!(!lx.code[0].contains("inner"));
        assert!(!lx.code[0].contains("still"));
    }

    #[test]
    fn blanks_string_contents_in_code_view_only() {
        let src = "let s = \"wall clock text\"; let t = 9;\n";
        let lx = lex(src);
        assert!(!lx.code[0].contains("wall clock"));
        assert!(lx.code[0].contains("let t = 9;"));
        assert!(lx.code_with_strings[0].contains("wall clock"));
    }

    #[test]
    fn raw_strings_with_hashes_span_lines() {
        let src = "let s = r#\"first \"quoted\" part\nsecond part\"#; let u = 3;\n";
        let lx = lex(src);
        assert!(!lx.code[0].contains("quoted"));
        assert!(!lx.code[1].contains("second part"));
        assert!(lx.code[1].contains("let u = 3;"));
    }

    #[test]
    fn escaped_strings_do_not_end_early() {
        let src = "let s = \"a \\\" b\"; let z = 4;\n";
        let lx = lex(src);
        assert!(!lx.code[0].contains("a "));
        assert!(lx.code[0].contains("let z = 4;"));
    }

    #[test]
    fn char_literal_with_quote_vs_lifetime() {
        let src = "fn f<'a>(x: &'a str) -> char { let q = '\"'; let e = '\\''; q }\n";
        let lx = lex(src);
        // Lifetimes survive in code; the quote chars inside literals are
        // blanked and must not open a string that swallows the rest.
        assert!(lx.code[0].contains("fn f<'a>(x: &'a str)"));
        assert!(lx.code[0].contains("q }"));
    }

    #[test]
    fn parses_allow_with_reason() {
        let src = "let x = 1; // detlint:allow(D002) reason=\"human-facing timing\"\n";
        let lx = lex(src);
        assert_eq!(lx.allows.len(), 1);
        assert_eq!(lx.allows[0].line, 0);
        assert_eq!(lx.allows[0].rules, vec!["D002".to_string()]);
        assert_eq!(lx.allows[0].reason, "human-facing timing");
        assert!(lx.malformed.is_empty());
    }

    #[test]
    fn allow_with_rule_list() {
        let src = "// detlint:allow(D001, D004) reason=\"order-insensitive fold\"\nstmt();\n";
        let lx = lex(src);
        assert_eq!(lx.allows.len(), 1);
        assert_eq!(lx.allows[0].rules, vec!["D001".to_string(), "D004".to_string()]);
    }

    #[test]
    fn reasonless_allow_is_malformed() {
        let src = "stmt(); // detlint:allow(D003)\n";
        let lx = lex(src);
        assert!(lx.allows.is_empty());
        assert_eq!(lx.malformed.len(), 1);
        assert!(lx.malformed[0].what.contains("reason"));
    }

    #[test]
    fn empty_reason_is_malformed() {
        let src = "stmt(); // detlint:allow(D003) reason=\"  \"\n";
        let lx = lex(src);
        assert!(lx.allows.is_empty());
        assert_eq!(lx.malformed.len(), 1);
    }

    #[test]
    fn bad_rule_id_is_malformed() {
        let src = "stmt(); // detlint:allow(all) reason=\"nope\"\n";
        let lx = lex(src);
        assert!(lx.allows.is_empty());
        assert_eq!(lx.malformed.len(), 1);
    }

    #[test]
    fn line_counts_preserved() {
        let src = "a\nb\nc\n";
        let lx = lex(src);
        assert_eq!(lx.code.len(), lx.code_with_strings.len());
        assert_eq!(lx.code.len(), src.split('\n').count());
    }
}
