//! # detlint — the determinism & invariant static-analysis pass
//!
//! Every load-bearing claim this repo makes (defenses-off ≡ baseline,
//! observability on/off bit-identical, policy-object ≡ scalar-knob) rests
//! on pinned replay fingerprints in `rust/tests/replay_equivalence.rs`.
//! Those tests catch a broken determinism contract only *after* the fact —
//! and Rust's per-instance-random `HashMap` hashing means an
//! iteration-order bug can pass a single-process run and flake in the
//! next. This module is the static side of the contract
//! (`docs/determinism.md`): a dependency-free lexer + line-scanner —
//! matching the crate's hand-rolled-everything policy, no `syn`, no
//! clippy plugin — that walks `rust/src/`, `rust/tests/` and `benches/`
//! and enforces:
//!
//! | rule | guards against |
//! |------|----------------|
//! | D001 | unordered `HashMap`/`HashSet` iteration on sim-visible paths |
//! | D002 | wall-clock reads outside `net/tcp.rs` / `benchlib/` |
//! | D003 | RNG construction outside `util/rng.rs` |
//! | D004 | float accumulation over unordered iterators |
//! | D005 | `{:?}` of hash maps feeding codecs / fingerprints / traces |
//!
//! Suppression is explicit and audited: only an inline
//! `// detlint:allow(D00x) reason="…"` with a non-empty reason exempts a
//! line, and every exemption lands in the report census. The `detlint`
//! bin (`rust/src/bin/detlint.rs`) exits nonzero on unexempted findings
//! and writes `DETLINT_report.json` for CI upload.
//!
//! Layout: [`lexer`] strips comments/literals and extracts annotations,
//! [`rules`] classifies paths and runs D001–D006 over the stripped lines,
//! [`report`] aggregates per-file results into the JSON artifact.

pub mod lexer;
pub mod report;
pub mod rules;

pub use report::Report;
pub use rules::{classify, scan, Exemption, FileClass, Finding, ScanResult, RuleInfo, RULES};

/// Scan a list of `(path, source)` pairs into one aggregated [`Report`].
///
/// Pure function of its inputs (no filesystem access) so the whole
/// pipeline is unit-testable; the bin supplies real file contents.
pub fn scan_tree<'a, I>(files: I) -> Report
where
    I: IntoIterator<Item = (&'a str, &'a str)>,
{
    let mut rep = Report::default();
    for (path, source) in files {
        let r = rules::scan(path, source);
        rep.scanned_files += 1;
        rep.findings.extend(r.findings);
        rep.exemptions.extend(r.exemptions);
        rep.malformed.extend(r.malformed);
        rep.unused_allows.extend(r.unused_allows);
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_tree_aggregates_across_files() {
        let clean = "pub fn ok() {}\n";
        let dirty = "pub fn bad() { let t = std::time::Instant::now(); drop(t); }\n";
        let rep = scan_tree(vec![
            ("rust/src/util/a.rs", clean),
            ("rust/src/util/b.rs", dirty),
        ]);
        assert_eq!(rep.scanned_files, 2);
        assert_eq!(rep.findings.len(), 1);
        assert!(rep.failed());
    }
}
