//! `DETLINT_report.json` rendering.
//!
//! Machine-readable run summary for CI artifact upload: every unexempted
//! finding (rule, file, line, snippet), the full exemption census (every
//! `detlint:allow` that suppressed something, with its mandatory reason),
//! malformed annotations, and stale allows. Objects serialize through
//! [`crate::util::json::Json`], whose `BTreeMap` backing makes the output
//! byte-deterministic — the report of a deterministic tree is itself
//! reproducible.

use crate::util::json::Json;

use super::rules::{Exemption, Finding, MalformedAllow, RULES};

/// Aggregated results of scanning a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub scanned_files: usize,
    pub findings: Vec<Finding>,
    pub exemptions: Vec<Exemption>,
    pub malformed: Vec<MalformedAllow>,
    /// (file, 1-based line, comma-joined rule ids) of stale allows.
    pub unused_allows: Vec<(String, usize, String)>,
}

impl Report {
    /// Nonzero-exit condition: any unexempted finding, or any annotation
    /// too broken to audit.
    pub fn failed(&self) -> bool {
        !self.findings.is_empty() || !self.malformed.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let rules = Json::Obj(
            RULES
                .iter()
                .map(|r| {
                    (
                        r.id.to_string(),
                        Json::obj(vec![
                            ("title", Json::str(r.title)),
                            ("summary", Json::str(r.summary)),
                        ]),
                    )
                })
                .collect(),
        );
        let findings = Json::Arr(
            self.findings
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("rule", Json::str(f.rule)),
                        ("file", Json::str(&f.file)),
                        ("line", Json::num(f.line as f64)),
                        ("snippet", Json::str(&f.snippet)),
                        ("message", Json::str(&f.message)),
                    ])
                })
                .collect(),
        );
        let exemptions = Json::Arr(
            self.exemptions
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("rule", Json::str(e.rule)),
                        ("file", Json::str(&e.file)),
                        ("line", Json::num(e.line as f64)),
                        ("reason", Json::str(&e.reason)),
                        ("snippet", Json::str(&e.snippet)),
                    ])
                })
                .collect(),
        );
        let malformed = Json::Arr(
            self.malformed
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("file", Json::str(&m.file)),
                        ("line", Json::num(m.line as f64)),
                        ("what", Json::str(&m.what)),
                    ])
                })
                .collect(),
        );
        let unused = Json::Arr(
            self.unused_allows
                .iter()
                .map(|(file, line, rules)| {
                    Json::obj(vec![
                        ("file", Json::str(file)),
                        ("line", Json::num(*line as f64)),
                        ("rules", Json::str(rules)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("tool", Json::str("detlint")),
            ("version", Json::num(1.0)),
            ("scanned_files", Json::num(self.scanned_files as f64)),
            ("rules", rules),
            ("findings", findings),
            ("exemptions", exemptions),
            ("malformed_exemptions", malformed),
            ("unused_allows", unused),
            (
                "summary",
                Json::obj(vec![
                    ("findings", Json::num(self.findings.len() as f64)),
                    ("exemptions", Json::num(self.exemptions.len() as f64)),
                    ("malformed", Json::num(self.malformed.len() as f64)),
                    ("passed", Json::Bool(!self.failed())),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_passes_and_serializes() {
        let r = Report::default();
        assert!(!r.failed());
        let j = r.to_json();
        assert_eq!(j.get("tool").as_str(), Some("detlint"));
        assert_eq!(j.at(&["summary", "passed"]).as_bool(), Some(true));
        assert_eq!(j.get("rules").as_obj().map(|o| o.len()), Some(6));
    }

    #[test]
    fn findings_fail_and_round_trip() {
        let r = Report {
            scanned_files: 3,
            findings: vec![Finding {
                rule: "D002",
                file: "rust/src/x.rs".into(),
                line: 7,
                snippet: "let t = now();".into(),
                message: "wall-clock read".into(),
            }],
            ..Report::default()
        };
        assert!(r.failed());
        let text = r.to_json().to_string();
        let back = Json::parse(&text).expect("report must be valid JSON");
        assert_eq!(back.at(&["summary", "findings"]).as_usize(), Some(1));
        assert_eq!(back.at(&["summary", "passed"]).as_bool(), Some(false));
        assert_eq!(back.get("findings").as_arr().map(|a| a.len()), Some(1));
    }

    #[test]
    fn malformed_alone_fails() {
        let r = Report {
            malformed: vec![MalformedAllow {
                file: "rust/src/x.rs".into(),
                line: 2,
                what: "missing reason".into(),
            }],
            ..Report::default()
        };
        assert!(r.failed());
    }
}
