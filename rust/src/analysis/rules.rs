//! The detlint rule engine: D001–D006 over lexed source lines.
//!
//! Rules operate on `(path classification, stripped lines)` so unit tests
//! can feed synthetic fixtures under any pretend path. Scope model:
//!
//! * **sim-visible** — modules whose state feeds the deterministic replay
//!   fingerprint: `coordinator`, `gossip`, `ledger`, `reputation`,
//!   `latency`, `capacity`, `sim`, `pos`, `duel` under `rust/src/`.
//!   D001/D004/D005 fire only here, and only outside test scope.
//! * **wall-clock allowlist** — `rust/src/net/tcp.rs` (real sockets need a
//!   real clock) and `rust/src/benchlib/` (the timing harness *is* a wall
//!   clock). D002 fires everywhere else, tests and benches included.
//! * **RNG home** — `rust/src/util/rng.rs` is the only module allowed to
//!   construct RNG state; everything else must `fork()` a lineage that
//!   traces back to the world seed. D003 fires in non-test library code.
//! * **test scope** — files under `rust/tests/` and `benches/`, plus
//!   everything from a file's first `#[cfg(test)]` line to EOF. Tests may
//!   seed fixture RNGs and iterate scratch maps freely.
//!
//! Suppression: `// detlint:allow(D00x) reason="…"` on the offending line
//! or the line directly above (see [`super::lexer`]). Suppressed findings
//! become [`Exemption`]s and are listed in the report census.

use std::collections::BTreeSet;

use super::lexer;

/// Static description of one rule, for reports and docs.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub id: &'static str,
    pub title: &'static str,
    pub summary: &'static str,
}

/// The rule table (mirrored in `docs/determinism.md`).
pub const RULES: [RuleInfo; 6] = [
    RuleInfo {
        id: "D001",
        title: "unordered map iteration on a sim-visible path",
        summary: "HashMap/HashSet iteration (for..in, .iter(), .keys(), .values(), \
                  .drain(), .into_iter()) in a sim-visible module without a \
                  sort-before-use or a BTreeMap: per-instance hash randomization \
                  makes the visit order differ across runs, breaking replay.",
    },
    RuleInfo {
        id: "D002",
        title: "wall-clock read outside the allowlist",
        summary: "Instant::now()/SystemTime::now() anywhere except net/tcp.rs and \
                  benchlib/: simulated time is the only clock the deterministic \
                  World may observe.",
    },
    RuleInfo {
        id: "D003",
        title: "RNG constructed outside util/rng.rs",
        summary: "Rng::new(..) or a foreign RNG (thread_rng, from_entropy, StdRng, \
                  SmallRng) in non-test library code: all randomness must be a \
                  fork() of the single seeded lineage rooted at the world seed.",
    },
    RuleInfo {
        id: "D004",
        title: "float accumulation over an unordered iterator",
        summary: "Summing/folding floats over HashMap/HashSet iteration: float \
                  addition is not associative, so even a full visit gives \
                  order-dependent totals.",
    },
    RuleInfo {
        id: "D005",
        title: "Debug-format of a hash map on a sim-visible path",
        summary: "{:?} of a HashMap/HashSet-typed value in a sim-visible module: \
                  Debug output inherits iteration order, so anything it feeds \
                  (wire codecs, fingerprints, trace export) becomes \
                  run-dependent.",
    },
    RuleInfo {
        id: "D006",
        title: "node-id stringification on a sim-visible path",
        summary: "to_string()/format! of a NodeId-typed value (or an `n{..}` \
                  node-label build) in a sim-visible module: hot paths carry \
                  interned u32 ids, and ordering or keying by the resolved \
                  string diverges from id order and allocates per event. \
                  Strings belong at config-parse and export boundaries — \
                  label builds there carry a detlint:allow with the reason.",
    },
];

/// One unexempted violation.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub snippet: String,
    pub message: String,
}

/// A violation suppressed by a well-formed `detlint:allow`.
#[derive(Debug, Clone, PartialEq)]
pub struct Exemption {
    pub rule: &'static str,
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub reason: String,
    pub snippet: String,
}

/// A broken `detlint:allow` annotation (fails the run: a reasonless allow
/// is indistinguishable from a stale one).
#[derive(Debug, Clone, PartialEq)]
pub struct MalformedAllow {
    pub file: String,
    /// 1-based.
    pub line: usize,
    pub what: String,
}

/// Everything `scan` learned about one file.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub findings: Vec<Finding>,
    pub exemptions: Vec<Exemption>,
    pub malformed: Vec<MalformedAllow>,
    /// Well-formed allows that suppressed nothing (stale — reported as a
    /// warning in the census, not a failure).
    pub unused_allows: Vec<(String, usize, String)>,
}

/// Path-derived scope of one file (all decisions the rules need).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FileClass {
    pub sim_visible: bool,
    pub wallclock_exempt: bool,
    pub rng_home: bool,
    pub test_file: bool,
}

const SIM_VISIBLE_MODULES: [&str; 9] = [
    "coordinator",
    "gossip",
    "ledger",
    "reputation",
    "latency",
    "capacity",
    "sim",
    "pos",
    "duel",
];

/// Classify a repo-relative path (forward slashes).
pub fn classify(path: &str) -> FileClass {
    let p = path.trim_start_matches("./");
    let sim_visible = SIM_VISIBLE_MODULES.iter().any(|m| {
        p.starts_with(&format!("rust/src/{m}/")) || p == format!("rust/src/{m}.rs")
    });
    FileClass {
        sim_visible,
        wallclock_exempt: p == "rust/src/net/tcp.rs" || p.starts_with("rust/src/benchlib/"),
        rng_home: p == "rust/src/util/rng.rs",
        test_file: p.starts_with("rust/tests/") || p.starts_with("benches/"),
    }
}

const ITER_METHODS: [&str; 9] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
];

const WALLCLOCK_PATTERNS: [&str; 2] = ["Instant::now", "SystemTime::now"];

const RNG_PATTERNS: [&str; 5] = [
    "Rng::new(",
    "thread_rng",
    "from_entropy",
    "StdRng",
    "SmallRng",
];

const FLOAT_ACC_PATTERNS: [&str; 5] = [
    "sum::<f64>()",
    "sum::<f32>()",
    ".fold(0.0",
    ".fold(0f64",
    ".fold(0f32",
];

/// How many lines after an iteration site we look for a `.sort`/`BTree`
/// that makes the order deterministic before anything consumes it.
const SORT_WINDOW: usize = 7;

/// Run every rule over one file.
pub fn scan(path: &str, source: &str) -> ScanResult {
    let class = classify(path);
    let lexed = lexer::lex(source);
    let raw_lines: Vec<&str> = source.split('\n').collect();
    let test_from = if class.test_file {
        0
    } else {
        lexed
            .code
            .iter()
            .position(|l| l.trim_start().starts_with("#[cfg(test)]"))
            .unwrap_or(usize::MAX)
    };
    let hash_idents = collect_hash_idents(&lexed.code);
    let nodeid_idents = collect_typed_idents(&lexed.code, &["NodeId"]);

    let mut raw: Vec<Finding> = Vec::new();
    let mut push = |raw: &mut Vec<Finding>, rule: &'static str, i: usize, msg: String| {
        raw.push(Finding {
            rule,
            file: path.to_string(),
            line: i + 1,
            snippet: snippet(raw_lines.get(i).copied().unwrap_or("")),
            message: msg,
        });
    };

    for (i, line) in lexed.code.iter().enumerate() {
        let in_test = i >= test_from;

        // D002 — applies everywhere (tests and benches too), minus allowlist.
        if !class.wallclock_exempt {
            for pat in WALLCLOCK_PATTERNS {
                if line.contains(pat) {
                    push(&mut raw, "D002", i, format!("wall-clock read `{pat}`"));
                    break;
                }
            }
        }

        // D003 — non-test library code outside the RNG home module.
        if !class.rng_home && !in_test {
            for pat in RNG_PATTERNS {
                if line.contains(pat) {
                    push(
                        &mut raw,
                        "D003",
                        i,
                        format!("RNG constructed via `{}` outside util/rng.rs", pat.trim_end_matches('(')),
                    );
                    break;
                }
            }
        }

        if class.sim_visible && !in_test {
            for id in &hash_idents {
                if !iterates(line, id) {
                    continue;
                }
                // D001 — unless a sort (or BTree re-collect) follows closely.
                if !sorted_nearby(&lexed.code, i) {
                    push(
                        &mut raw,
                        "D001",
                        i,
                        format!("unordered iteration over hash-typed `{id}`"),
                    );
                }
                // D004 — float accumulation is broken even when sorted later:
                // the sum happens in visit order.
                if float_acc_nearby(&lexed.code, i) {
                    push(
                        &mut raw,
                        "D004",
                        i,
                        format!("float accumulation over hash-typed `{id}`"),
                    );
                }
            }

            // D005 — Debug-format of a hash-typed value. Format strings are
            // string literals, so this scans the strings-kept view.
            let ws = &lexed.code_with_strings[i];
            if ws.contains(":?}") {
                for id in &hash_idents {
                    let inline = format!("{{{id}:?}}");
                    if ws.contains(&inline) || word_in(line, id) {
                        push(
                            &mut raw,
                            "D005",
                            i,
                            format!("Debug-format of hash-typed `{id}`"),
                        );
                        break;
                    }
                }
            }

            // D006 — node-id stringification: hot-path identifiers are
            // interned u32s; the resolved string belongs at export
            // boundaries only. Covers `.to_string()` on a NodeId-typed
            // name, a format! capturing one, and the canonical
            // `format!("n{..}")` node-label build.
            if ws.contains("format!(\"n{") {
                push(
                    &mut raw,
                    "D006",
                    i,
                    "node-label string built on a sim-visible path".to_string(),
                );
            } else {
                for id in &nodeid_idents {
                    let direct =
                        method_called(line, id, ".to_string()");
                    let fmt = line.contains("format!")
                        && (ws.contains(&format!("{{{id}}}"))
                            || ws.contains(&format!("{{{id}:?}}")));
                    if direct || fmt {
                        push(
                            &mut raw,
                            "D006",
                            i,
                            format!("stringified node id `{id}`"),
                        );
                        break;
                    }
                }
            }
        }
    }

    // Apply exemptions: an allow covers its own line and the line below.
    let mut used = vec![false; lexed.allows.len()];
    let mut out = ScanResult::default();
    for f in raw {
        let fline0 = f.line - 1;
        let hit = lexed.allows.iter().enumerate().find(|(_, a)| {
            (a.line == fline0 || a.line + 1 == fline0) && a.rules.iter().any(|r| r == f.rule)
        });
        if let Some((ai, a)) = hit {
            used[ai] = true;
            out.exemptions.push(Exemption {
                rule: f.rule,
                file: f.file,
                line: f.line,
                reason: a.reason.clone(),
                snippet: f.snippet,
            });
        } else {
            out.findings.push(f);
        }
    }
    for (ai, a) in lexed.allows.iter().enumerate() {
        if !used[ai] {
            out.unused_allows
                .push((path.to_string(), a.line + 1, a.rules.join(",")));
        }
    }
    for m in lexed.malformed {
        out.malformed.push(MalformedAllow {
            file: path.to_string(),
            line: m.line + 1,
            what: m.what,
        });
    }
    out
}

fn snippet(line: &str) -> String {
    let t = line.trim();
    if t.len() > 120 {
        let mut cut = 120;
        while !t.is_char_boundary(cut) {
            cut -= 1;
        }
        format!("{}…", &t[..cut])
    } else {
        t.to_string()
    }
}

/// Collect names declared with a HashMap/HashSet type anywhere in the file:
/// `let [mut] name = HashMap::new()`, struct fields and fn params
/// (`name: HashMap<..>`, `name: Arc<Mutex<HashMap<..>>>`). Line-local
/// heuristic — good enough for the declaration styles this crate uses.
fn collect_hash_idents(lines: &[String]) -> Vec<String> {
    collect_typed_idents(lines, &["HashMap", "HashSet"])
}

/// Collect names declared with any of the given type names anywhere in the
/// file, using the same line-local `decl_name` heuristic as the hash-ident
/// scan. Shared by D001/D005 (hash containers) and D006 (`NodeId`).
fn collect_typed_idents(lines: &[String], types: &[&str]) -> Vec<String> {
    let mut ids: BTreeSet<String> = BTreeSet::new();
    for line in lines {
        let line = sanitize_ascii(line);
        for ty in types {
            let mut from = 0usize;
            while let Some(p) = line[from..].find(ty) {
                let abs = from + p;
                from = abs + ty.len();
                if !word_boundary(&line, abs, ty.len()) {
                    continue;
                }
                if let Some(name) = decl_name(&line[..abs]) {
                    ids.insert(name);
                }
            }
        }
    }
    ids.into_iter().collect()
}

/// Does `line` call `method` (e.g. `.to_string()`) on `id`, with a word
/// boundary at the identifier's start? Mirrors the scan in [`iterates`].
fn method_called(line: &str, id: &str, method: &str) -> bool {
    let line = sanitize_ascii(line);
    let pat = format!("{id}{method}");
    let mut from = 0usize;
    while let Some(p) = line[from..].find(&pat) {
        let abs = from + p;
        from = abs + 1;
        if word_boundary(&line, abs, id.len()) {
            return true;
        }
    }
    false
}

/// Non-ASCII chars (only ever inside comments/strings, which are already
/// blanked, or in prose that is not code) become spaces so the byte-index
/// scans below stay on char boundaries.
fn sanitize_ascii(line: &str) -> String {
    if line.is_ascii() {
        line.to_string()
    } else {
        line.chars().map(|c| if c.is_ascii() { c } else { ' ' }).collect()
    }
}

fn word_boundary(line: &str, start: usize, len: usize) -> bool {
    let b = line.as_bytes();
    let before_ok = start == 0 || !is_ident_byte(b[start - 1]);
    let after_ok = start + len >= b.len() || !is_ident_byte(b[start + len]);
    before_ok && after_ok
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// The identifier being declared on `head` (the text before the type name):
/// `let [mut] NAME = …` or `NAME: …` (skipping `::` path separators).
fn decl_name(head: &str) -> Option<String> {
    let head = sanitize_ascii(head);
    if let Some(lp) = head.rfind("let ") {
        let rest = head[lp + 4..].trim_start();
        let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
        let name: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            return Some(name);
        }
    }
    let b = head.as_bytes();
    let mut j = b.len();
    while j > 0 {
        j -= 1;
        if b[j] != b':' {
            continue;
        }
        if j > 0 && b[j - 1] == b':' {
            // `::` path separator — skip both colons.
            j -= 1;
            continue;
        }
        if j + 1 < b.len() && b[j + 1] == b':' {
            continue;
        }
        // Single `:` — the field/param name sits directly before it.
        let mut k = j;
        while k > 0 && (b[k - 1] as char).is_whitespace() {
            k -= 1;
        }
        let mut s = k;
        while s > 0 && is_ident_byte(b[s - 1]) {
            s -= 1;
        }
        if s < k {
            return Some(head[s..k].to_string());
        }
        return None;
    }
    None
}

/// Does `line` iterate `id`? Either `id.iter()`-style (any method in
/// [`ITER_METHODS`]) or a `for … in [&[mut ]]id` loop header.
fn iterates(line: &str, id: &str) -> bool {
    let line = sanitize_ascii(line);
    for m in ITER_METHODS {
        let pat = format!("{id}{m}");
        let mut from = 0usize;
        while let Some(p) = line[from..].find(&pat) {
            let abs = from + p;
            from = abs + 1;
            if word_boundary(&line, abs, id.len()) {
                return true;
            }
        }
    }
    if line.contains("for ") {
        for pre in [" in &mut ", " in &", " in "] {
            let pat = format!("{pre}{id}");
            let mut from = 0usize;
            while let Some(p) = line[from..].find(&pat) {
                let abs = from + p;
                from = abs + 1;
                let end = abs + pat.len();
                let next = line.as_bytes().get(end).copied();
                let terminated = match next {
                    None => true,
                    Some(b) => !is_ident_byte(b) && b != b'.',
                };
                if terminated {
                    return true;
                }
            }
        }
    }
    false
}

/// Is the iteration's order laundered through a sort (or a BTree
/// re-collect) within the following few lines?
fn sorted_nearby(lines: &[String], i: usize) -> bool {
    let end = (i + SORT_WINDOW).min(lines.len());
    lines[i..end]
        .iter()
        .any(|l| l.contains(".sort") || l.contains("BTreeMap") || l.contains("BTreeSet"))
}

/// Does a float accumulator consume the iteration within the statement?
fn float_acc_nearby(lines: &[String], i: usize) -> bool {
    let end = (i + 3).min(lines.len());
    lines[i..end]
        .iter()
        .any(|l| FLOAT_ACC_PATTERNS.iter().any(|p| l.contains(p)))
}

/// Word-boundary occurrence of `id` anywhere in `line`.
fn word_in(line: &str, id: &str) -> bool {
    let line = sanitize_ascii(line);
    let mut from = 0usize;
    while let Some(p) = line[from..].find(id) {
        let abs = from + p;
        from = abs + 1;
        if word_boundary(&line, abs, id.len()) {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIM_PATH: &str = "rust/src/coordinator/fixture.rs";
    const PLAIN_PATH: &str = "rust/src/util/fixture.rs";

    fn rules_fired(r: &ScanResult) -> Vec<&'static str> {
        r.findings.iter().map(|f| f.rule).collect()
    }

    // ---- classification ---------------------------------------------------

    #[test]
    fn classify_scopes() {
        assert!(classify("rust/src/gossip/mod.rs").sim_visible);
        assert!(classify("rust/src/ledger/chain.rs").sim_visible);
        assert!(!classify("rust/src/util/json.rs").sim_visible);
        assert!(!classify("rust/src/simulator_helpers.rs").sim_visible);
        assert!(classify("rust/src/benchlib/mod.rs").wallclock_exempt);
        assert!(classify("rust/src/net/tcp.rs").wallclock_exempt);
        assert!(!classify("rust/src/net/mod.rs").wallclock_exempt);
        assert!(classify("rust/src/util/rng.rs").rng_home);
        assert!(classify("rust/tests/integration.rs").test_file);
        assert!(classify("benches/fleet_scale.rs").test_file);
    }

    // ---- D001 -------------------------------------------------------------

    #[test]
    fn d001_true_positive_for_loop() {
        let src = "use std::collections::HashMap;\n\
                   struct S { pending: HashMap<u64, f64> }\n\
                   impl S { fn f(&self) { for (k, v) in self.pending.iter() { drop((k, v)); } } }\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D001"]);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn d001_true_positive_drain() {
        let src = "fn f() {\n    let mut seen = std::collections::HashSet::new();\n    seen.insert(1u32);\n    for v in seen.drain() { drop(v); }\n}\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D001"]);
    }

    #[test]
    fn d001_true_negative_sorted_after_collect() {
        let src = "struct S { pending: std::collections::HashMap<u64, f64> }\n\
                   impl S { fn f(&self) -> Vec<u64> {\n\
                   let mut v: Vec<u64> = self.pending.keys().copied().collect();\n\
                   v.sort_unstable();\n\
                   v } }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn d001_true_negative_btreemap() {
        let src = "struct S { pending: std::collections::BTreeMap<u64, f64> }\n\
                   impl S { fn f(&self) { for k in self.pending.keys() { drop(k); } } }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d001_true_negative_outside_sim_visible() {
        let src = "fn f(m: &std::collections::HashMap<u64, u64>) { for k in m.keys() { drop(k); } }\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d001_true_negative_in_test_scope() {
        let src = "pub fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t(m: std::collections::HashMap<u8, u8>) { for k in m.keys() { drop(k); } }\n}\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d001_keyed_access_is_fine() {
        let src = "struct S { pending: std::collections::HashMap<u64, f64> }\n\
                   impl S { fn f(&self) -> Option<&f64> { self.pending.get(&1) } }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty());
    }

    // ---- D002 -------------------------------------------------------------

    #[test]
    fn d002_true_positive_even_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let t0 = std::time::Instant::now(); drop(t0); }\n}\n";
        let r = scan(PLAIN_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D002"]);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn d002_true_negative_allowlisted_module() {
        let src = "fn t() { let t0 = std::time::Instant::now(); drop(t0); }\n";
        let r = scan("rust/src/benchlib/mod.rs", src);
        assert!(r.findings.is_empty());
        let r = scan("rust/src/net/tcp.rs", src);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d002_true_negative_inside_string_literal() {
        // The pattern name appearing in a string (e.g. this lint's own
        // tables) is not a clock read.
        let src = "const PATTERNS: [&str; 1] = [\"Instant::now\"];\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d002_exempted_with_reason() {
        let src = "// detlint:allow(D002) reason=\"human-facing CLI timing only\"\nlet t0 = std::time::Instant::now();\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty());
        assert_eq!(r.exemptions.len(), 1);
        assert_eq!(r.exemptions[0].rule, "D002");
        assert_eq!(r.exemptions[0].reason, "human-facing CLI timing only");
    }

    #[test]
    fn d002_reasonless_allow_does_not_suppress() {
        let src = "// detlint:allow(D002)\nlet t0 = std::time::Instant::now();\n";
        let r = scan(PLAIN_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D002"]);
        assert_eq!(r.malformed.len(), 1);
    }

    // ---- D003 -------------------------------------------------------------

    #[test]
    fn d003_true_positive() {
        let src = "use crate::util::rng::Rng;\nfn f() { let mut rng = Rng::new(7); drop(rng.next_u64()); }\n";
        let r = scan(PLAIN_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D003"]);
    }

    #[test]
    fn d003_true_negative_in_rng_home() {
        let src = "pub fn fresh() -> Rng { Rng::new(42) }\n";
        let r = scan("rust/src/util/rng.rs", src);
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d003_true_negative_in_tests() {
        let src = "pub fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let _ = crate::util::rng::Rng::new(7); }\n}\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty());
        let r = scan("rust/tests/fixture.rs", "fn t() { let _ = wwwserve::util::rng::Rng::new(7); }\n");
        assert!(r.findings.is_empty());
    }

    #[test]
    fn d003_fork_is_fine() {
        let src = "fn f(parent: &mut crate::util::rng::Rng) { let _child = parent.fork(); }\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty());
    }

    // ---- D004 -------------------------------------------------------------

    #[test]
    fn d004_true_positive_float_sum() {
        let src = "struct S { load: std::collections::HashMap<u32, f64> }\n\
                   impl S { fn f(&self) -> f64 { self.load.values().sum::<f64>() } }\n";
        let r = scan(SIM_PATH, src);
        let fired = rules_fired(&r);
        assert!(fired.contains(&"D004"), "{fired:?}");
        // The same line is also an unordered iteration.
        assert!(fired.contains(&"D001"), "{fired:?}");
    }

    #[test]
    fn d004_true_negative_integer_sum() {
        // Integer addition is associative and commutative: order-insensitive,
        // so only D001 applies — and a sort nearby silences that too.
        let src = "struct S { load: std::collections::HashMap<u32, u64> }\n\
                   impl S { fn f(&self) -> u64 { self.load.values().sum() } // sum into BTreeMap-independent u64, .sort not needed\n}\n";
        let r = scan(SIM_PATH, src);
        assert!(!rules_fired(&r).contains(&"D004"));
    }

    #[test]
    fn d004_true_negative_btreemap_float_sum() {
        let src = "struct S { load: std::collections::BTreeMap<u32, f64> }\n\
                   impl S { fn f(&self) -> f64 { self.load.values().sum::<f64>() } }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty());
    }

    // ---- D005 -------------------------------------------------------------

    #[test]
    fn d005_true_positive_debug_format() {
        let src = "struct S { sent: std::collections::HashMap<u32, u64> }\n\
                   impl S { fn f(&self) -> String { format!(\"{:?}\", self.sent) } }\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D005"]);
    }

    #[test]
    fn d005_true_positive_inline_capture() {
        let src = "fn f(sent: std::collections::HashMap<u32, u64>) -> String { format!(\"{sent:?}\") }\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D005"]);
    }

    #[test]
    fn d005_true_negative_debug_of_vec() {
        let src = "struct S { sent: std::collections::HashMap<u32, u64> }\n\
                   impl S { fn f(&self, v: &Vec<u64>) -> String { format!(\"{v:?}\") } }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn d005_true_negative_in_test_scope() {
        let src = "pub fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t(m: std::collections::HashMap<u8, u8>) { println!(\"{m:?}\"); }\n}\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty());
    }

    // ---- D006 -------------------------------------------------------------

    #[test]
    fn d006_true_positive_to_string() {
        let src = "use crate::types::NodeId;\n\
                   fn f(peer: NodeId) -> String { peer.to_string() }\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D006"]);
        assert_eq!(r.findings[0].line, 2);
    }

    #[test]
    fn d006_true_positive_format_capture() {
        let src = "use crate::types::NodeId;\n\
                   fn f(peer: NodeId) -> String { format!(\"peer {peer}\") }\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D006"]);
    }

    #[test]
    fn d006_true_positive_node_label_build() {
        // The canonical `n{index}` label build fires even when the index is
        // a bare integer rather than a NodeId-typed binding.
        let src = "fn f(i: u32) -> String { format!(\"n{i}\") }\n";
        let r = scan(SIM_PATH, src);
        assert_eq!(rules_fired(&r), vec!["D006"]);
    }

    #[test]
    fn d006_true_negative_outside_sim_visible() {
        let src = "use crate::types::NodeId;\n\
                   fn f(peer: NodeId) -> String { peer.to_string() }\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn d006_true_negative_in_test_scope() {
        let src = "pub fn prod() {}\n#[cfg(test)]\nmod tests {\n    use crate::types::NodeId;\n    fn t(peer: NodeId) -> String { format!(\"{peer:?}\") }\n}\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn d006_true_negative_numeric_use() {
        // Using the id as a number (keying, ordering, arithmetic) is the
        // whole point of interning — only the string round-trip fires.
        let src = "use crate::types::NodeId;\n\
                   fn f(peer: NodeId) -> f64 { peer.0 as f64 }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn d006_exempted_with_reason() {
        let src = "// detlint:allow(D006) reason=\"metric labels at the export boundary\"\n\
                   fn f(i: u32) -> String { format!(\"n{i}\") }\n";
        let r = scan(SIM_PATH, src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.exemptions.len(), 1);
        assert_eq!(r.exemptions[0].rule, "D006");
    }

    // ---- census bookkeeping ----------------------------------------------

    #[test]
    fn unused_allow_is_reported_not_fatal() {
        let src = "// detlint:allow(D002) reason=\"nothing here reads a clock\"\nlet x = 1;\n";
        let r = scan(PLAIN_PATH, src);
        assert!(r.findings.is_empty());
        assert!(r.exemptions.is_empty());
        assert_eq!(r.unused_allows.len(), 1);
    }

    #[test]
    fn hash_ident_collection_styles() {
        let lines: Vec<String> = [
            "let mut direct = HashMap::new();",
            "    pub field: HashMap<u64, f64>,",
            "    nested: Arc<Mutex<HashMap<String, u32>>>,",
            "fn f(param: &mut std::collections::HashSet<u8>) {}",
            "let keep: BTreeMap<u8, u8> = BTreeMap::new();",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let ids = collect_hash_idents(&lines);
        assert_eq!(ids, vec!["direct", "field", "nested", "param"]);
    }
}
