//! Backend-agnostic execution (§3.2 "Model Manager" substrate).
//!
//! The paper's Model Manager abstracts over SGLang/vLLM servers on real
//! GPUs; here a [`Backend`] is anything that can run inference requests with
//! continuous-batching semantics and report utilization. Two
//! implementations:
//!
//! * [`sim::SimBackend`] — an event-driven processor-sharing model of a
//!   modern LLM server (prefill + decode phases, KV-memory concurrency cap,
//!   batch-throughput saturation). Used by every experiment bench; see
//!   DESIGN.md §2 for why this preserves the paper's measured behaviour.
//! * `runtime::PjrtBackend` — real token generation on the AOT-compiled
//!   JAX/Pallas transformer via PJRT (the e2e example path).

pub mod pjrt;
pub mod profiles;
pub mod sim;

pub use pjrt::PjrtBackend;
pub use profiles::{BackendProfile, Gpu, ModelClass, Profile, ServingStack};
pub use sim::SimBackend;

use crate::types::{ExecKind, Request, Time};

/// A completed inference.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub request: Request,
    pub kind: ExecKind,
    /// When the backend finished generating.
    pub finished_at: Time,
    /// When the backend started working on it (left the queue).
    pub started_at: Time,
    /// When the first output token was produced (the prefill→decode
    /// boundary). `None` for backends that don't track phases.
    pub first_token_at: Option<Time>,
}

/// Continuous-batching inference backend, driven by (virtual or wall) time.
///
/// The contract mirrors how the coordinator polls an OpenAI-compatible
/// server for queue metrics in the paper's implementation (Appendix B):
/// `advance(now)` settles all work up to `now` and returns completions;
/// `next_event()` tells the event loop when something will change.
pub trait Backend {
    /// Enqueue a request at time `now`.
    fn submit(&mut self, req: Request, kind: ExecKind, now: Time);

    /// Settle work up to `now`; return requests that finished.
    fn advance(&mut self, now: Time) -> Vec<Completion>;

    /// Next time the backend's state changes on its own (a completion or a
    /// phase transition), if any work is in flight.
    fn next_event(&self) -> Option<Time>;

    /// Running-slot utilization in [0, 1] (running / max concurrent).
    fn utilization(&self) -> f64;

    /// Requests waiting for a slot.
    fn queue_len(&self) -> usize;

    /// Requests currently being served.
    fn running_len(&self) -> usize;

    /// The node's intrinsic response quality q_i in [0, 1] (§5 Assumption
    /// 5.1) — drives the duel mechanism's win probabilities.
    fn quality(&self) -> f64;

    /// Withdraw up to `k` of the node's *own* still-queued requests (newest
    /// first) so the scheduler can re-dispatch them elsewhere — the queue
    /// rebalancing a provider's Policy Manager performs when overloaded.
    /// Default: backends that can't un-queue return nothing.
    fn steal_queued(&mut self, _k: usize) -> Vec<Request> {
        Vec::new()
    }

    /// Current admission cap (concurrency slots committed) — the knob the
    /// elastic-capacity controller works (`capacity` module). Backends
    /// without an adjustable cap report `usize::MAX`.
    fn slots(&self) -> usize {
        usize::MAX
    }

    /// Scale the admission cap. Running work is never killed: a shrink
    /// takes effect as slots drain, a growth admits from the queue
    /// immediately. Default: no-op for fixed-capacity backends.
    fn set_slots(&mut self, _slots: usize, _now: Time) {}

    /// Prefill-pool cap when the backend runs disaggregated prefill/decode
    /// pools (streaming mode). Backends without a split report `usize::MAX`
    /// (prefill admission shares the unified `slots()` cap).
    fn prefill_slots(&self) -> usize {
        usize::MAX
    }

    /// Scale the prefill-pool cap (second lever of the elastic-capacity
    /// controller in streaming mode). Enabling this on a [`SimBackend`]
    /// switches it into split-pool admission: prefill is compute-gated by
    /// this cap while decode stays KV-gated by `max_batch`, so a node can
    /// sell prefill capacity while decode is full. Default: no-op.
    fn set_prefill_slots(&mut self, _slots: usize, _now: Time) {}

    /// Sequences currently in the prefill phase (0 for phase-less backends).
    fn prefill_running(&self) -> usize {
        0
    }

    /// Sequences currently holding a decode (KV-memory) slot. Defaults to
    /// `running_len()` for unified backends.
    fn decode_running(&self) -> usize {
        self.running_len()
    }
}
