//! Real-inference backend: the AOT-compiled JAX/Pallas transformer served
//! via PJRT (`runtime::Engine`), behind the same `Backend` trait the
//! simulator uses — so a `coordinator::Node` can run actual token
//! generation on the request path (the e2e example does exactly this over
//! TCP).
//!
//! Semantics: `advance(now)` runs continuous-batching decode steps
//! synchronously until a small wall-clock budget is spent (the real-time
//! runner calls it every pump). Requests carry their prompt in
//! `Request::payload`; generated tokens land in the completion's request
//! payload is untouched — callers read `generated` off the completion via
//! the executor-side response `tokens` (see `coordinator::Node`).

use std::collections::VecDeque;

use super::{Backend, Completion};
use crate::runtime::{engine::argmax, Engine, SeqKv};
use crate::types::{ExecKind, Request, Time};

struct Active {
    req: Request,
    kind: ExecKind,
    kv: SeqKv,
    next_token: u32,
    generated: u32,
    started_at: Time,
    first_token_at: Time,
}

pub struct PjrtBackend {
    engine: Engine,
    queue: VecDeque<(Request, ExecKind)>,
    active: Vec<Active>,
    done: Vec<Completion>,
    quality: f64,
    /// Wall-clock budget per `advance` call (seconds).
    step_budget: f64,
    last_now: Time,
    pub tokens_generated: u64,
}

impl PjrtBackend {
    pub fn new(engine: Engine, quality: f64) -> PjrtBackend {
        PjrtBackend {
            engine,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            quality,
            step_budget: 0.050,
            last_now: 0.0,
            tokens_generated: 0,
        }
    }

    fn admit(&mut self, now: Time) {
        let max_batch = self.engine.batcher.max_batch();
        let mut new_prompts = Vec::new();
        let mut metas = Vec::new();
        while self.active.len() + new_prompts.len() < max_batch {
            let Some((req, kind)) = self.queue.pop_front() else { break };
            // Honor the full declared prompt length, capped only by the
            // engine's context window (leaving room for one generated
            // token). Truncating further would decouple real prefill cost
            // from the workload's declared length.
            let ctx_cap =
                (self.engine.manifest.max_seq.saturating_sub(1)).max(1) as u32;
            let prompt: Vec<u32> = if req.payload.is_empty() {
                // Synthetic/sim requests: derive a deterministic prompt.
                (0..req.prompt_tokens.min(ctx_cap))
                    .map(|i| (req.id.seq as u32 + i) % 256)
                    .collect()
            } else {
                if req.payload.len() as u32 != req.prompt_tokens {
                    eprintln!(
                        "WARNING: pjrt request {} declares {} prompt tokens \
                         but carries a {}-token payload; prefill cost and \
                         SLO accounting will disagree",
                        req.id,
                        req.prompt_tokens,
                        req.payload.len()
                    );
                }
                req.payload.clone()
            };
            new_prompts.push(prompt);
            metas.push((req, kind));
        }
        if new_prompts.is_empty() {
            return;
        }
        match self.engine.prefill(&new_prompts) {
            Ok(results) => {
                for ((logits, kv), (req, kind)) in
                    results.into_iter().zip(metas)
                {
                    let next = argmax(&logits);
                    self.active.push(Active {
                        req,
                        kind,
                        kv,
                        next_token: next,
                        generated: 1,
                        started_at: now,
                        // Prefill's own logits yield the first token.
                        first_token_at: now,
                    });
                }
            }
            Err(e) => {
                // Surface as an immediate empty completion (error path).
                eprintln!("pjrt prefill failed: {e}");
                for (req, kind) in metas {
                    self.done.push(Completion {
                        request: req,
                        kind,
                        finished_at: now,
                        started_at: now,
                        first_token_at: None,
                    });
                }
            }
        }
    }

    /// One packed decode step over all active sequences.
    fn step(&mut self, now: Time) {
        if self.active.is_empty() {
            return;
        }
        let tokens: Vec<u32> =
            self.active.iter().map(|a| a.next_token).collect();
        let max_seq = self.engine.manifest.max_seq;
        {
            let mut kvs: Vec<&mut SeqKv> =
                self.active.iter_mut().map(|a| &mut a.kv).collect();
            match self.engine.decode_step(&mut kvs, &tokens) {
                Ok(all_logits) => {
                    drop(kvs);
                    for (a, logits) in
                        self.active.iter_mut().zip(all_logits)
                    {
                        a.next_token = argmax(&logits);
                        a.generated += 1;
                        self.tokens_generated += 1;
                    }
                }
                Err(e) => {
                    eprintln!("pjrt decode failed: {e}");
                }
            }
        }
        // Retire finished sequences.
        let mut i = 0;
        while i < self.active.len() {
            let a = &self.active[i];
            let finished = a.generated >= a.req.output_tokens
                || a.kv.len >= max_seq - 1;
            if finished {
                let a = self.active.swap_remove(i);
                self.done.push(Completion {
                    request: a.req,
                    kind: a.kind,
                    finished_at: now,
                    started_at: a.started_at,
                    first_token_at: Some(a.first_token_at),
                });
            } else {
                i += 1;
            }
        }
    }
}

impl Backend for PjrtBackend {
    fn submit(&mut self, req: Request, kind: ExecKind, now: Time) {
        self.queue.push_back((req, kind));
        self.admit(now);
    }

    fn advance(&mut self, now: Time) -> Vec<Completion> {
        self.last_now = now;
        // detlint:allow(D002) reason="real-compute step budget: bounds wall time spent in PJRT, never enters sim state"
        let t0 = std::time::Instant::now();
        while !self.active.is_empty()
            && t0.elapsed().as_secs_f64() < self.step_budget
        {
            self.step(now);
            self.admit(now);
        }
        self.admit(now);
        std::mem::take(&mut self.done)
    }

    fn next_event(&self) -> Option<Time> {
        if self.active.is_empty() && self.queue.is_empty() {
            None
        } else {
            // Real time: ask to be pumped again almost immediately.
            Some(self.last_now + 0.01)
        }
    }

    fn utilization(&self) -> f64 {
        self.active.len() as f64 / self.engine.batcher.max_batch() as f64
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn running_len(&self) -> usize {
        self.active.len()
    }

    fn quality(&self) -> f64 {
        self.quality
    }
}
