//! Hardware/model/stack throughput profiles — the simulation stand-in for
//! the paper's testbed (Appendix C, Table 3).
//!
//! Calibration: single-stream decode is modelled as memory-bandwidth-bound
//! (`bandwidth / model_bytes * eff`), aggregate decode and prefill as
//! compute-bound (`flops / (2 * params) * eff`), and the concurrency cap by
//! KV memory ((VRAM - weights) / KV-per-sequence). Constants come from
//! public spec sheets; only *ratios* between tiers matter for the paper's
//! figures (who wins and by roughly how much), not absolute tok/s.

/// GPU tiers used in Table 3 + Figure 6d.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Gpu {
    A100x4,
    A100,
    L40S,
    Ada6000,
    Rtx4090,
    Rtx3090,
}

impl Gpu {
    /// (fp16 TFLOPs, memory bandwidth GB/s, VRAM GB)
    fn specs(self) -> (f64, f64, f64) {
        match self {
            Gpu::A100x4 => (312.0 * 4.0, 2039.0 * 4.0, 80.0 * 4.0),
            Gpu::A100 => (312.0, 2039.0, 80.0),
            Gpu::L40S => (362.0, 864.0, 48.0),
            Gpu::Ada6000 => (364.0, 960.0, 48.0),
            Gpu::Rtx4090 => (330.0, 1008.0, 24.0),
            Gpu::Rtx3090 => (142.0, 936.0, 24.0),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Gpu::A100x4 => "4xA100",
            Gpu::A100 => "A100",
            Gpu::L40S => "L40S",
            Gpu::Ada6000 => "ADA6000",
            Gpu::Rtx4090 => "RTX4090",
            Gpu::Rtx3090 => "RTX3090",
        }
    }
}

/// Model tiers from the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelClass {
    Qwen3_32B,
    Qwen3_8B,
    Qwen3_4B,
    Qwen3_0_6B,
    DeepSeekQwen7B,
    Llama31_8B,
}

impl ModelClass {
    /// Billions of parameters.
    fn params_b(self) -> f64 {
        match self {
            ModelClass::Qwen3_32B => 32.0,
            ModelClass::Qwen3_8B => 8.0,
            ModelClass::Qwen3_4B => 4.0,
            ModelClass::Qwen3_0_6B => 0.6,
            ModelClass::DeepSeekQwen7B => 7.0,
            ModelClass::Llama31_8B => 8.0,
        }
    }

    /// Intrinsic response quality q_i (§5). Calibrated so the duel win
    /// rates land near Figure 6a's measured 0.57 / 0.53 / 0.39.
    pub fn quality(self) -> f64 {
        match self {
            ModelClass::Qwen3_32B => 0.84,
            ModelClass::Qwen3_8B => 0.78,
            ModelClass::Qwen3_4B => 0.74,
            ModelClass::Qwen3_0_6B => 0.62,
            ModelClass::DeepSeekQwen7B => 0.72,
            ModelClass::Llama31_8B => 0.75,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelClass::Qwen3_32B => "Qwen3-32B",
            ModelClass::Qwen3_8B => "Qwen3-8B",
            ModelClass::Qwen3_4B => "Qwen3-4B",
            ModelClass::Qwen3_0_6B => "Qwen3-0.6B",
            ModelClass::DeepSeekQwen7B => "DeepSeek-Qwen-7B",
            ModelClass::Llama31_8B => "Llama3.1-8B",
        }
    }
}

/// Serving stacks (Figure 6c compares attention backends within one stack;
/// the stack factor captures SGLang-vs-vLLM style differences).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServingStack {
    SgLang,
    Vllm,
}

impl ServingStack {
    /// Relative throughput multiplier (continuous-batching efficiency).
    fn factor(self) -> f64 {
        match self {
            ServingStack::SgLang => 1.0,
            ServingStack::Vllm => 0.92,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServingStack::SgLang => "SGLang",
            ServingStack::Vllm => "vLLM",
        }
    }
}

/// Throughput/capacity/quality parameters of one node's backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Profile {
    /// Aggregate prompt-processing throughput (tokens/s, compute-bound).
    pub prefill_tok_s: f64,
    /// Single-stream decode speed (tokens/s, bandwidth-bound).
    pub decode_tok_s: f64,
    /// Aggregate decode ceiling across the whole batch (tokens/s).
    pub max_agg_decode_tok_s: f64,
    /// Max concurrent sequences (KV memory cap).
    pub max_batch: usize,
    /// Intrinsic response quality q_i in [0, 1].
    pub quality: f64,
    /// KV-cache footprint of one resident sequence (GB). `max_batch` is the
    /// derived free-VRAM / kv_gb_per_seq cap; the streaming layer uses this
    /// to size KV transfers when a session is re-dispatched.
    pub kv_gb_per_seq: f64,
}

impl Profile {
    /// Build from a (model, GPU, stack) triple per the calibration model,
    /// assuming the ~6k-token context footprint of the Table-3 reasoning
    /// workloads.
    pub fn derive(model: ModelClass, gpu: Gpu, stack: ServingStack) -> Profile {
        Self::derive_with_ctx(model, gpu, stack, 6000.0)
    }

    /// Like [`Profile::derive`] but for a workload with a different average
    /// context length (prompt + generated) — the KV concurrency cap scales
    /// with it.
    pub fn derive_with_ctx(
        model: ModelClass,
        gpu: Gpu,
        stack: ServingStack,
        ctx_tokens: f64,
    ) -> Profile {
        let (tflops, bw_gbs, vram_gb) = gpu.specs();
        let params_b = model.params_b();
        let f = stack.factor();

        let model_gb = params_b * 2.0; // fp16 weights
        // Bandwidth-bound single stream: eff ~0.6 of peak, capped at the
        // sampler/kernel-launch floor small models hit in practice.
        let decode = (bw_gbs / model_gb * 0.6 * f).clamp(1.0, 300.0);
        // Prefill is compute-bound: 2*params flops/token, eff ~0.55.
        let prefill = tflops * 1e12 / (2.0 * params_b * 1e9) * 0.55 * f;
        // KV: ~20 kB per 1B params per token (fp16 GQA).
        let kv_gb_per_seq = 0.00002 * params_b * ctx_tokens;
        let free_gb = (vram_gb - model_gb).max(vram_gb * 0.1);
        let max_batch = ((free_gb / kv_gb_per_seq) as usize).clamp(2, 256);
        // Aggregate decode: batching amortizes weight reads until the
        // attention/KV bandwidth wall, ~30x single-stream on big-VRAM parts.
        let agg = decode * (max_batch as f64 * 0.35).clamp(1.0, 30.0);

        Profile {
            prefill_tok_s: prefill,
            decode_tok_s: decode,
            max_agg_decode_tok_s: agg,
            max_batch,
            quality: model.quality(),
            kv_gb_per_seq,
        }
    }

    /// Scale every throughput knob (used by Figure-6 ablations to express
    /// attention-backend or quantization differences).
    pub fn scaled(mut self, factor: f64) -> Profile {
        self.prefill_tok_s *= factor;
        self.decode_tok_s *= factor;
        self.max_agg_decode_tok_s *= factor;
        self
    }

    pub fn with_quality(mut self, q: f64) -> Profile {
        self.quality = q;
        self
    }

    pub fn with_max_batch(mut self, b: usize) -> Profile {
        self.max_batch = b;
        self
    }

    /// A small uniform test profile (fast to reason about in unit tests).
    pub fn test(decode_tok_s: f64, max_batch: usize) -> Profile {
        Profile {
            prefill_tok_s: decode_tok_s * 50.0,
            decode_tok_s,
            max_agg_decode_tok_s: decode_tok_s * max_batch as f64 * 0.5,
            max_batch,
            quality: 0.7,
            kv_gb_per_seq: 0.5,
        }
    }
}

// Public alias used across the crate.
pub use Profile as BackendProfile;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigger_model_slower_decode() {
        let p32 = Profile::derive(ModelClass::Qwen3_32B, Gpu::A100, ServingStack::SgLang);
        let p8 = Profile::derive(ModelClass::Qwen3_8B, Gpu::A100, ServingStack::SgLang);
        let p06 = Profile::derive(ModelClass::Qwen3_0_6B, Gpu::A100, ServingStack::SgLang);
        assert!(p32.decode_tok_s < p8.decode_tok_s);
        assert!(p8.decode_tok_s < p06.decode_tok_s);
    }

    #[test]
    fn gpu_ordering_matches_fig6d() {
        // A100 > RTX4090 > RTX3090 for the same 8B model (Figure 6d: served
        // 1717 / 1195 / 1088).
        let a100 = Profile::derive(ModelClass::Qwen3_8B, Gpu::A100, ServingStack::SgLang);
        let r4090 = Profile::derive(ModelClass::Qwen3_8B, Gpu::Rtx4090, ServingStack::SgLang);
        let r3090 = Profile::derive(ModelClass::Qwen3_8B, Gpu::Rtx3090, ServingStack::SgLang);
        assert!(a100.decode_tok_s > r4090.decode_tok_s);
        assert!(r4090.max_agg_decode_tok_s > r3090.max_agg_decode_tok_s);
        assert!(a100.max_batch >= r4090.max_batch);
    }

    #[test]
    fn quality_ordering_matches_fig6a() {
        assert!(ModelClass::Qwen3_8B.quality() > ModelClass::Qwen3_4B.quality());
        assert!(ModelClass::Qwen3_4B.quality() > ModelClass::Qwen3_0_6B.quality());
    }

    #[test]
    fn sane_ranges() {
        for model in [
            ModelClass::Qwen3_32B,
            ModelClass::Qwen3_8B,
            ModelClass::Qwen3_4B,
            ModelClass::Qwen3_0_6B,
            ModelClass::DeepSeekQwen7B,
            ModelClass::Llama31_8B,
        ] {
            for gpu in [Gpu::A100x4, Gpu::A100, Gpu::L40S, Gpu::Ada6000,
                        Gpu::Rtx4090, Gpu::Rtx3090] {
                let p = Profile::derive(model, gpu, ServingStack::Vllm);
                assert!(p.decode_tok_s >= 1.0);
                assert!(p.max_agg_decode_tok_s >= p.decode_tok_s);
                assert!(p.prefill_tok_s > 0.0);
                assert!((2..=256).contains(&p.max_batch));
                assert!((0.0..=1.0).contains(&p.quality));
                assert!(p.kv_gb_per_seq > 0.0);
            }
        }
    }

    #[test]
    fn stack_factor_orders_throughput() {
        let sg = Profile::derive(ModelClass::Qwen3_8B, Gpu::L40S, ServingStack::SgLang);
        let vl = Profile::derive(ModelClass::Qwen3_8B, Gpu::L40S, ServingStack::Vllm);
        assert!(sg.decode_tok_s > vl.decode_tok_s);
    }

    #[test]
    fn scaled_profile() {
        let p = Profile::test(50.0, 8);
        let half = p.scaled(0.5);
        assert!((half.decode_tok_s - 25.0).abs() < 1e-9);
        assert_eq!(half.max_batch, 8);
        assert!((half.quality - p.quality).abs() < 1e-12);
    }
}
