//! Event-driven processor-sharing model of a continuous-batching LLM server.
//!
//! Each admitted request passes through two phases:
//!
//! * **Prefill** — `prompt_tokens` of compute-bound work; the aggregate
//!   prefill throughput is shared equally among requests in prefill.
//! * **Decode** — `output_tokens` of generation; each running request
//!   decodes at `min(decode_tok_s, max_agg_decode_tok_s / n_decoding)` —
//!   full single-stream speed below the saturation batch, fair-shared above
//!   it.
//!
//! Admission is capped at `max_batch` concurrent sequences (the KV-memory
//! limit); excess waits in a FIFO queue (optionally two-class: own-user
//! requests before delegated ones, per NodePolicy). Between state changes
//! rates are constant, so the model integrates exactly — the simulation is
//! event-driven, deterministic, and runs 750-second experiments in
//! microseconds of wall time.
//!
//! **Split-pool (streaming) mode** — `set_prefill_slots` switches admission
//! from the unified `max_batch` gate to two independent pools: prefill is
//! compute-gated by the prefill-slot cap, decode stays KV-gated by
//! `max_batch`. A sequence finishing prefill when decode is full parks in a
//! FIFO (`decode_wait`, KV already materialized) until a decode slot frees,
//! so a node can keep selling prefill capacity while its decode pool is
//! full — the DeServe-style disaggregation the dispatch layer prices. With
//! the split disabled (the default) every code path is bit-identical to the
//! pre-streaming backend; the first-token stamp is purely observational.

use std::collections::VecDeque;

use super::profiles::Profile;
use super::{Backend, Completion};
use crate::types::{ExecKind, Request, Time};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
struct Slot {
    req: Request,
    kind: ExecKind,
    phase: Phase,
    /// Tokens of work left in the current phase.
    remaining: f64,
    started_at: Time,
    /// Stamped at the prefill→decode boundary (the first output token is
    /// produced by the prefill forward pass).
    first_token_at: Option<Time>,
}

/// The simulated server. See module docs.
#[derive(Debug, Clone)]
pub struct SimBackend {
    profile: Profile,
    running: Vec<Slot>,
    /// Two-class queue: own-user requests drain first when
    /// `prioritize_own` (set by the owning node's policy).
    own_queue: VecDeque<(Request, ExecKind)>,
    delegated_queue: VecDeque<(Request, ExecKind)>,
    prioritize_own: bool,
    /// `Some(cap)` switches on split-pool admission (see module docs);
    /// `None` is the unified pre-streaming gate.
    prefill_cap: Option<usize>,
    /// Sequences that finished prefill while the decode pool was full
    /// (split mode only). KV is resident; they make no progress here.
    decode_wait: VecDeque<Slot>,
    last_settled: Time,
    /// Completions accumulated by `advance`.
    done: Vec<Completion>,
    /// Total tokens generated (throughput accounting).
    pub tokens_generated: f64,
}

impl SimBackend {
    pub fn new(profile: Profile) -> Self {
        SimBackend {
            profile,
            running: Vec::new(),
            own_queue: VecDeque::new(),
            delegated_queue: VecDeque::new(),
            prioritize_own: true,
            prefill_cap: None,
            decode_wait: VecDeque::new(),
            last_settled: 0.0,
            done: Vec::new(),
            tokens_generated: 0.0,
        }
    }

    pub fn with_priority(mut self, prioritize_own: bool) -> Self {
        self.prioritize_own = prioritize_own;
        self
    }

    /// Construction-time form of [`Backend::set_prefill_slots`].
    pub fn with_split_pools(mut self, prefill_slots: usize) -> Self {
        self.prefill_cap = Some(prefill_slots.max(1));
        self
    }

    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    /// Concurrency at which the server is throughput-saturated: beyond the
    /// batch where aggregate decode caps out, admitting more sequences only
    /// slows everyone (processor sharing). This — not the KV memory cap —
    /// is the utilization scale a serving scheduler cares about.
    pub fn effective_capacity(&self) -> usize {
        let sat = (self.profile.max_agg_decode_tok_s
            / self.profile.decode_tok_s)
            .round()
            .max(1.0) as usize;
        sat.min(self.profile.max_batch)
    }

    /// Per-phase rates given the current running mix.
    fn rates(&self) -> (f64, f64) {
        let n_prefill =
            self.running.iter().filter(|s| s.phase == Phase::Prefill).count();
        let n_decode = self.running.len() - n_prefill;
        let prefill_rate = if n_prefill == 0 {
            0.0
        } else {
            self.profile.prefill_tok_s / n_prefill as f64
        };
        let decode_rate = if n_decode == 0 {
            0.0
        } else {
            self.profile
                .decode_tok_s
                .min(self.profile.max_agg_decode_tok_s / n_decode as f64)
        };
        (prefill_rate, decode_rate)
    }

    fn rate_of(&self, phase: Phase, rates: (f64, f64)) -> f64 {
        match phase {
            Phase::Prefill => rates.0,
            Phase::Decode => rates.1,
        }
    }

    /// Earliest time any running slot finishes its current phase, given
    /// current rates. Floored at 1 ns of progress so float dust can never
    /// produce a zero-width event loop.
    fn next_phase_end(&self) -> Option<Time> {
        let rates = self.rates();
        self.running
            .iter()
            .filter_map(|s| {
                let r = self.rate_of(s.phase, rates);
                if r <= 0.0 {
                    None
                } else {
                    Some(self.last_settled + (s.remaining / r).max(1e-9))
                }
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    fn pop_next(&mut self) -> Option<(Request, ExecKind)> {
        if self.prioritize_own {
            self.own_queue
                .pop_front()
                .or_else(|| self.delegated_queue.pop_front())
        } else {
            // Single logical FIFO: pick whichever queued earlier.
            match (self.own_queue.front(), self.delegated_queue.front()) {
                (Some(a), Some(b)) => {
                    if a.0.submitted_at <= b.0.submitted_at {
                        self.own_queue.pop_front()
                    } else {
                        self.delegated_queue.pop_front()
                    }
                }
                (Some(_), None) => self.own_queue.pop_front(),
                (None, Some(_)) => self.delegated_queue.pop_front(),
                (None, None) => None,
            }
        }
    }

    fn phase_count(&self, phase: Phase) -> usize {
        self.running.iter().filter(|s| s.phase == phase).count()
    }

    /// Fill free slots from the queues. Unified mode gates on `max_batch`;
    /// split mode first promotes parked sequences into freed decode slots,
    /// then admits new prefill work under the prefill cap.
    fn admit(&mut self, now: Time) {
        if let Some(cap) = self.prefill_cap {
            while self.phase_count(Phase::Decode) < self.profile.max_batch {
                let Some(slot) = self.decode_wait.pop_front() else { break };
                self.running.push(slot);
            }
            while self.phase_count(Phase::Prefill) < cap {
                let Some((req, kind)) = self.pop_next() else { break };
                let remaining = req.prompt_tokens.max(1) as f64;
                self.running.push(Slot {
                    req,
                    kind,
                    phase: Phase::Prefill,
                    remaining,
                    started_at: now,
                    first_token_at: None,
                });
            }
            return;
        }
        while self.running.len() < self.profile.max_batch {
            let Some((req, kind)) = self.pop_next() else { break };
            let remaining = req.prompt_tokens.max(1) as f64;
            self.running.push(Slot {
                req,
                kind,
                phase: Phase::Prefill,
                remaining,
                started_at: now,
                first_token_at: None,
            });
        }
    }

    /// Integrate work over [last_settled, until] assuming no admissions in
    /// between; splits at internal phase boundaries.
    fn settle(&mut self, until: Time) {
        while self.last_settled < until - 1e-12 {
            let boundary = self
                .next_phase_end()
                .map(|t| t.min(until))
                .unwrap_or(until);
            let dt = boundary - self.last_settled;
            if dt > 0.0 {
                let rates = self.rates();
                let mut finished = Vec::new();
                let mut transitioned = Vec::new();
                for (i, s) in self.running.iter_mut().enumerate() {
                    let r = match s.phase {
                        Phase::Prefill => rates.0,
                        Phase::Decode => rates.1,
                    };
                    let work = r * dt;
                    if s.phase == Phase::Decode {
                        self.tokens_generated += work.min(s.remaining);
                    }
                    s.remaining -= work;
                    // Finish threshold: a millionth of a token (absorbs
                    // float dust without affecting any latency metric).
                    if s.remaining <= 1e-6 {
                        match s.phase {
                            Phase::Prefill => {
                                s.phase = Phase::Decode;
                                s.remaining = s.req.output_tokens.max(1) as f64;
                                s.first_token_at = Some(boundary);
                                transitioned.push(i);
                            }
                            Phase::Decode => finished.push(i),
                        }
                    }
                }
                // Split mode: the decode pool is KV-capped at `max_batch`.
                // If this boundary's transitions overflow it (net of the
                // decode slots freed by `finished`), park the newest
                // transitions — KV already materialized, no progress until
                // a decode slot frees.
                let mut parked = Vec::new();
                if self.prefill_cap.is_some() {
                    let decoding = self.phase_count(Phase::Decode)
                        - finished.len();
                    // A set_slots shrink can leave decode transiently
                    // over-cap (never evicted); only this boundary's own
                    // transitions are parkable.
                    let excess = decoding
                        .saturating_sub(self.profile.max_batch)
                        .min(transitioned.len());
                    if excess > 0 {
                        parked = transitioned.split_off(transitioned.len() - excess);
                    }
                }
                // Remove finished + parked (descending order keeps indices
                // valid across swap_remove).
                let mut removals: Vec<(usize, bool)> = finished
                    .iter()
                    .map(|&i| (i, true))
                    .chain(parked.iter().map(|&i| (i, false)))
                    .collect();
                removals.sort_unstable_by(|a, b| b.0.cmp(&a.0));
                let mut newly_parked = Vec::new();
                for (i, is_done) in removals {
                    let s = self.running.swap_remove(i);
                    if is_done {
                        self.done.push(Completion {
                            request: s.req,
                            kind: s.kind,
                            finished_at: boundary,
                            started_at: s.started_at,
                            first_token_at: s.first_token_at,
                        });
                    } else {
                        newly_parked.push(s);
                    }
                }
                // Descending removal reversed the parked order; restore
                // ascending (FIFO) before queueing.
                for s in newly_parked.into_iter().rev() {
                    self.decode_wait.push_back(s);
                }
                let refill = if self.prefill_cap.is_some() {
                    // Transitions free prefill slots too in split mode.
                    !finished.is_empty() || !transitioned.is_empty()
                        || !parked.is_empty()
                } else {
                    !finished.is_empty()
                };
                if refill {
                    self.admit(boundary);
                }
            }
            self.last_settled = boundary;
        }
        self.last_settled = until;
    }
}

impl Backend for SimBackend {
    fn submit(&mut self, req: Request, kind: ExecKind, now: Time) {
        self.settle(now.max(self.last_settled));
        match kind {
            ExecKind::Local => self.own_queue.push_back((req, kind)),
            _ => self.delegated_queue.push_back((req, kind)),
        }
        self.admit(now);
    }

    fn advance(&mut self, now: Time) -> Vec<Completion> {
        self.settle(now.max(self.last_settled));
        std::mem::take(&mut self.done)
    }

    fn next_event(&self) -> Option<Time> {
        self.next_phase_end()
    }

    fn utilization(&self) -> f64 {
        self.running.len() as f64 / self.effective_capacity() as f64
    }

    fn queue_len(&self) -> usize {
        // Parked post-prefill sequences count as waiting work (split mode
        // only; the deque is always empty in unified mode).
        self.own_queue.len() + self.delegated_queue.len() + self.decode_wait.len()
    }

    fn running_len(&self) -> usize {
        self.running.len()
    }

    fn quality(&self) -> f64 {
        self.profile.quality
    }

    fn steal_queued(&mut self, k: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            // Newest first: the oldest waiters are closest to a local slot.
            match self.own_queue.pop_back() {
                Some((req, _kind)) => out.push(req),
                None => break,
            }
        }
        out
    }

    fn slots(&self) -> usize {
        self.profile.max_batch
    }

    /// Elastic admission cap (`capacity` controller lever): settle work to
    /// `now`, move the cap, and — on a growth — admit from the queue
    /// immediately. A shrink never evicts running sequences; it simply
    /// stops admissions until attrition brings the batch under the new
    /// cap. Utilization reporting follows the new cap at once (the
    /// saturation batch still bounds `effective_capacity`).
    fn set_slots(&mut self, slots: usize, now: Time) {
        let slots = slots.max(1);
        if slots == self.profile.max_batch {
            return;
        }
        self.settle(now.max(self.last_settled));
        self.profile.max_batch = slots;
        self.admit(now.max(self.last_settled));
    }

    fn prefill_slots(&self) -> usize {
        self.prefill_cap.unwrap_or(usize::MAX)
    }

    /// Second capacity lever (streaming mode): settle, move the prefill
    /// cap — switching split-pool admission on if it wasn't — and admit
    /// newly-allowed prefill work immediately. Like `set_slots`, a shrink
    /// never interrupts sequences already prefilling.
    fn set_prefill_slots(&mut self, slots: usize, now: Time) {
        let slots = slots.max(1);
        if self.prefill_cap == Some(slots) {
            return;
        }
        self.settle(now.max(self.last_settled));
        self.prefill_cap = Some(slots);
        self.admit(now.max(self.last_settled));
    }

    fn prefill_running(&self) -> usize {
        self.phase_count(Phase::Prefill)
    }

    fn decode_running(&self) -> usize {
        self.phase_count(Phase::Decode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{NodeId, RequestId};

    fn req(seq: u64, prompt: u32, output: u32, at: Time) -> Request {
        Request {
            id: RequestId { origin: NodeId(0), seq },
            prompt_tokens: prompt,
            output_tokens: output,
            submitted_at: at,
            slo_deadline: 1e9,
            synthetic: false,
            payload: vec![],
            session: 0,
            ttft_deadline: f64::INFINITY,
        }
    }

    fn profile(decode: f64, agg: f64, prefill: f64, max_batch: usize) -> Profile {
        Profile {
            prefill_tok_s: prefill,
            decode_tok_s: decode,
            max_agg_decode_tok_s: agg,
            max_batch,
            quality: 0.7,
            kv_gb_per_seq: 0.5,
        }
    }

    #[test]
    fn single_request_exact_latency() {
        // prefill 100 tok @ 1000 tok/s = 0.1s; decode 50 tok @ 10 tok/s = 5s.
        let mut b = SimBackend::new(profile(10.0, 100.0, 1000.0, 4));
        b.submit(req(0, 100, 50, 0.0), ExecKind::Local, 0.0);
        assert_eq!(b.running_len(), 1);
        let done = b.advance(10.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].finished_at - 5.1).abs() < 1e-6,
                "finished at {}", done[0].finished_at);
    }

    #[test]
    fn next_event_predicts_completion() {
        let mut b = SimBackend::new(profile(10.0, 100.0, 1000.0, 4));
        b.submit(req(0, 100, 50, 0.0), ExecKind::Local, 0.0);
        // First event is the prefill->decode transition at 0.1s.
        let t1 = b.next_event().unwrap();
        assert!((t1 - 0.1).abs() < 1e-9);
        b.advance(t1);
        let t2 = b.next_event().unwrap();
        assert!((t2 - 5.1).abs() < 1e-6);
    }

    #[test]
    fn unsaturated_batch_runs_at_full_speed() {
        // Two requests, saturation batch = agg/decode = 10: both full speed.
        let mut b = SimBackend::new(profile(10.0, 100.0, 1e9, 8));
        b.submit(req(0, 1, 100, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 1, 100, 0.0), ExecKind::Local, 0.0);
        let done = b.advance(20.0);
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!((c.finished_at - 10.0).abs() < 0.01,
                    "finished {}", c.finished_at);
        }
    }

    #[test]
    fn saturated_batch_shares_throughput() {
        // agg cap 20 tok/s, 4 decoding -> 5 tok/s each.
        let mut b = SimBackend::new(profile(10.0, 20.0, 1e9, 8));
        for i in 0..4 {
            b.submit(req(i, 1, 100, 0.0), ExecKind::Local, 0.0);
        }
        let done = b.advance(100.0);
        assert_eq!(done.len(), 4);
        for c in &done {
            assert!((c.finished_at - 20.0).abs() < 0.1,
                    "finished {}", c.finished_at);
        }
    }

    #[test]
    fn queue_waits_for_slot() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 1));
        b.submit(req(0, 10, 10, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 10, 10, 0.0), ExecKind::Local, 0.0);
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.queue_len(), 1);
        let done = b.advance(100.0);
        assert_eq!(done.len(), 2);
        // Second starts only after first finishes.
        assert!(done[1].started_at >= done[0].finished_at - 1e-9);
    }

    #[test]
    fn own_prioritized_over_delegated() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 1));
        b.submit(req(0, 10, 10, 0.0), ExecKind::Local, 0.0);
        // Delegated queued first, own second — own should still run first.
        b.submit(req(1, 10, 10, 0.1), ExecKind::Delegated, 0.1);
        b.submit(req(2, 10, 10, 0.2), ExecKind::Local, 0.2);
        let done = b.advance(100.0);
        let order: Vec<u64> = done.iter().map(|c| c.request.id.seq).collect();
        assert_eq!(order, vec![0, 2, 1]);
    }

    #[test]
    fn fifo_when_priority_disabled() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 1))
            .with_priority(false);
        b.submit(req(0, 10, 10, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 10, 10, 0.1), ExecKind::Delegated, 0.1);
        b.submit(req(2, 10, 10, 0.2), ExecKind::Local, 0.2);
        let done = b.advance(100.0);
        let order: Vec<u64> = done.iter().map(|c| c.request.id.seq).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn utilization_reflects_running() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 4));
        assert_eq!(b.utilization(), 0.0);
        b.submit(req(0, 10, 1000, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 10, 1000, 0.0), ExecKind::Local, 0.0);
        assert!((b.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn idle_backend_no_events() {
        let b = SimBackend::new(profile(10.0, 1e9, 1e9, 4));
        assert!(b.next_event().is_none());
    }

    #[test]
    fn tokens_generated_accounting() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 4));
        b.submit(req(0, 10, 50, 0.0), ExecKind::Local, 0.0);
        b.advance(100.0);
        assert!((b.tokens_generated - 50.0).abs() < 1e-6);
    }

    #[test]
    fn set_slots_grows_admission_and_shrink_never_evicts() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 2));
        for i in 0..4 {
            b.submit(req(i, 10, 1000, 0.0), ExecKind::Local, 0.0);
        }
        assert_eq!(b.running_len(), 2);
        assert_eq!(b.queue_len(), 2);
        assert_eq!(b.slots(), 2);
        // Growing the cap admits the queued work immediately.
        b.set_slots(4, 1.0);
        assert_eq!(b.slots(), 4);
        assert_eq!(b.running_len(), 4);
        assert_eq!(b.queue_len(), 0);
        assert!((b.utilization() - 4.0 / 4.0).abs() < 1e-12);
        // Shrinking never kills running sequences; admission just stops.
        b.set_slots(1, 2.0);
        assert_eq!(b.running_len(), 4);
        b.submit(req(9, 10, 10, 3.0), ExecKind::Local, 3.0);
        assert_eq!(b.running_len(), 4, "over-cap admission after shrink");
        assert_eq!(b.queue_len(), 1);
        // A floor of one slot always remains.
        b.set_slots(0, 4.0);
        assert_eq!(b.slots(), 1);
    }

    #[test]
    fn set_slots_noop_preserves_trace() {
        let run = |rescale: bool| {
            let mut b = SimBackend::new(profile(7.0, 23.0, 400.0, 3));
            for i in 0..10 {
                b.submit(
                    req(i, 50, 40, i as f64),
                    ExecKind::Local,
                    i as f64,
                );
                if rescale {
                    b.set_slots(3, i as f64); // same cap: must be inert
                }
            }
            b.advance(500.0)
                .iter()
                .map(|c| (c.request.id.seq, (c.finished_at * 1e9) as i64))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn first_token_stamped_at_prefill_boundary() {
        // prefill 100 tok @ 1000 tok/s = 0.1s; decode 50 @ 10 = 5s more.
        let mut b = SimBackend::new(profile(10.0, 100.0, 1000.0, 4));
        b.submit(req(0, 100, 50, 0.0), ExecKind::Local, 0.0);
        let done = b.advance(10.0);
        assert_eq!(done.len(), 1);
        let ft = done[0].first_token_at.expect("first token stamped");
        assert!((ft - 0.1).abs() < 1e-6, "first token at {ft}");
        assert!((done[0].finished_at - 5.1).abs() < 1e-6);
    }

    #[test]
    fn split_mode_sells_prefill_while_decode_full() {
        // Decode pool of 1 (max_batch), prefill pool of 2. With one
        // sequence decoding for a long time, new work must still prefill.
        let mut b = SimBackend::new(profile(10.0, 1e9, 1000.0, 1))
            .with_split_pools(2);
        b.submit(req(0, 100, 1000, 0.0), ExecKind::Local, 0.0);
        b.advance(1.0); // seq 0 now decoding (prefill took 0.1s)
        assert_eq!(b.decode_running(), 1);
        b.submit(req(1, 500, 10, 1.0), ExecKind::Local, 1.0);
        b.submit(req(2, 500, 10, 1.0), ExecKind::Local, 1.0);
        // Both admitted straight into prefill despite decode being full —
        // the unified gate would have queued them.
        assert_eq!(b.prefill_running(), 2);
        assert_eq!(b.decode_running(), 1);
        // They finish prefill (shared 1000 tok/s → 1s for 2x500) and park.
        let done = b.advance(3.0);
        assert!(done.is_empty());
        assert_eq!(b.decode_running(), 1, "decode cap respected");
        assert_eq!(b.queue_len(), 2, "parked sequences count as waiting");
        // Their first token is already stamped (produced by prefill).
        let done = b.advance(200.0);
        assert_eq!(done.len(), 3);
        for c in &done {
            assert!(c.first_token_at.is_some());
        }
    }

    #[test]
    fn split_mode_decode_cap_never_exceeded_property() {
        // Property sweep (satellite: decode-slot admission never exceeds
        // the profile's KV-memory cap): drive a split backend with a
        // deterministic pseudo-random arrival pattern and check the decode
        // invariant at every backend event.
        let mut rng = crate::util::rng::Rng::new(0xDECODE);
        for case in 0..20u64 {
            let max_batch = 1 + (case % 4) as usize;
            let prefill_slots = 1 + (case % 3) as usize;
            let mut b = SimBackend::new(profile(8.0, 40.0, 600.0, max_batch))
                .with_split_pools(prefill_slots);
            let mut t = 0.0;
            let mut pending = 40u64;
            let mut seq = 0u64;
            while pending > 0 || b.running_len() > 0 || b.queue_len() > 0 {
                if pending > 0 {
                    let prompt = 20 + (rng.below(200) as u32);
                    let output = 10 + (rng.below(80) as u32);
                    b.submit(req(seq, prompt, output, t), ExecKind::Local, t);
                    seq += 1;
                    pending -= 1;
                }
                assert!(
                    b.decode_running() <= max_batch,
                    "decode pool {} exceeds KV cap {} (case {case})",
                    b.decode_running(),
                    max_batch
                );
                assert!(
                    b.prefill_running() <= prefill_slots,
                    "prefill pool over cap (case {case})"
                );
                t = match b.next_event() {
                    Some(next) => next.max(t + 0.05),
                    None => t + 0.05,
                };
                b.advance(t);
                assert!(b.decode_running() <= max_batch);
                if t > 10_000.0 {
                    panic!("case {case} failed to drain");
                }
            }
            assert_eq!(seq, 40, "all requests admitted (case {case})");
        }
    }

    #[test]
    fn split_mode_determinism_double_run() {
        let run = || {
            let mut b = SimBackend::new(profile(7.0, 23.0, 400.0, 3))
                .with_split_pools(2);
            for i in 0..20 {
                b.submit(
                    req(i, 17 + (i as u32 * 13) % 97, 29 + (i as u32 * 7) % 61,
                        i as f64 * 0.37),
                    if i % 3 == 0 { ExecKind::Delegated } else { ExecKind::Local },
                    i as f64 * 0.37,
                );
            }
            b.advance(500.0)
                .iter()
                .map(|c| {
                    (
                        c.request.id.seq,
                        (c.finished_at * 1e9) as i64,
                        (c.first_token_at.unwrap() * 1e9) as i64,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn determinism() {
        let run = || {
            let mut b = SimBackend::new(profile(7.0, 23.0, 400.0, 3));
            for i in 0..20 {
                b.submit(
                    req(i, 17 + (i as u32 * 13) % 97, 29 + (i as u32 * 7) % 61,
                        i as f64 * 0.37),
                    if i % 3 == 0 { ExecKind::Delegated } else { ExecKind::Local },
                    i as f64 * 0.37,
                );
            }
            b.advance(500.0)
                .iter()
                .map(|c| (c.request.id.seq, (c.finished_at * 1e9) as i64))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
