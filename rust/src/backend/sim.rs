//! Event-driven processor-sharing model of a continuous-batching LLM server.
//!
//! Each admitted request passes through two phases:
//!
//! * **Prefill** — `prompt_tokens` of compute-bound work; the aggregate
//!   prefill throughput is shared equally among requests in prefill.
//! * **Decode** — `output_tokens` of generation; each running request
//!   decodes at `min(decode_tok_s, max_agg_decode_tok_s / n_decoding)` —
//!   full single-stream speed below the saturation batch, fair-shared above
//!   it.
//!
//! Admission is capped at `max_batch` concurrent sequences (the KV-memory
//! limit); excess waits in a FIFO queue (optionally two-class: own-user
//! requests before delegated ones, per NodePolicy). Between state changes
//! rates are constant, so the model integrates exactly — the simulation is
//! event-driven, deterministic, and runs 750-second experiments in
//! microseconds of wall time.

use std::collections::VecDeque;

use super::profiles::Profile;
use super::{Backend, Completion};
use crate::types::{ExecKind, Request, Time};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
struct Slot {
    req: Request,
    kind: ExecKind,
    phase: Phase,
    /// Tokens of work left in the current phase.
    remaining: f64,
    started_at: Time,
}

/// The simulated server. See module docs.
#[derive(Debug, Clone)]
pub struct SimBackend {
    profile: Profile,
    running: Vec<Slot>,
    /// Two-class queue: own-user requests drain first when
    /// `prioritize_own` (set by the owning node's policy).
    own_queue: VecDeque<(Request, ExecKind)>,
    delegated_queue: VecDeque<(Request, ExecKind)>,
    prioritize_own: bool,
    last_settled: Time,
    /// Completions accumulated by `advance`.
    done: Vec<Completion>,
    /// Total tokens generated (throughput accounting).
    pub tokens_generated: f64,
}

impl SimBackend {
    pub fn new(profile: Profile) -> Self {
        SimBackend {
            profile,
            running: Vec::new(),
            own_queue: VecDeque::new(),
            delegated_queue: VecDeque::new(),
            prioritize_own: true,
            last_settled: 0.0,
            done: Vec::new(),
            tokens_generated: 0.0,
        }
    }

    pub fn with_priority(mut self, prioritize_own: bool) -> Self {
        self.prioritize_own = prioritize_own;
        self
    }

    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    /// Concurrency at which the server is throughput-saturated: beyond the
    /// batch where aggregate decode caps out, admitting more sequences only
    /// slows everyone (processor sharing). This — not the KV memory cap —
    /// is the utilization scale a serving scheduler cares about.
    pub fn effective_capacity(&self) -> usize {
        let sat = (self.profile.max_agg_decode_tok_s
            / self.profile.decode_tok_s)
            .round()
            .max(1.0) as usize;
        sat.min(self.profile.max_batch)
    }

    /// Per-phase rates given the current running mix.
    fn rates(&self) -> (f64, f64) {
        let n_prefill =
            self.running.iter().filter(|s| s.phase == Phase::Prefill).count();
        let n_decode = self.running.len() - n_prefill;
        let prefill_rate = if n_prefill == 0 {
            0.0
        } else {
            self.profile.prefill_tok_s / n_prefill as f64
        };
        let decode_rate = if n_decode == 0 {
            0.0
        } else {
            self.profile
                .decode_tok_s
                .min(self.profile.max_agg_decode_tok_s / n_decode as f64)
        };
        (prefill_rate, decode_rate)
    }

    fn rate_of(&self, phase: Phase, rates: (f64, f64)) -> f64 {
        match phase {
            Phase::Prefill => rates.0,
            Phase::Decode => rates.1,
        }
    }

    /// Earliest time any running slot finishes its current phase, given
    /// current rates. Floored at 1 ns of progress so float dust can never
    /// produce a zero-width event loop.
    fn next_phase_end(&self) -> Option<Time> {
        let rates = self.rates();
        self.running
            .iter()
            .filter_map(|s| {
                let r = self.rate_of(s.phase, rates);
                if r <= 0.0 {
                    None
                } else {
                    Some(self.last_settled + (s.remaining / r).max(1e-9))
                }
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Fill free slots from the queues.
    fn admit(&mut self, now: Time) {
        while self.running.len() < self.profile.max_batch {
            let next = if self.prioritize_own {
                self.own_queue
                    .pop_front()
                    .or_else(|| self.delegated_queue.pop_front())
            } else {
                // Single logical FIFO: pick whichever queued earlier.
                match (self.own_queue.front(), self.delegated_queue.front()) {
                    (Some(a), Some(b)) => {
                        if a.0.submitted_at <= b.0.submitted_at {
                            self.own_queue.pop_front()
                        } else {
                            self.delegated_queue.pop_front()
                        }
                    }
                    (Some(_), None) => self.own_queue.pop_front(),
                    (None, Some(_)) => self.delegated_queue.pop_front(),
                    (None, None) => None,
                }
            };
            let Some((req, kind)) = next else { break };
            let remaining = req.prompt_tokens.max(1) as f64;
            self.running.push(Slot {
                req,
                kind,
                phase: Phase::Prefill,
                remaining,
                started_at: now,
            });
        }
    }

    /// Integrate work over [last_settled, until] assuming no admissions in
    /// between; splits at internal phase boundaries.
    fn settle(&mut self, until: Time) {
        while self.last_settled < until - 1e-12 {
            let boundary = self
                .next_phase_end()
                .map(|t| t.min(until))
                .unwrap_or(until);
            let dt = boundary - self.last_settled;
            if dt > 0.0 {
                let rates = self.rates();
                let mut finished = Vec::new();
                for (i, s) in self.running.iter_mut().enumerate() {
                    let r = match s.phase {
                        Phase::Prefill => rates.0,
                        Phase::Decode => rates.1,
                    };
                    let work = r * dt;
                    if s.phase == Phase::Decode {
                        self.tokens_generated += work.min(s.remaining);
                    }
                    s.remaining -= work;
                    // Finish threshold: a millionth of a token (absorbs
                    // float dust without affecting any latency metric).
                    if s.remaining <= 1e-6 {
                        match s.phase {
                            Phase::Prefill => {
                                s.phase = Phase::Decode;
                                s.remaining = s.req.output_tokens.max(1) as f64;
                            }
                            Phase::Decode => finished.push(i),
                        }
                    }
                }
                // Remove finished (reverse order keeps indices valid).
                for &i in finished.iter().rev() {
                    let s = self.running.swap_remove(i);
                    self.done.push(Completion {
                        request: s.req,
                        kind: s.kind,
                        finished_at: boundary,
                        started_at: s.started_at,
                    });
                }
                if !finished.is_empty() {
                    self.admit(boundary);
                }
            }
            self.last_settled = boundary;
        }
        self.last_settled = until;
    }
}

impl Backend for SimBackend {
    fn submit(&mut self, req: Request, kind: ExecKind, now: Time) {
        self.settle(now.max(self.last_settled));
        match kind {
            ExecKind::Local => self.own_queue.push_back((req, kind)),
            _ => self.delegated_queue.push_back((req, kind)),
        }
        self.admit(now);
    }

    fn advance(&mut self, now: Time) -> Vec<Completion> {
        self.settle(now.max(self.last_settled));
        std::mem::take(&mut self.done)
    }

    fn next_event(&self) -> Option<Time> {
        self.next_phase_end()
    }

    fn utilization(&self) -> f64 {
        self.running.len() as f64 / self.effective_capacity() as f64
    }

    fn queue_len(&self) -> usize {
        self.own_queue.len() + self.delegated_queue.len()
    }

    fn running_len(&self) -> usize {
        self.running.len()
    }

    fn quality(&self) -> f64 {
        self.profile.quality
    }

    fn steal_queued(&mut self, k: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            // Newest first: the oldest waiters are closest to a local slot.
            match self.own_queue.pop_back() {
                Some((req, _kind)) => out.push(req),
                None => break,
            }
        }
        out
    }

    fn slots(&self) -> usize {
        self.profile.max_batch
    }

    /// Elastic admission cap (`capacity` controller lever): settle work to
    /// `now`, move the cap, and — on a growth — admit from the queue
    /// immediately. A shrink never evicts running sequences; it simply
    /// stops admissions until attrition brings the batch under the new
    /// cap. Utilization reporting follows the new cap at once (the
    /// saturation batch still bounds `effective_capacity`).
    fn set_slots(&mut self, slots: usize, now: Time) {
        let slots = slots.max(1);
        if slots == self.profile.max_batch {
            return;
        }
        self.settle(now.max(self.last_settled));
        self.profile.max_batch = slots;
        self.admit(now.max(self.last_settled));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{NodeId, RequestId};

    fn req(seq: u64, prompt: u32, output: u32, at: Time) -> Request {
        Request {
            id: RequestId { origin: NodeId(0), seq },
            prompt_tokens: prompt,
            output_tokens: output,
            submitted_at: at,
            slo_deadline: 1e9,
            synthetic: false,
            payload: vec![],
        }
    }

    fn profile(decode: f64, agg: f64, prefill: f64, max_batch: usize) -> Profile {
        Profile {
            prefill_tok_s: prefill,
            decode_tok_s: decode,
            max_agg_decode_tok_s: agg,
            max_batch,
            quality: 0.7,
        }
    }

    #[test]
    fn single_request_exact_latency() {
        // prefill 100 tok @ 1000 tok/s = 0.1s; decode 50 tok @ 10 tok/s = 5s.
        let mut b = SimBackend::new(profile(10.0, 100.0, 1000.0, 4));
        b.submit(req(0, 100, 50, 0.0), ExecKind::Local, 0.0);
        assert_eq!(b.running_len(), 1);
        let done = b.advance(10.0);
        assert_eq!(done.len(), 1);
        assert!((done[0].finished_at - 5.1).abs() < 1e-6,
                "finished at {}", done[0].finished_at);
    }

    #[test]
    fn next_event_predicts_completion() {
        let mut b = SimBackend::new(profile(10.0, 100.0, 1000.0, 4));
        b.submit(req(0, 100, 50, 0.0), ExecKind::Local, 0.0);
        // First event is the prefill->decode transition at 0.1s.
        let t1 = b.next_event().unwrap();
        assert!((t1 - 0.1).abs() < 1e-9);
        b.advance(t1);
        let t2 = b.next_event().unwrap();
        assert!((t2 - 5.1).abs() < 1e-6);
    }

    #[test]
    fn unsaturated_batch_runs_at_full_speed() {
        // Two requests, saturation batch = agg/decode = 10: both full speed.
        let mut b = SimBackend::new(profile(10.0, 100.0, 1e9, 8));
        b.submit(req(0, 1, 100, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 1, 100, 0.0), ExecKind::Local, 0.0);
        let done = b.advance(20.0);
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!((c.finished_at - 10.0).abs() < 0.01,
                    "finished {}", c.finished_at);
        }
    }

    #[test]
    fn saturated_batch_shares_throughput() {
        // agg cap 20 tok/s, 4 decoding -> 5 tok/s each.
        let mut b = SimBackend::new(profile(10.0, 20.0, 1e9, 8));
        for i in 0..4 {
            b.submit(req(i, 1, 100, 0.0), ExecKind::Local, 0.0);
        }
        let done = b.advance(100.0);
        assert_eq!(done.len(), 4);
        for c in &done {
            assert!((c.finished_at - 20.0).abs() < 0.1,
                    "finished {}", c.finished_at);
        }
    }

    #[test]
    fn queue_waits_for_slot() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 1));
        b.submit(req(0, 10, 10, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 10, 10, 0.0), ExecKind::Local, 0.0);
        assert_eq!(b.running_len(), 1);
        assert_eq!(b.queue_len(), 1);
        let done = b.advance(100.0);
        assert_eq!(done.len(), 2);
        // Second starts only after first finishes.
        assert!(done[1].started_at >= done[0].finished_at - 1e-9);
    }

    #[test]
    fn own_prioritized_over_delegated() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 1));
        b.submit(req(0, 10, 10, 0.0), ExecKind::Local, 0.0);
        // Delegated queued first, own second — own should still run first.
        b.submit(req(1, 10, 10, 0.1), ExecKind::Delegated, 0.1);
        b.submit(req(2, 10, 10, 0.2), ExecKind::Local, 0.2);
        let done = b.advance(100.0);
        let order: Vec<u64> = done.iter().map(|c| c.request.id.seq).collect();
        assert_eq!(order, vec![0, 2, 1]);
    }

    #[test]
    fn fifo_when_priority_disabled() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 1))
            .with_priority(false);
        b.submit(req(0, 10, 10, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 10, 10, 0.1), ExecKind::Delegated, 0.1);
        b.submit(req(2, 10, 10, 0.2), ExecKind::Local, 0.2);
        let done = b.advance(100.0);
        let order: Vec<u64> = done.iter().map(|c| c.request.id.seq).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn utilization_reflects_running() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 4));
        assert_eq!(b.utilization(), 0.0);
        b.submit(req(0, 10, 1000, 0.0), ExecKind::Local, 0.0);
        b.submit(req(1, 10, 1000, 0.0), ExecKind::Local, 0.0);
        assert!((b.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn idle_backend_no_events() {
        let b = SimBackend::new(profile(10.0, 1e9, 1e9, 4));
        assert!(b.next_event().is_none());
    }

    #[test]
    fn tokens_generated_accounting() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 4));
        b.submit(req(0, 10, 50, 0.0), ExecKind::Local, 0.0);
        b.advance(100.0);
        assert!((b.tokens_generated - 50.0).abs() < 1e-6);
    }

    #[test]
    fn set_slots_grows_admission_and_shrink_never_evicts() {
        let mut b = SimBackend::new(profile(10.0, 1e9, 1e9, 2));
        for i in 0..4 {
            b.submit(req(i, 10, 1000, 0.0), ExecKind::Local, 0.0);
        }
        assert_eq!(b.running_len(), 2);
        assert_eq!(b.queue_len(), 2);
        assert_eq!(b.slots(), 2);
        // Growing the cap admits the queued work immediately.
        b.set_slots(4, 1.0);
        assert_eq!(b.slots(), 4);
        assert_eq!(b.running_len(), 4);
        assert_eq!(b.queue_len(), 0);
        assert!((b.utilization() - 4.0 / 4.0).abs() < 1e-12);
        // Shrinking never kills running sequences; admission just stops.
        b.set_slots(1, 2.0);
        assert_eq!(b.running_len(), 4);
        b.submit(req(9, 10, 10, 3.0), ExecKind::Local, 3.0);
        assert_eq!(b.running_len(), 4, "over-cap admission after shrink");
        assert_eq!(b.queue_len(), 1);
        // A floor of one slot always remains.
        b.set_slots(0, 4.0);
        assert_eq!(b.slots(), 1);
    }

    #[test]
    fn set_slots_noop_preserves_trace() {
        let run = |rescale: bool| {
            let mut b = SimBackend::new(profile(7.0, 23.0, 400.0, 3));
            for i in 0..10 {
                b.submit(
                    req(i, 50, 40, i as f64),
                    ExecKind::Local,
                    i as f64,
                );
                if rescale {
                    b.set_slots(3, i as f64); // same cap: must be inert
                }
            }
            b.advance(500.0)
                .iter()
                .map(|c| (c.request.id.seq, (c.finished_at * 1e9) as i64))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn determinism() {
        let run = || {
            let mut b = SimBackend::new(profile(7.0, 23.0, 400.0, 3));
            for i in 0..20 {
                b.submit(
                    req(i, 17 + (i as u32 * 13) % 97, 29 + (i as u32 * 7) % 61,
                        i as f64 * 0.37),
                    if i % 3 == 0 { ExecKind::Delegated } else { ExecKind::Local },
                    i as f64 * 0.37,
                );
            }
            b.advance(500.0)
                .iter()
                .map(|c| (c.request.id.seq, (c.finished_at * 1e9) as i64))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
