//! Minimal benchmarking harness (criterion is unavailable offline —
//! DESIGN.md §8). Used by every `benches/*.rs` (all `harness = false`).
//!
//! Features the benches need: warmup, timed iterations with mean/p50/p99,
//! throughput reporting, simple fixed-width table printing for the
//! paper-figure harnesses, and machine-readable JSON reports
//! ([`write_json_report`]) — the `BENCH_*.json` artifacts that let future
//! PRs track perf regressions (see `benches/fleet_scale.rs`).

pub mod perf_gate;

use std::time::Instant;

use crate::util::json::Json;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Machine-readable form for `BENCH_*.json` perf-trajectory artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("p50_ns", Json::num(self.p50_ns)),
            ("p99_ns", Json::num(self.p99_ns)),
            ("min_ns", Json::num(self.min_ns)),
        ])
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` repeatedly: `warmup` unmeasured runs, then up to `iters`
/// measured runs (capped at ~`budget_s` wall seconds).
pub fn bench<R>(
    name: &str,
    warmup: usize,
    iters: usize,
    budget_s: f64,
    mut f: impl FnMut() -> R,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed().as_secs_f64() > budget_s {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let pick = |p: f64| samples[((n - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: if samples.is_empty() { 0.0 } else { pick(0.5) },
        p99_ns: if samples.is_empty() { 0.0 } else { pick(0.99) },
        min_ns: samples.first().copied().unwrap_or(0.0),
    };
    r.report();
    r
}

/// Write a machine-readable bench report. Benches call this with a path
/// like `BENCH_fleet_scale.json` (cargo runs benches from the workspace
/// root, so the artifact lands next to the sources where the perf
/// trajectory is tracked). The file gets a trailing newline so diffs stay
/// clean.
pub fn write_json_report(path: &str, report: &Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{report}\n"))
}

/// Print a markdown-ish table (paper-figure harness output).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let r = bench("noop", 2, 50, 1.0, || 1 + 1);
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns * 1.5 + 1.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // just must not panic
    }

    #[test]
    fn json_report_roundtrips() {
        let r = bench("noop2", 0, 10, 1.0, || 2 + 2);
        let j = r.to_json();
        assert_eq!(j.get("name").as_str(), Some("noop2"));
        assert!(j.get("mean_ns").as_f64().is_some());
        let path = std::env::temp_dir().join("wwwserve_bench_report.json");
        let path = path.to_str().unwrap().to_string();
        let report = Json::obj(vec![("results", Json::Arr(vec![j]))]);
        write_json_report(&path, &report).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("results").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
