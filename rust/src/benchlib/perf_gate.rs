//! Perf-trajectory gate: compare a freshly produced `BENCH_*.json`
//! artifact against a committed baseline and fail on regression
//! (ROADMAP follow-on: "track wall-clock events/sec across PRs from the
//! artifact history").
//!
//! The gate matches runs by `(nodes, gossip)` key and checks:
//!
//! * `events_per_sec` — higher is better; fail when the current run drops
//!   more than `tolerance` below the baseline (wall-clock noise is real on
//!   shared CI runners, hence the generous default of 20%).
//! * `gossip_bytes_per_round` — lower is better; fail when the current
//!   run exceeds the baseline by more than `tolerance` (this one is
//!   deterministic given the seed, so a trip is a genuine protocol
//!   regression, not noise).
//!
//! A baseline with `"bootstrap": true` passes with a notice — that is how
//! the gate ships before any machine has recorded real numbers: the first
//! CI run prints the artifact to commit as `perf/fleet_scale.baseline.json`.
//! Runs present on only one side are reported but never fail (smoke tiers
//! measure a subset of the full-size sweep).
//!
//! A baseline with `"reference": true` is a *committed, machine-agnostic*
//! floor (see `perf/README.md`): `gossip_bytes_per_round` is a pure
//! simulation output — deterministic given the seed, identical on any
//! hardware — so it gates at the standard tolerance, while
//! `events_per_sec` is wall-clock from whatever machine measured the
//! artifact, so it gates only against catastrophic collapse
//! ([`REFERENCE_EVENTS_TOLERANCE`]). The rolling Actions-cache baseline
//! (like-hardware, neither flag) remains the preferred comparison; the
//! reference mode is what makes a committed artifact meaningful on a
//! cold cache without failing every slower runner.

use crate::util::json::Json;

/// Default relative regression tolerance (20%) — the value the CI gate
/// runs with unless the `PERF_GATE_TOLERANCE` env var overrides it (see
/// `rust/src/bin/perf_gate.rs`). The boundary is *inclusive*: a run at
/// exactly `baseline * (1 - tolerance)` events/sec (or
/// `baseline * (1 + tolerance)` gossip bytes) still passes.
pub const PERF_GATE_TOLERANCE: f64 = 0.20;

/// Wall-clock tolerance against a `"reference": true` baseline: the
/// committed artifact was measured on unknown hardware, so events/sec
/// only fails on a collapse past 80% — an order-of-magnitude canary, not
/// a perf trajectory. Gossip bytes stay at the standard tolerance (they
/// are machine-independent).
pub const REFERENCE_EVENTS_TOLERANCE: f64 = 0.80;

/// Outcome of one gate evaluation.
#[derive(Debug, Default)]
pub struct GateReport {
    /// Human-readable per-check lines (pass and informational).
    pub checked: Vec<String>,
    /// Regressions — non-empty means the gate fails.
    pub failures: Vec<String>,
    /// The baseline was a placeholder; nothing was compared.
    pub bootstrap: bool,
    /// The baseline was a committed machine-agnostic reference: wall-clock
    /// metrics gated at [`REFERENCE_EVENTS_TOLERANCE`] instead of
    /// `tolerance`.
    pub reference: bool,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

fn runs(j: &Json) -> Vec<&Json> {
    j.get("runs").as_arr().map(|a| a.iter().collect()).unwrap_or_default()
}

fn run_key(r: &Json) -> Option<(u64, String)> {
    let nodes = r.get("nodes").as_f64()? as u64;
    let mode = r.get("gossip").as_str()?.to_string();
    Some((nodes, mode))
}

/// Compare `current` against `baseline` with a relative `tolerance`
/// (e.g. 0.20 = fail on >20% regression).
pub fn compare(baseline: &Json, current: &Json, tolerance: f64) -> GateReport {
    let mut rep = GateReport::default();
    if baseline.get("bootstrap").as_bool().unwrap_or(false) {
        rep.bootstrap = true;
        rep.checked.push(
            "baseline is a bootstrap placeholder — nothing compared; \
             commit the current artifact as the baseline to arm the gate"
                .to_string(),
        );
        return rep;
    }
    if baseline.get("reference").as_bool().unwrap_or(false) {
        rep.reference = true;
        rep.checked.push(format!(
            "baseline is a committed machine-agnostic reference — \
             gossip bytes gated at {:.0}%, events/sec only at the \
             catastrophic {:.0}% floor",
            tolerance * 100.0,
            REFERENCE_EVENTS_TOLERANCE * 100.0
        ));
    }
    let events_tolerance = if rep.reference {
        REFERENCE_EVENTS_TOLERANCE
    } else {
        tolerance
    };
    let base_runs = runs(baseline);
    let cur_runs = runs(current);
    if cur_runs.is_empty() {
        rep.failures.push("current report has no runs".to_string());
        return rep;
    }
    let mut compared = 0usize;
    for cur in &cur_runs {
        let Some(key) = run_key(cur) else {
            rep.failures
                .push("current run missing nodes/gossip key".to_string());
            continue;
        };
        let Some(base) = base_runs
            .iter()
            .find(|b| run_key(b).as_ref() == Some(&key))
        else {
            rep.checked.push(format!(
                "n={} {}: no baseline counterpart (skipped)",
                key.0, key.1
            ));
            continue;
        };
        compared += 1;
        // events/sec: higher is better.
        check_metric(
            &mut rep,
            &key,
            "events_per_sec",
            base.get("events_per_sec").as_f64(),
            cur.get("events_per_sec").as_f64(),
            events_tolerance,
            true,
        );
        // gossip bytes/round: lower is better.
        check_metric(
            &mut rep,
            &key,
            "gossip_bytes_per_round",
            base.get("gossip_bytes_per_round").as_f64(),
            cur.get("gossip_bytes_per_round").as_f64(),
            tolerance,
            false,
        );
    }
    if compared == 0 {
        rep.failures.push(
            "no current run matched any baseline run — wrong artifact?"
                .to_string(),
        );
    }
    rep
}

#[allow(clippy::too_many_arguments)]
fn check_metric(
    rep: &mut GateReport,
    key: &(u64, String),
    metric: &str,
    base: Option<f64>,
    cur: Option<f64>,
    tolerance: f64,
    higher_is_better: bool,
) {
    let label = format!("n={} {} {metric}", key.0, key.1);
    let (Some(base), Some(cur)) = (base, cur) else {
        rep.checked.push(format!("{label}: missing value (skipped)"));
        return;
    };
    if !(base.is_finite() && cur.is_finite() && base > 0.0) {
        rep.checked.push(format!("{label}: non-finite value (skipped)"));
        return;
    }
    let (regressed, change) = if higher_is_better {
        (cur < base * (1.0 - tolerance), cur / base - 1.0)
    } else {
        (cur > base * (1.0 + tolerance), cur / base - 1.0)
    };
    let line = format!(
        "{label}: baseline {base:.1}, current {cur:.1} ({:+.1}%)",
        change * 100.0
    );
    if regressed {
        rep.failures.push(line);
    } else {
        rep.checked.push(line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(runs: &[(u64, &str, f64, f64)]) -> Json {
        Json::obj(vec![(
            "runs",
            Json::Arr(
                runs.iter()
                    .map(|(n, mode, eps, gbpr)| {
                        Json::obj(vec![
                            ("nodes", Json::num(*n as f64)),
                            ("gossip", Json::str(*mode)),
                            ("events_per_sec", Json::num(*eps)),
                            ("gossip_bytes_per_round", Json::num(*gbpr)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    #[test]
    fn bootstrap_baseline_passes_with_notice() {
        let base = Json::obj(vec![("bootstrap", Json::Bool(true))]);
        let cur = report(&[(50, "delta", 1000.0, 500.0)]);
        let rep = compare(&base, &cur, 0.2);
        assert!(rep.passed());
        assert!(rep.bootstrap);
    }

    #[test]
    fn within_tolerance_passes_beyond_fails() {
        let base = report(&[(50, "delta", 1000.0, 500.0)]);
        // 15% slower events/sec, same bytes: passes at 20% tolerance.
        let ok = report(&[(50, "delta", 850.0, 500.0)]);
        assert!(compare(&base, &ok, 0.2).passed());
        // 25% slower: fails.
        let slow = report(&[(50, "delta", 750.0, 500.0)]);
        let rep = compare(&base, &slow, 0.2);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("events_per_sec"));
        // 25% more gossip bytes/round: fails (lower is better).
        let fat = report(&[(50, "delta", 1000.0, 625.1)]);
        let rep = compare(&base, &fat, 0.2);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("gossip_bytes_per_round"));
        // Improvements never fail.
        let fast = report(&[(50, "delta", 5000.0, 100.0)]);
        assert!(compare(&base, &fast, 0.2).passed());
    }

    #[test]
    fn tolerance_boundary_exactly_at_gate_tolerance_passes() {
        let base = report(&[(50, "delta", 1000.0, 500.0)]);
        // The boundary is inclusive on both metrics: exactly
        // tolerance-worse still passes...
        let floor = 1000.0 * (1.0 - PERF_GATE_TOLERANCE);
        let ceil = 500.0 * (1.0 + PERF_GATE_TOLERANCE);
        let at = report(&[(50, "delta", floor, ceil)]);
        assert!(compare(&base, &at, PERF_GATE_TOLERANCE).passed());
        // ...and anything past it fails, one metric at a time.
        let slow = report(&[(50, "delta", floor - 1.0, ceil)]);
        let rep = compare(&base, &slow, PERF_GATE_TOLERANCE);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("events_per_sec"));
        let fat = report(&[(50, "delta", floor, ceil + 1.0)]);
        let rep = compare(&base, &fat, PERF_GATE_TOLERANCE);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("gossip_bytes_per_round"));
    }

    fn run_with(pairs: Vec<(&str, Json)>) -> Json {
        let mut fields = vec![
            ("nodes", Json::num(50.0)),
            ("gossip", Json::str("delta")),
        ];
        fields.extend(pairs);
        Json::obj(vec![("runs", Json::Arr(vec![Json::obj(fields)]))])
    }

    #[test]
    fn metric_missing_from_either_side_skips_not_fails() {
        // Baseline has only events/sec, current has only gossip bytes:
        // each metric is missing from exactly one side. Neither direction
        // may fail the gate — smoke tiers and schema drift measure
        // subsets — but both must be called out as skipped.
        let base = run_with(vec![("events_per_sec", Json::num(1000.0))]);
        let cur =
            run_with(vec![("gossip_bytes_per_round", Json::num(400.0))]);
        let rep = compare(&base, &cur, 0.2);
        assert!(rep.passed(), "missing metrics failed the gate: {rep:?}");
        assert_eq!(
            rep.checked
                .iter()
                .filter(|l| l.contains("missing value"))
                .count(),
            2,
            "both one-sided metrics must be reported skipped: {rep:?}"
        );
        // The run keys still matched, so this is not the
        // nothing-in-common wiring failure.
        assert!(rep.failures.is_empty());
    }

    #[test]
    fn zero_and_nan_baselines_are_skipped_diagnostics() {
        // A zeroed baseline (bad artifact) or NaN (corrupt JSON maths)
        // must not divide-by-zero into a pass *or* a spurious failure.
        let base = report(&[(50, "delta", 0.0, f64::NAN)]);
        let cur = report(&[(50, "delta", 900.0, 500.0)]);
        let rep = compare(&base, &cur, 0.2);
        assert!(rep.passed());
        assert_eq!(
            rep.checked
                .iter()
                .filter(|l| l.contains("non-finite"))
                .count(),
            2,
            "zero/NaN baselines must be skipped with a notice: {rep:?}"
        );
        // NaN on the current side is equally inert.
        let base = report(&[(50, "delta", 1000.0, 500.0)]);
        let cur = report(&[(50, "delta", f64::NAN, 500.0)]);
        assert!(compare(&base, &cur, 0.2).passed());
    }

    fn reference_report(runs_spec: &[(u64, &str, f64, f64)]) -> Json {
        let mut j = report(runs_spec);
        if let Json::Obj(o) = &mut j {
            o.insert("reference".to_string(), Json::Bool(true));
        }
        j
    }

    #[test]
    fn reference_baseline_widens_only_the_wallclock_metric() {
        let base = reference_report(&[(50, "delta", 1000.0, 500.0)]);
        // 50% slower events/sec on different hardware: passes (only the
        // catastrophic 80% floor applies to wall clock)...
        let slower_hw = report(&[(50, "delta", 500.0, 500.0)]);
        let rep = compare(&base, &slower_hw, 0.2);
        assert!(rep.passed(), "{rep:?}");
        assert!(rep.reference);
        // ...a collapse past the floor still fails...
        let collapsed = report(&[(50, "delta", 150.0, 500.0)]);
        let rep = compare(&base, &collapsed, 0.2);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("events_per_sec"));
        // ...and gossip bytes (machine-independent) keep the standard
        // tolerance: +25% fails exactly as against a measured baseline.
        let fat = report(&[(50, "delta", 1000.0, 625.1)]);
        let rep = compare(&base, &fat, 0.2);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("gossip_bytes_per_round"));
    }

    #[test]
    fn new_report_sections_are_ignored_not_failed() {
        // BENCH artifacts grow new top-level sections over time (e.g.
        // geo_scale's "streaming" block): the gate keys on the `runs`
        // array and its named metrics only, so unknown sections on either
        // side are inert — never a failure, never a comparison.
        let mut base = report(&[(50, "delta", 1000.0, 500.0)]);
        if let Json::Obj(o) = &mut base {
            o.insert("elastic".to_string(), Json::obj(vec![]));
        }
        let mut cur = report(&[(50, "delta", 990.0, 500.0)]);
        if let Json::Obj(o) = &mut cur {
            o.insert(
                "streaming".to_string(),
                Json::obj(vec![("kv_bytes", Json::num(1e9))]),
            );
        }
        let rep = compare(&base, &cur, 0.2);
        assert!(rep.passed(), "new section keys tripped the gate: {rep:?}");
    }

    #[test]
    fn bootstrap_wins_over_reference_when_both_set() {
        // A placeholder that also claims to be a reference is still a
        // placeholder: nothing to compare against.
        let mut base = reference_report(&[]);
        if let Json::Obj(o) = &mut base {
            o.insert("bootstrap".to_string(), Json::Bool(true));
        }
        let cur = report(&[(50, "delta", 1000.0, 500.0)]);
        let rep = compare(&base, &cur, 0.2);
        assert!(rep.passed());
        assert!(rep.bootstrap);
    }

    #[test]
    fn unmatched_runs_skip_but_total_mismatch_fails() {
        let base = report(&[(50, "delta", 1000.0, 500.0)]);
        // Extra current sizes (full tier vs smoke baseline) are skipped.
        let cur = report(&[
            (50, "delta", 990.0, 500.0),
            (500, "delta", 400.0, 9000.0),
        ]);
        assert!(compare(&base, &cur, 0.2).passed());
        // Nothing in common at all: that is a wiring error, not a pass.
        let other = report(&[(200, "full", 1.0, 1.0)]);
        assert!(!compare(&base, &other, 0.2).passed());
        // An empty current report always fails.
        let empty = Json::obj(vec![("runs", Json::Arr(vec![]))]);
        assert!(!compare(&base, &empty, 0.2).passed());
    }
}
