//! detlint CLI — the determinism & invariant static-analysis gate.
//!
//! Usage: `cargo run --bin detlint [-- <repo-root>]` (default `.`).
//!
//! Walks `rust/src/`, `rust/tests/` and `benches/` under the given root,
//! runs the D001–D006 rule engine (`wwwserve::analysis`) over every `.rs`
//! file, prints unexempted findings plus the full exemption census, writes
//! `DETLINT_report.json` at the root, and exits nonzero when any
//! unexempted finding or malformed `detlint:allow` annotation remains.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use wwwserve::analysis;

const SCAN_ROOTS: [&str; 3] = ["rust/src", "rust/tests", "benches"];

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    let root = PathBuf::from(root);

    let mut files: Vec<PathBuf> = Vec::new();
    for sub in SCAN_ROOTS {
        collect_rs(&root.join(sub), &mut files);
    }
    // Deterministic scan order regardless of filesystem enumeration order.
    files.sort();

    let mut sources: Vec<(String, String)> = Vec::new();
    for path in &files {
        let rel = rel_path(&root, path);
        match fs::read_to_string(path) {
            Ok(src) => sources.push((rel, src)),
            Err(e) => {
                eprintln!("detlint: cannot read {rel}: {e}");
                return ExitCode::from(2);
            }
        }
    }

    let report = analysis::scan_tree(sources.iter().map(|(p, s)| (p.as_str(), s.as_str())));

    for f in &report.findings {
        println!(
            "detlint: {}: {}:{}: {}\n    {}",
            f.rule, f.file, f.line, f.message, f.snippet
        );
    }
    for m in &report.malformed {
        println!(
            "detlint: malformed detlint:allow at {}:{}: {}",
            m.file, m.line, m.what
        );
    }
    for (file, line, rules) in &report.unused_allows {
        println!("detlint: warning: unused detlint:allow({rules}) at {file}:{line}");
    }

    // Exemption census: every allow that is load-bearing, with its reason —
    // CI prints this so reviewers see the full suppression surface.
    println!("\ndetlint exemption census ({}):", report.exemptions.len());
    for e in &report.exemptions {
        println!("  {} {}:{} — {}", e.rule, e.file, e.line, e.reason);
        println!("      {}", e.snippet);
    }

    let out = root.join("DETLINT_report.json");
    if let Err(e) = fs::write(&out, format!("{}\n", report.to_json())) {
        eprintln!("detlint: cannot write {}: {e}", out.display());
        return ExitCode::from(2);
    }

    println!(
        "\ndetlint: {} files, {} findings, {} exemptions, {} malformed — {}",
        report.scanned_files,
        report.findings.len(),
        report.exemptions.len(),
        report.malformed.len(),
        if report.failed() { "FAIL" } else { "ok" }
    );
    if report.failed() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

/// Recursively collect `.rs` files under `dir` (missing dirs are skipped so
/// the bin also runs on partial checkouts).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Root-relative path with forward slashes — what `analysis::classify`
/// keys its scoping decisions on.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.to_string_lossy().replace('\\', "/")
}
