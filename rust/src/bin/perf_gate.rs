//! CI perf-trajectory gate: `perf_gate <current.json> <baseline.json>`.
//!
//! Compares a freshly produced `BENCH_*.json` artifact against the
//! committed baseline (see `perf/`) and exits non-zero on a regression
//! beyond the tolerance (`PERF_GATE_TOLERANCE`, default 0.20 = 20%).
//! A `"bootstrap": true` baseline passes with instructions — commit the
//! printed artifact to arm the gate.

use wwwserve::benchlib::perf_gate::{compare, PERF_GATE_TOLERANCE};
use wwwserve::util::json::Json;

fn load(path: &str) -> Json {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("perf_gate: cannot read {path}: {e}");
            std::process::exit(2);
        }
    };
    match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("perf_gate: cannot parse {path}: {e}");
            std::process::exit(2);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let [current_path, baseline_path] = args.as_slice() else {
        eprintln!("usage: perf_gate <current.json> <baseline.json>");
        std::process::exit(2);
    };
    let tolerance = std::env::var("PERF_GATE_TOLERANCE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(PERF_GATE_TOLERANCE);
    let current = load(current_path);
    let baseline = load(baseline_path);
    let rep = compare(&baseline, &current, tolerance);
    println!(
        "# perf gate: {current_path} vs {baseline_path} \
         (tolerance {:.0}%)",
        tolerance * 100.0
    );
    for line in &rep.checked {
        println!("  ok   {line}");
    }
    for line in &rep.failures {
        println!("  FAIL {line}");
    }
    if rep.bootstrap {
        println!(
            "\nbaseline is bootstrap-only: commit {current_path} as the \
             baseline file to arm the gate."
        );
    }
    if rep.passed() {
        println!("\nperf gate passed");
    } else {
        println!("\nperf gate FAILED: >{:.0}% regression", tolerance * 100.0);
        std::process::exit(1);
    }
}
