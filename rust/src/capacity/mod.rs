//! Elastic per-region capacity: decentralized autoscaling of resource
//! commitments against the diurnal wave.
//!
//! The paper's participants "flexibly determine their participation
//! policies and **resource commitments**" — PR 4 made the *behaviour* half
//! pluggable ([`crate::policy::ParticipationPolicy`]); this module makes
//! the *commitment* half elastic. Each fleet group runs its own
//! autoscaling controller — there is **no global coordinator**; a group's
//! controller is the group operator's own policy loop, and it only watches
//! signals the group's nodes already have:
//!
//! * **local backend pressure** — running-slot utilization and queue
//!   length straight from [`crate::backend::Backend`] (the same signals
//!   the dispatch layer polls);
//! * **region SLO pressure** — the windowed miss fraction of the group's
//!   home region, the per-region health summary that already circulates
//!   with gossip digests (the simulator folds it from the recorder; a TCP
//!   deployment would fold the gossiped region summaries);
//! * **live latency to remote capacity** — the group's
//!   [`crate::latency::LatencyEstimator`] estimate to the nearest other
//!   region. When idle remote capacity is effectively next door, spinning
//!   local standbys is less urgent; across an ocean it is the only way to
//!   protect the SLO.
//!
//! ## The controller loop
//!
//! Every [`CapacityConfig::eval_every`] seconds the group controller:
//!
//! 1. **accrues holding costs** — online replicas burn
//!    [`CapacityConfig::online_cost_per_hour`] credits per node-hour,
//!    idle standbys burn the (much cheaper)
//!    [`CapacityConfig::standby_cost_per_hour`] — the commitment
//!    economics: capacity you keep hot costs you credits whether or not
//!    it earns serving rewards;
//! 2. **scales slots** — each online member's backend admission cap moves
//!    within the declared commitment range
//!    `[min_slots, max_slots]` ([`crate::backend::Backend::set_slots`]);
//!    running work is never killed, a shrink takes effect as slots drain;
//! 3. **spawns / retires replicas** — whole standby replicas come online
//!    (`Join`) under sustained pressure once the slot lever is exhausted,
//!    and drain + leave (`Leave`) when the wave passes, reusing the exact
//!    join/leave churn machinery fleets already exercise. Only *idle*
//!    replicas are retired — in-flight work is never abandoned.
//!
//! All decisions are threshold-based and deterministic: the controller
//! consumes **no randomness**, so a capacity-managed world stays
//! bit-reproducible from the seed, and the [`StaticCapacity`] no-op policy
//! (or an absent `capacity` config block) leaves the trace of a
//! capacity-free world untouched byte for byte
//! (`rust/tests/replay_equivalence.rs`).
//!
//! Declaratively, a `topology.fleet` group opts in with a `capacity`
//! block (see `config::parse_experiment`):
//!
//! ```json
//! { "region": "us", "count": 1,
//!   "capacity": { "policy": "reactive", "standby": 3,
//!                 "min_slots": 2, "max_slots": 8,
//!                 "scale_up_util": 0.7, "scale_down_util": 0.25,
//!                 "cooldown": 6, "eval_every": 2,
//!                 "online_cost_per_hour": 1.0,
//!                 "standby_cost_per_hour": 0.1 } }
//! ```
//!
//! `standby: k` stamps `k` extra copies of the group's node template that
//! start offline — the declared-but-idle half of the commitment range.
//! `benches/geo_scale.rs` part 6 rides a 3-region elastic fleet over the
//! follow-the-sun diurnal wave and pins the claim: peak-window SLO within
//! a few points of static peak provisioning at materially fewer
//! node-hours.

use crate::types::{Time, CREDIT};

/// Which controller a capacity-managed group runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CapacityPolicyKind {
    /// Declared commitment only — no controller runs, nothing is charged,
    /// nothing scales, and [`CapacityConfig::check`] rejects standby or
    /// holding-cost knobs (they would be dead weight). A
    /// `capacity: {policy: "static"}` group replays the trace of a
    /// capacity-free config bit for bit.
    #[default]
    Static,
    /// Threshold-based reactive scaling (see [`ReactiveCapacity`]).
    Reactive,
}

impl CapacityPolicyKind {
    /// Parse a config-file name. `None` for unknown names — the config
    /// layer turns that into a loud error.
    pub fn parse(s: &str) -> Option<CapacityPolicyKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "static" => CapacityPolicyKind::Static,
            "reactive" => CapacityPolicyKind::Reactive,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CapacityPolicyKind::Static => "static",
            CapacityPolicyKind::Reactive => "reactive",
        }
    }

    /// Instantiate the policy object.
    pub fn build(self) -> Box<dyn CapacityPolicy> {
        match self {
            CapacityPolicyKind::Static => Box::new(StaticCapacity),
            CapacityPolicyKind::Reactive => Box::new(ReactiveCapacity),
        }
    }
}

/// Declarative knobs for one group's elastic commitment (the `capacity`
/// block on a `topology.fleet` group).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityConfig {
    pub policy: CapacityPolicyKind,
    /// Slot-scaling commitment floor. `0` (with `max_slots` 0) disables
    /// the slot lever — the backend keeps its profile's admission cap.
    pub min_slots: usize,
    /// Slot-scaling commitment ceiling (`0` = slot lever disabled).
    pub max_slots: usize,
    /// Slots added/removed per scale event.
    pub slot_step: usize,
    /// Standby replicas stamped behind the group (start offline).
    /// Requires a scaling policy: a `Static` declaration could never
    /// activate them, so `check` rejects the combination.
    pub standby: usize,
    /// Mean online-member utilization at/above which capacity grows.
    pub scale_up_util: f64,
    /// Mean online-member utilization at/below which capacity shrinks.
    pub scale_down_util: f64,
    /// Region SLO attainment target: a windowed miss fraction above
    /// `1 - slo_target` counts as pressure even below the utilization
    /// threshold.
    pub slo_target: f64,
    /// Minimum seconds between scale actions (slot or replica).
    pub cooldown: f64,
    /// Controller cadence (seconds between evaluations).
    pub eval_every: f64,
    /// Credits burned per node-hour while a replica is online.
    pub online_cost_per_hour: f64,
    /// Credits burned per node-hour while a standby replica sits offline
    /// (the cheap half of the commitment economics).
    pub standby_cost_per_hour: f64,
    /// Scale the *prefill* admission pool independently of the unified /
    /// decode slot cap (split-pool backends only — see
    /// [`crate::backend::Backend::set_prefill_slots`] and the `streaming`
    /// config block). The prefill lever moves within the same
    /// `[min_slots, max_slots]` commitment range but is driven by
    /// prefill-pool occupancy, so a compute-bound prefill wave grows
    /// prefill slots without inflating the KV-memory-bound decode pool.
    pub scale_prefill: bool,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        CapacityConfig {
            policy: CapacityPolicyKind::Static,
            min_slots: 0,
            max_slots: 0,
            slot_step: 2,
            standby: 0,
            scale_up_util: 0.8,
            scale_down_util: 0.3,
            slo_target: 0.9,
            cooldown: 30.0,
            eval_every: 5.0,
            online_cost_per_hour: 0.0,
            standby_cost_per_hour: 0.0,
            scale_prefill: false,
        }
    }
}

impl CapacityConfig {
    /// Range-check every knob; the single source of validity used by both
    /// the config parser (mapped to a `ConfigError`) and
    /// [`validate`](Self::validate) (panicking form).
    pub fn check(&self) -> Result<(), String> {
        if (self.min_slots == 0) != (self.max_slots == 0) {
            return Err(format!(
                "capacity.min_slots/max_slots must be given together \
                 (both > 0) or both omitted, got {}/{}",
                self.min_slots, self.max_slots
            ));
        }
        if self.min_slots > self.max_slots {
            return Err(format!(
                "capacity.min_slots {} > max_slots {}",
                self.min_slots, self.max_slots
            ));
        }
        if self.slot_step == 0 {
            return Err("capacity.slot_step must be >= 1".to_string());
        }
        if self.scale_prefill && !self.scales_slots() {
            return Err(
                "capacity.scale_prefill needs the slot lever: give \
                 min_slots/max_slots"
                    .to_string(),
            );
        }
        if self.policy == CapacityPolicyKind::Static
            && (self.standby > 0
                || self.online_cost_per_hour > 0.0
                || self.standby_cost_per_hour > 0.0
                || self.scale_prefill)
        {
            return Err(format!(
                "a static capacity declaration is inert (no controller \
                 runs): standby ({}), holding costs ({}/{}) and \
                 scale_prefill require policy \"reactive\"",
                self.standby,
                self.online_cost_per_hour,
                self.standby_cost_per_hour
            ));
        }
        for (name, v, lo_ok) in [
            ("scale_up_util", self.scale_up_util, self.scale_up_util > 0.0),
            (
                "scale_down_util",
                self.scale_down_util,
                self.scale_down_util >= 0.0,
            ),
        ] {
            if !(v.is_finite() && lo_ok) {
                return Err(format!("capacity.{name} invalid: {v}"));
            }
        }
        if self.scale_down_util >= self.scale_up_util {
            return Err(format!(
                "capacity.scale_down_util {} must be below scale_up_util {}",
                self.scale_down_util, self.scale_up_util
            ));
        }
        if !(self.slo_target.is_finite()
            && (0.0..=1.0).contains(&self.slo_target))
        {
            return Err(format!(
                "capacity.slo_target must be in [0, 1], got {}",
                self.slo_target
            ));
        }
        if !(self.cooldown.is_finite() && self.cooldown >= 0.0) {
            return Err(format!(
                "capacity.cooldown must be >= 0, got {}",
                self.cooldown
            ));
        }
        if !(self.eval_every.is_finite() && self.eval_every > 0.0) {
            return Err(format!(
                "capacity.eval_every must be > 0, got {}",
                self.eval_every
            ));
        }
        for (name, v) in [
            ("online_cost_per_hour", self.online_cost_per_hour),
            ("standby_cost_per_hour", self.standby_cost_per_hour),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("capacity.{name} must be >= 0, got {v}"));
            }
        }
        Ok(())
    }

    /// Panics with a descriptive message on invalid knobs (construction
    /// and `WorldConfig::validate` paths — misconfigured experiments fail
    /// loudly; the config parser uses [`check`](Self::check) to return
    /// `Err` on malformed user input instead).
    pub fn validate(&self) {
        if let Err(msg) = self.check() {
            panic!("{msg}");
        }
    }

    /// Does this group's slot lever exist at all?
    pub fn scales_slots(&self) -> bool {
        self.max_slots > 0
    }
}

/// Everything one controller evaluation can see about its group —
/// aggregated from signals the nodes already expose locally.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupSignals {
    /// Mean running-slot utilization over the group's *online* replicas
    /// (0 when none are online).
    pub mean_util: f64,
    /// Total requests waiting for a slot across online replicas.
    pub queued: usize,
    /// Online replicas (committed members + activated standbys).
    pub online: usize,
    /// Standby replicas currently offline (available to activate).
    pub offline_standby: usize,
    /// Activated standbys currently online (available to retire).
    pub elastic_online: usize,
    /// True when the slot lever cannot grow further (disabled, or every
    /// online replica is already at `max_slots`).
    pub slots_maxed: bool,
    /// Windowed miss fraction of the group's home region (0 with no
    /// completions in the window).
    pub slo_pressure: f64,
    /// Live one-way latency estimate to the nearest *other* region.
    /// `f64::INFINITY` in flat / single-region worlds: there is no remote
    /// capacity to lean on, so local standbys are the only lever.
    pub remote_latency: f64,
    /// Mean prefill-pool occupancy over online replicas with a split
    /// pool (0 when no replica runs one) — the compute-bound half of the
    /// pressure picture, driving the independent prefill lever.
    pub mean_prefill_util: f64,
}

/// One replica's locally observable state, as gathered at evaluation time.
#[derive(Debug, Clone, Copy)]
pub struct MemberState {
    /// Node index in the world.
    pub node: usize,
    pub online: bool,
    /// Running-slot utilization in [0, 1].
    pub utilization: f64,
    /// Requests waiting for a slot.
    pub queue_len: usize,
    /// Current backend admission cap.
    pub slots: usize,
    /// Current prefill-pool cap (0 = unified admission, no split pool).
    pub prefill_slots: usize,
    /// Prefill-pool occupancy in [0, 1] (0 without a split pool).
    pub prefill_util: f64,
}

/// A group's commitment-scaling policy: how the declared range is worked,
/// given the signals. Deterministic by contract — implementations consume
/// no randomness, so capacity-managed worlds replay from the seed.
pub trait CapacityPolicy: std::fmt::Debug {
    /// Stable name for config selection and reporting.
    fn name(&self) -> &'static str;

    /// Desired admission-slot count for one online replica currently at
    /// `current` slots. Return `current` to hold. Only called when the
    /// group's slot lever is enabled.
    fn desired_slots(
        &self,
        _cfg: &CapacityConfig,
        _signals: &GroupSignals,
        current: usize,
    ) -> usize {
        current
    }

    /// Desired *prefill-pool* cap for one online replica currently at
    /// `current` prefill slots. Return `current` to hold. Only called
    /// when [`CapacityConfig::scale_prefill`] is set and the replica
    /// runs a split pool (`MemberState::prefill_slots > 0`).
    fn desired_prefill_slots(
        &self,
        _cfg: &CapacityConfig,
        _signals: &GroupSignals,
        current: usize,
    ) -> usize {
        current
    }

    /// Replica-level decision: `+1` activate one standby, `-1` retire one
    /// idle elastic replica, `0` hold.
    fn replica_delta(
        &self,
        _cfg: &CapacityConfig,
        _signals: &GroupSignals,
    ) -> i32 {
        0
    }
}

/// Declared commitment only: never scales, never spawns, never retires.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticCapacity;

impl CapacityPolicy for StaticCapacity {
    fn name(&self) -> &'static str {
        "static"
    }
}

/// Threshold-based reactive controller: grow on sustained backend pressure
/// (or a missed regional SLO target), shrink when the wave passes. The
/// slot lever moves first; whole replicas only once slots are exhausted —
/// and spinning a replica is *more* urgent when the nearest remote
/// capacity is an ocean away (`remote_latency`).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReactiveCapacity;

/// Remote capacity closer than this (one-way seconds) counts as
/// effectively local: the replica lever then only engages on SLO
/// pressure, not on utilization alone — the market can absorb the wave.
const CHEAP_REMOTE_LATENCY: f64 = 0.02;

impl CapacityPolicy for ReactiveCapacity {
    fn name(&self) -> &'static str {
        "reactive"
    }

    fn desired_slots(
        &self,
        cfg: &CapacityConfig,
        s: &GroupSignals,
        current: usize,
    ) -> usize {
        if s.mean_util >= cfg.scale_up_util || s.queued > 0 {
            current.saturating_add(cfg.slot_step).min(cfg.max_slots)
        } else if s.mean_util <= cfg.scale_down_util && s.queued == 0 {
            current.saturating_sub(cfg.slot_step).max(cfg.min_slots)
        } else {
            current
        }
    }

    fn desired_prefill_slots(
        &self,
        cfg: &CapacityConfig,
        s: &GroupSignals,
        current: usize,
    ) -> usize {
        // Same thresholds as the unified lever, but driven by the
        // prefill pool's own occupancy — the two pools move
        // independently.
        if s.mean_prefill_util >= cfg.scale_up_util {
            current.saturating_add(cfg.slot_step).min(cfg.max_slots)
        } else if s.mean_prefill_util <= cfg.scale_down_util {
            current.saturating_sub(cfg.slot_step).max(cfg.min_slots)
        } else {
            current
        }
    }

    fn replica_delta(&self, cfg: &CapacityConfig, s: &GroupSignals) -> i32 {
        let slo_missing = s.slo_pressure > 1.0 - cfg.slo_target;
        let remote_is_far = s.remote_latency > CHEAP_REMOTE_LATENCY;
        let pressured = s.mean_util >= cfg.scale_up_util
            && s.slots_maxed
            && (remote_is_far || slo_missing);
        if (pressured || slo_missing) && s.offline_standby > 0 {
            return 1;
        }
        if s.mean_util <= cfg.scale_down_util
            && s.queued == 0
            && !slo_missing
            && s.elastic_online > 0
        {
            return -1;
        }
        0
    }
}

/// A scale/charge decision the simulator (or a runner) applies on the
/// controller's behalf.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CapacityAction {
    /// Set one online replica's backend admission cap.
    SetSlots { node: usize, slots: usize },
    /// Set one online replica's prefill-pool cap (split-pool backends;
    /// `CapacityConfig::scale_prefill`).
    SetPrefillSlots { node: usize, slots: usize },
    /// Bring one standby replica online (a `Join`).
    Activate { node: usize },
    /// Take one idle elastic replica offline (a `Leave`).
    Retire { node: usize },
    /// Burn `amount` micro-credits of holding cost from a replica's
    /// balance (`OpReason::CapacityHold`).
    Charge { node: usize, amount: u64 },
}

impl CapacityAction {
    /// The replica this action targets.
    pub fn node(&self) -> usize {
        match *self {
            CapacityAction::SetSlots { node, .. }
            | CapacityAction::SetPrefillSlots { node, .. }
            | CapacityAction::Activate { node }
            | CapacityAction::Retire { node }
            | CapacityAction::Charge { node, .. } => node,
        }
    }

    /// Stable short label for observability (`scale` span attribution).
    pub fn kind_name(&self) -> &'static str {
        match self {
            CapacityAction::SetSlots { .. } => "set_slots",
            CapacityAction::SetPrefillSlots { .. } => "set_prefill_slots",
            CapacityAction::Activate { .. } => "activate",
            CapacityAction::Retire { .. } => "retire",
            CapacityAction::Charge { .. } => "charge",
        }
    }

    /// Kind-specific `detail` payload for `scale` spans: the new slot
    /// count for `SetSlots`, the charged amount for `Charge`, 0 otherwise.
    pub fn detail(&self) -> u64 {
        match *self {
            CapacityAction::SetSlots { slots, .. }
            | CapacityAction::SetPrefillSlots { slots, .. } => slots as u64,
            CapacityAction::Charge { amount, .. } => amount,
            CapacityAction::Activate { .. } | CapacityAction::Retire { .. } => 0,
        }
    }
}

/// Static description of one capacity-managed group, carried on
/// `WorldConfig` (the config layer builds these from `capacity` blocks;
/// tests build them directly).
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityGroupSpec {
    /// Reporting label (the fleet group's name).
    pub label: String,
    /// Home region index (SLO pressure is folded from this region's
    /// completions).
    pub region: u32,
    /// Committed members — always-on replicas, never retired.
    pub members: Vec<usize>,
    /// Standby replicas (stamped offline; activated/retired by the
    /// controller).
    pub standby: Vec<usize>,
    pub cfg: CapacityConfig,
}

/// One group's controller state. Driven by the simulator every
/// `eval_every` seconds with freshly gathered [`MemberState`]s; emits
/// [`CapacityAction`]s. Deterministic: no RNG, ties broken by node index.
#[derive(Debug)]
pub struct GroupController {
    pub spec: CapacityGroupSpec,
    policy: Box<dyn CapacityPolicy>,
    /// Cooldown anchor: time of the last scale action.
    last_scale: f64,
    /// Last evaluation time (holding-cost integration anchor).
    last_eval: f64,
    /// Fractional micro-credits owed per replica (members then standby,
    /// same order as `all_nodes`) — charges are emitted in whole
    /// micro-credits, the remainder carries.
    owed: Vec<f64>,
    /// Recorder cursor: completions before this index are already folded
    /// into past SLO-pressure windows.
    pub seen_records: usize,
}

impl GroupController {
    pub fn new(spec: CapacityGroupSpec) -> GroupController {
        spec.cfg.validate();
        let n = spec.members.len() + spec.standby.len();
        GroupController {
            policy: spec.cfg.policy.build(),
            spec,
            last_scale: f64::NEG_INFINITY,
            last_eval: 0.0,
            owed: vec![0.0; n],
            seen_records: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// All replica node indices, committed members first.
    pub fn all_nodes(&self) -> Vec<usize> {
        self.spec
            .members
            .iter()
            .chain(self.spec.standby.iter())
            .copied()
            .collect()
    }

    /// Fold signals and emit this round's actions. `states` must be in
    /// `all_nodes()` order; `slo_pressure` is the windowed miss fraction
    /// of the group's region and `remote_latency` the live estimate to
    /// the nearest other region.
    pub fn evaluate(
        &mut self,
        states: &[MemberState],
        slo_pressure: f64,
        remote_latency: f64,
        now: Time,
    ) -> Vec<CapacityAction> {
        debug_assert_eq!(states.len(), self.owed.len());
        let mut actions = Vec::new();
        let cfg = self.spec.cfg;

        // 1. Holding costs (continuous accrual, whole micro-credits out).
        let dt = (now - self.last_eval).max(0.0);
        self.last_eval = now;
        if cfg.online_cost_per_hour > 0.0 || cfg.standby_cost_per_hour > 0.0 {
            for (i, st) in states.iter().enumerate() {
                let per_hour = if st.online {
                    cfg.online_cost_per_hour
                } else {
                    cfg.standby_cost_per_hour
                };
                self.owed[i] += per_hour * CREDIT as f64 * dt / 3600.0;
                let whole = self.owed[i].floor();
                if whole >= 1.0 {
                    self.owed[i] -= whole;
                    actions.push(CapacityAction::Charge {
                        node: st.node,
                        amount: whole as u64,
                    });
                }
            }
        }

        // 2. Signals over the online population.
        let online: Vec<&MemberState> =
            states.iter().filter(|s| s.online).collect();
        let n_members = self.spec.members.len();
        let elastic_online =
            states[n_members..].iter().filter(|s| s.online).count();
        let offline_standby =
            states[n_members..].iter().filter(|s| !s.online).count();
        let mean_util = if online.is_empty() {
            0.0
        } else {
            online.iter().map(|s| s.utilization).sum::<f64>()
                / online.len() as f64
        };
        let split: Vec<&&MemberState> =
            online.iter().filter(|s| s.prefill_slots > 0).collect();
        let mean_prefill_util = if split.is_empty() {
            0.0
        } else {
            split.iter().map(|s| s.prefill_util).sum::<f64>()
                / split.len() as f64
        };
        let signals = GroupSignals {
            mean_util,
            queued: online.iter().map(|s| s.queue_len).sum(),
            online: online.len(),
            offline_standby,
            elastic_online,
            slots_maxed: !cfg.scales_slots()
                || online.iter().all(|s| s.slots >= cfg.max_slots),
            slo_pressure,
            remote_latency,
            mean_prefill_util,
        };

        // 3. Scale levers, gated by the cooldown.
        if now - self.last_scale < cfg.cooldown {
            return actions;
        }
        let mut scaled = false;
        if cfg.scales_slots() {
            for st in &online {
                let want = self
                    .policy
                    .desired_slots(&cfg, &signals, st.slots)
                    .clamp(cfg.min_slots, cfg.max_slots);
                if want != st.slots {
                    actions.push(CapacityAction::SetSlots {
                        node: st.node,
                        slots: want,
                    });
                    scaled = true;
                }
                if cfg.scale_prefill && st.prefill_slots > 0 {
                    let want = self
                        .policy
                        .desired_prefill_slots(&cfg, &signals, st.prefill_slots)
                        .clamp(cfg.min_slots, cfg.max_slots);
                    if want != st.prefill_slots {
                        actions.push(CapacityAction::SetPrefillSlots {
                            node: st.node,
                            slots: want,
                        });
                        scaled = true;
                    }
                }
            }
        }
        match self.policy.replica_delta(&cfg, &signals) {
            d if d > 0 => {
                // Lowest-indexed offline standby comes up first.
                if let Some(st) =
                    states[n_members..].iter().find(|s| !s.online)
                {
                    actions.push(CapacityAction::Activate { node: st.node });
                    scaled = true;
                }
            }
            d if d < 0 => {
                // Highest-indexed *idle* elastic replica drains out first;
                // busy replicas are never abandoned mid-request.
                if let Some(st) = states[n_members..]
                    .iter()
                    .rev()
                    .find(|s| {
                        s.online && s.utilization <= 0.0 && s.queue_len == 0
                    })
                {
                    actions.push(CapacityAction::Retire { node: st.node });
                    scaled = true;
                }
            }
            _ => {}
        }
        if scaled {
            self.last_scale = now;
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CapacityConfig {
        CapacityConfig {
            policy: CapacityPolicyKind::Reactive,
            min_slots: 2,
            max_slots: 8,
            slot_step: 2,
            standby: 2,
            scale_up_util: 0.8,
            scale_down_util: 0.3,
            cooldown: 10.0,
            eval_every: 2.0,
            ..Default::default()
        }
    }

    fn member(node: usize, online: bool, util: f64, slots: usize) -> MemberState {
        MemberState {
            node,
            online,
            utilization: util,
            queue_len: 0,
            slots,
            prefill_slots: 0,
            prefill_util: 0.0,
        }
    }

    fn signals(util: f64) -> GroupSignals {
        GroupSignals {
            mean_util: util,
            queued: 0,
            online: 1,
            offline_standby: 1,
            elastic_online: 1,
            slots_maxed: true,
            slo_pressure: 0.0,
            remote_latency: 0.08,
            mean_prefill_util: 0.0,
        }
    }

    #[test]
    fn config_check_rejects_bad_knobs() {
        assert!(cfg().check().is_ok());
        assert!(CapacityConfig::default().check().is_ok());
        let bad = |f: &dyn Fn(&mut CapacityConfig)| {
            let mut c = cfg();
            f(&mut c);
            c.check().is_err()
        };
        assert!(bad(&|c| c.min_slots = 9)); // min > max
        assert!(bad(&|c| c.min_slots = 0)); // one of the pair missing
        assert!(bad(&|c| c.slot_step = 0));
        // Standbys behind a static declaration could never activate.
        assert!(bad(&|c| c.policy = CapacityPolicyKind::Static));
        // The prefill lever needs the slot range to move within.
        assert!(bad(&|c| {
            c.min_slots = 0;
            c.max_slots = 0;
            c.scale_prefill = true;
        }));
        assert!(bad(&|c| c.scale_down_util = 0.9)); // down >= up
        assert!(bad(&|c| c.scale_up_util = f64::NAN));
        assert!(bad(&|c| c.slo_target = 1.5));
        assert!(bad(&|c| c.cooldown = -1.0));
        assert!(bad(&|c| c.eval_every = 0.0));
        assert!(bad(&|c| c.online_cost_per_hour = -0.5));
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(
            CapacityPolicyKind::parse("static"),
            Some(CapacityPolicyKind::Static)
        );
        assert_eq!(
            CapacityPolicyKind::parse("REACTIVE"),
            Some(CapacityPolicyKind::Reactive)
        );
        assert!(CapacityPolicyKind::parse("clairvoyant").is_none());
        for k in [CapacityPolicyKind::Static, CapacityPolicyKind::Reactive] {
            assert_eq!(k.build().name(), k.name());
        }
    }

    #[test]
    fn reactive_slot_lever_moves_within_commitment() {
        let r = ReactiveCapacity;
        let c = cfg();
        // Pressure grows by slot_step, capped at max.
        assert_eq!(r.desired_slots(&c, &signals(0.9), 4), 6);
        assert_eq!(r.desired_slots(&c, &signals(0.9), 8), 8);
        // Idle shrinks, floored at min.
        assert_eq!(r.desired_slots(&c, &signals(0.1), 4), 2);
        assert_eq!(r.desired_slots(&c, &signals(0.1), 2), 2);
        // In-band holds.
        assert_eq!(r.desired_slots(&c, &signals(0.5), 4), 4);
        // A backlog counts as pressure even at moderate utilization.
        let mut s = signals(0.5);
        s.queued = 3;
        assert_eq!(r.desired_slots(&c, &s, 4), 6);
    }

    #[test]
    fn reactive_replica_lever_spawns_and_retires() {
        let r = ReactiveCapacity;
        let c = cfg();
        // Hot + slots maxed + remote capacity far away: spawn.
        assert_eq!(r.replica_delta(&c, &signals(0.9)), 1);
        // Hot but slots still have headroom: slot lever goes first.
        let mut s = signals(0.9);
        s.slots_maxed = false;
        assert_eq!(r.replica_delta(&c, &s), 0);
        // Hot but remote capacity is effectively local and SLO is fine:
        // let the market absorb it.
        let mut s = signals(0.9);
        s.remote_latency = 0.001;
        assert_eq!(r.replica_delta(&c, &s), 0);
        // ...unless the region is missing its SLO target.
        s.slo_pressure = 0.5;
        assert_eq!(r.replica_delta(&c, &s), 1);
        // Nothing left to activate.
        let mut s = signals(0.9);
        s.offline_standby = 0;
        assert_eq!(r.replica_delta(&c, &s), 0);
        // Idle: retire an elastic replica...
        assert_eq!(r.replica_delta(&c, &signals(0.1)), -1);
        // ...but never a committed member.
        let mut s = signals(0.1);
        s.elastic_online = 0;
        assert_eq!(r.replica_delta(&c, &s), 0);
    }

    /// Replica-lever-only controller (slot scaling off) so the replica
    /// tests see no SetSlots noise.
    fn controller() -> GroupController {
        GroupController::new(CapacityGroupSpec {
            label: "us/elastic".into(),
            region: 0,
            members: vec![1],
            standby: vec![2, 3],
            cfg: CapacityConfig {
                policy: CapacityPolicyKind::Reactive,
                scale_up_util: 0.8,
                scale_down_util: 0.3,
                cooldown: 10.0,
                eval_every: 2.0,
                ..Default::default()
            },
        })
    }

    #[test]
    fn controller_activates_under_pressure_and_retires_when_idle() {
        let mut c = controller();
        let hot = [
            member(1, true, 1.0, 8),
            member(2, false, 0.0, 8),
            member(3, false, 0.0, 8),
        ];
        let a = c.evaluate(&hot, 0.0, 0.08, 10.0);
        assert_eq!(a, vec![CapacityAction::Activate { node: 2 }]);
        // Cooldown gates the next replica action...
        let hot2 = [
            member(1, true, 1.0, 8),
            member(2, true, 1.0, 8),
            member(3, false, 0.0, 8),
        ];
        assert!(c.evaluate(&hot2, 0.0, 0.08, 12.0).is_empty());
        // ...and after it, the next standby comes up.
        let a = c.evaluate(&hot2, 0.0, 0.08, 21.0);
        assert_eq!(a, vec![CapacityAction::Activate { node: 3 }]);
        // Wave passed: the highest-indexed idle elastic replica retires;
        // a busy one is skipped.
        let cool_busy3 = [
            member(1, true, 0.1, 8),
            member(2, true, 0.0, 8),
            member(3, true, 0.5, 8),
        ];
        let a = c.evaluate(&cool_busy3, 0.0, 0.08, 40.0);
        assert_eq!(a, vec![CapacityAction::Retire { node: 2 }]);
        // Committed member 1 is never retired even when everything idles.
        let all_idle = [
            member(1, true, 0.0, 8),
            member(2, true, 0.0, 8),
            member(3, false, 0.0, 8),
        ];
        let a = c.evaluate(&all_idle, 0.0, 0.08, 60.0);
        assert_eq!(a, vec![CapacityAction::Retire { node: 2 }]);
    }

    #[test]
    fn controller_scales_slots_before_replicas() {
        let mut c = GroupController::new(CapacityGroupSpec {
            label: "us/elastic".into(),
            region: 0,
            members: vec![1],
            standby: vec![2, 3],
            cfg: cfg(), // slot lever on: min 2 / max 8 / step 2
        });
        let hot_with_headroom = [
            member(1, true, 0.9, 4),
            member(2, false, 0.0, 4),
            member(3, false, 0.0, 4),
        ];
        let a = c.evaluate(&hot_with_headroom, 0.0, 0.08, 10.0);
        assert_eq!(a, vec![CapacityAction::SetSlots { node: 1, slots: 6 }]);
    }

    #[test]
    fn prefill_lever_moves_independently_of_the_unified_cap() {
        let r = ReactiveCapacity;
        let mut c = cfg();
        c.scale_prefill = true;
        assert!(c.check().is_ok());
        // Prefill pressure grows the prefill pool even while overall
        // utilization sits in-band (and vice versa).
        let mut s = signals(0.5);
        s.mean_prefill_util = 0.95;
        assert_eq!(r.desired_slots(&c, &s, 4), 4);
        assert_eq!(r.desired_prefill_slots(&c, &s, 4), 6);
        s.mean_prefill_util = 0.1;
        assert_eq!(r.desired_prefill_slots(&c, &s, 4), 2);
        s.mean_prefill_util = 0.5;
        assert_eq!(r.desired_prefill_slots(&c, &s, 4), 4);
    }

    #[test]
    fn controller_emits_set_prefill_slots_for_split_pool_replicas() {
        let mut c = GroupController::new(CapacityGroupSpec {
            label: "us/elastic".into(),
            region: 0,
            members: vec![1],
            standby: vec![2, 3],
            cfg: CapacityConfig { scale_prefill: true, ..cfg() },
        });
        // Replica 1 runs a split pool under prefill pressure; overall
        // utilization is in-band so the unified cap holds.
        let mut st = member(1, true, 0.5, 4);
        st.prefill_slots = 4;
        st.prefill_util = 1.0;
        let states =
            [st, member(2, false, 0.0, 4), member(3, false, 0.0, 4)];
        let a = c.evaluate(&states, 0.0, 0.08, 10.0);
        assert_eq!(
            a,
            vec![CapacityAction::SetPrefillSlots { node: 1, slots: 6 }]
        );
        // A unified replica (prefill_slots = 0) never sees the action.
        let mut c2 = GroupController::new(CapacityGroupSpec {
            label: "us/elastic".into(),
            region: 0,
            members: vec![1],
            standby: vec![],
            cfg: CapacityConfig { scale_prefill: true, ..cfg() },
        });
        let a = c2.evaluate(&[member(1, true, 0.5, 4)], 0.0, 0.08, 10.0);
        assert!(a.is_empty());
    }

    #[test]
    fn controller_charges_online_full_and_standby_cheap() {
        let mut spec = controller().spec;
        spec.cfg.online_cost_per_hour = 3600.0; // 1 credit/second
        spec.cfg.standby_cost_per_hour = 360.0; // 0.1 credit/second
        spec.cfg.cooldown = 1e9; // isolate charging
        let mut c = GroupController::new(spec);
        // First eval anchors at t=0 with dt=10.
        let states = [
            member(1, true, 0.5, 8),
            member(2, false, 0.0, 8),
            member(3, false, 0.0, 8),
        ];
        let a = c.evaluate(&states, 0.0, 0.08, 10.0);
        let charge_of = |node: usize| {
            a.iter()
                .find_map(|x| match x {
                    CapacityAction::Charge { node: n, amount }
                        if *n == node =>
                    {
                        Some(*amount)
                    }
                    _ => None,
                })
                .unwrap_or(0)
        };
        // 10 s online at 1 credit/s = 10 credits; standby a tenth of that.
        assert_eq!(charge_of(1), 10 * CREDIT);
        assert_eq!(charge_of(2), CREDIT);
        assert_eq!(charge_of(3), CREDIT);
    }

    #[test]
    fn static_policy_is_fully_inert() {
        // A static declaration may carry no live knobs at all...
        let live_knobs: [&dyn Fn(&mut CapacityConfig); 3] = [
            &|c| c.standby = 1,
            &|c| c.online_cost_per_hour = 1.0,
            &|c| c.standby_cost_per_hour = 0.1,
        ];
        for live in live_knobs {
            let mut c = CapacityConfig::default();
            live(&mut c);
            assert!(c.check().is_err(), "static accepted a live knob");
        }
        // ...and a static controller emits nothing, however hot the group.
        let mut spec = controller().spec;
        spec.cfg.policy = CapacityPolicyKind::Static;
        let mut c = GroupController::new(spec);
        let hot = [
            member(1, true, 1.0, 8),
            member(2, false, 0.0, 8),
            member(3, false, 0.0, 8),
        ];
        assert!(c.evaluate(&hot, 0.9, 0.08, 100.0).is_empty());
    }

    #[test]
    fn fractional_charges_carry_across_evaluations() {
        let mut spec = controller().spec;
        spec.cfg.online_cost_per_hour = 3600.0 * 0.4e-6; // 0.4 µcr/s
        spec.cfg.standby_cost_per_hour = 0.0;
        spec.cfg.cooldown = 1e9;
        let mut c = GroupController::new(spec);
        let states = [
            member(1, true, 0.5, 8),
            member(2, false, 0.0, 8),
            member(3, false, 0.0, 8),
        ];
        // 1 s * 0.4 µcr = 0.4 owed: below a whole micro-credit, no charge.
        assert!(c.evaluate(&states, 0.0, 0.08, 1.0).is_empty());
        // Two more seconds: 1.2 owed, one micro-credit out, 0.2 carried.
        let a = c.evaluate(&states, 0.0, 0.08, 3.0);
        assert_eq!(
            a,
            vec![CapacityAction::Charge { node: 1, amount: 1 }]
        );
    }
}
