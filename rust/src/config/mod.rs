//! Experiment configuration files (Appendix B's YAML schema, as JSON —
//! DESIGN.md §8).
//!
//! Example:
//! ```json
//! {
//!   "seed": 7,
//!   "horizon": 750,
//!   "strategy": "decentralized",
//!   "ledger": "shared",
//!   "system": { "duel_rate": 0.1, "judges": 2 },
//!   "nodes": [
//!     {
//!       "model": "qwen3-8b", "gpu": "ada6000", "backend": "sglang",
//!       "policy": { "stake": 10, "offload_freq": 0.8, "accept_freq": 0.8 },
//!       "schedule": [ {"from": 0, "to": 300, "inter_arrival": 5},
//!                     {"from": 300, "to": 750, "inter_arrival": 20} ]
//!     }
//!   ]
//! }
//! ```

use crate::backend::{Gpu, ModelClass, Profile, ServingStack};
use crate::capacity::{CapacityConfig, CapacityGroupSpec, CapacityPolicyKind};
use crate::latency::LatencyConfig;
use crate::obs::ObservabilityConfig;
use crate::policy::{
    ByzantineKind, NodePolicy, ParticipationKind, SystemPolicy,
};
use crate::reputation::DefenseConfig;
use crate::schedulers::Strategy;
use crate::sim::{LedgerMode, NodeSetup, WorldConfig};
use crate::streaming::StreamingConfig;
use crate::topology::{LinkChange, LinkProfile, Topology};
use crate::types::{NodeId, CREDIT};
use crate::util::json::Json;
use crate::workload::{
    diurnal_phases, Generator, LengthDist, Phase, SessionProfile,
};

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("parse error: {0}")]
    Parse(#[from] crate::util::json::ParseError),
    #[error("invalid config: {0}")]
    Invalid(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// A scheduled availability change for one node, expanded from a fleet
/// group's declarative `churn` block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    /// Node index (into `Experiment::setups`).
    pub node: usize,
    pub at: f64,
    /// true = join (come online), false = leave.
    pub join: bool,
}

/// A fully parsed experiment description.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub seed: u64,
    pub horizon: f64,
    pub strategy: Strategy,
    pub world: WorldConfig,
    pub setups: Vec<NodeSetup>,
    /// Per-node join/leave schedule expanded from fleet `churn` blocks
    /// (empty when no group declares churn). Informational: the same
    /// schedule is carried in `world.churn`, which `sim::World::new`
    /// installs automatically — no extra call site obligation.
    pub churn: Vec<ChurnEvent>,
}

fn bad(msg: impl Into<String>) -> ConfigError {
    ConfigError::Invalid(msg.into())
}

fn parse_model(s: &str) -> Result<ModelClass, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "qwen3-32b" => ModelClass::Qwen3_32B,
        "qwen3-8b" => ModelClass::Qwen3_8B,
        "qwen3-4b" => ModelClass::Qwen3_4B,
        "qwen3-0.6b" => ModelClass::Qwen3_0_6B,
        "deepseek-qwen-7b" => ModelClass::DeepSeekQwen7B,
        "llama3.1-8b" => ModelClass::Llama31_8B,
        other => return Err(bad(format!("unknown model '{other}'"))),
    })
}

fn parse_gpu(s: &str) -> Result<Gpu, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "4xa100" => Gpu::A100x4,
        "a100" => Gpu::A100,
        "l40s" => Gpu::L40S,
        "ada6000" => Gpu::Ada6000,
        "rtx4090" => Gpu::Rtx4090,
        "rtx3090" => Gpu::Rtx3090,
        other => return Err(bad(format!("unknown gpu '{other}'"))),
    })
}

fn parse_stack(s: &str) -> Result<ServingStack, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "sglang" => ServingStack::SgLang,
        "vllm" => ServingStack::Vllm,
        other => return Err(bad(format!("unknown backend '{other}'"))),
    })
}

fn parse_strategy(s: &str) -> Result<Strategy, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "single" => Strategy::Single,
        "centralized" => Strategy::Centralized,
        "decentralized" => Strategy::Decentralized,
        other => return Err(bad(format!("unknown strategy '{other}'"))),
    })
}

/// Parse the scalar policy knobs on top of `d` — the base defaults come
/// from the node's participation kind, so e.g. a `requester_only` group
/// gets stake 0 / accept 0 without spelling it out.
fn parse_policy(j: &Json, d: NodePolicy) -> NodePolicy {
    NodePolicy {
        stake: j
            .get("stake")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.stake),
        offload_freq: j.get("offload_freq").as_f64().unwrap_or(d.offload_freq),
        accept_freq: j.get("accept_freq").as_f64().unwrap_or(d.accept_freq),
        target_utilization: j
            .get("target_utilization")
            .as_f64()
            .unwrap_or(d.target_utilization),
        queue_threshold: j
            .get("queue_threshold")
            .as_usize()
            .unwrap_or(d.queue_threshold),
        prioritize_own: j
            .get("prioritize_own")
            .as_bool()
            .unwrap_or(d.prioritize_own),
        requester_only: j
            .get("requester_only")
            .as_bool()
            .unwrap_or(d.requester_only),
        latency_penalty: j
            .get("latency_penalty")
            .as_f64()
            .unwrap_or(d.latency_penalty),
    }
}

// ---------------------------------------------------------------------------
// Topology block (geo-distributed scenarios)
// ---------------------------------------------------------------------------

fn parse_link_profile(j: &Json, default: LinkProfile) -> Result<LinkProfile, ConfigError> {
    let mut p = default;
    if !j.get("latency").is_null() {
        let arr = j
            .get("latency")
            .as_arr()
            .ok_or_else(|| bad("link latency must be [lo, hi]"))?;
        if arr.len() != 2 {
            return Err(bad("link latency must be [lo, hi]"));
        }
        p.latency = (
            arr[0].as_f64().ok_or_else(|| bad("link latency lo"))?,
            arr[1].as_f64().ok_or_else(|| bad("link latency hi"))?,
        );
    }
    if let Some(jit) = j.get("jitter").as_f64() {
        p.jitter = jit;
    }
    if let Some(mbps) = j.get("bandwidth_mbps").as_f64() {
        p = p.with_bandwidth_mbps(mbps);
    }
    // Reject bad values here with Err rather than letting the topology
    // builder's asserts abort the process on malformed user input.
    let (lo, hi) = p.latency;
    if !(lo.is_finite() && hi.is_finite() && lo >= 0.0 && lo <= hi) {
        return Err(bad(format!(
            "link latency must satisfy 0 <= lo <= hi, got [{lo}, {hi}]"
        )));
    }
    if !(p.jitter.is_finite() && p.jitter >= 0.0) {
        return Err(bad(format!("link jitter must be >= 0, got {}", p.jitter)));
    }
    if !(p.bandwidth > 0.0) {
        return Err(bad("link bandwidth_mbps must be > 0"));
    }
    Ok(p)
}

fn parse_link_change(j: &Json) -> Result<LinkChange, ConfigError> {
    match j.get("change").as_str().unwrap_or("") {
        "partition" => Ok(LinkChange::Partition),
        "heal" => Ok(LinkChange::Heal),
        kind @ ("degrade" | "degrade_one_way") => {
            let latency_factor =
                j.get("latency_factor").as_f64().unwrap_or(1.0);
            let bandwidth_factor =
                j.get("bandwidth_factor").as_f64().unwrap_or(1.0);
            if !(latency_factor > 0.0 && bandwidth_factor > 0.0) {
                return Err(bad("degrade factors must be > 0"));
            }
            if kind == "degrade_one_way" {
                // Applies only to the a -> b direction (one-way congestion);
                // the return path keeps its pristine profile.
                Ok(LinkChange::DegradeDirectional {
                    latency_factor,
                    bandwidth_factor,
                })
            } else {
                Ok(LinkChange::Degrade { latency_factor, bandwidth_factor })
            }
        }
        other => Err(bad(format!(
            "unknown link change '{other}' \
             (partition|heal|degrade|degrade_one_way)"
        ))),
    }
}

/// Parse the declarative `"topology"` block plus per-node `"region"` tags:
///
/// ```json
/// "topology": {
///   "regions": ["us", "eu", "asia"],
///   "intra": { "latency": [0.002, 0.010] },
///   "inter": { "latency": [0.040, 0.080], "jitter": 0.005 },
///   "links": [
///     { "a": "us", "b": "asia", "latency": [0.075, 0.095],
///       "bandwidth_mbps": 300 }
///   ],
///   "events": [
///     { "at": 250, "a": "us", "b": "asia", "change": "partition" },
///     { "at": 450, "a": "us", "b": "asia", "change": "heal" }
///   ]
/// },
/// "nodes": [ { "region": "us", ... }, ... ]
/// ```
fn parse_topology(
    j: &Json,
    nodes: &[Json],
) -> Result<Option<Topology>, ConfigError> {
    if j.is_null() {
        return Ok(None);
    }
    let region_names: Vec<String> = j
        .get("regions")
        .as_arr()
        .ok_or_else(|| bad("topology.regions must be an array of names"))?
        .iter()
        .map(|r| {
            r.as_str()
                .map(str::to_string)
                .ok_or_else(|| bad("topology region names must be strings"))
        })
        .collect::<Result<_, _>>()?;
    if region_names.is_empty() {
        return Err(bad("topology.regions is empty"));
    }
    let known = |name: &str| -> Result<(), ConfigError> {
        if region_names.iter().any(|r| r == name) {
            Ok(())
        } else {
            Err(bad(format!("unknown region '{name}' in topology")))
        }
    };

    let mut b = Topology::builder();
    for r in &region_names {
        b = b.region(r);
    }
    let intra =
        parse_link_profile(j.get("intra"), LinkProfile::new(0.002, 0.010))?;
    let inter =
        parse_link_profile(j.get("inter"), LinkProfile::new(0.040, 0.080))?;
    b = b.default_intra(intra).default_inter(inter);
    if let Some(links) = j.get("links").as_arr() {
        for l in links {
            let a = l.get("a").as_str().ok_or_else(|| bad("link.a"))?;
            let bname = l.get("b").as_str().ok_or_else(|| bad("link.b"))?;
            known(a)?;
            known(bname)?;
            // Partial overrides inherit the configured default for the
            // pair kind, not a hardcoded range.
            let base = if a == bname { intra } else { inter };
            let p = parse_link_profile(l, base)?;
            b = b.link(a, bname, p);
        }
    }
    if let Some(events) = j.get("events").as_arr() {
        for e in events {
            let at = e.get("at").as_f64().ok_or_else(|| bad("event.at"))?;
            if !(at.is_finite() && at >= 0.0) {
                return Err(bad(format!("event.at must be >= 0, got {at}")));
            }
            let a = e.get("a").as_str().ok_or_else(|| bad("event.a"))?;
            let bname = e.get("b").as_str().ok_or_else(|| bad("event.b"))?;
            known(a)?;
            known(bname)?;
            b = b.event(a, bname, at, parse_link_change(e)?);
        }
    }
    // Node placement from the per-node "region" tags; an untagged node
    // lands in the first declared region.
    for (i, nj) in nodes.iter().enumerate() {
        let r = nj.get("region").as_str().unwrap_or(region_names[0].as_str());
        known(r).map_err(|_| {
            bad(format!("node {i}: unknown region '{r}'"))
        })?;
        b = b.node(r);
    }
    Ok(Some(b.build()))
}

// ---------------------------------------------------------------------------
// Fleet templates (stamp out whole regions without listing every node)
// ---------------------------------------------------------------------------

/// Expand the optional `topology.fleet` block into per-node specs:
///
/// ```json
/// "topology": {
///   "regions": ["us", "eu", "asia"],
///   "fleet": [
///     { "region": "us", "count": 166,
///       "node": { "profile": { ... }, "policy": { "accept_freq": 1.0 } },
///       "diurnal": { "period": 300, "peak_inter_arrival": 2.5,
///                    "off_inter_arrival": 25, "offset": 0 },
///       "lengths": { "output_mean": 900, "output_sigma": 0.5 } }
///   ]
/// }
/// ```
///
/// Each group stamps out `count` copies of its `node` template, tagged with
/// the group's region and workload template (`schedule`, `diurnal`,
/// `lengths` — same schema as per-node keys). Explicit `nodes` entries come
/// first, fleet groups after, in declaration order; node ids follow that
/// order. This is how `benches/fleet_scale.rs` stands up 1000-node worlds
/// from a few lines of JSON.
///
/// Heterogeneous-fleet keys per group:
///
/// * `"policy": "<name>"` — a [`ParticipationKind`] name
///   (`default` / `requester_only` / `greedy_local` / `selective`); the
///   whole group runs that participation behaviour, so one scenario can
///   mix policy populations.
/// * `"name": "<label>"` — reporting label for per-policy-group summaries
///   (defaults to `"<region>/<policy>"`).
/// * `"start_offline": true` — the whole group starts offline.
/// * `"churn": [ {"at": T, "action": "leave"|"join", "count": K}, ... ]` —
///   scheduled availability changes. A `leave` takes down the K
///   lowest-indexed currently-up nodes of the group, a `join` brings back
///   the K lowest-indexed currently-down ones; over-subscribing either is
///   a config error. Returned as the second element.
/// * `"capacity": { "policy": "reactive"|"static", "standby": K,
///   "min_slots"/"max_slots"/"slot_step", "scale_up_util"/
///   "scale_down_util"/"slo_target", "cooldown", "eval_every",
///   "online_cost_per_hour"/"standby_cost_per_hour", "scale_prefill" }`
///   — the group's
///   elastic resource commitment (see [`crate::capacity`]). `standby: K`
///   stamps K extra copies of the node template that start offline behind
///   the group; a `reactive` policy autoscales them (and the members'
///   backend slots) against load. Validated here with `Err`, never a
///   panic; `"static"` (or an absent block) is an inert declaration —
///   standby/holding-cost knobs are rejected on it, and it replays a
///   capacity-free config's trace bit for bit.
fn expand_fleet(
    topology: &Json,
    explicit: Vec<Json>,
) -> Result<(Vec<Json>, Vec<ChurnEvent>, Vec<FleetCapacity>), ConfigError> {
    let mut out = explicit;
    let mut churn = Vec::new();
    let mut caps = Vec::new();
    let fleet = topology.get("fleet");
    if fleet.is_null() {
        return Ok((out, churn, caps));
    }
    let Some(groups) = fleet.as_arr() else {
        return Err(bad("topology.fleet must be an array of groups"));
    };
    for (gi, g) in groups.iter().enumerate() {
        let count = g
            .get("count")
            .as_usize()
            .ok_or_else(|| bad(format!("fleet group {gi}: missing count")))?;
        if count == 0 {
            return Err(bad(format!("fleet group {gi}: count must be > 0")));
        }
        let region = g
            .get("region")
            .as_str()
            .ok_or_else(|| bad(format!("fleet group {gi}: missing region")))?;
        let mut template = match g.get("node") {
            Json::Obj(m) => m.clone(),
            Json::Null => std::collections::BTreeMap::new(),
            _ => {
                return Err(bad(format!(
                    "fleet group {gi}: node template must be an object"
                )))
            }
        };
        template.insert("region".to_string(), Json::str(region));
        for key in ["schedule", "diurnal", "lengths", "sessions"] {
            if !g.get(key).is_null() {
                template.insert(key.to_string(), g.get(key).clone());
            }
        }
        // Participation policy for the whole group.
        let policy_name = match g.get("policy") {
            Json::Null => ParticipationKind::Default.name(),
            p => {
                let name = p.as_str().ok_or_else(|| {
                    bad(format!(
                        "fleet group {gi}: policy must be a participation \
                         name string"
                    ))
                })?;
                ParticipationKind::parse(name).ok_or_else(|| {
                    bad(format!(
                        "fleet group {gi}: unknown participation policy \
                         '{name}'"
                    ))
                })?;
                template
                    .insert("participation".to_string(), Json::str(name));
                name
            }
        };
        // Byzantine personality for the whole group (attacker policies —
        // see `crate::policy::byzantine`). Stamped into every copy as the
        // per-node "byzantine" key; overrides the participation policy at
        // world build.
        let byz_name = match g.get("byzantine") {
            Json::Null => None,
            b => {
                let name = b.as_str().ok_or_else(|| {
                    bad(format!(
                        "fleet group {gi}: byzantine must be an attacker \
                         name string"
                    ))
                })?;
                ByzantineKind::parse(name).ok_or_else(|| {
                    bad(format!(
                        "fleet group {gi}: unknown byzantine policy '{name}'"
                    ))
                })?;
                template.insert("byzantine".to_string(), Json::str(name));
                Some(name)
            }
        };
        // Reporting label: byzantine groups label by their attack so
        // honest/byzantine splits fall out of the per-group summaries.
        let label = match g.get("name") {
            Json::Null => match byz_name {
                Some(b) => format!("{region}/{b}"),
                None => format!("{region}/{policy_name}"),
            },
            n => n
                .as_str()
                .ok_or_else(|| {
                    bad(format!("fleet group {gi}: name must be a string"))
                })?
                .to_string(),
        };
        template.insert("group".to_string(), Json::str(label.clone()));
        // Whole-group initial availability: the group-level key wins, but
        // a `start_offline` inside the node template counts too — churn
        // validation must see what the per-node parse will actually do.
        if g.get("start_offline").as_bool().unwrap_or(false) {
            template.insert("start_offline".to_string(), Json::Bool(true));
        }
        let start_offline = template
            .get("start_offline")
            .and_then(|j| j.as_bool())
            .unwrap_or(false);
        let base = out.len();
        for _ in 0..count {
            out.push(Json::Obj(template.clone()));
        }
        churn.extend(parse_group_churn(
            g.get("churn"),
            gi,
            base,
            count,
            start_offline,
        )?);
        // Elastic capacity: stamp the declared standby replicas (offline
        // copies of the same template, appended after the committed
        // members, outside the churn-eligible range) and record the group
        // for `WorldConfig.capacity`.
        if let Some(cap) = parse_capacity(g.get("capacity"), gi)? {
            let standby_base = out.len();
            if cap.standby > 0 {
                let mut standby_template = template.clone();
                standby_template
                    .insert("start_offline".to_string(), Json::Bool(true));
                for _ in 0..cap.standby {
                    out.push(Json::Obj(standby_template.clone()));
                }
            }
            caps.push(FleetCapacity {
                label,
                region: region.to_string(),
                members: (base..base + count).collect(),
                standby: (standby_base..standby_base + cap.standby).collect(),
                cfg: cap,
            });
        }
    }
    Ok((out, churn, caps))
}

/// One fleet group's parsed `capacity` block, with the region still a
/// *name* — resolved to an index (and into a
/// [`CapacityGroupSpec`]) once the topology is built.
struct FleetCapacity {
    label: String,
    region: String,
    members: Vec<usize>,
    standby: Vec<usize>,
    cfg: CapacityConfig,
}

/// Parse one group's `capacity` block. All keys optional except that
/// malformed values (wrong types, inverted ranges, negative costs,
/// unknown policies) are loud `Err`s, never panics.
fn parse_capacity(
    j: &Json,
    gi: usize,
) -> Result<Option<CapacityConfig>, ConfigError> {
    if j.is_null() {
        return Ok(None);
    }
    if !matches!(j, Json::Obj(_)) {
        return Err(bad(format!(
            "fleet group {gi}: capacity must be an object"
        )));
    }
    let d = CapacityConfig::default();
    let policy = match j.get("policy") {
        Json::Null => CapacityPolicyKind::Static,
        p => {
            let name = p.as_str().ok_or_else(|| {
                bad(format!(
                    "fleet group {gi}: capacity.policy must be a string"
                ))
            })?;
            CapacityPolicyKind::parse(name).ok_or_else(|| {
                bad(format!(
                    "fleet group {gi}: unknown capacity policy '{name}'"
                ))
            })?
        }
    };
    let get_usize = |key: &str, dflt: usize| -> Result<usize, ConfigError> {
        match j.get(key) {
            Json::Null => Ok(dflt),
            v => v.as_usize().ok_or_else(|| {
                bad(format!(
                    "fleet group {gi}: capacity.{key} must be a \
                     non-negative integer"
                ))
            }),
        }
    };
    let get_f64 = |key: &str, dflt: f64| -> Result<f64, ConfigError> {
        match j.get(key) {
            Json::Null => Ok(dflt),
            v => v.as_f64().ok_or_else(|| {
                bad(format!(
                    "fleet group {gi}: capacity.{key} must be a number"
                ))
            }),
        }
    };
    let cfg = CapacityConfig {
        policy,
        min_slots: get_usize("min_slots", d.min_slots)?,
        max_slots: get_usize("max_slots", d.max_slots)?,
        slot_step: get_usize("slot_step", d.slot_step)?,
        standby: get_usize("standby", d.standby)?,
        scale_up_util: get_f64("scale_up_util", d.scale_up_util)?,
        scale_down_util: get_f64("scale_down_util", d.scale_down_util)?,
        slo_target: get_f64("slo_target", d.slo_target)?,
        cooldown: get_f64("cooldown", d.cooldown)?,
        eval_every: get_f64("eval_every", d.eval_every)?,
        online_cost_per_hour: get_f64(
            "online_cost_per_hour",
            d.online_cost_per_hour,
        )?,
        standby_cost_per_hour: get_f64(
            "standby_cost_per_hour",
            d.standby_cost_per_hour,
        )?,
        scale_prefill: j
            .get("scale_prefill")
            .as_bool()
            .unwrap_or(d.scale_prefill),
    };
    cfg.check()
        .map_err(|e| bad(format!("fleet group {gi}: {e}")))?;
    Ok(Some(cfg))
}

/// Expand one group's `churn` array into per-node [`ChurnEvent`]s,
/// validating that every entry is satisfiable given the group's
/// availability at that time (events apply in time order; ties keep
/// declaration order).
fn parse_group_churn(
    j: &Json,
    gi: usize,
    base: usize,
    count: usize,
    start_offline: bool,
) -> Result<Vec<ChurnEvent>, ConfigError> {
    if j.is_null() {
        return Ok(Vec::new());
    }
    let arr = j.as_arr().ok_or_else(|| {
        bad(format!("fleet group {gi}: churn must be an array"))
    })?;
    let mut entries = Vec::with_capacity(arr.len());
    for (ei, e) in arr.iter().enumerate() {
        let at = e.get("at").as_f64().ok_or_else(|| {
            bad(format!("fleet group {gi}: churn[{ei}].at"))
        })?;
        if !(at.is_finite() && at >= 0.0) {
            return Err(bad(format!(
                "fleet group {gi}: churn[{ei}].at must be >= 0, got {at}"
            )));
        }
        let join = match e.get("action").as_str() {
            Some("join") => true,
            Some("leave") => false,
            other => {
                return Err(bad(format!(
                    "fleet group {gi}: churn[{ei}].action must be \
                     join|leave, got {other:?}"
                )))
            }
        };
        let k = e.get("count").as_usize().unwrap_or(1);
        if k == 0 || k > count {
            return Err(bad(format!(
                "fleet group {gi}: churn[{ei}].count must be in 1..={count}"
            )));
        }
        entries.push((at, join, k, ei));
    }
    entries.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap().then(a.3.cmp(&b.3))
    });
    let mut up = vec![!start_offline; count];
    let mut out = Vec::new();
    for (at, join, k, ei) in entries {
        let mut picked = 0usize;
        for (i, slot) in up.iter_mut().enumerate() {
            if picked == k {
                break;
            }
            if *slot != join {
                *slot = join;
                picked += 1;
                out.push(ChurnEvent { node: base + i, at, join });
            }
        }
        if picked < k {
            let action = if join { "join" } else { "leave" };
            return Err(bad(format!(
                "fleet group {gi}: churn[{ei}] asks to {action} {k} nodes \
                 at t={at} but only {picked} are eligible"
            )));
        }
    }
    Ok(out)
}

/// Parse the declarative `"latency_estimation"` block (all keys optional):
///
/// ```json
/// "latency_estimation": {
///   "enabled": true,
///   "alpha": 0.3,
///   "decay_after": 60.0,
///   "prior_weight": 1.0,
///   "share_every": 5.0
/// }
/// ```
///
/// `enabled: false` freezes dispatch on the static expected-latency matrix
/// — the pre-estimator baseline the reroute bench compares against.
fn parse_latency_estimation(j: &Json) -> Result<LatencyConfig, ConfigError> {
    let d = LatencyConfig::default();
    if j.is_null() {
        return Ok(d);
    }
    let cfg = LatencyConfig {
        enabled: j.get("enabled").as_bool().unwrap_or(d.enabled),
        alpha: j.get("alpha").as_f64().unwrap_or(d.alpha),
        decay_after: j.get("decay_after").as_f64().unwrap_or(d.decay_after),
        prior_weight: j
            .get("prior_weight")
            .as_f64()
            .unwrap_or(d.prior_weight),
        share_every: j.get("share_every").as_f64().unwrap_or(d.share_every),
    };
    // Reject bad values with Err here rather than letting
    // `LatencyConfig::validate` abort the process on malformed user input.
    cfg.check().map_err(bad)?;
    Ok(cfg)
}

/// Parse the declarative `"observability"` block (all keys optional):
///
/// ```json
/// "observability": {
///   "enabled": true,
///   "sample_rate": 1.0,
///   "ring_capacity": 4096,
///   "slo_misses_only": false
/// }
/// ```
///
/// `enabled: false` (the default) keeps the flight recorder and metrics
/// registry completely out of the run — pre-observability configs replay
/// byte for byte. `enabled: true` is purely observational, so the replay
/// fingerprint still matches (`rust/tests/replay_equivalence.rs`).
fn parse_observability(j: &Json) -> Result<ObservabilityConfig, ConfigError> {
    let d = ObservabilityConfig::default();
    if j.is_null() {
        return Ok(d);
    }
    let cfg = ObservabilityConfig {
        enabled: j.get("enabled").as_bool().unwrap_or(d.enabled),
        sample_rate: j.get("sample_rate").as_f64().unwrap_or(d.sample_rate),
        ring_capacity: match j.get("ring_capacity") {
            Json::Null => d.ring_capacity,
            v => v.as_usize().ok_or_else(|| {
                bad("observability.ring_capacity must be a non-negative \
                     integer")
            })?,
        },
        slo_misses_only: j
            .get("slo_misses_only")
            .as_bool()
            .unwrap_or(d.slo_misses_only),
    };
    // Reject bad values with Err here rather than letting
    // `ObservabilityConfig::validate` abort the process on malformed input.
    cfg.check().map_err(bad)?;
    Ok(cfg)
}

/// Parse the declarative `"defenses"` block (all keys optional):
///
/// ```json
/// "defenses": {
///   "enabled": true,
///   "receipts": true,
///   "reputation": true,
///   "quarantine_threshold": 0.25,
///   "hearsay_cap": 3.0
/// }
/// ```
///
/// `enabled: false` (the default) keeps every Byzantine defense out of
/// the run — no receipts on the wire, no reputation rows in gossip, no
/// hearsay capping — so pre-defense configs replay byte for byte
/// (`rust/tests/replay_equivalence.rs`).
fn parse_defenses(j: &Json) -> Result<DefenseConfig, ConfigError> {
    let d = DefenseConfig::default();
    if j.is_null() {
        return Ok(d);
    }
    let cfg = DefenseConfig {
        enabled: j.get("enabled").as_bool().unwrap_or(d.enabled),
        receipts: j.get("receipts").as_bool().unwrap_or(d.receipts),
        reputation: j.get("reputation").as_bool().unwrap_or(d.reputation),
        quarantine_threshold: j
            .get("quarantine_threshold")
            .as_f64()
            .unwrap_or(d.quarantine_threshold),
        hearsay_cap: j.get("hearsay_cap").as_f64().unwrap_or(d.hearsay_cap),
    };
    // Reject bad values with Err here rather than letting
    // `DefenseConfig::validate` abort the process on malformed input.
    cfg.check().map_err(bad)?;
    Ok(cfg)
}

fn parse_lengths(j: &Json) -> LengthDist {
    let d = LengthDist::default();
    LengthDist {
        prompt_mean: j.get("prompt_mean").as_f64().unwrap_or(d.prompt_mean),
        prompt_sigma: j.get("prompt_sigma").as_f64().unwrap_or(d.prompt_sigma),
        output_mean: j.get("output_mean").as_f64().unwrap_or(d.output_mean),
        output_sigma: j.get("output_sigma").as_f64().unwrap_or(d.output_sigma),
        max_tokens: j
            .get("max_tokens")
            .as_u64()
            .map(|v| v as u32)
            .unwrap_or(d.max_tokens),
    }
}

/// Parse a workload group's `"sessions"` block (all keys optional):
///
/// ```json
/// "sessions": {
///   "turns_mean": 3.0,
///   "max_turns": 12,
///   "think_mean": 20.0,
///   "ttft_scale": 3.0,
///   "ttft_floor": 2.0
/// }
/// ```
///
/// Presence of the block (even empty) switches the group's generator to
/// multi-turn session traces with per-turn TTFT deadlines
/// (`workload::SessionProfile`).
fn parse_sessions(j: &Json) -> Result<SessionProfile, ConfigError> {
    let d = SessionProfile::default();
    let cfg = SessionProfile {
        turns_mean: j.get("turns_mean").as_f64().unwrap_or(d.turns_mean),
        max_turns: j
            .get("max_turns")
            .as_u64()
            .map(|v| v as u32)
            .unwrap_or(d.max_turns),
        think_mean: j.get("think_mean").as_f64().unwrap_or(d.think_mean),
        ttft_scale: j.get("ttft_scale").as_f64().unwrap_or(d.ttft_scale),
        ttft_floor: j.get("ttft_floor").as_f64().unwrap_or(d.ttft_floor),
    };
    cfg.check().map_err(|e| bad(format!("sessions: {e}")))?;
    Ok(cfg)
}

/// Parse the declarative `"streaming"` block (all keys optional):
///
/// ```json
/// "streaming": {
///   "enabled": true,
///   "affinity_bonus": 1.0,
///   "kv_bytes_per_token": 160000,
///   "prefill_slots": 0,
///   "churn_nack": true
/// }
/// ```
///
/// `enabled: false` (the default) keeps dispatch session-blind, admission
/// unified, and the churn NACK off — pre-streaming configs replay byte
/// for byte (`rust/tests/replay_equivalence.rs`).
fn parse_streaming(j: &Json) -> Result<StreamingConfig, ConfigError> {
    let d = StreamingConfig::default();
    if j.is_null() {
        return Ok(d);
    }
    let cfg = StreamingConfig {
        enabled: j.get("enabled").as_bool().unwrap_or(d.enabled),
        affinity_bonus: j
            .get("affinity_bonus")
            .as_f64()
            .unwrap_or(d.affinity_bonus),
        kv_bytes_per_token: j
            .get("kv_bytes_per_token")
            .as_f64()
            .unwrap_or(d.kv_bytes_per_token),
        prefill_slots: match j.get("prefill_slots") {
            Json::Null => d.prefill_slots,
            v => v.as_usize().ok_or_else(|| {
                bad("streaming.prefill_slots must be a non-negative integer")
            })?,
        },
        churn_nack: j.get("churn_nack").as_bool().unwrap_or(d.churn_nack),
    };
    // Reject bad values with Err here rather than letting
    // `StreamingConfig::validate` abort the process on malformed input.
    cfg.check().map_err(|e| bad(format!("streaming: {e}")))?;
    Ok(cfg)
}

fn parse_system(j: &Json) -> SystemPolicy {
    let d = SystemPolicy::default();
    SystemPolicy {
        base_reward: j
            .get("base_reward")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.base_reward),
        duel_rate: j.get("duel_rate").as_f64().unwrap_or(d.duel_rate),
        duel_reward: j
            .get("duel_reward")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.duel_reward),
        duel_penalty: j
            .get("duel_penalty")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.duel_penalty),
        judges: j.get("judges").as_usize().unwrap_or(d.judges),
        judge_reward: j
            .get("judge_reward")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.judge_reward),
        max_probes: j.get("max_probes").as_usize().unwrap_or(d.max_probes),
        genesis_credits: j
            .get("genesis_credits")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.genesis_credits),
        confirm_quorum: j
            .get("confirm_quorum")
            .as_f64()
            .unwrap_or(d.confirm_quorum),
    }
}

fn parse_phases(j: &Json) -> Result<Vec<Phase>, ConfigError> {
    let arr = j.as_arr().ok_or_else(|| bad("schedule must be an array"))?;
    arr.iter()
        .map(|p| {
            Ok(Phase::new(
                p.get("from").as_f64().ok_or_else(|| bad("phase.from"))?,
                p.get("to").as_f64().ok_or_else(|| bad("phase.to"))?,
                p.get("inter_arrival")
                    .as_f64()
                    .ok_or_else(|| bad("phase.inter_arrival"))?,
            ))
        })
        .collect()
}

/// Parse an experiment from JSON text.
pub fn parse_experiment(text: &str) -> Result<Experiment, ConfigError> {
    let j = Json::parse(text)?;
    let seed = j.get("seed").as_u64().unwrap_or(0);
    let horizon = j.get("horizon").as_f64().unwrap_or(750.0);
    let strategy =
        parse_strategy(j.get("strategy").as_str().unwrap_or("decentralized"))?;
    let ledger = match j.get("ledger").as_str().unwrap_or("shared") {
        "shared" => LedgerMode::Shared,
        "blockchain" => LedgerMode::Blockchain,
        other => return Err(bad(format!("unknown ledger mode '{other}'"))),
    };
    let system = parse_system(j.get("system"));
    let explicit: Vec<Json> = match j.get("nodes") {
        Json::Null => Vec::new(),
        other => other
            .as_arr()
            .ok_or_else(|| bad("'nodes' must be an array"))?
            .to_vec(),
    };
    let (nodes, churn, fleet_caps) = expand_fleet(j.get("topology"), explicit)?;
    if nodes.is_empty() {
        return Err(bad(
            "no nodes: provide a 'nodes' array or a 'topology.fleet' block",
        ));
    }
    let topology = parse_topology(j.get("topology"), &nodes)?;
    let latency_estimation =
        parse_latency_estimation(j.get("latency_estimation"))?;
    let observability = parse_observability(j.get("observability"))?;
    let defenses = parse_defenses(j.get("defenses"))?;
    let streaming = parse_streaming(j.get("streaming"))?;
    // Capacity groups: resolve region names against the built topology
    // (a fleet block implies a topology block, so it is always present
    // and already validated here).
    let mut capacity = Vec::with_capacity(fleet_caps.len());
    for fc in fleet_caps {
        let region = topology
            .as_ref()
            .and_then(|t| t.region_index(&fc.region))
            .ok_or_else(|| {
                bad(format!("capacity group '{}': unknown region", fc.label))
            })? as u32;
        capacity.push(CapacityGroupSpec {
            label: fc.label,
            region,
            members: fc.members,
            standby: fc.standby,
            cfg: fc.cfg,
        });
    }

    let mut setups = Vec::with_capacity(nodes.len());
    for (i, nj) in nodes.iter().enumerate() {
        // Either a (model, gpu, backend) triple or an explicit profile.
        let profile = if nj.get("profile").is_null() {
            let model =
                parse_model(nj.get("model").as_str().unwrap_or("qwen3-8b"))?;
            let gpu = parse_gpu(nj.get("gpu").as_str().unwrap_or("a100"))?;
            let stack =
                parse_stack(nj.get("backend").as_str().unwrap_or("sglang"))?;
            Profile::derive(model, gpu, stack)
        } else {
            let p = nj.get("profile");
            Profile {
                prefill_tok_s: p
                    .get("prefill_tok_s")
                    .as_f64()
                    .ok_or_else(|| bad("profile.prefill_tok_s"))?,
                decode_tok_s: p
                    .get("decode_tok_s")
                    .as_f64()
                    .ok_or_else(|| bad("profile.decode_tok_s"))?,
                max_agg_decode_tok_s: p
                    .get("max_agg_decode_tok_s")
                    .as_f64()
                    .ok_or_else(|| bad("profile.max_agg_decode_tok_s"))?,
                max_batch: p
                    .get("max_batch")
                    .as_usize()
                    .ok_or_else(|| bad("profile.max_batch"))?,
                quality: p.get("quality").as_f64().unwrap_or(0.7),
                kv_gb_per_seq: p
                    .get("kv_gb_per_seq")
                    .as_f64()
                    .unwrap_or(0.5),
            }
        };
        // Participation behaviour (per-node "participation" key; fleet
        // groups stamp it from their "policy" key). The kind also sets the
        // scalar-knob base defaults.
        let participation = match nj.get("participation") {
            Json::Null => ParticipationKind::Default,
            p => {
                let name = p.as_str().ok_or_else(|| {
                    bad(format!("node {i}: participation must be a string"))
                })?;
                ParticipationKind::parse(name).ok_or_else(|| {
                    bad(format!(
                        "node {i}: unknown participation policy '{name}'"
                    ))
                })?
            }
        };
        let policy =
            parse_policy(nj.get("policy"), participation.base_policy());
        let mut setup =
            NodeSetup::new(profile, policy).with_participation(participation);
        // Byzantine personality (per-node "byzantine" key; fleet groups
        // stamp it from their group-level key). Overrides participation.
        match nj.get("byzantine") {
            Json::Null => {}
            b => {
                let name = b.as_str().ok_or_else(|| {
                    bad(format!("node {i}: byzantine must be a string"))
                })?;
                let kind = ByzantineKind::parse(name).ok_or_else(|| {
                    bad(format!(
                        "node {i}: unknown byzantine policy '{name}'"
                    ))
                })?;
                setup = setup.with_byzantine(kind);
            }
        }
        if let Some(label) = nj.get("group").as_str() {
            setup = setup.with_group(label);
        }
        // Workload: an explicit phase schedule, or a follow-the-sun diurnal
        // template (period-halved peak/off windows over the horizon).
        let phases = if !nj.get("schedule").is_null() {
            Some(parse_phases(nj.get("schedule"))?)
        } else if !nj.get("diurnal").is_null() {
            let dj = nj.get("diurnal");
            let period = dj
                .get("period")
                .as_f64()
                .ok_or_else(|| bad("diurnal.period"))?;
            if !(period > 0.0 && period.is_finite()) {
                return Err(bad("diurnal.period must be > 0"));
            }
            let peak = dj
                .get("peak_inter_arrival")
                .as_f64()
                .ok_or_else(|| bad("diurnal.peak_inter_arrival"))?;
            let off = dj
                .get("off_inter_arrival")
                .as_f64()
                .ok_or_else(|| bad("diurnal.off_inter_arrival"))?;
            let offset = dj.get("offset").as_f64().unwrap_or(0.0);
            Some(diurnal_phases(horizon, period, peak, off, offset))
        } else {
            None
        };
        if let Some(phases) = phases {
            let mut generator = Generator::new(NodeId(i as u32), phases);
            if !nj.get("lengths").is_null() {
                generator =
                    generator.with_lengths(parse_lengths(nj.get("lengths")));
            }
            if !nj.get("sessions").is_null() {
                generator = generator
                    .with_sessions(parse_sessions(nj.get("sessions"))?);
            }
            setup = setup.with_generator(generator);
        }
        if nj.get("start_offline").as_bool().unwrap_or(false) {
            setup = setup.offline();
        }
        setups.push(setup);
    }

    Ok(Experiment {
        seed,
        horizon,
        strategy,
        world: WorldConfig {
            seed,
            system,
            ledger,
            topology,
            latency_estimation,
            observability,
            defenses,
            streaming,
            churn: churn.iter().map(|c| (c.node, c.at, c.join)).collect(),
            capacity,
            ..Default::default()
        },
        setups,
        churn,
    })
}

/// Read + parse a config file.
pub fn load_experiment(path: &str) -> Result<Experiment, ConfigError> {
    let text = std::fs::read_to_string(path)?;
    parse_experiment(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "seed": 7,
        "horizon": 200,
        "strategy": "decentralized",
        "ledger": "shared",
        "system": { "duel_rate": 0.25, "judges": 3 },
        "nodes": [
            { "model": "qwen3-8b", "gpu": "ada6000", "backend": "sglang",
              "policy": { "stake": 5, "offload_freq": 0.5 },
              "schedule": [ {"from": 0, "to": 200, "inter_arrival": 10} ] },
            { "model": "qwen3-4b", "gpu": "rtx3090", "backend": "vllm",
              "start_offline": true }
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let e = parse_experiment(SAMPLE).unwrap();
        assert_eq!(e.seed, 7);
        assert_eq!(e.horizon, 200.0);
        assert_eq!(e.strategy, Strategy::Decentralized);
        assert_eq!(e.setups.len(), 2);
        assert!((e.world.system.duel_rate - 0.25).abs() < 1e-12);
        assert_eq!(e.world.system.judges, 3);
        assert_eq!(e.setups[0].policy.stake, 5 * CREDIT);
        assert!((e.setups[0].policy.offload_freq - 0.5).abs() < 1e-12);
        // Defaults fill unspecified fields.
        assert!((e.setups[0].policy.accept_freq - 0.8).abs() < 1e-12);
        assert!(e.setups[0].generator.is_some());
        assert!(e.setups[1].generator.is_none());
        assert!(e.setups[1].start_offline);
    }

    #[test]
    fn explicit_profile() {
        let text = r#"{
            "nodes": [ { "profile": { "prefill_tok_s": 1000,
                "decode_tok_s": 50, "max_agg_decode_tok_s": 500,
                "max_batch": 16, "quality": 0.9 } } ]
        }"#;
        let e = parse_experiment(text).unwrap();
        assert_eq!(e.setups[0].profile.max_batch, 16);
        assert!((e.setups[0].profile.quality - 0.9).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(parse_experiment("{").is_err());
        assert!(parse_experiment(r#"{"nodes": []}"#).is_err());
        assert!(parse_experiment(
            r#"{"nodes": [{"model": "gpt99"}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"strategy": "quantum", "nodes": [{}]}"#
        )
        .is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let e = parse_experiment(r#"{"nodes": [{}]}"#).unwrap();
        assert_eq!(e.horizon, 750.0);
        assert_eq!(e.strategy, Strategy::Decentralized);
        assert_eq!(e.world.ledger, LedgerMode::Shared);
        assert!(e.world.topology.is_none(), "flat network by default");
    }

    const GEO_SAMPLE: &str = r#"{
        "seed": 3,
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.001, 0.004] },
            "inter": { "latency": [0.040, 0.080] },
            "links": [
                { "a": "us", "b": "eu", "latency": [0.045, 0.055],
                  "jitter": 0.005, "bandwidth_mbps": 400 }
            ],
            "events": [
                { "at": 250, "a": "us", "b": "eu", "change": "partition" },
                { "at": 400, "a": "us", "b": "eu", "change": "heal" },
                { "at": 100, "a": "us", "b": "eu", "change": "degrade",
                  "latency_factor": 3, "bandwidth_factor": 0.5 }
            ]
        },
        "nodes": [
            { "region": "us",
              "policy": { "latency_penalty": 10.0 } },
            { "region": "eu" },
            { }
        ]
    }"#;

    #[test]
    fn parses_topology_block() {
        let e = parse_experiment(GEO_SAMPLE).unwrap();
        let topo = e.world.topology.as_ref().expect("topology parsed");
        assert_eq!(topo.num_regions(), 2);
        assert_eq!(topo.region_index("eu"), Some(1));
        // Node placement: tagged nodes land where they asked, untagged in
        // the first region.
        assert_eq!(topo.region_of(0), 0);
        assert_eq!(topo.region_of(1), 1);
        assert_eq!(topo.region_of(2), 0);
        // Link override with jitter and bandwidth.
        let l = topo.link(0, 1);
        assert!((l.latency.0 - 0.045).abs() < 1e-12);
        assert!((l.jitter - 0.005).abs() < 1e-12);
        assert!((l.bandwidth - 400.0 * 1e6 / 8.0).abs() < 1e-6);
        // Events sorted by time regardless of declaration order.
        let times: Vec<f64> = topo.events().iter().map(|ev| ev.at).collect();
        assert_eq!(times, vec![100.0, 250.0, 400.0]);
        // Policy knob reached the node setup.
        assert!((e.setups[0].policy.latency_penalty - 10.0).abs() < 1e-12);
        assert_eq!(e.setups[1].policy.latency_penalty, 0.0);
        // The parsed world actually constructs and validates.
        topo.validate(e.setups.len());
    }

    #[test]
    fn rejects_bad_topology() {
        assert!(parse_experiment(
            r#"{"topology": {"regions": []}, "nodes": [{}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"]},
                "nodes": [{"region": "mars"}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "events": [{"at": 1, "a": "us", "b": "eu",
                            "change": "explode"}]},
                "nodes": [{}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "links": [{"a": "us", "b": "nowhere"}]},
                "nodes": [{}]}"#
        )
        .is_err());
        // Numeric garbage yields Err, not a builder panic.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "inter": {"latency": [0.08, 0.02]}},
                "nodes": [{}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "inter": {"bandwidth_mbps": 0}},
                "nodes": [{}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "events": [{"at": -5, "a": "us", "b": "eu",
                            "change": "partition"}]},
                "nodes": [{}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "events": [{"at": 1, "a": "us", "b": "eu",
                            "change": "degrade", "latency_factor": 0}]},
                "nodes": [{}]}"#
        )
        .is_err());
    }

    const FLEET_SAMPLE: &str = r#"{
        "seed": 4, "horizon": 300,
        "topology": {
            "regions": ["us", "eu"],
            "intra": { "latency": [0.001, 0.004] },
            "inter": { "latency": [0.040, 0.080] },
            "fleet": [
                { "region": "us", "count": 3,
                  "node": { "profile": { "prefill_tok_s": 2000,
                            "decode_tok_s": 40, "max_agg_decode_tok_s": 320,
                            "max_batch": 16 },
                            "policy": { "accept_freq": 1.0 } },
                  "diurnal": { "period": 100, "peak_inter_arrival": 2,
                               "off_inter_arrival": 20 },
                  "lengths": { "output_mean": 900, "output_sigma": 0.5 } },
                { "region": "eu", "count": 2 }
            ]
        },
        "nodes": [ { "region": "eu", "policy": { "stake": 7 } } ]
    }"#;

    #[test]
    fn fleet_block_stamps_out_nodes() {
        let e = parse_experiment(FLEET_SAMPLE).unwrap();
        // 1 explicit + 3 us + 2 eu, ids in declaration order.
        assert_eq!(e.setups.len(), 6);
        let topo = e.world.topology.as_ref().expect("topology parsed");
        assert_eq!(topo.region_of(0), 1);
        for i in 1..4 {
            assert_eq!(topo.region_of(i), 0, "node {i} not in us");
        }
        for i in 4..6 {
            assert_eq!(topo.region_of(i), 1, "node {i} not in eu");
        }
        // The node template reached every stamped copy.
        assert_eq!(e.setups[1].profile.max_batch, 16);
        assert_eq!(e.setups[3].profile.max_batch, 16);
        assert!((e.setups[1].policy.accept_freq - 1.0).abs() < 1e-12);
        // Workload template: diurnal phases covering the horizon, with the
        // group's length distribution.
        let g = e.setups[1].generator.as_ref().expect("diurnal generator");
        assert_eq!(g.phases[0].inter_arrival, 2.0);
        assert_eq!(g.phases[1].inter_arrival, 20.0);
        assert_eq!(g.phases.last().unwrap().to, 300.0);
        assert!((g.lengths.output_mean - 900.0).abs() < 1e-12);
        // A bare group stamps workload-free default servers.
        assert!(e.setups[4].generator.is_none());
        // The explicit node keeps its own policy.
        assert_eq!(e.setups[0].policy.stake, 7 * CREDIT);
        topo.validate(e.setups.len());
    }

    #[test]
    fn fleet_only_config_needs_no_nodes_array() {
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 4 }]}}"#,
        )
        .unwrap();
        assert_eq!(e.setups.len(), 4);
        assert!(e.world.topology.is_some());
    }

    #[test]
    fn fleet_block_rejects_bad_groups() {
        // Non-array fleet block (easy authoring mistake) must be a hard
        // error, not a silently node-less world.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": { "region": "us", "count": 4 }}}"#
        )
        .is_err());
        // Missing count.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us" }]}}"#
        )
        .is_err());
        // Zero count.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 0 }]}}"#
        )
        .is_err());
        // Unknown region.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "mars", "count": 2 }]}}"#
        )
        .is_err());
        // Non-object node template.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2, "node": 5 }]}}"#
        )
        .is_err());
        // Bad diurnal template.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                            "diurnal": { "period": 0,
                                         "peak_inter_arrival": 2,
                                         "off_inter_arrival": 20 }}]}}"#
        )
        .is_err());
    }

    #[test]
    fn parses_latency_estimation_block() {
        let e = parse_experiment(
            r#"{"latency_estimation": { "enabled": false, "alpha": 0.5,
                "decay_after": 30, "prior_weight": 2, "share_every": 10 },
                "nodes": [{}]}"#,
        )
        .unwrap();
        let l = e.world.latency_estimation;
        assert!(!l.enabled);
        assert!((l.alpha - 0.5).abs() < 1e-12);
        assert!((l.decay_after - 30.0).abs() < 1e-12);
        assert!((l.prior_weight - 2.0).abs() < 1e-12);
        assert!((l.share_every - 10.0).abs() < 1e-12);
        // Absent block -> defaults (live estimation on).
        let e = parse_experiment(r#"{"nodes": [{}]}"#).unwrap();
        assert_eq!(e.world.latency_estimation, LatencyConfig::default());
        assert!(e.world.latency_estimation.enabled);
    }

    #[test]
    fn rejects_bad_latency_estimation() {
        for block in [
            r#"{"alpha": 0}"#,
            r#"{"alpha": 1.5}"#,
            r#"{"decay_after": 0}"#,
            r#"{"decay_after": -3}"#,
            r#"{"prior_weight": -1}"#,
            r#"{"share_every": -1}"#,
        ] {
            let text = format!(
                r#"{{"latency_estimation": {block}, "nodes": [{{}}]}}"#
            );
            assert!(
                parse_experiment(&text).is_err(),
                "accepted bad latency_estimation block {block}"
            );
        }
    }

    #[test]
    fn parses_observability_block() {
        let e = parse_experiment(
            r#"{"observability": { "enabled": true, "sample_rate": 0.25,
                "ring_capacity": 128, "slo_misses_only": true },
                "nodes": [{}]}"#,
        )
        .unwrap();
        let o = e.world.observability;
        assert!(o.enabled);
        assert!((o.sample_rate - 0.25).abs() < 1e-12);
        assert_eq!(o.ring_capacity, 128);
        assert!(o.slo_misses_only);
        // Absent block -> defaults (observability off, replay-identical).
        let e = parse_experiment(r#"{"nodes": [{}]}"#).unwrap();
        assert_eq!(e.world.observability, ObservabilityConfig::default());
        assert!(!e.world.observability.enabled);
    }

    #[test]
    fn rejects_bad_observability() {
        for block in [
            r#"{"sample_rate": -0.1}"#,
            r#"{"sample_rate": 1.5}"#,
            r#"{"enabled": true, "ring_capacity": 0}"#,
            r#"{"ring_capacity": -4}"#,
            r#"{"ring_capacity": "big"}"#,
        ] {
            let text = format!(
                r#"{{"observability": {block}, "nodes": [{{}}]}}"#
            );
            assert!(
                parse_experiment(&text).is_err(),
                "accepted bad observability block {block}"
            );
        }
    }

    #[test]
    fn parses_defenses_block() {
        let e = parse_experiment(
            r#"{"defenses": { "enabled": true, "receipts": true,
                "reputation": false, "quarantine_threshold": 0.1,
                "hearsay_cap": 5 },
                "nodes": [{}]}"#,
        )
        .unwrap();
        let d = e.world.defenses;
        assert!(d.enabled);
        assert!(d.receipts);
        assert!(!d.reputation);
        assert!((d.quarantine_threshold - 0.1).abs() < 1e-12);
        assert!((d.hearsay_cap - 5.0).abs() < 1e-12);
        // Absent block -> defaults (defenses off, replay-identical).
        let e = parse_experiment(r#"{"nodes": [{}]}"#).unwrap();
        assert_eq!(e.world.defenses, DefenseConfig::default());
        assert!(!e.world.defenses.enabled);
    }

    #[test]
    fn rejects_bad_defenses() {
        for block in [
            r#"{"quarantine_threshold": -0.1}"#,
            r#"{"quarantine_threshold": 1.0}"#,
            r#"{"hearsay_cap": 0.5}"#,
            r#"{"hearsay_cap": -3}"#,
        ] {
            let text =
                format!(r#"{{"defenses": {block}, "nodes": [{{}}]}}"#);
            assert!(
                parse_experiment(&text).is_err(),
                "accepted bad defenses block {block}"
            );
        }
    }

    #[test]
    fn parses_streaming_block() {
        let e = parse_experiment(
            r#"{"streaming": { "enabled": true, "affinity_bonus": 0.9,
                "kv_bytes_per_token": 200000, "prefill_slots": 4,
                "churn_nack": false },
                "nodes": [{}]}"#,
        )
        .unwrap();
        let s = e.world.streaming;
        assert!(s.enabled);
        assert!((s.affinity_bonus - 0.9).abs() < 1e-12);
        assert!((s.kv_bytes_per_token - 200_000.0).abs() < 1e-6);
        assert_eq!(s.prefill_slots, 4);
        assert!(!s.churn_nack);
        // Absent block -> defaults (streaming off, replay-identical).
        let e = parse_experiment(r#"{"nodes": [{}]}"#).unwrap();
        assert_eq!(e.world.streaming, StreamingConfig::default());
        assert!(!e.world.streaming.enabled);
    }

    #[test]
    fn rejects_bad_streaming() {
        for block in [
            r#"{"enabled": true, "affinity_bonus": 1.5}"#,
            r#"{"enabled": true, "affinity_bonus": -0.1}"#,
            r#"{"enabled": true, "kv_bytes_per_token": -1}"#,
            r#"{"enabled": true, "prefill_slots": -2}"#,
            r#"{"enabled": true, "prefill_slots": "many"}"#,
            // Live knobs on a disabled block are a config smell.
            r#"{"enabled": false, "prefill_slots": 4}"#,
            r#"{"affinity_bonus": 0.5}"#,
        ] {
            let text =
                format!(r#"{{"streaming": {block}, "nodes": [{{}}]}}"#);
            assert!(
                parse_experiment(&text).is_err(),
                "accepted bad streaming block {block}"
            );
        }
    }

    #[test]
    fn sessions_block_arms_the_session_generator() {
        // Fleet groups carry the key into every stamped copy; explicit
        // nodes take it directly.
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [
                  { "region": "us", "count": 2, "policy": "requester_only",
                    "schedule": [ {"from": 0, "to": 100,
                                   "inter_arrival": 5} ],
                    "sessions": { "turns_mean": 4, "max_turns": 6,
                                  "think_mean": 15, "ttft_scale": 2.5,
                                  "ttft_floor": 1.5 } },
                  { "region": "us", "count": 1,
                    "schedule": [ {"from": 0, "to": 100,
                                   "inter_arrival": 5} ] }
                ]}}"#,
        )
        .unwrap();
        let gen = e.setups[0].generator.as_ref().unwrap();
        let sp = gen.sessions.expect("sessions armed");
        assert!((sp.turns_mean - 4.0).abs() < 1e-12);
        assert_eq!(sp.max_turns, 6);
        assert!((sp.think_mean - 15.0).abs() < 1e-12);
        assert!((sp.ttft_scale - 2.5).abs() < 1e-12);
        assert!((sp.ttft_floor - 1.5).abs() < 1e-12);
        assert!(e.setups[1].generator.as_ref().unwrap().sessions.is_some());
        // No sessions key -> classic point-event generator.
        assert!(e.setups[2].generator.as_ref().unwrap().sessions.is_none());
        // Bad values are rejected at parse time, not at world build.
        assert!(parse_experiment(
            r#"{"nodes": [{ "schedule": [{"from": 0, "to": 10,
                                          "inter_arrival": 1}],
                            "sessions": { "turns_mean": 0 } }]}"#
        )
        .is_err());
    }

    #[test]
    fn parses_degrade_one_way_link_event() {
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "events": [
                  { "at": 50, "a": "us", "b": "eu",
                    "change": "degrade_one_way",
                    "latency_factor": 4, "bandwidth_factor": 0.25 }
                ]},
                "nodes": [{ "region": "us" }, { "region": "eu" }]}"#,
        )
        .unwrap();
        let topo = e.world.topology.as_ref().unwrap();
        let ev = &topo.events()[0];
        assert_eq!(
            ev.change,
            LinkChange::DegradeDirectional {
                latency_factor: 4.0,
                bandwidth_factor: 0.25,
            }
        );
        // The shared factor validation still applies.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "events": [{"at": 1, "a": "us", "b": "eu",
                            "change": "degrade_one_way",
                            "bandwidth_factor": 0}]},
                "nodes": [{}]}"#
        )
        .is_err());
    }

    #[test]
    fn fleet_byzantine_key_stamps_attackers_per_group() {
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "fleet": [
                  { "region": "us", "count": 2, "byzantine": "free_rider" },
                  { "region": "eu", "count": 1, "byzantine": "result_faker",
                    "name": "eu-fakers" },
                  { "region": "eu", "count": 1 }
                ]},
                "nodes": [{ "byzantine": "latency_liar" }]}"#,
        )
        .unwrap();
        assert_eq!(e.setups.len(), 5);
        // Explicit node: per-node byzantine key.
        assert_eq!(e.setups[0].byzantine, Some(ByzantineKind::LatencyLiar));
        // Group key stamps every copy, with attack-derived/explicit labels.
        assert_eq!(e.setups[1].byzantine, Some(ByzantineKind::FreeRider));
        assert_eq!(e.setups[2].byzantine, Some(ByzantineKind::FreeRider));
        assert_eq!(e.setups[1].group.as_deref(), Some("us/free_rider"));
        assert_eq!(e.setups[3].byzantine, Some(ByzantineKind::ResultFaker));
        assert_eq!(e.setups[3].group.as_deref(), Some("eu-fakers"));
        // Honest group stays honest.
        assert_eq!(e.setups[4].byzantine, None);
        assert_eq!(e.setups[4].group.as_deref(), Some("eu/default"));
    }

    #[test]
    fn rejects_unknown_byzantine_policies() {
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 1,
                            "byzantine": "saint" }]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 1, "byzantine": 5 }]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"nodes": [{ "byzantine": "gremlin" }]}"#
        )
        .is_err());
    }

    #[test]
    fn fleet_policy_key_selects_participation_per_group() {
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "fleet": [
                  { "region": "us", "count": 2, "policy": "greedy_local" },
                  { "region": "eu", "count": 1, "policy": "requester_only",
                    "name": "eu-consumers" },
                  { "region": "eu", "count": 1 }
                ]},
                "nodes": [{ "participation": "selective" }]}"#,
        )
        .unwrap();
        assert_eq!(e.setups.len(), 5);
        // Explicit node: per-node participation key.
        assert_eq!(e.setups[0].participation, ParticipationKind::Selective);
        assert!(e.setups[0].group.is_none());
        // Group policies stamp every copy, with auto/explicit labels.
        assert_eq!(e.setups[1].participation, ParticipationKind::GreedyLocal);
        assert_eq!(e.setups[2].participation, ParticipationKind::GreedyLocal);
        assert_eq!(e.setups[1].group.as_deref(), Some("us/greedy_local"));
        assert_eq!(
            e.setups[3].participation,
            ParticipationKind::RequesterOnly
        );
        assert_eq!(e.setups[3].group.as_deref(), Some("eu-consumers"));
        // The participation kind sets the scalar-knob base: requester-only
        // groups get stake 0 / accept 0 without spelling it out.
        assert!(e.setups[3].policy.requester_only);
        assert_eq!(e.setups[3].policy.stake, 0);
        assert!((e.setups[3].policy.accept_freq - 0.0).abs() < 1e-12);
        // Policy-less group stays on the default participation + knobs.
        assert_eq!(e.setups[4].participation, ParticipationKind::Default);
        assert_eq!(e.setups[4].group.as_deref(), Some("eu/default"));
        assert_eq!(e.setups[4].policy, NodePolicy::default());
    }

    #[test]
    fn rejects_unknown_participation_policies() {
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 1,
                            "policy": "freeloader" }]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 1, "policy": 5 }]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"nodes": [{ "participation": "freeloader" }]}"#
        )
        .is_err());
    }

    #[test]
    fn fleet_group_start_offline_and_churn_schedules() {
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "fleet": [
                  { "region": "us", "count": 3,
                    "churn": [
                      { "at": 100, "action": "leave", "count": 2 },
                      { "at": 200, "action": "join" }
                    ] },
                  { "region": "eu", "count": 2, "start_offline": true,
                    "churn": [ { "at": 50, "action": "join", "count": 2 } ] }
                ]}}"#,
        )
        .unwrap();
        // Whole-group start_offline reached every stamped copy.
        assert!(!e.setups[0].start_offline);
        assert!(e.setups[3].start_offline);
        assert!(e.setups[4].start_offline);
        // Churn expands deterministically: lowest-indexed eligible nodes
        // first; default count = 1.
        assert_eq!(
            e.churn,
            vec![
                ChurnEvent { node: 0, at: 100.0, join: false },
                ChurnEvent { node: 1, at: 100.0, join: false },
                ChurnEvent { node: 0, at: 200.0, join: true },
                ChurnEvent { node: 3, at: 50.0, join: true },
                ChurnEvent { node: 4, at: 50.0, join: true },
            ]
        );
    }

    #[test]
    fn churn_rejects_unsatisfiable_and_malformed_schedules() {
        // Leaving 3 of a 2-node group.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 10, "action": "leave", "count": 3 }]}]}}"#
        )
        .is_err());
        // Joining an already-up group.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 10, "action": "join" }]}]}}"#
        )
        .is_err());
        // Double leave exhausts the pool even across entries.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 10, "action": "leave", "count": 2 },
                            { "at": 20, "action": "leave" }]}]}}"#
        )
        .is_err());
        // Unknown action, negative time, zero count, non-array block.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 10, "action": "explode" }]}]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": -1, "action": "leave" }]}]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 1, "action": "leave", "count": 0 }]}]}}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": { "at": 1, "action": "leave" }}]}}"#
        )
        .is_err());
        // A leave-then-rejoin cycle is fine.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 10, "action": "leave", "count": 2 },
                            { "at": 20, "action": "join", "count": 2 },
                            { "at": 30, "action": "leave" }]}]}}"#
        )
        .is_ok());
        // `start_offline` inside the node template counts for churn
        // validation just like the group-level key: joining a
        // template-offline group is satisfiable.
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "node": { "start_offline": true },
                  "churn": [{ "at": 10, "action": "join", "count": 2 }]}]}}"#,
        )
        .unwrap();
        assert!(e.setups[0].start_offline && e.setups[1].start_offline);
        assert_eq!(e.churn.len(), 2);
        // The parsed schedule rides along in the world config.
        assert_eq!(e.world.churn, vec![(0, 10.0, true), (1, 10.0, true)]);
    }

    #[test]
    fn capacity_block_stamps_standby_and_builds_spec() {
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us", "eu"],
                "fleet": [
                  { "region": "us", "count": 2, "name": "us-srv",
                    "capacity": { "policy": "reactive", "standby": 2,
                                  "min_slots": 2, "max_slots": 8,
                                  "scale_up_util": 0.7,
                                  "scale_down_util": 0.2,
                                  "cooldown": 10, "eval_every": 2,
                                  "online_cost_per_hour": 1.5,
                                  "standby_cost_per_hour": 0.2 } },
                  { "region": "eu", "count": 1 }
                ]}}"#,
        )
        .unwrap();
        // 2 committed + 2 stamped standbys + 1 eu node, in that order.
        assert_eq!(e.setups.len(), 5);
        assert!(!e.setups[0].start_offline && !e.setups[1].start_offline);
        assert!(e.setups[2].start_offline && e.setups[3].start_offline);
        assert!(!e.setups[4].start_offline);
        // Standbys keep the group's label and region.
        assert_eq!(e.setups[2].group.as_deref(), Some("us-srv"));
        let topo = e.world.topology.as_ref().unwrap();
        assert_eq!(topo.region_of(2), 0);
        assert_eq!(topo.region_of(3), 0);
        // The spec reached the world config, region resolved to an index.
        assert_eq!(e.world.capacity.len(), 1);
        let spec = &e.world.capacity[0];
        assert_eq!(spec.label, "us-srv");
        assert_eq!(spec.region, 0);
        assert_eq!(spec.members, vec![0, 1]);
        assert_eq!(spec.standby, vec![2, 3]);
        assert_eq!(spec.cfg.policy, CapacityPolicyKind::Reactive);
        assert_eq!(spec.cfg.min_slots, 2);
        assert_eq!(spec.cfg.max_slots, 8);
        assert!((spec.cfg.scale_up_util - 0.7).abs() < 1e-12);
        assert!((spec.cfg.online_cost_per_hour - 1.5).abs() < 1e-12);
        // The parsed world constructs (indices and knobs validate).
        let w = crate::sim::World::new(e.world.clone(), e.setups.clone());
        assert_eq!(w.capacity_groups().len(), 1);
        // A bare static declaration parses too and installs no controller.
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                            "capacity": { "policy": "static" } }]}}"#,
        )
        .unwrap();
        assert_eq!(e.setups.len(), 2);
        assert_eq!(e.world.capacity.len(), 1);
        assert_eq!(
            e.world.capacity[0].cfg.policy,
            CapacityPolicyKind::Static
        );
        let w = crate::sim::World::new(e.world.clone(), e.setups.clone());
        assert!(w.capacity_groups().is_empty(), "static installs nothing");
    }

    #[test]
    fn capacity_rejects_malformed_blocks() {
        let cases = [
            // Non-object block.
            r#""capacity": 5"#,
            // Unknown policy / wrong type.
            r#""capacity": { "policy": "clairvoyant" }"#,
            r#""capacity": { "policy": 3 }"#,
            // Inverted or half-declared slot range.
            r#""capacity": { "min_slots": 8, "max_slots": 2 }"#,
            r#""capacity": { "min_slots": 4 }"#,
            // Inverted utilization thresholds.
            r#""capacity": { "scale_up_util": 0.2,
                             "scale_down_util": 0.5 }"#,
            // Live knobs behind a static (controller-less) declaration.
            r#""capacity": { "standby": 2 }"#,
            r#""capacity": { "policy": "static", "standby": 1 }"#,
            r#""capacity": { "policy": "static",
                             "online_cost_per_hour": 1.0 }"#,
            // Negative / zero / non-numeric knobs.
            r#""capacity": { "standby": -1 }"#,
            r#""capacity": { "online_cost_per_hour": -0.5 }"#,
            r#""capacity": { "eval_every": 0 }"#,
            r#""capacity": { "cooldown": "fast" }"#,
            r#""capacity": { "slot_step": 0 }"#,
            r#""capacity": { "slo_target": 1.5 }"#,
        ];
        for block in cases {
            let text = format!(
                r#"{{"topology": {{"regions": ["us"],
                    "fleet": [{{ "region": "us", "count": 2, {block} }}]}}}}"#
            );
            assert!(
                parse_experiment(&text).is_err(),
                "accepted malformed capacity block: {block}"
            );
        }
    }

    #[test]
    fn churn_and_start_offline_edge_interactions() {
        // Join scheduled *before* any leave on an online group: the events
        // apply in time order, so the early join finds nobody down — Err,
        // even though the leave is declared first.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "churn": [{ "at": 20, "action": "leave" },
                            { "at": 10, "action": "join" }]}]}}"#
        )
        .is_err());
        // An offline group cannot leave before it ever joined.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "start_offline": true,
                  "churn": [{ "at": 10, "action": "leave" }]}]}}"#
        )
        .is_err());
        // Offline group joining mid-run, leaving, and rejoining is fine,
        // and expands against the lowest-indexed eligible nodes.
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "start_offline": true,
                  "churn": [{ "at": 50, "action": "join", "count": 2 },
                            { "at": 100, "action": "leave" },
                            { "at": 150, "action": "join" }]}]}}"#,
        )
        .unwrap();
        assert_eq!(
            e.churn,
            vec![
                ChurnEvent { node: 0, at: 50.0, join: true },
                ChurnEvent { node: 1, at: 50.0, join: true },
                ChurnEvent { node: 0, at: 100.0, join: false },
                ChurnEvent { node: 0, at: 150.0, join: true },
            ]
        );
        // Churn count exceeding the group size is rejected even when the
        // group also stamps capacity standbys — standbys are not
        // churn-eligible spares.
        assert!(parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "capacity": { "policy": "reactive", "standby": 3 },
                  "churn": [{ "at": 10, "action": "leave", "count": 3 }]}]}}"#
        )
        .is_err());
        // And a churn schedule on a capacity group only ever touches the
        // committed members, never the stamped standbys.
        let e = parse_experiment(
            r#"{"topology": {"regions": ["us"],
                "fleet": [{ "region": "us", "count": 2,
                  "capacity": { "policy": "reactive", "standby": 2 },
                  "churn": [{ "at": 10, "action": "leave", "count": 2 }]}]}}"#,
        )
        .unwrap();
        assert_eq!(e.setups.len(), 4);
        assert!(e.churn.iter().all(|c| c.node < 2), "{:?}", e.churn);
        assert_eq!(e.world.capacity[0].standby, vec![2, 3]);
    }

    #[test]
    fn partial_link_override_inherits_configured_default() {
        // Only bandwidth overridden on us-eu: latency must come from the
        // configured "inter" profile, not a hardcoded range.
        let e = parse_experiment(
            r#"{"topology": {
                "regions": ["us", "eu"],
                "inter": { "latency": [0.150, 0.200], "jitter": 0.01 },
                "links": [{ "a": "us", "b": "eu", "bandwidth_mbps": 100 }]},
                "nodes": [{"region": "us"}, {"region": "eu"}]}"#,
        )
        .unwrap();
        let topo = e.world.topology.unwrap();
        let l = topo.link(0, 1);
        assert!((l.latency.0 - 0.150).abs() < 1e-12);
        assert!((l.latency.1 - 0.200).abs() < 1e-12);
        assert!((l.jitter - 0.01).abs() < 1e-12);
        assert!((l.bandwidth - 100.0 * 1e6 / 8.0).abs() < 1e-6);
    }
}
