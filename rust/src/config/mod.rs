//! Experiment configuration files (Appendix B's YAML schema, as JSON —
//! DESIGN.md §8).
//!
//! Example:
//! ```json
//! {
//!   "seed": 7,
//!   "horizon": 750,
//!   "strategy": "decentralized",
//!   "ledger": "shared",
//!   "system": { "duel_rate": 0.1, "judges": 2 },
//!   "nodes": [
//!     {
//!       "model": "qwen3-8b", "gpu": "ada6000", "backend": "sglang",
//!       "policy": { "stake": 10, "offload_freq": 0.8, "accept_freq": 0.8 },
//!       "schedule": [ {"from": 0, "to": 300, "inter_arrival": 5},
//!                     {"from": 300, "to": 750, "inter_arrival": 20} ]
//!     }
//!   ]
//! }
//! ```

use crate::backend::{Gpu, ModelClass, Profile, ServingStack};
use crate::policy::{NodePolicy, SystemPolicy};
use crate::schedulers::Strategy;
use crate::sim::{LedgerMode, NodeSetup, WorldConfig};
use crate::types::{NodeId, CREDIT};
use crate::util::json::Json;
use crate::workload::{Generator, Phase};

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("parse error: {0}")]
    Parse(#[from] crate::util::json::ParseError),
    #[error("invalid config: {0}")]
    Invalid(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// A fully parsed experiment description.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub seed: u64,
    pub horizon: f64,
    pub strategy: Strategy,
    pub world: WorldConfig,
    pub setups: Vec<NodeSetup>,
}

fn bad(msg: impl Into<String>) -> ConfigError {
    ConfigError::Invalid(msg.into())
}

fn parse_model(s: &str) -> Result<ModelClass, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "qwen3-32b" => ModelClass::Qwen3_32B,
        "qwen3-8b" => ModelClass::Qwen3_8B,
        "qwen3-4b" => ModelClass::Qwen3_4B,
        "qwen3-0.6b" => ModelClass::Qwen3_0_6B,
        "deepseek-qwen-7b" => ModelClass::DeepSeekQwen7B,
        "llama3.1-8b" => ModelClass::Llama31_8B,
        other => return Err(bad(format!("unknown model '{other}'"))),
    })
}

fn parse_gpu(s: &str) -> Result<Gpu, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "4xa100" => Gpu::A100x4,
        "a100" => Gpu::A100,
        "l40s" => Gpu::L40S,
        "ada6000" => Gpu::Ada6000,
        "rtx4090" => Gpu::Rtx4090,
        "rtx3090" => Gpu::Rtx3090,
        other => return Err(bad(format!("unknown gpu '{other}'"))),
    })
}

fn parse_stack(s: &str) -> Result<ServingStack, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "sglang" => ServingStack::SgLang,
        "vllm" => ServingStack::Vllm,
        other => return Err(bad(format!("unknown backend '{other}'"))),
    })
}

fn parse_strategy(s: &str) -> Result<Strategy, ConfigError> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "single" => Strategy::Single,
        "centralized" => Strategy::Centralized,
        "decentralized" => Strategy::Decentralized,
        other => return Err(bad(format!("unknown strategy '{other}'"))),
    })
}

fn parse_policy(j: &Json) -> NodePolicy {
    let d = NodePolicy::default();
    NodePolicy {
        stake: j
            .get("stake")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.stake),
        offload_freq: j.get("offload_freq").as_f64().unwrap_or(d.offload_freq),
        accept_freq: j.get("accept_freq").as_f64().unwrap_or(d.accept_freq),
        target_utilization: j
            .get("target_utilization")
            .as_f64()
            .unwrap_or(d.target_utilization),
        queue_threshold: j
            .get("queue_threshold")
            .as_usize()
            .unwrap_or(d.queue_threshold),
        prioritize_own: j
            .get("prioritize_own")
            .as_bool()
            .unwrap_or(d.prioritize_own),
        requester_only: j
            .get("requester_only")
            .as_bool()
            .unwrap_or(d.requester_only),
    }
}

fn parse_system(j: &Json) -> SystemPolicy {
    let d = SystemPolicy::default();
    SystemPolicy {
        base_reward: j
            .get("base_reward")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.base_reward),
        duel_rate: j.get("duel_rate").as_f64().unwrap_or(d.duel_rate),
        duel_reward: j
            .get("duel_reward")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.duel_reward),
        duel_penalty: j
            .get("duel_penalty")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.duel_penalty),
        judges: j.get("judges").as_usize().unwrap_or(d.judges),
        judge_reward: j
            .get("judge_reward")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.judge_reward),
        max_probes: j.get("max_probes").as_usize().unwrap_or(d.max_probes),
        genesis_credits: j
            .get("genesis_credits")
            .as_f64()
            .map(|c| (c * CREDIT as f64) as u64)
            .unwrap_or(d.genesis_credits),
        confirm_quorum: j
            .get("confirm_quorum")
            .as_f64()
            .unwrap_or(d.confirm_quorum),
    }
}

fn parse_phases(j: &Json) -> Result<Vec<Phase>, ConfigError> {
    let arr = j.as_arr().ok_or_else(|| bad("schedule must be an array"))?;
    arr.iter()
        .map(|p| {
            Ok(Phase::new(
                p.get("from").as_f64().ok_or_else(|| bad("phase.from"))?,
                p.get("to").as_f64().ok_or_else(|| bad("phase.to"))?,
                p.get("inter_arrival")
                    .as_f64()
                    .ok_or_else(|| bad("phase.inter_arrival"))?,
            ))
        })
        .collect()
}

/// Parse an experiment from JSON text.
pub fn parse_experiment(text: &str) -> Result<Experiment, ConfigError> {
    let j = Json::parse(text)?;
    let seed = j.get("seed").as_u64().unwrap_or(0);
    let horizon = j.get("horizon").as_f64().unwrap_or(750.0);
    let strategy =
        parse_strategy(j.get("strategy").as_str().unwrap_or("decentralized"))?;
    let ledger = match j.get("ledger").as_str().unwrap_or("shared") {
        "shared" => LedgerMode::Shared,
        "blockchain" => LedgerMode::Blockchain,
        other => return Err(bad(format!("unknown ledger mode '{other}'"))),
    };
    let system = parse_system(j.get("system"));
    let nodes = j
        .get("nodes")
        .as_arr()
        .ok_or_else(|| bad("missing 'nodes' array"))?;
    if nodes.is_empty() {
        return Err(bad("empty 'nodes' array"));
    }

    let mut setups = Vec::with_capacity(nodes.len());
    for (i, nj) in nodes.iter().enumerate() {
        // Either a (model, gpu, backend) triple or an explicit profile.
        let profile = if nj.get("profile").is_null() {
            let model =
                parse_model(nj.get("model").as_str().unwrap_or("qwen3-8b"))?;
            let gpu = parse_gpu(nj.get("gpu").as_str().unwrap_or("a100"))?;
            let stack =
                parse_stack(nj.get("backend").as_str().unwrap_or("sglang"))?;
            Profile::derive(model, gpu, stack)
        } else {
            let p = nj.get("profile");
            Profile {
                prefill_tok_s: p
                    .get("prefill_tok_s")
                    .as_f64()
                    .ok_or_else(|| bad("profile.prefill_tok_s"))?,
                decode_tok_s: p
                    .get("decode_tok_s")
                    .as_f64()
                    .ok_or_else(|| bad("profile.decode_tok_s"))?,
                max_agg_decode_tok_s: p
                    .get("max_agg_decode_tok_s")
                    .as_f64()
                    .ok_or_else(|| bad("profile.max_agg_decode_tok_s"))?,
                max_batch: p
                    .get("max_batch")
                    .as_usize()
                    .ok_or_else(|| bad("profile.max_batch"))?,
                quality: p.get("quality").as_f64().unwrap_or(0.7),
            }
        };
        let policy = parse_policy(nj.get("policy"));
        let mut setup = NodeSetup::new(profile, policy);
        if !nj.get("schedule").is_null() {
            let phases = parse_phases(nj.get("schedule"))?;
            setup = setup
                .with_generator(Generator::new(NodeId(i as u32), phases));
        }
        if nj.get("start_offline").as_bool().unwrap_or(false) {
            setup = setup.offline();
        }
        setups.push(setup);
    }

    Ok(Experiment {
        seed,
        horizon,
        strategy,
        world: WorldConfig {
            seed,
            system,
            ledger,
            ..Default::default()
        },
        setups,
    })
}

/// Read + parse a config file.
pub fn load_experiment(path: &str) -> Result<Experiment, ConfigError> {
    let text = std::fs::read_to_string(path)?;
    parse_experiment(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "seed": 7,
        "horizon": 200,
        "strategy": "decentralized",
        "ledger": "shared",
        "system": { "duel_rate": 0.25, "judges": 3 },
        "nodes": [
            { "model": "qwen3-8b", "gpu": "ada6000", "backend": "sglang",
              "policy": { "stake": 5, "offload_freq": 0.5 },
              "schedule": [ {"from": 0, "to": 200, "inter_arrival": 10} ] },
            { "model": "qwen3-4b", "gpu": "rtx3090", "backend": "vllm",
              "start_offline": true }
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let e = parse_experiment(SAMPLE).unwrap();
        assert_eq!(e.seed, 7);
        assert_eq!(e.horizon, 200.0);
        assert_eq!(e.strategy, Strategy::Decentralized);
        assert_eq!(e.setups.len(), 2);
        assert!((e.world.system.duel_rate - 0.25).abs() < 1e-12);
        assert_eq!(e.world.system.judges, 3);
        assert_eq!(e.setups[0].policy.stake, 5 * CREDIT);
        assert!((e.setups[0].policy.offload_freq - 0.5).abs() < 1e-12);
        // Defaults fill unspecified fields.
        assert!((e.setups[0].policy.accept_freq - 0.8).abs() < 1e-12);
        assert!(e.setups[0].generator.is_some());
        assert!(e.setups[1].generator.is_none());
        assert!(e.setups[1].start_offline);
    }

    #[test]
    fn explicit_profile() {
        let text = r#"{
            "nodes": [ { "profile": { "prefill_tok_s": 1000,
                "decode_tok_s": 50, "max_agg_decode_tok_s": 500,
                "max_batch": 16, "quality": 0.9 } } ]
        }"#;
        let e = parse_experiment(text).unwrap();
        assert_eq!(e.setups[0].profile.max_batch, 16);
        assert!((e.setups[0].profile.quality - 0.9).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(parse_experiment("{").is_err());
        assert!(parse_experiment(r#"{"nodes": []}"#).is_err());
        assert!(parse_experiment(
            r#"{"nodes": [{"model": "gpt99"}]}"#
        )
        .is_err());
        assert!(parse_experiment(
            r#"{"strategy": "quantum", "nodes": [{}]}"#
        )
        .is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let e = parse_experiment(r#"{"nodes": [{}]}"#).unwrap();
        assert_eq!(e.horizon, 750.0);
        assert_eq!(e.strategy, Strategy::Decentralized);
        assert_eq!(e.world.ledger, LedgerMode::Shared);
    }
}
