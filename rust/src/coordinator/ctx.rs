//! The shared substrate the coordinator layers operate on.
//!
//! [`Ctx`] is a borrow bundle over the composition root's state
//! (`node::Node`), built once per `handle()` activation and threaded
//! through the layer pipeline (`dispatch` → `duel` → `gossip_driver`),
//! so each layer struct owns *its* state while borrowing the shared
//! pieces (backend, view, ledger, RNG, latency feed, snapshot cache)
//! without fighting the borrow checker.
//!
//! [`PeerScratch`] is the per-activation alive-peer view: ledger paths
//! used to rebuild the filtered alive-peer `Vec` two or three times per
//! event (payment + tick retries + stake maintenance); the scratch
//! memoizes one build per `(now, view clock)` and hands out slices.

use super::events::Action;
use super::latency_feed::LatencyFeed;
use super::ledger_manager::LedgerManager;
use super::msg::Message;
use super::snapshot::Snapshots;
use crate::backend::Backend;
use crate::gossip::PeerView;
use crate::ledger::CreditOp;
use crate::obs::{FlightRecorder, SpanKind};
use crate::policy::{NodePolicy, ParticipationPolicy, SystemPolicy};
use crate::reputation::{DefenseState, RepEvent, Transition};
use crate::streaming::StreamingConfig;
use crate::types::{ExecKind, NodeId, Request, Time};
use crate::util::rng::Rng;

use super::node::NodeStats;

/// Memoized alive-peer list, keyed on `(now, view mutation clock)` —
/// rebuilt at most once per distinct (time, view) state instead of once
/// per caller. The buffer is reused across activations, so steady-state
/// ticks allocate nothing.
#[derive(Debug, Default)]
pub(crate) struct PeerScratch {
    key: Option<(u64, u64)>,
    buf: Vec<NodeId>,
}

impl PeerScratch {
    /// Peers currently believed alive — one filtered build per
    /// `(now, view clock)`, shared by every caller in the activation.
    pub fn alive<'s>(&'s mut self, view: &PeerView, now: Time) -> &'s [NodeId] {
        let key = (now.to_bits(), view.clock());
        if self.key != Some(key) {
            view.alive_peers_into(now, &mut self.buf);
            self.key = Some(key);
        }
        &self.buf
    }
}

/// One activation's view of the node: everything the layers share.
/// Layer-owned state (pending delegations, duels, gossip cadence) is NOT
/// here — each layer keeps its own and receives the others explicitly.
pub(crate) struct Ctx<'a> {
    pub id: NodeId,
    pub policy: &'a NodePolicy,
    pub system: &'a SystemPolicy,
    pub participation: &'a dyn ParticipationPolicy,
    pub backend: &'a mut dyn Backend,
    pub view: &'a mut PeerView,
    pub ledger: &'a mut LedgerManager,
    pub rng: &'a mut Rng,
    pub feed: &'a mut LatencyFeed,
    pub snaps: &'a mut Snapshots,
    pub stats: &'a mut NodeStats,
    pub peers: &'a mut PeerScratch,
    pub obs: &'a mut FlightRecorder,
    pub defense: &'a mut DefenseState,
    pub streaming: &'a StreamingConfig,
}

/// Stable `detail` encoding of an [`ExecKind`] for `execute_*` spans.
pub(crate) fn exec_kind_code(kind: ExecKind) -> u64 {
    match kind {
        ExecKind::Local => 0,
        ExecKind::Delegated => 1,
        ExecKind::Duel => 2,
        ExecKind::Judge => 3,
    }
}

impl Ctx<'_> {
    /// Put a request on our own backend.
    pub fn execute_locally(
        &mut self,
        req: Request,
        kind: ExecKind,
        now: Time,
    ) -> Vec<Action> {
        if kind == ExecKind::Local {
            self.stats.served_local += 1;
        }
        self.obs.span(
            req.id,
            SpanKind::ExecuteStart,
            self.id,
            None,
            now,
            exec_kind_code(kind),
        );
        self.backend.submit(req, kind, now);
        vec![]
    }

    /// Refresh the cached delegation snapshot (see [`Snapshots`]),
    /// reputation-gated when defenses are on.
    pub fn refresh_snapshot(&mut self, now: Time) {
        self.snaps.refresh(
            self.id,
            self.policy,
            self.participation,
            self.view,
            self.ledger,
            self.feed,
            self.defense.rep_if_on(),
            now,
        );
    }

    /// Submit ledger ops. Only chain mode broadcasts ledger messages, so
    /// only chain mode pays for the (memoized) alive-peer view; shared
    /// mode applies in place with an empty peer list.
    pub fn ledger_submit(
        &mut self,
        ops: Vec<CreditOp>,
        now: Time,
    ) -> Vec<Action> {
        if self.ledger.is_chain() {
            let peers = self.peers.alive(self.view, now);
            self.ledger.submit(ops, self.id, peers, now)
        } else {
            self.ledger.submit(ops, self.id, &[], now)
        }
    }

    /// Route a ledger protocol message (block proposal/vote/commit, chain
    /// sync) into the ledger manager.
    pub fn ledger_on_message(
        &mut self,
        from: NodeId,
        msg: &Message,
        now: Time,
    ) -> Vec<Action> {
        let peers = self.peers.alive(self.view, now);
        self.ledger.on_message(from, msg, self.id, peers, now)
    }

    /// Per-tick ledger maintenance (chain-mode head races). Shared mode
    /// has no ledger traffic — skip even the memoized peer lookup.
    pub fn ledger_tick(&mut self, now: Time) -> Vec<Action> {
        if self.ledger.is_chain() {
            let peers = self.peers.alive(self.view, now);
            self.ledger.on_tick(peers, now)
        } else {
            Vec::new()
        }
    }

    /// Feed one piece of first-hand evidence about `peer` into the
    /// reputation book (no-op when defenses are off), recording quarantine
    /// transitions in stats and the flight recorder.
    pub fn rep_event(&mut self, peer: NodeId, ev: RepEvent, now: Time) {
        if !self.defense.reputation_on() {
            return;
        }
        match self.defense.rep.record(peer, ev, now) {
            Transition::Quarantined => {
                self.stats.quarantines += 1;
                self.obs.node_span(
                    SpanKind::Quarantine,
                    self.id,
                    Some(peer),
                    now,
                    1,
                );
            }
            Transition::Released => {
                self.obs.node_span(
                    SpanKind::Quarantine,
                    self.id,
                    Some(peer),
                    now,
                    0,
                );
            }
            Transition::None => {}
        }
    }

    /// Merge gossip-borne reputation rows from a peer (no-op when defenses
    /// are off), recording any resulting quarantine transitions. Remote
    /// opinion is bounded — it can corroborate our own evidence but never
    /// quarantine a peer by itself (see `crate::reputation`).
    pub fn ingest_rep_rows(&mut self, rows: &[(u32, u32)], now: Time) {
        if rows.is_empty() || !self.defense.reputation_on() {
            return;
        }
        for (peer, tr) in self.defense.rep.merge_remote(self.id, rows, now) {
            match tr {
                Transition::Quarantined => {
                    self.stats.quarantines += 1;
                    self.obs.node_span(
                        SpanKind::Quarantine,
                        self.id,
                        Some(peer),
                        now,
                        1,
                    );
                }
                Transition::Released => {
                    self.obs.node_span(
                        SpanKind::Quarantine,
                        self.id,
                        Some(peer),
                        now,
                        0,
                    );
                }
                Transition::None => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gossip::GossipConfig;

    #[test]
    fn peer_scratch_memoizes_per_time_and_clock() {
        let mut view = PeerView::new(NodeId(0), GossipConfig::default(), 0.0);
        view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        let mut scratch = PeerScratch::default();
        assert_eq!(scratch.alive(&view, 0.5), &[NodeId(1)]);
        let key0 = scratch.key;
        // Same (now, clock): served from the memo, key untouched.
        assert_eq!(scratch.alive(&view, 0.5), &[NodeId(1)]);
        assert_eq!(scratch.key, key0);
        // View mutation bumps the clock: rebuilt.
        view.merge(&[(NodeId(2), 1, true, 0, 0)], 0.6);
        assert_eq!(scratch.alive(&view, 0.6), &[NodeId(1), NodeId(2)]);
        assert_ne!(scratch.key, key0);
        // Time moving (heartbeat aging) also rebuilds: peers age out.
        let aged = 0.6 + GossipConfig::default().suspect_after + 1.0;
        assert!(scratch.alive(&view, aged).is_empty());
    }
}
