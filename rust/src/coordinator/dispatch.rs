//! The delegation dispatch layer: admission (offload-or-serve), the
//! probe → delegate → response state machine around [`PendingState`], the
//! executor-side ticket table, and the timeout scan.
//!
//! The *decisions* at this boundary — whether a user request enters the
//! market, whether an incoming probe is accepted — are delegated to the
//! node's pluggable [`ParticipationPolicy`]; this layer owns the
//! *mechanics*: pending-state bookkeeping, probe retries, local fallback,
//! RTT feedback into the latency feed, and payment on response.
//!
//! [`ParticipationPolicy`]: crate::policy::ParticipationPolicy

use std::collections::BTreeMap;

use super::ctx::{exec_kind_code, Ctx};
use super::duel::DuelCourt;
use super::events::Action;
use super::msg::Message;
use crate::backend::Completion;
use crate::crypto::{response_digest, Receipt};
use crate::duel as duel_mech;
use crate::ledger::{CreditOp, OpReason};
use crate::obs::SpanKind;
use crate::policy::{OffloadCtx, ProbeCtx};
use crate::reputation::RepEvent;
use crate::types::{
    ExecKind, NodeId, Request, RequestId, RequestRecord, Response, Time,
};

/// Seconds to wait for a probe answer before trying the next candidate.
pub(crate) const PROBE_TIMEOUT: Time = 3.0;
/// Multiple of the SLO deadline to wait for a delegated response before
/// falling back to local execution (covers executor crashes).
pub(crate) const RESPONSE_TIMEOUT_FACTOR: f64 = 3.0;

#[derive(Debug, Clone)]
pub(crate) enum PendingState {
    /// Waiting for a ProbeAccept/Reject from `candidate`. `sent_at` stamps
    /// the probe send so the reply measures a live RTT (and a timeout
    /// penalizes the candidate's region in the latency estimator).
    Probing {
        candidate: NodeId,
        probes_left: usize,
        sent_at: Time,
    },
    /// Waiting for the executor's response.
    AwaitingResponse { executor: NodeId },
    /// Waiting for both duel responses.
    AwaitingDuel,
}

#[derive(Debug, Clone)]
pub(crate) struct PendingDelegation {
    pub req: Request,
    pub state: PendingState,
    pub deadline: Time,
}

/// Executor-side record of who to answer for a delegated request.
#[derive(Debug, Clone, Copy)]
struct ExecTicket {
    origin: NodeId,
    duel: bool,
}

/// Where a streaming session's KV cache currently lives, and how big it
/// is. `home` is the last node that completed a turn for the session;
/// `ctx_tokens` accumulates the turns' prompt + output tokens, sizing the
/// `KvTransfer` a re-dispatch away from home must ship.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SessionKv {
    pub home: NodeId,
    pub ctx_tokens: u64,
}

/// Origin-side pending delegations + executor-side tickets.
///
/// All three tables are `BTreeMap`s, not `HashMap`s: the timeout scan
/// iterates `pending` and the churn NACK drains `exec_tickets`, and a hash
/// table's per-process iteration order would make same-tick expiries (or
/// abort sends) replay differently across runs (determinism contract,
/// `docs/determinism.md`). `RequestId`'s derived `Ord` is
/// `(origin, seq)` — exactly the order the scan wants.
#[derive(Debug, Default)]
pub(crate) struct Dispatch {
    pending: BTreeMap<RequestId, PendingDelegation>,
    exec_tickets: BTreeMap<RequestId, ExecTicket>,
    /// Per-session KV residency (origin side; streaming only — stays
    /// empty, and costs nothing, when the block is disabled).
    sessions: BTreeMap<u64, SessionKv>,
}

impl Dispatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// The duel layer inserts/removes pending entries when it starts or
    /// settles a duel for the origin.
    pub fn pending_mut(
        &mut self,
    ) -> &mut BTreeMap<RequestId, PendingDelegation> {
        &mut self.pending
    }

    // ---- origin side --------------------------------------------------------

    /// Admission: ask the participation policy whether this request enters
    /// the delegation market; otherwise put it on the local backend. No
    /// live peer at all is an explicit serve-locally case — never a
    /// sentinel distance fed through the offload damping roll.
    pub fn on_user_request(
        &mut self,
        ctx: &mut Ctx<'_>,
        court: &mut DuelCourt,
        req: Request,
        now: Time,
    ) -> Vec<Action> {
        ctx.stats.user_requests += 1;
        ctx.obs.span(req.id, SpanKind::Admit, ctx.id, None, now, 0);
        let util = ctx.backend.utilization();
        let qlen = ctx.backend.queue_len();
        let part = ctx.participation;
        let offload = match ctx.feed.nearest_peer_latency(
            ctx.view,
            ctx.policy.latency_penalty,
            now,
        ) {
            Some(near) => part.should_offload(
                ctx.policy,
                &OffloadCtx {
                    utilization: util,
                    queue_len: qlen,
                    nearest_latency: near,
                },
                ctx.rng,
            ),
            None => false,
        };
        if !offload {
            return ctx.execute_locally(req, ExecKind::Local, now);
        }
        self.try_delegate(ctx, court, req, now)
    }

    /// Start the delegation state machine (PoS sample → probe). Falls back
    /// to local execution when no viable peer or unaffordable.
    pub(crate) fn try_delegate(
        &mut self,
        ctx: &mut Ctx<'_>,
        court: &mut DuelCourt,
        req: Request,
        now: Time,
    ) -> Vec<Action> {
        // Can we afford the offload payment?
        if ctx.ledger.balance(ctx.id) < ctx.system.base_reward {
            ctx.stats.fallback_local += 1;
            return ctx.execute_locally(req, ExecKind::Local, now);
        }
        ctx.refresh_snapshot(now);
        let candidates = ctx.snaps.candidates();
        if candidates == 0 {
            ctx.stats.fallback_local += 1;
            return ctx.execute_locally(req, ExecKind::Local, now);
        }

        // KV affinity (streaming): a session turn prefers its KV home —
        // the node already holding the session's cache — with probability
        // `affinity_bonus`, skipping the duel roll (a duel would fork the
        // stream onto a second executor and ship the KV twice). Everything
        // here is gated on `streaming.enabled && session != 0`, so the
        // disabled path spends exactly the classic RNG draws.
        if ctx.streaming.enabled && req.session != 0 {
            let home = self.sessions.get(&req.session).map(|s| s.home);
            if let Some(home) = home {
                if ctx.rng.chance(ctx.streaming.affinity_bonus) {
                    if home == ctx.id {
                        // The KV already lives on our own backend.
                        return ctx.execute_locally(req, ExecKind::Local, now);
                    }
                    if ctx.snaps.contains(home) {
                        return self.send_probe(ctx, req, home, now);
                    }
                    // Home died or got quarantined: fall through to a
                    // fresh draw; the KV will have to move.
                }
            }
        } else if ctx.rng.chance(ctx.system.duel_rate) && candidates >= 2 {
            // Duel roll (§4.2): a fraction p_d of delegated requests go to
            // two executors directly.
            return court.start_duel(ctx, &mut self.pending, req, now);
        }

        let candidate = ctx.snaps.sample(ctx.rng);
        let Some(candidate) = candidate else {
            ctx.stats.fallback_local += 1;
            return ctx.execute_locally(req, ExecKind::Local, now);
        };
        self.send_probe(ctx, req, candidate, now)
    }

    /// Probe `candidate` for `req` and park the pending entry — the common
    /// tail of the stake-draw and KV-affinity dispatch paths.
    fn send_probe(
        &mut self,
        ctx: &mut Ctx<'_>,
        req: Request,
        candidate: NodeId,
        now: Time,
    ) -> Vec<Action> {
        let probe = Message::Probe {
            req_id: req.id,
            prompt_tokens: req.prompt_tokens,
            output_tokens: req.output_tokens,
        };
        ctx.obs.span(
            req.id,
            SpanKind::ProbeSent,
            ctx.id,
            Some(candidate),
            now,
            0,
        );
        self.pending.insert(
            req.id,
            PendingDelegation {
                req,
                state: PendingState::Probing {
                    candidate,
                    probes_left: ctx.system.max_probes.saturating_sub(1),
                    sent_at: now,
                },
                deadline: now + PROBE_TIMEOUT,
            },
        );
        vec![Action::Send { to: candidate, msg: probe }]
    }

    /// Session bookkeeping on a completed turn: the executor that just
    /// finished now holds the (grown) KV cache. No-op outside streaming.
    pub fn note_session_completion(
        &mut self,
        ctx: &Ctx<'_>,
        req: &Request,
        executor: NodeId,
    ) {
        if !ctx.streaming.enabled || req.session == 0 {
            return;
        }
        let s = self
            .sessions
            .entry(req.session)
            .or_insert(SessionKv { home: executor, ctx_tokens: 0 });
        s.home = executor;
        s.ctx_tokens += (req.prompt_tokens + req.output_tokens) as u64;
    }

    /// If delegating `req` to `executor` moves a session away from its KV
    /// home, the size of the cache that has to travel with it.
    fn kv_payload(
        &self,
        ctx: &Ctx<'_>,
        req: &Request,
        executor: NodeId,
    ) -> Option<(u64, u64)> {
        if !ctx.streaming.enabled || req.session == 0 {
            return None;
        }
        let s = self.sessions.get(&req.session)?;
        if s.home == executor || s.ctx_tokens == 0 {
            return None;
        }
        let bytes =
            (s.ctx_tokens as f64 * ctx.streaming.kv_bytes_per_token) as u64;
        if bytes == 0 {
            return None;
        }
        Some((req.session, bytes))
    }

    pub fn on_probe_accept(
        &mut self,
        ctx: &mut Ctx<'_>,
        from: NodeId,
        req_id: RequestId,
        now: Time,
    ) -> Vec<Action> {
        let Some(p) = self.pending.get_mut(&req_id) else {
            return vec![]; // stale (already timed out / answered)
        };
        let PendingState::Probing { candidate, sent_at, .. } = p.state else {
            return vec![];
        };
        if candidate != from {
            return vec![]; // answer from a node we no longer care about
        }
        ctx.stats.delegated_out += 1;
        let req = p.req.clone();
        p.state = PendingState::AwaitingResponse { executor: from };
        p.deadline = now + req.slo_deadline * RESPONSE_TIMEOUT_FACTOR;
        let rtt = (now - sent_at).max(0.0);
        ctx.obs.span(
            req_id,
            SpanKind::ProbeAcked,
            ctx.id,
            Some(from),
            now,
            (rtt * 1e6) as u64,
        );
        // The probe round trip is a clean network RTT sample.
        ctx.feed.observe_peer_rtt(ctx.obs, ctx.view, from, rtt, now);
        ctx.obs.span(req_id, SpanKind::Delegate, ctx.id, Some(from), now, 0);
        // Streaming: dispatching a session turn away from its KV home
        // ships the resident cache with the request. The KvTransfer's wire
        // size includes the KV bytes, so the fabric's bandwidth model
        // prices the move as a real queue delay — TTFT pays for blindness.
        let msg = match self.kv_payload(ctx, &req, from) {
            Some((session, kv_bytes)) => {
                ctx.obs.span(
                    req_id,
                    SpanKind::KvTransfer,
                    ctx.id,
                    Some(from),
                    now,
                    kv_bytes,
                );
                Message::KvTransfer { request: req, session, kv_bytes }
            }
            None => Message::Delegate { request: req, duel: false },
        };
        vec![Action::Send { to: from, msg }]
    }

    pub fn on_probe_reject(
        &mut self,
        ctx: &mut Ctx<'_>,
        from: NodeId,
        req_id: RequestId,
        now: Time,
    ) -> Vec<Action> {
        let (req, probes_left, sent_at) = {
            let Some(p) = self.pending.get(&req_id) else {
                return vec![];
            };
            let PendingState::Probing { candidate, probes_left, sent_at } =
                p.state
            else {
                return vec![];
            };
            if candidate != from {
                return vec![];
            }
            (p.req.clone(), probes_left, sent_at)
        };
        let rtt = (now - sent_at).max(0.0);
        ctx.obs.span(
            req_id,
            SpanKind::ProbeRejected,
            ctx.id,
            Some(from),
            now,
            (rtt * 1e6) as u64,
        );
        // A reject still answers the probe: same clean RTT sample.
        ctx.feed.observe_peer_rtt(ctx.obs, ctx.view, from, rtt, now);
        ctx.stats.probe_rejects += 1;
        if probes_left == 0 {
            self.pending.remove(&req_id);
            ctx.stats.fallback_local += 1;
            return ctx.execute_locally(req, ExecKind::Local, now);
        }
        // Try another candidate.
        ctx.refresh_snapshot(now);
        let next = ctx.snaps.sample(ctx.rng);
        match next {
            Some(c) => {
                let probe = Message::Probe {
                    req_id,
                    prompt_tokens: req.prompt_tokens,
                    output_tokens: req.output_tokens,
                };
                ctx.obs.span(
                    req_id,
                    SpanKind::ProbeSent,
                    ctx.id,
                    Some(c),
                    now,
                    0,
                );
                let p = self.pending.get_mut(&req_id).expect("checked above");
                p.state = PendingState::Probing {
                    candidate: c,
                    probes_left: probes_left - 1,
                    sent_at: now,
                };
                p.deadline = now + PROBE_TIMEOUT;
                vec![Action::Send { to: c, msg: probe }]
            }
            None => {
                self.pending.remove(&req_id);
                ctx.stats.fallback_local += 1;
                ctx.execute_locally(req, ExecKind::Local, now)
            }
        }
    }

    /// The executor's answer for a non-duel delegation: check the work
    /// receipt (when defenses are on), then pay and complete. A missing or
    /// mis-signed receipt means the work is never paid — the request falls
    /// back to local execution and the executor's reputation takes a
    /// `ReceiptFail` hit (see `crate::reputation`).
    pub fn on_response(
        &mut self,
        ctx: &mut Ctx<'_>,
        response: Response,
        receipt: Option<Receipt>,
        now: Time,
    ) -> Vec<Action> {
        let Some(p) = self.pending.remove(&response.id) else {
            return vec![]; // stale (timed out, user already answered)
        };
        let PendingState::AwaitingResponse { executor } = p.state else {
            self.pending.insert(response.id, p);
            return vec![];
        };
        if ctx.defense.receipts_on()
            && !receipt_settles(ctx, &response, executor, receipt.as_ref())
        {
            ctx.stats.receipt_rejects += 1;
            ctx.stats.fallback_local += 1;
            ctx.obs.span(
                response.id,
                SpanKind::ReceiptReject,
                ctx.id,
                Some(executor),
                now,
                0,
            );
            ctx.rep_event(executor, RepEvent::ReceiptFail, now);
            // Unreceipted work is never paid; serve the user ourselves.
            return ctx.execute_locally(p.req, ExecKind::Local, now);
        }
        ctx.rep_event(executor, RepEvent::Success, now);
        ctx.obs.span(
            response.id,
            SpanKind::Settle,
            ctx.id,
            Some(executor),
            now,
            0,
        );
        // Pay the executor (credits-for-offloading).
        let mut actions = ctx.ledger_submit(
            vec![CreditOp::Transfer {
                from: ctx.id,
                to: executor,
                amount: ctx.system.base_reward,
                reason: OpReason::OffloadPayment(response.id),
            }],
            now,
        );
        self.note_session_completion(ctx, &p.req, executor);
        actions.push(Action::Done(RequestRecord {
            id: p.req.id,
            origin: ctx.id,
            executor,
            kind: ExecKind::Delegated,
            prompt_tokens: p.req.prompt_tokens,
            output_tokens: p.req.output_tokens,
            submitted_at: p.req.submitted_at,
            completed_at: now,
            slo_deadline: p.req.slo_deadline,
            synthetic: p.req.synthetic,
            session: p.req.session,
            ttft_deadline: p.req.ttft_deadline,
            first_token_at: response.first_token_at,
        }));
        actions
    }

    /// Executor-side churn NACK arrived: the executor is leaving and
    /// aborts our in-flight delegation. An honest goodbye is not Byzantine
    /// silence — prompt local fallback, no `RESPONSE_TIMEOUT_FACTOR` wait,
    /// and **no** `RepEvent::Timeout` strike against the leaver.
    pub fn on_exec_abort(
        &mut self,
        ctx: &mut Ctx<'_>,
        from: NodeId,
        req_id: RequestId,
        now: Time,
    ) -> Vec<Action> {
        {
            let Some(p) = self.pending.get(&req_id) else {
                return vec![]; // stale (already answered / timed out)
            };
            let PendingState::AwaitingResponse { executor } = p.state else {
                return vec![];
            };
            if executor != from {
                return vec![];
            }
        }
        let p = self.pending.remove(&req_id).expect("checked above");
        ctx.stats.exec_aborts += 1;
        ctx.stats.fallback_local += 1;
        // Timeout-span detail 3 = "aborted by executor churn".
        ctx.obs.span(req_id, SpanKind::Timeout, ctx.id, Some(from), now, 3);
        ctx.execute_locally(p.req, ExecKind::Local, now)
    }

    // ---- executor side ------------------------------------------------------

    /// A delegated request arrives: remember who to answer and execute.
    /// A free-riding participation policy (`delivers_responses() == false`)
    /// silently drops the work here — the requester only learns via its
    /// response timeout.
    pub fn on_delegate(
        &mut self,
        ctx: &mut Ctx<'_>,
        from: NodeId,
        request: Request,
        duel: bool,
        now: Time,
    ) -> Vec<Action> {
        ctx.stats.delegated_in += 1;
        ctx.obs.span(request.id, SpanKind::Queue, ctx.id, Some(from), now, 0);
        if !ctx.participation.delivers_responses() {
            return vec![];
        }
        self.exec_tickets
            .insert(request.id, ExecTicket { origin: from, duel });
        let kind = if duel { ExecKind::Duel } else { ExecKind::Delegated };
        ctx.execute_locally(request, kind, now)
    }

    /// Accept-or-reject an incoming probe — the participation policy's
    /// call, given local load and the job size.
    pub fn on_probe(
        ctx: &mut Ctx<'_>,
        from: NodeId,
        req_id: RequestId,
        prompt_tokens: u32,
        output_tokens: u32,
    ) -> Vec<Action> {
        let util = ctx.backend.utilization();
        let qlen = ctx.backend.queue_len();
        let part = ctx.participation;
        let accept = part.accept_probe(
            ctx.policy,
            &ProbeCtx {
                from,
                prompt_tokens,
                output_tokens,
                utilization: util,
                queue_len: qlen,
            },
            ctx.rng,
        );
        let reply = if accept {
            Message::ProbeAccept { req_id }
        } else {
            Message::ProbeReject { req_id }
        };
        vec![Action::Send { to: from, msg: reply }]
    }

    /// A delegated/duel execution finished on our backend: draw the
    /// response quality, sign a work receipt (defenses on), and answer the
    /// origin. A faking participation policy degrades the quality
    /// (`quality_factor`) and/or signs over the wrong content
    /// (`honest_receipts() == false`), which the requester's settlement
    /// check catches.
    pub fn on_exec_completion(
        &mut self,
        ctx: &mut Ctx<'_>,
        c: Completion,
    ) -> Vec<Action> {
        let Some(ticket) = self.exec_tickets.remove(&c.request.id) else {
            return vec![];
        };
        let kind = if ticket.duel { ExecKind::Duel } else { ExecKind::Delegated };
        ctx.obs.span(
            c.request.id,
            SpanKind::ExecuteEnd,
            ctx.id,
            Some(ticket.origin),
            c.finished_at,
            exec_kind_code(kind),
        );
        let mut quality =
            duel_mech::draw_response_quality(ctx.backend.quality(), ctx.rng);
        let factor = ctx.participation.quality_factor();
        if factor != 1.0 {
            // Only scale on genuinely faking policies: honest nodes keep
            // the drawn value bit-exactly (replay equivalence).
            quality *= factor;
        }
        let response = Response {
            id: c.request.id,
            executor: ctx.id,
            quality,
            finished_at: c.finished_at,
            first_token_at: c.first_token_at,
            tokens: vec![],
        };
        let receipt = match ctx.defense.signing_key() {
            Some(key) if ctx.defense.receipts_on() => {
                let digest = if ctx.participation.honest_receipts() {
                    response_digest(&response)
                } else {
                    // A faker signs over content it never produced.
                    crate::crypto::sha256(b"result-faker-phantom-work")
                };
                Some(Receipt::sign(
                    key,
                    c.request.id,
                    ticket.origin,
                    c.request.submitted_at,
                    c.finished_at,
                    digest,
                ))
            }
            _ => None,
        };
        vec![Action::Send {
            to: ticket.origin,
            msg: Message::DelegateResponse {
                response,
                duel: ticket.duel,
                receipt,
            },
        }]
    }

    /// Drain every executor-side ticket for the churn NACK: the node is
    /// leaving, so each delegation it still owes an answer for gets an
    /// `ExecAbort` to its origin instead of silence. BTreeMap order keeps
    /// the abort sequence replay-stable.
    pub fn take_exec_tickets(&mut self) -> Vec<(RequestId, NodeId)> {
        std::mem::take(&mut self.exec_tickets)
            .into_iter()
            .map(|(id, t)| (id, t.origin))
            .collect()
    }

    // ---- timeouts -----------------------------------------------------------

    /// Expire overdue pending delegations: probe timeouts penalize the
    /// candidate's region and fall back locally; vanished executors fall
    /// back locally; duel timeouts settle through the duel layer.
    pub fn expire(
        &mut self,
        ctx: &mut Ctx<'_>,
        court: &mut DuelCourt,
        now: Time,
    ) -> Vec<Action> {
        // BTreeMap iteration is `(origin, seq)`-ordered, so multiple
        // same-tick expiries replay identically across runs and processes
        // without an explicit sort (this is byte-for-byte the order the
        // pre-migration `sort_unstable_by_key` produced).
        let expired: Vec<RequestId> = self
            .pending
            .iter()
            .filter(|(_, p)| now >= p.deadline)
            .map(|(id, _)| *id)
            .collect();
        let mut actions = Vec::new();
        for id in expired {
            let p = self.pending.remove(&id).expect("just listed");
            match p.state {
                PendingState::Probing { candidate, .. } => {
                    // Probe never answered: the candidate died or the path
                    // to its region is down. Penalize the region in the
                    // latency estimator and serve locally.
                    ctx.stats.probe_timeouts += 1;
                    ctx.stats.fallback_local += 1;
                    ctx.obs.span(
                        id,
                        SpanKind::Timeout,
                        ctx.id,
                        Some(candidate),
                        now,
                        0,
                    );
                    ctx.feed.observe_probe_timeout(
                        ctx.obs, ctx.view, candidate, now,
                    );
                    actions.extend(
                        ctx.execute_locally(p.req, ExecKind::Local, now),
                    );
                }
                PendingState::AwaitingResponse { executor } => {
                    // Executor vanished mid-flight (crashed, or a free-rider
                    // silently dropping work): local fallback + a reputation
                    // strike against the executor.
                    ctx.stats.fallback_local += 1;
                    ctx.obs.span(
                        id,
                        SpanKind::Timeout,
                        ctx.id,
                        Some(executor),
                        now,
                        1,
                    );
                    ctx.rep_event(executor, RepEvent::Timeout, now);
                    actions.extend(
                        ctx.execute_locally(p.req, ExecKind::Local, now),
                    );
                }
                PendingState::AwaitingDuel => {
                    ctx.obs.span(id, SpanKind::Timeout, ctx.id, None, now, 2);
                    actions.extend(court.on_duel_timeout(ctx, id, p.req, now));
                }
            }
        }
        actions
    }
}

/// Does this receipt let the response settle? Checks presence, the
/// signature against the executor's registered key, and that the receipt
/// binds exactly this request, this requester, the probed executor, and
/// the response content actually received.
fn receipt_settles(
    ctx: &Ctx<'_>,
    response: &Response,
    executor: NodeId,
    receipt: Option<&Receipt>,
) -> bool {
    let Some(r) = receipt else {
        return false;
    };
    let Some(keys) = ctx.defense.key_store() else {
        return false;
    };
    r.request == response.id
        && r.executor == executor
        && r.requester == ctx.id
        && r.response_digest == response_digest(response)
        && r.verify(keys).is_ok()
}

#[cfg(test)]
mod tests {
    use super::super::events::{Action, Event};
    use super::super::msg::Message;
    use super::super::node::testutil::{mk_node, user_req};
    use super::PROBE_TIMEOUT;
    use crate::latency::LatencyConfig;
    use crate::ledger::{Ledger, SharedLedger};
    use crate::policy::{NodePolicy, SystemPolicy};
    use crate::types::{ExecKind, NodeId};
    use std::sync::{Arc, Mutex};

    #[test]
    fn pressured_node_probes_staked_peer() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        // Node 1 exists in the ledger (stakes) and in node 0's view.
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0, // always offload
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        // duel_rate 0 for a deterministic single probe
        n0.system.duel_rate = 0.0;
        let actions = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let sends: Vec<_> = actions
            .iter()
            .filter_map(|a| match a {
                Action::Send { to, msg } => Some((*to, msg.kind())),
                _ => None,
            })
            .collect();
        assert_eq!(sends, vec![(NodeId(1), "probe")]);
    }

    #[test]
    fn full_delegation_roundtrip_pays_executor() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        n1.policy.accept_freq = 1.0;

        let bal0 = shared.lock().unwrap().balance(NodeId(0));
        let bal1 = shared.lock().unwrap().balance(NodeId(1));

        // 0 -> probe -> 1
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let Action::Send { msg: probe, .. } = &a[0] else { panic!() };
        // 1 -> accept -> 0
        let a = n1.handle(
            Event::Message { from: NodeId(0), msg: probe.clone() },
            0.1,
        );
        let Action::Send { msg: accept, .. } = &a[0] else { panic!() };
        assert_eq!(accept.kind(), "probe_accept");
        // 0 -> delegate -> 1
        let a = n0.handle(
            Event::Message { from: NodeId(1), msg: accept.clone() },
            0.2,
        );
        let Action::Send { msg: delegate, .. } = &a[0] else { panic!() };
        assert_eq!(delegate.kind(), "delegate");
        // 1 executes...
        n1.handle(
            Event::Message { from: NodeId(0), msg: delegate.clone() },
            0.3,
        );
        let a = n1.handle(Event::BackendWake, 100.0);
        let Some(Action::Send { to, msg: resp }) = a
            .iter()
            .find(|x| matches!(x, Action::Send { .. }))
        else {
            panic!("no response sent: {a:?}")
        };
        assert_eq!(*to, NodeId(0));
        assert_eq!(resp.kind(), "delegate_response");
        // 0 receives the response: record + payment.
        let a = n0.handle(
            Event::Message { from: NodeId(1), msg: resp.clone() },
            100.1,
        );
        let rec = a
            .iter()
            .find_map(|x| match x {
                Action::Done(r) => Some(r),
                _ => None,
            })
            .expect("completion record");
        assert_eq!(rec.executor, NodeId(1));
        assert_eq!(rec.kind, ExecKind::Delegated);
        let pay = SystemPolicy::default().base_reward;
        assert_eq!(shared.lock().unwrap().balance(NodeId(0)), bal0 - pay);
        assert_eq!(shared.lock().unwrap().balance(NodeId(1)), bal1 + pay);
    }

    #[test]
    fn probe_reject_falls_back_after_retries() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.system.max_probes = 2;
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);

        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let Action::Send { msg: Message::Probe { req_id, .. }, .. } = a[0]
        else {
            panic!()
        };
        // First reject -> re-probe (only node 1 is available, so again 1).
        let a = n0.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::ProbeReject { req_id },
            },
            0.1,
        );
        assert!(a.iter().any(
            |x| matches!(x, Action::Send { msg: Message::Probe { .. }, .. })
        ));
        // Second reject -> local fallback (probes exhausted).
        let a = n0.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::ProbeReject { req_id },
            },
            0.2,
        );
        assert!(a
            .iter()
            .all(|x| !matches!(x, Action::Send { msg: Message::Probe { .. }, .. })));
        assert_eq!(n0.backend().running_len(), 1);
        assert_eq!(n0.stats.fallback_local, 1);
    }

    #[test]
    fn probe_timeout_falls_back_locally() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert_eq!(n0.backend().running_len(), 0);
        // Silence until past PROBE_TIMEOUT.
        n0.handle(Event::Tick, PROBE_TIMEOUT + 0.5);
        assert_eq!(n0.backend().running_len(), 1);
    }

    #[test]
    fn locality_penalty_prefers_near_candidates() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        // Equal stakes: node 1 shares n0's region, node 2 is an ocean away.
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 50.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.set_locality(
            0,
            vec![vec![0.005, 0.100], vec![0.100, 0.005]],
            LatencyConfig::default(),
        );
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.view.merge(&[(NodeId(2), 1, true, 0, 1)], 0.0);

        let mut near = 0usize;
        let mut far = 0usize;
        for seq in 0..400u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            for act in &a {
                match act {
                    Action::Send { to, msg: Message::Probe { .. } } => {
                        if *to == NodeId(1) {
                            near += 1;
                        } else {
                            far += 1;
                        }
                    }
                    _ => {}
                }
            }
        }
        // Damping 1/(1+50*0.005)=0.8 vs 1/(1+50*0.1)=0.167: ~83% near.
        assert!(
            near > far * 2,
            "locality penalty ignored: near={near} far={far}"
        );
    }

    #[test]
    fn no_live_peer_is_explicit_local_execute() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 50.0,
                ..Default::default()
            },
            &shared,
        );
        n0.set_locality(
            0,
            vec![vec![0.005, 0.100], vec![0.100, 0.005]],
            LatencyConfig::default(),
        );
        // Locality active but zero live peers: the nearest-peer term is an
        // explicit None, not a 1e6 sentinel fed into the damping math.
        assert_eq!(
            n0.feed.nearest_peer_latency(&n0.view, n0.policy.latency_penalty, 0.0),
            None
        );
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert!(
            a.iter().all(|x| !matches!(x, Action::Send { .. })),
            "no-peer case must not probe: {a:?}"
        );
        assert_eq!(n0.backend().running_len(), 1, "must execute locally");
        assert_eq!(n0.stats.served_local, 1);
        // Flat/region-blind nodes keep the zero-latency fast path.
        let n_flat = mk_node(1, NodePolicy::default(), &shared);
        assert_eq!(
            n_flat
                .feed
                .nearest_peer_latency(&n_flat.view, n_flat.policy.latency_penalty, 0.0),
            Some(0.0)
        );
    }

    #[test]
    fn probe_replies_and_timeouts_feed_the_estimator() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.set_locality(
            0,
            vec![vec![0.005, 0.080], vec![0.080, 0.005]],
            LatencyConfig::default(),
        );
        // The only candidate lives in region 1.
        n0.view.merge(&[(NodeId(1), 1, true, 0, 1)], 0.0);
        let prior = n0.latency_estimator().unwrap().expected_from_me(1, 0.0);
        assert_eq!(prior, 0.080);
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let Action::Send { msg: Message::Probe { req_id, .. }, .. } = a[0]
        else {
            panic!("expected a probe, got {a:?}")
        };
        // The reject answers 0.4 s later: a measured RTT well above the
        // 80 ms prior must raise the estimate.
        n0.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::ProbeReject { req_id },
            },
            0.4,
        );
        let after_reply =
            n0.latency_estimator().unwrap().expected_from_me(1, 0.4);
        assert!(after_reply > prior, "RTT sample ignored: {after_reply}");
        // The retry probe (sent at 0.4) is never answered: the timeout
        // penalty must push the estimate far beyond anything measured.
        n0.handle(Event::Tick, 5.0);
        assert_eq!(n0.stats.probe_timeouts, 1);
        let after_timeout =
            n0.latency_estimator().unwrap().expected_from_me(1, 5.0);
        assert!(
            after_timeout > 0.3,
            "timeout penalty too weak: {after_timeout}"
        );
    }
}
