//! The duel-and-judge settlement layer (§4.2): duplicate execution,
//! first-answer-wins completion, PoS-sampled judge committees, and the
//! winner/loser/judge credit settlement.
//!
//! This is the coordinator-side orchestration around the mechanism
//! primitives in [`crate::duel`] (`DuelState`, quality draws, verdict
//! tallies). The origin-side pending slot lives in the dispatch layer, so
//! duel entry points receive its pending table explicitly — starting or
//! settling a duel is the one cross-layer handoff.

use std::collections::BTreeMap;

use super::ctx::Ctx;
use super::dispatch::{PendingDelegation, PendingState, RESPONSE_TIMEOUT_FACTOR};
use super::events::Action;
use super::msg::Message;
use crate::backend::Completion;
use crate::duel as duel_mech;
use crate::duel::DuelState;
use crate::ledger::{CreditOp, OpReason};
use crate::obs::SpanKind;
use crate::reputation::RepEvent;
use crate::types::{
    ExecKind, NodeId, Request, RequestId, RequestRecord, Response, Time,
};

/// Judge evaluation output length (short comparison verdicts).
const JUDGE_OUTPUT_TOKENS: u32 = 64;

/// Judge-side record for an in-flight evaluation.
#[derive(Debug, Clone)]
struct JudgeTask {
    duel_id: RequestId,
    origin: NodeId,
    resp_a: Response,
    resp_b: Response,
}

/// Origin-side duel states + judge-side evaluation tasks.
#[derive(Debug)]
pub(crate) struct DuelCourt {
    // Ordered maps (determinism contract, `docs/determinism.md`): nothing
    // iterates these today, but they sit on the settlement path and must
    // never grow a replay-order hazard.
    duels: BTreeMap<RequestId, DuelState>,
    judge_tasks: BTreeMap<RequestId, JudgeTask>,
    /// Synthetic request sequence (judge evals and other self-generated
    /// work carry our own origin with high seq numbers).
    synth_seq: u64,
}

impl Default for DuelCourt {
    fn default() -> Self {
        DuelCourt {
            duels: BTreeMap::new(),
            judge_tasks: BTreeMap::new(),
            synth_seq: 1 << 40,
        }
    }
}

impl DuelCourt {
    pub fn new() -> Self {
        Self::default()
    }

    /// Escalate a delegated request into a duel: two distinct executors,
    /// one pending slot awaiting both answers.
    pub fn start_duel(
        &mut self,
        ctx: &mut Ctx<'_>,
        pending: &mut BTreeMap<RequestId, PendingDelegation>,
        req: Request,
        now: Time,
    ) -> Vec<Action> {
        let execs = ctx.snaps.sample_distinct(ctx.rng, 2);
        if execs.len() < 2 {
            ctx.stats.fallback_local += 1;
            return ctx.execute_locally(req, ExecKind::Local, now);
        }
        ctx.stats.duels_started += 1;
        ctx.stats.delegated_out += 1;
        let duel = DuelState::new(req.clone(), [execs[0], execs[1]], now);
        pending.insert(
            req.id,
            PendingDelegation {
                req: req.clone(),
                state: PendingState::AwaitingDuel,
                deadline: now + req.slo_deadline * RESPONSE_TIMEOUT_FACTOR,
            },
        );
        self.duels.insert(req.id, duel);
        execs
            .into_iter()
            .map(|to| {
                // Duel copies ship straight to both executors (no probe).
                ctx.obs
                    .span(req.id, SpanKind::Delegate, ctx.id, Some(to), now, 1);
                Action::Send {
                    to,
                    msg: Message::Delegate { request: req.clone(), duel: true },
                }
            })
            .collect()
    }

    /// One duel executor answered: first answer completes the request for
    /// the user (and pays both executors); the second closes the duel and
    /// dispatches the judge committee.
    pub fn on_duel_response(
        &mut self,
        ctx: &mut Ctx<'_>,
        pending: &mut BTreeMap<RequestId, PendingDelegation>,
        response: Response,
        now: Time,
    ) -> Vec<Action> {
        let executor = response.executor;
        let (first, both_in, req, execs) = {
            let Some(d) = self.duels.get_mut(&response.id) else {
                return vec![];
            };
            let first = d.responses.is_empty() && !d.user_answered;
            let both_in = d.add_response(response.clone());
            if first {
                d.user_answered = true;
            }
            (first, both_in, d.request.clone(), d.executors)
        };
        let mut actions = Vec::new();

        if first {
            // The user takes the first answer; the duel settles afterwards.
            actions.push(Action::Done(RequestRecord {
                id: req.id,
                origin: ctx.id,
                executor,
                kind: ExecKind::Delegated,
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.output_tokens,
                submitted_at: req.submitted_at,
                completed_at: now,
                slo_deadline: req.slo_deadline,
                synthetic: req.synthetic,
                session: req.session,
                ttft_deadline: req.ttft_deadline,
                first_token_at: response.first_token_at,
            }));
            // Both executors get the base payment (both did the work).
            let ops = execs
                .iter()
                .map(|e| CreditOp::Transfer {
                    from: ctx.id,
                    to: *e,
                    amount: ctx.system.base_reward,
                    reason: OpReason::OffloadPayment(req.id),
                })
                .collect();
            actions.extend(ctx.ledger_submit(ops, now));
        } else {
            // The slower duel copy: synthetic overhead record (§7.1).
            actions.push(Action::Done(RequestRecord {
                id: req.id,
                origin: ctx.id,
                executor,
                kind: ExecKind::Duel,
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.output_tokens,
                submitted_at: req.submitted_at,
                completed_at: now,
                slo_deadline: req.slo_deadline,
                synthetic: true,
                session: req.session,
                ttft_deadline: req.ttft_deadline,
                first_token_at: response.first_token_at,
            }));
        }

        if both_in {
            actions.extend(self.dispatch_judges(ctx, pending, response.id, now));
        }
        actions
    }

    fn dispatch_judges(
        &mut self,
        ctx: &mut Ctx<'_>,
        pending: &mut BTreeMap<RequestId, PendingDelegation>,
        duel_id: RequestId,
        now: Time,
    ) -> Vec<Action> {
        ctx.refresh_snapshot(now);
        // Judges: PoS-sampled, excluding the two executors (impartiality).
        // Duels are rare, so cloning the cached snapshot for the exclusion
        // filter is fine; the per-request path never clones.
        let mut pool = ctx.snaps.clone_snapshot();
        let d = self.duels.get_mut(&duel_id).expect("duel exists");
        let execs = d.executors;
        pool.retain(|n| n != execs[0] && n != execs[1]);
        let judges = pool.sample_distinct(ctx.rng, ctx.system.judges);
        if judges.is_empty() {
            // No impartial judges available — settle as a wash (no
            // redistribution), keep the duel out of stats.
            self.duels.remove(&duel_id);
            pending.remove(&duel_id);
            return vec![];
        }
        d.assign_judges(judges.clone());
        let (a, b) = (d.responses[0].clone(), d.responses[1].clone());
        let est = d.request.output_tokens.saturating_mul(2).clamp(64, 8192);
        judges
            .into_iter()
            .map(|j| Action::Send {
                to: j,
                msg: Message::JudgeAssign {
                    duel_id,
                    resp_a: a.clone(),
                    resp_b: b.clone(),
                    est_tokens: est,
                },
            })
            .collect()
    }

    /// We were drafted as a judge: evaluating costs real compute, so a
    /// synthetic evaluation request goes on our own backend.
    #[allow(clippy::too_many_arguments)]
    pub fn on_judge_assign(
        &mut self,
        ctx: &mut Ctx<'_>,
        from: NodeId,
        duel_id: RequestId,
        resp_a: Response,
        resp_b: Response,
        est_tokens: u32,
        now: Time,
    ) -> Vec<Action> {
        ctx.stats.judge_evals += 1;
        // Judging costs real compute: enqueue a synthetic evaluation request
        // on our own backend (reading both answers + a short verdict).
        let seq = self.synth_seq;
        self.synth_seq += 1;
        let eval_req = Request {
            id: RequestId { origin: ctx.id, seq },
            prompt_tokens: est_tokens,
            output_tokens: JUDGE_OUTPUT_TOKENS,
            submitted_at: now,
            slo_deadline: f64::INFINITY,
            synthetic: true,
            payload: vec![],
            session: 0,
            ttft_deadline: f64::INFINITY,
        };
        self.judge_tasks.insert(
            eval_req.id,
            JudgeTask { duel_id, origin: from, resp_a, resp_b },
        );
        ctx.execute_locally(eval_req, ExecKind::Judge, now)
    }

    /// A judge's verdict arrived at the duel origin; on quorum, settle:
    /// winner reward, loser slash, judge rewards (§4.2).
    pub fn on_judge_verdict(
        &mut self,
        ctx: &mut Ctx<'_>,
        pending: &mut BTreeMap<RequestId, PendingDelegation>,
        from: NodeId,
        duel_id: RequestId,
        winner: NodeId,
        now: Time,
    ) -> Vec<Action> {
        let Some(d) = self.duels.get_mut(&duel_id) else {
            return vec![];
        };
        let Some(outcome) = d.add_verdict(from, winner) else {
            return vec![];
        };
        let judges = d.judges.clone();
        self.duels.remove(&duel_id);
        pending.remove(&duel_id);
        // Duel outcomes are first-hand quality evidence: the loser's
        // reputation takes a hit, the winner's recovers (see
        // `crate::reputation`).
        ctx.rep_event(outcome.loser, RepEvent::DuelLoss, now);
        ctx.rep_event(outcome.winner, RepEvent::DuelWin, now);
        ctx.obs.span(
            duel_id,
            SpanKind::DuelSettle,
            ctx.id,
            Some(outcome.winner),
            now,
            outcome.loser.0 as u64,
        );
        let mut ops = vec![
            CreditOp::Mint {
                to: outcome.winner,
                amount: ctx.system.duel_reward,
                reason: OpReason::DuelWin(duel_id),
            },
            CreditOp::Slash {
                from: outcome.loser,
                amount: ctx.system.duel_penalty,
                reason: OpReason::DuelLoss(duel_id),
            },
        ];
        for j in judges {
            ops.push(CreditOp::Mint {
                to: j,
                amount: ctx.system.judge_reward,
                reason: OpReason::JudgeReward(duel_id),
            });
        }
        let mut actions = ctx.ledger_submit(ops, now);
        actions.push(Action::DuelSettled(outcome));
        actions
    }

    /// Our judge evaluation finished on the backend: compare and report.
    pub fn on_judge_completion(
        &mut self,
        ctx: &mut Ctx<'_>,
        c: Completion,
    ) -> Vec<Action> {
        let Some(task) = self.judge_tasks.remove(&c.request.id) else {
            return vec![];
        };
        let winner =
            duel_mech::judge_compare(&task.resp_a, &task.resp_b, ctx.rng);
        vec![
            Action::Send {
                to: task.origin,
                msg: Message::JudgeVerdict { duel_id: task.duel_id, winner },
            },
            // Judge work is synthetic overhead (§7.1 accounting).
            Action::Done(RequestRecord {
                id: c.request.id,
                origin: ctx.id,
                executor: ctx.id,
                kind: ExecKind::Judge,
                prompt_tokens: c.request.prompt_tokens,
                output_tokens: c.request.output_tokens,
                submitted_at: c.request.submitted_at,
                completed_at: c.finished_at,
                slo_deadline: c.request.slo_deadline,
                synthetic: true,
                session: c.request.session,
                ttft_deadline: c.request.ttft_deadline,
                first_token_at: c.first_token_at,
            }),
        ]
    }

    /// The duel's pending slot timed out at the origin. If nobody answered
    /// the user yet, fall back locally; either way the duel is abandoned
    /// (no settlement) — a judge or executor died.
    pub fn on_duel_timeout(
        &mut self,
        ctx: &mut Ctx<'_>,
        id: RequestId,
        req: Request,
        now: Time,
    ) -> Vec<Action> {
        let d = self.duels.remove(&id);
        if let Some(d) = d {
            if !d.user_answered {
                // Neither executor answered: local fallback.
                ctx.stats.fallback_local += 1;
                return ctx.execute_locally(req, ExecKind::Local, now);
            }
        }
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::super::events::{Action, Event};
    use super::super::msg::Message;
    use super::super::node::testutil::{mk_node, user_req};
    use super::super::node::Node;
    use crate::gossip::{GossipConfig, PeerView};
    use crate::ledger::{Ledger, SharedLedger};
    use crate::policy::{NodePolicy, SystemPolicy};
    use crate::types::NodeId;
    use std::sync::{Arc, Mutex};

    #[test]
    fn duel_roundtrip_settles_credits() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut nodes: Vec<Node> = (0..5)
            .map(|i| {
                let mut n = mk_node(i, NodePolicy::default(), &shared);
                n.policy.accept_freq = 1.0;
                // The hand-rolled pump below advances time in 50 s jumps
                // with no gossip rounds, so disable heartbeat aging.
                n.view = PeerView::new(
                    NodeId(i),
                    GossipConfig { suspect_after: 1e12, ..Default::default() },
                    0.0,
                );
                n
            })
            .collect();
        // Node 0 always duels.
        nodes[0].system.duel_rate = 1.0;
        nodes[0].policy.target_utilization = 0.0;
        nodes[0].policy.offload_freq = 1.0;
        for i in 1..5u32 {
            nodes[0].view.merge(&[(NodeId(i), 1, true, 0, 0)], 0.0);
        }

        // Kick off: two Delegate{duel} sends.
        let a = nodes[0].handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let delegates: Vec<(NodeId, Message)> = a
            .iter()
            .filter_map(|x| match x {
                Action::Send { to, msg: m @ Message::Delegate { .. } } => {
                    Some((*to, m.clone()))
                }
                _ => None,
            })
            .collect();
        assert_eq!(delegates.len(), 2);

        // Pump the whole network until quiet (mini event loop).
        let mut inbox: Vec<(NodeId, NodeId, Message)> = delegates
            .iter()
            .map(|(to, m)| (*to, NodeId(0), m.clone()))
            .collect();
        let mut t = 1.0;
        let mut settled = None;
        let mut guard = 0;
        while !inbox.is_empty() && guard < 1000 {
            guard += 1;
            let (to, from, msg) = inbox.remove(0);
            let actions = nodes[to.0 as usize].handle(
                Event::Message { from, msg },
                t,
            );
            // Also run backends forward generously.
            t += 50.0;
            for (i, n) in nodes.iter_mut().enumerate() {
                for act in n.handle(Event::BackendWake, t) {
                    match act {
                        Action::Send { to, msg } => {
                            inbox.push((to, NodeId(i as u32), msg))
                        }
                        Action::DuelSettled(o) => settled = Some(o),
                        _ => {}
                    }
                }
            }
            for act in actions {
                match act {
                    Action::Send { to: t2, msg } => inbox.push((t2, to, msg)),
                    Action::DuelSettled(o) => settled = Some(o),
                    _ => {}
                }
            }
        }
        let outcome = settled.expect("duel settled");
        assert_ne!(outcome.winner, outcome.loser);
        // Winner got R_add minted on top of base pay; loser lost stake.
        let sys = SystemPolicy::default();
        let pol = NodePolicy::default();
        let (winner_total, loser_stake) = {
            let l = shared.lock().unwrap();
            (
                l.balance(outcome.winner) + l.stake(outcome.winner),
                l.stake(outcome.loser),
            )
        };
        assert_eq!(
            winner_total,
            sys.genesis_credits + sys.base_reward + sys.duel_reward
        );
        assert_eq!(loser_stake, pol.stake - sys.duel_penalty);
    }
}
