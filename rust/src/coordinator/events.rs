//! Events into and actions out of the sans-io node state machine.
//!
//! `Node::handle(Event, now) -> Vec<Action>` is the whole interface: the
//! deterministic simulator (`sim::World`) and the real-time TCP runner
//! (`net::tcp`) both drive nodes through it, so every line of coordination
//! logic is exercised identically under test and in deployment.

use super::msg::Message;
use crate::duel::DuelOutcome;
use crate::types::{NodeId, Request, RequestRecord, Time};

/// Everything that can happen to a node.
#[derive(Debug, Clone)]
pub enum Event {
    /// A local user submitted a request.
    UserRequest(Request),
    /// A peer sent us a message.
    Message { from: NodeId, msg: Message },
    /// Periodic pump (default 1 s): gossip round, timeout scan, backend
    /// progress collection.
    Tick,
    /// Wake-up at a predicted backend completion time.
    BackendWake,
    /// The provider takes this node offline (graceful: gossips a goodbye).
    Leave,
    /// The provider brings this node (back) online.
    Join,
}

/// Everything a node can ask its runner to do.
#[derive(Debug, Clone)]
pub enum Action {
    /// Deliver a message to a peer.
    Send { to: NodeId, msg: Message },
    /// A request finished from the user's perspective (origin side), or a
    /// synthetic duel/judge execution finished (executor side,
    /// `record.synthetic == true`).
    Done(RequestRecord),
    /// Ask to be woken with `BackendWake` at this time (runner keeps the
    /// earliest outstanding wake per node).
    WakeAt(Time),
    /// A duel settled at this originator (stats for Figure 6).
    DuelSettled(DuelOutcome),
}

impl Action {
    pub fn kind(&self) -> &'static str {
        match self {
            Action::Send { .. } => "send",
            Action::Done(_) => "done",
            Action::WakeAt(_) => "wake_at",
            Action::DuelSettled(_) => "duel_settled",
        }
    }
}
