//! The gossip driving layer: round cadence, delta vs. anti-entropy form
//! selection, suspicion probes, leave/join announcements, and the
//! incoming-gossip handlers — the coordinator-side driver around
//! [`crate::gossip::PeerView`].
//!
//! Latency-feed integration rides along: outgoing pushes are stamped so
//! pull replies measure live RTTs, and same-region RTT summaries are
//! piggybacked on deltas (see `latency_feed`).

use super::ctx::Ctx;
use super::events::Action;
use super::msg::Message;
use crate::gossip::{Digest, Heartbeats};
use crate::latency::RegionRtts;
use crate::obs::SpanKind;
use crate::types::{NodeId, Time};

/// Gossip round cadence state.
#[derive(Debug)]
pub(crate) struct GossipDriver {
    last_gossip: Time,
    /// Gossip rounds completed — drives the delta/anti-entropy cadence.
    gossip_round: u64,
}

impl GossipDriver {
    pub fn new(now: Time) -> Self {
        GossipDriver { last_gossip: now - 1e9, gossip_round: 0 }
    }

    /// The single gossip-broadcast path: one wave to `targets`, shared by
    /// the regular tick round, leave/join announcements and suspicion
    /// probes. `full` sends the complete digest (anti-entropy form, built
    /// once and cloned per target); otherwise each target gets its own
    /// delta, and empty exchanges are skipped entirely.
    pub fn send(
        &mut self,
        ctx: &mut Ctx<'_>,
        targets: &[NodeId],
        full: bool,
        now: Time,
    ) -> Vec<Action> {
        let mut out = Vec::with_capacity(targets.len());
        if full {
            if targets.is_empty() {
                return out;
            }
            let digest = ctx.view.digest();
            for t in targets {
                ctx.view.mark_synced(*t);
                ctx.feed.stamp_gossip_push(*t, now);
                out.push(Action::Send {
                    to: *t,
                    msg: Message::Gossip { digest: digest.clone() },
                });
            }
        } else {
            for t in targets {
                let (delta, heartbeats) = ctx.view.delta_for(*t, now);
                if delta.is_empty() && heartbeats.is_empty() {
                    continue;
                }
                let (rtts, rep) = delta_payload(ctx, *t, now);
                ctx.feed.stamp_gossip_push(*t, now);
                out.push(Action::Send {
                    to: *t,
                    msg: Message::GossipDelta { delta, heartbeats, rtts, rep },
                });
            }
        }
        out
    }

    /// Run a gossip round if one is due (§A.2): deltas on regular rounds,
    /// the full digest on the first and every `anti_entropy_every`-th
    /// round, and always for the suspicion probe (a heal must pull the
    /// whole view back in).
    ///
    /// Exception: a bootstrap-sealed view skips the round-one full digest
    /// — the seeded membership is common knowledge, and with every node
    /// ticking at the same instant the synchronized first round would put
    /// O(n²) digest rows in flight at once (gigabytes of transient
    /// allocation at 10k nodes) to ship zero new information. Unsealed
    /// views (the TCP runner, hand-built tests) keep the eager first
    /// exchange.
    pub fn tick(&mut self, ctx: &mut Ctx<'_>, now: Time) -> Vec<Action> {
        if now - self.last_gossip < ctx.view.config().interval {
            return vec![];
        }
        self.last_gossip = now;
        self.gossip_round += 1;
        ctx.obs.node_span(
            SpanKind::GossipRound,
            ctx.id,
            None,
            now,
            self.gossip_round,
        );
        ctx.view.heartbeat(now);
        let ae = ctx.view.config().anti_entropy_every;
        let full = (ae <= 1 || self.gossip_round % ae == 1)
            && !(self.gossip_round == 1 && ctx.view.bootstrap_sealed());
        let (regular, suspect) = ctx.view.pick_round_targets(ctx.rng, now);
        let mut actions = self.send(ctx, &regular, full, now);
        if let Some(s) = suspect {
            actions.extend(self.send(ctx, &[s], true, now));
        }
        actions
    }

    /// Incoming full digest (push half of an anti-entropy exchange):
    /// merge and answer with our full view.
    pub fn on_gossip(
        ctx: &mut Ctx<'_>,
        from: NodeId,
        digest: &Digest,
        now: Time,
    ) -> Vec<Action> {
        ctx.view.merge(digest, now);
        let reply = ctx.view.digest();
        ctx.view.mark_synced(from);
        vec![Action::Send {
            to: from,
            msg: Message::GossipReply { digest: reply },
        }]
    }

    /// Pull half of a full-digest push-pull we initiated: a measured
    /// gossip round trip for the estimator, then merge.
    pub fn on_gossip_reply(
        ctx: &mut Ctx<'_>,
        from: NodeId,
        digest: &Digest,
        now: Time,
    ) -> Vec<Action> {
        ctx.feed.observe_gossip_reply(ctx.obs, ctx.view, from, now);
        ctx.view.merge(digest, now);
        vec![]
    }

    /// Incoming delta push: merge (entries + heartbeats + piggybacked
    /// RTTs + reputation rows), then answer with our own delta minus
    /// whatever we just accepted from the initiator (no echo). An empty
    /// exchange is skipped — nothing to learn, no bytes burned.
    #[allow(clippy::too_many_arguments)]
    pub fn on_delta(
        ctx: &mut Ctx<'_>,
        from: NodeId,
        delta: &Digest,
        heartbeats: &Heartbeats,
        rtts: &RegionRtts,
        rep: &[(u32, u32)],
        now: Time,
    ) -> Vec<Action> {
        let cap = ctx.defense.hearsay_cap();
        ctx.feed.merge_rtts(rtts, now, cap, ctx.stats);
        ctx.ingest_rep_rows(rep, now);
        let mut fresh = ctx.view.merge(delta, now);
        fresh.extend(ctx.view.merge_heartbeats(heartbeats, now));
        fresh.sort_unstable();
        let (delta, heartbeats) =
            ctx.view.delta_for_excluding(from, now, &fresh);
        if delta.is_empty() && heartbeats.is_empty() {
            vec![]
        } else {
            let (rtts, rep) = delta_payload(ctx, from, now);
            vec![Action::Send {
                to: from,
                msg: Message::GossipDeltaReply { delta, heartbeats, rtts, rep },
            }]
        }
    }

    /// Pull half of a delta exchange we initiated.
    #[allow(clippy::too_many_arguments)]
    pub fn on_delta_reply(
        ctx: &mut Ctx<'_>,
        from: NodeId,
        delta: &Digest,
        heartbeats: &Heartbeats,
        rtts: &RegionRtts,
        rep: &[(u32, u32)],
        now: Time,
    ) -> Vec<Action> {
        ctx.feed.observe_gossip_reply(ctx.obs, ctx.view, from, now);
        let cap = ctx.defense.hearsay_cap();
        ctx.feed.merge_rtts(rtts, now, cap, ctx.stats);
        ctx.ingest_rep_rows(rep, now);
        ctx.view.merge(delta, now);
        ctx.view.merge_heartbeats(heartbeats, now);
        vec![]
    }

    /// Goodbye gossip so the network learns quickly (Fig. 5b) — always
    /// the full digest (our departure is membership news). The composition
    /// root flips `online` off before calling.
    pub fn on_leave(&mut self, ctx: &mut Ctx<'_>, now: Time) -> Vec<Action> {
        ctx.view.announce_leave(now);
        let peers = ctx.view.alive_peers(now);
        self.send(ctx, &peers, true, now)
    }

    /// (Re)join: heartbeat flips us back online in our own digest,
    /// bootstrap peers become contactable again, and the per-peer delta
    /// floors reset — after downtime we no longer know what peers saw.
    pub fn on_join(&mut self, ctx: &mut Ctx<'_>, now: Time) -> Vec<Action> {
        ctx.view.heartbeat(now);
        ctx.view.refresh(now);
        self.last_gossip = now;
        let targets = ctx.view.pick_targets(ctx.rng, now);
        self.send(ctx, &targets, true, now)
    }
}

/// Build the piggyback payload for a delta to `peer`: RTT summaries
/// (rate-limited, same-region) and reputation rows (defenses on), each run
/// through the participation policy's corruption hooks — honest policies
/// leave both untouched, a latency liar poisons the RTT rows, a colluder
/// slanders via the reputation rows. Both are empty (zero wire bytes)
/// for honest nodes with defenses off.
fn delta_payload(
    ctx: &mut Ctx<'_>,
    peer: NodeId,
    now: Time,
) -> (RegionRtts, Vec<(u32, u32)>) {
    let mut rtts = ctx.feed.rtts_for(ctx.view, peer, now);
    ctx.participation.corrupt_rtts(&mut rtts);
    let mut rep = match ctx.defense.rep_if_on() {
        Some(book) => book.rep_rows(now),
        None => Vec::new(),
    };
    ctx.participation.corrupt_rep(&mut rep);
    (rtts, rep)
}

#[cfg(test)]
mod tests {
    use super::super::events::{Action, Event};
    use super::super::msg::Message;
    use super::super::node::testutil::mk_node;
    use crate::latency::LatencyConfig;
    use crate::ledger::SharedLedger;
    use crate::policy::NodePolicy;
    use crate::types::NodeId;
    use std::sync::{Arc, Mutex};

    #[test]
    fn tick_gossip_uses_deltas_between_anti_entropy_rounds() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut a = mk_node(0, NodePolicy::default(), &shared);
        let mut b = mk_node(1, NodePolicy::default(), &shared);
        a.view.add_seed(NodeId(1), 0, 0, 0.0);
        b.view.add_seed(NodeId(0), 0, 0, 0.0);
        let gossip_kinds = |actions: &[Action]| -> Vec<&'static str> {
            actions
                .iter()
                .filter_map(|x| match x {
                    Action::Send { msg, .. } => Some(msg.kind()),
                    _ => None,
                })
                .collect()
        };
        // Round 1 bootstraps with the full digest (anti-entropy form)...
        let out = a.handle(Event::Tick, 1.0);
        assert_eq!(gossip_kinds(&out), vec!["gossip"]);
        // ...subsequent rounds ship deltas.
        let out = a.handle(Event::Tick, 2.0);
        assert_eq!(gossip_kinds(&out), vec!["gossip_delta"]);
        // The delta carries our heartbeat: the receiver keeps us alive
        // without ever seeing another full digest.
        let delta = out
            .iter()
            .find_map(|x| match x {
                Action::Send { msg: m @ Message::GossipDelta { .. }, .. } => {
                    Some(m.clone())
                }
                _ => None,
            })
            .expect("delta sent");
        b.handle(Event::Message { from: NodeId(0), msg: delta }, 2.1);
        assert!(b.view.is_alive(NodeId(0), 2.1));
    }

    #[test]
    fn sealed_bootstrap_skips_round_one_digest() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut a = mk_node(0, NodePolicy::default(), &shared);
        a.view.add_seed(NodeId(1), 0, 0, 0.0);
        a.view.seal_bootstrap();
        let gossip_kinds = |actions: &[Action]| -> Vec<&'static str> {
            actions
                .iter()
                .filter_map(|x| match x {
                    Action::Send { msg, .. } => Some(msg.kind()),
                    _ => None,
                })
                .collect()
        };
        // Seeded membership is common knowledge: round 1 must NOT ship the
        // O(n) full digest — only a delta carrying our fresh heartbeat.
        let out = a.handle(Event::Tick, 1.0);
        assert_eq!(gossip_kinds(&out), vec!["gossip_delta"]);
        // The periodic anti-entropy cadence is untouched: with the default
        // `anti_entropy_every = 32`, round 33 ships the full digest.
        for round in 2..=32u64 {
            let out = a.handle(Event::Tick, round as f64);
            assert!(
                !gossip_kinds(&out).contains(&"gossip"),
                "round {round} shipped a full digest"
            );
        }
        let out = a.handle(Event::Tick, 33.0);
        assert_eq!(gossip_kinds(&out), vec!["gossip"]);
    }

    #[test]
    fn leave_gossips_goodbye() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n = mk_node(0, NodePolicy::default(), &shared);
        n.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        let a = n.handle(Event::Leave, 1.0);
        assert!(a.iter().any(|x| matches!(
            x,
            Action::Send { to: NodeId(1), msg: Message::Gossip { .. } }
        )));
        // Our own digest must mark us offline.
        let e = n.view.entry(NodeId(0)).unwrap();
        assert!(!e.online);
    }

    #[test]
    fn gossip_deltas_piggyback_region_rtts_to_same_region_peers() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut a = mk_node(0, NodePolicy::default(), &shared);
        let mut b = mk_node(1, NodePolicy::default(), &shared);
        let prior = vec![vec![0.005, 0.080], vec![0.080, 0.005]];
        a.set_locality(0, prior.clone(), LatencyConfig::default());
        b.set_locality(0, prior, LatencyConfig::default());
        a.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        b.view.merge(&[(NodeId(0), 1, true, 0, 0)], 0.0);
        // a directly measured region 1 (say via probes).
        a.latency_estimator_mut().unwrap().observe_rtt(1, 2.0, 0.0);
        // Round 1 is the full-digest bootstrap; round 2 ships a delta with
        // the measured row piggybacked (same-region peer, first share).
        a.handle(Event::Tick, 1.0);
        let out = a.handle(Event::Tick, 2.0);
        let delta = out
            .iter()
            .find_map(|x| match x {
                Action::Send { msg: m @ Message::GossipDelta { .. }, .. } => {
                    Some(m.clone())
                }
                _ => None,
            })
            .expect("delta sent");
        let Message::GossipDelta { ref rtts, .. } = delta else {
            unreachable!()
        };
        assert!(
            !rtts.is_empty(),
            "same-region delta must carry RTT summaries"
        );
        // b merges the summary: its estimate moves off the prior with no
        // direct measurement of its own — regions without direct traffic
        // still converge.
        let before = b.latency_estimator().unwrap().expected_from_me(1, 2.1);
        b.handle(Event::Message { from: NodeId(0), msg: delta }, 2.1);
        let after = b.latency_estimator().unwrap().expected_from_me(1, 2.1);
        assert!(
            after > before,
            "piggybacked summary ignored: {before} -> {after}"
        );
    }
}
