//! RTT plumbing between the node's live traffic and its
//! [`LatencyEstimator`] — the coordinator-side half of `crate::latency`.
//!
//! The feed owns the estimator plus the attribution bookkeeping that turns
//! ambient traffic into clean samples:
//!
//! * probe→accept/reject round trips and delegation-response freshness
//!   touches ([`observe_peer_rtt`](LatencyFeed::observe_peer_rtt),
//!   [`touch_peer`](LatencyFeed::touch_peer));
//! * probe-timeout penalties, so a partitioned region is shed within a few
//!   timeouts — long before gossip liveness aging notices;
//! * gossip push→pull stamps with ambiguity protection
//!   ([`stamp_gossip_push`](LatencyFeed::stamp_gossip_push));
//! * rate-limited same-region RTT summaries piggybacked on gossip deltas
//!   ([`rtts_for`](LatencyFeed::rtts_for)).
//!
//! Region resolution goes through the gossip view's region tags; unknown
//! or garbage tags are never fed (and score conservatively at read time).

use std::collections::BTreeMap;

use super::dispatch::PROBE_TIMEOUT;
use super::node::NodeStats;
use crate::gossip::PeerView;
use crate::latency::{LatencyConfig, LatencyEstimator, RegionRtts};
use crate::obs::{FlightRecorder, SpanKind};
use crate::types::{NodeId, Time};

/// Hard ceiling on any gossip-borne RTT summary value, always enforced:
/// honest estimators never share anything near this (the probe-timeout
/// penalty tops out at a few seconds), so values above it are junk or
/// poison regardless of whether the defense layer is armed.
pub(crate) const ABSURD_RTT: f64 = 60.0;

/// Live per-region latency knowledge + the RTT attribution state.
/// `None` estimator = no locality information: dispatch stays region-blind
/// regardless of `latency_penalty`.
#[derive(Debug, Default)]
pub(crate) struct LatencyFeed {
    lat: Option<LatencyEstimator>,
    /// Bumped on every [`set_locality`](LatencyFeed::set_locality) — part
    /// of the snapshot-cache key.
    locality_epoch: u64,
    /// Gossip push send-times awaiting a pull reply, per peer. Only
    /// *unambiguous* exchanges are measured: a second push while one is
    /// still unanswered clears the stamp and skips measurement for that
    /// round, because a reply could then match either push.
    gossip_sent_at: BTreeMap<NodeId, Time>,
    /// Last time region-RTT summaries were piggybacked to each peer
    /// (`LatencyConfig::share_every` rate limit).
    rtts_sent_at: BTreeMap<NodeId, Time>,
}

impl LatencyFeed {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install the pristine inter-region latency matrix as the live
    /// estimator's cold-start prior. An empty matrix clears locality
    /// (region-blind dispatch). The caller (the composition root) also
    /// tags the gossip view with the region.
    pub fn set_locality(
        &mut self,
        region: u32,
        prior: Vec<Vec<f64>>,
        cfg: LatencyConfig,
    ) {
        self.lat = if prior.is_empty() {
            None
        } else {
            Some(LatencyEstimator::new(region, prior, cfg))
        };
        self.locality_epoch += 1;
    }

    pub fn estimator(&self) -> Option<&LatencyEstimator> {
        self.lat.as_ref()
    }

    pub fn estimator_mut(&mut self) -> Option<&mut LatencyEstimator> {
        self.lat.as_mut()
    }

    pub fn has_estimator(&self) -> bool {
        self.lat.is_some()
    }

    /// `(locality epoch, drift-quantized estimator version)` — the feed's
    /// contribution to the snapshot-cache key.
    pub fn cache_key(&self) -> (u64, u64) {
        (self.locality_epoch, self.lat.as_ref().map_or(0, |l| l.version()))
    }

    /// Live one-way latency estimate to `peer` per its gossiped region tag
    /// (0.0 when we have no locality information). Peers with no known
    /// region tag — or a garbage one — get the estimator's *conservative*
    /// estimate (worst own-row prior), never region 0's row: an unknown
    /// peer must not accidentally score as the best-connected one.
    pub fn expected_latency_to(
        &self,
        view: &PeerView,
        peer: NodeId,
        now: Time,
    ) -> f64 {
        let Some(est) = &self.lat else {
            return 0.0;
        };
        match view.region_of(peer) {
            Some(r) => est.expected_from_me(r, now),
            None => est.conservative(),
        }
    }

    /// Latency estimate to the nearest live peer — the `should_offload`
    /// locality term. `Some(0.0)` in flat worlds and for region-blind
    /// policies (no iteration, no RNG impact, no wasted hot-path scan);
    /// `None` when locality is active but **no live peer exists** — the
    /// caller must treat that as an explicit serve-locally case rather
    /// than feeding a sentinel into the offload damping math. Scans the
    /// view's online index in place — no per-request allocation.
    pub fn nearest_peer_latency(
        &self,
        view: &PeerView,
        latency_penalty: f64,
        now: Time,
    ) -> Option<f64> {
        if latency_penalty <= 0.0 || self.lat.is_none() {
            return Some(0.0);
        }
        view.online_peers()
            .iter()
            .copied()
            .filter(|p| view.is_alive(*p, now))
            .map(|p| self.expected_latency_to(view, p, now))
            .reduce(f64::min)
    }

    /// Feed a measured request→reply round trip with `peer` into the live
    /// estimator (no-op without locality information or when the peer's
    /// region is unknown). Every accepted sample leaves an `rtt_observed`
    /// span (detail = RTT in µs) in the node's flight recorder.
    pub fn observe_peer_rtt(
        &mut self,
        obs: &mut FlightRecorder,
        view: &PeerView,
        peer: NodeId,
        rtt: Time,
        now: Time,
    ) {
        let Some(region) = view.region_of(peer) else {
            return;
        };
        if let Some(est) = self.lat.as_mut() {
            obs.node_span(
                SpanKind::RttObserved,
                view.me,
                Some(peer),
                now,
                (rtt * 1e6) as u64,
            );
            est.observe_rtt(region, rtt, now);
        }
    }

    /// A probe deadline expired: the candidate — or the path to it — is
    /// dead or drastically slow. Feed the timeout floor as a penalty
    /// observation so dispatch sheds the region within a few timeouts.
    pub fn observe_probe_timeout(
        &mut self,
        obs: &mut FlightRecorder,
        view: &PeerView,
        candidate: NodeId,
        now: Time,
    ) {
        let Some(region) = view.region_of(candidate) else {
            return;
        };
        if let Some(est) = self.lat.as_mut() {
            obs.node_span(
                SpanKind::RttObserved,
                view.me,
                Some(candidate),
                now,
                (PROBE_TIMEOUT * 1e6) as u64,
            );
            est.observe_timeout(region, PROBE_TIMEOUT, now);
        }
    }

    /// Evidence that the path to `peer`'s region is alive without a clean
    /// latency sample (delegation responses mix network and compute time).
    pub fn touch_peer(&mut self, view: &PeerView, peer: NodeId, now: Time) {
        let Some(region) = view.region_of(peer) else {
            return;
        };
        if let Some(est) = self.lat.as_mut() {
            est.touch(region, now);
        }
    }

    /// Stamp an outgoing gossip push so the pull reply measures a live
    /// RTT — but only when no earlier push to this peer is still
    /// unanswered. If one is, a future reply could match either push, so
    /// the stamp is cleared and this round goes unmeasured; the next
    /// uncontended push re-arms it. Gossip targets rotate, so consecutive
    /// pushes to the same peer are the exception and most exchanges stay
    /// measurable.
    pub fn stamp_gossip_push(&mut self, peer: NodeId, now: Time) {
        match self.gossip_sent_at.entry(peer) {
            std::collections::btree_map::Entry::Occupied(e) => {
                e.remove(); // ambiguous attribution: skip this round
            }
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(now);
            }
        }
    }

    /// Match an incoming gossip pull reply against its push stamp and feed
    /// the estimator. Samples slower than [`PROBE_TIMEOUT`] are discarded:
    /// paths that slow are the probe-timeout penalty's job, and a stamp
    /// that old may predate a partition heal.
    pub fn observe_gossip_reply(
        &mut self,
        obs: &mut FlightRecorder,
        view: &PeerView,
        peer: NodeId,
        now: Time,
    ) {
        if let Some(t0) = self.gossip_sent_at.remove(&peer) {
            let rtt = (now - t0).max(0.0);
            if rtt <= PROBE_TIMEOUT {
                self.observe_peer_rtt(obs, view, peer, rtt, now);
            }
        }
    }

    /// Merge region-RTT summaries a peer piggybacked on its gossip.
    ///
    /// Two layers of protection against gossip-borne poison:
    ///
    /// * **Junk guard** (always on): NaN, negative, and absurd
    ///   (> [`ABSURD_RTT`]) values are dropped outright, bumping
    ///   `stats.rtts_rejected` — they never reach the EWMA.
    /// * **Hearsay cap** (defenses on, `hearsay_cap` finite): a surviving
    ///   value may not land more than a bounded factor away from our *own*
    ///   current estimate for that cell — it is clamped into
    ///   `[own / cap, own * cap]`, bumping `stats.rtts_capped`. A latency
    ///   liar can therefore nudge an estimator cell, never teleport it.
    ///
    /// When every row is clean and uncapped (the honest steady state) the
    /// summaries merge exactly as they always did — no allocation, no
    /// behavioural drift on replays.
    pub fn merge_rtts(
        &mut self,
        rtts: &RegionRtts,
        now: Time,
        hearsay_cap: f64,
        stats: &mut NodeStats,
    ) {
        let Some(est) = self.lat.as_mut() else {
            return;
        };
        let junk = |v: f64| !v.is_finite() || v < 0.0 || v > ABSURD_RTT;
        let needs_work = rtts.iter().any(|&(a, b, v)| {
            junk(v)
                || (hearsay_cap.is_finite() && {
                    let own = est.expected(a, b, now);
                    v > own * hearsay_cap || v < own / hearsay_cap
                })
        });
        if !needs_work {
            est.merge(rtts, now);
            return;
        }
        let mut clean = Vec::with_capacity(rtts.len());
        for &(a, b, v) in rtts {
            if junk(v) {
                stats.rtts_rejected += 1;
                continue;
            }
            let mut val = v;
            if hearsay_cap.is_finite() {
                let own = est.expected(a, b, now);
                let (lo, hi) = (own / hearsay_cap, own * hearsay_cap);
                if val < lo || val > hi {
                    stats.rtts_capped += 1;
                    val = val.clamp(lo, hi);
                }
            }
            clean.push((a, b, val));
        }
        est.merge(&clean, now);
    }

    /// Region-RTT summaries to piggyback on a gossip delta to `peer`:
    /// same-region peers only (they share our vantage point), rate-limited
    /// to one summary per `LatencyConfig::share_every` seconds per peer so
    /// the byte overhead stays negligible at fleet scale.
    pub fn rtts_for(
        &mut self,
        view: &PeerView,
        peer: NodeId,
        now: Time,
    ) -> RegionRtts {
        let Some(est) = &self.lat else {
            return Vec::new();
        };
        if view.region_of(peer) != Some(est.my_region()) {
            return Vec::new();
        }
        let due = self
            .rtts_sent_at
            .get(&peer)
            .is_none_or(|t| now - *t >= est.config().share_every);
        if !due {
            return Vec::new();
        }
        let rtts = est.share(now);
        if !rtts.is_empty() {
            self.rtts_sent_at.insert(peer, now);
        }
        rtts
    }
}

#[cfg(test)]
mod tests {
    use super::super::node::testutil::mk_node;
    use crate::ledger::SharedLedger;
    use crate::latency::LatencyConfig;
    use crate::obs::FlightRecorder;
    use crate::policy::NodePolicy;
    use crate::types::NodeId;
    use std::sync::{Arc, Mutex};

    #[test]
    fn unknown_region_peer_scores_conservative_latency() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n0 = mk_node(0, NodePolicy::default(), &shared);
        n0.set_locality(
            0,
            vec![vec![0.005, 0.100], vec![0.100, 0.005]],
            LatencyConfig::default(),
        );
        // Known near peer in our own region.
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        // Peer gossiping a garbage region tag (outside the matrix).
        n0.view.merge(&[(NodeId(2), 1, true, 0, 9)], 0.0);
        let lat = |n: &super::super::node::Node, p: u32| {
            n.feed.expected_latency_to(&n.view, NodeId(p), 0.0)
        };
        assert_eq!(lat(&n0, 1), 0.005);
        // Garbage tags and wholly unknown peers both get the worst own-row
        // prior — never region 0's best-row latency.
        assert_eq!(lat(&n0, 2), 0.100);
        assert_eq!(lat(&n0, 77), 0.100);
    }

    #[test]
    fn ambiguous_gossip_push_skips_measurement() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n0 = mk_node(0, NodePolicy::default(), &shared);
        n0.set_locality(
            0,
            vec![vec![0.005, 0.080], vec![0.080, 0.005]],
            LatencyConfig::default(),
        );
        n0.view.merge(&[(NodeId(1), 1, true, 0, 1)], 0.0);
        let prior = n0.feed.expected_latency_to(&n0.view, NodeId(1), 0.0);
        // Two pushes without an intervening reply: the stamp is cleared,
        // so the (late, slow-looking) reply must not move the estimate.
        let mut obs = FlightRecorder::disabled();
        n0.feed.stamp_gossip_push(NodeId(1), 0.0);
        n0.feed.stamp_gossip_push(NodeId(1), 1.0);
        let view = n0.view.clone();
        n0.feed.observe_gossip_reply(&mut obs, &view, NodeId(1), 2.5);
        let after = n0.feed.expected_latency_to(&n0.view, NodeId(1), 2.5);
        assert_eq!(after, prior, "ambiguous exchange fed the estimator");
        // A fresh uncontended push re-arms measurement.
        n0.feed.stamp_gossip_push(NodeId(1), 3.0);
        n0.feed.observe_gossip_reply(&mut obs, &view, NodeId(1), 4.0);
        let measured = n0.feed.expected_latency_to(&n0.view, NodeId(1), 4.0);
        assert!(measured > prior, "clean exchange ignored: {measured}");
    }
}
