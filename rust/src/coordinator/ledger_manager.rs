//! Ledger Manager (Figure 2): the node's gateway to the credit system.
//!
//! Two modes behind one API:
//!
//! * **Shared** — an `Arc<Mutex<SharedLedger>>` shared by all nodes (the
//!   paper's Appendix-C deployment choice). `submit` applies immediately;
//!   no messages are produced.
//! * **Chain** — a full per-node Credit Block Chain replica. `submit`
//!   enqueues the op batch; batches are proposed one at a time as signed
//!   blocks, broadcast for votes, and committed at quorum. Conflicting
//!   heads (two proposers racing) resolve by re-proposing on the new head.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::events::Action;
use super::msg::Message;
use crate::crypto::{Hash256, KeyStore, NodeKey};
use crate::ledger::{Block, Chain, CreditOp, Ledger, SharedLedger};
use crate::types::{Credits, NodeId, Time};

/// Blockchain-mode replica state.
#[derive(Debug)]
pub struct ChainReplica {
    pub chain: Chain,
    key: NodeKey,
    keys: KeyStore,
    /// Votes needed to commit (incl. the proposer's implicit vote).
    quorum: usize,
    /// Batches waiting to be proposed (one in flight at a time).
    queue: VecDeque<Vec<CreditOp>>,
    /// The block we currently have in flight (id + its op batch, kept so a
    /// head race can re-propose the same ops on the new head).
    in_flight: Option<(Hash256, Vec<CreditOp>)>,
    /// Answer anchored [`Message::ChainRequest`]s with just the missing
    /// suffix ([`Message::ChainDelta`]) instead of a full snapshot. On by
    /// default; `false` reproduces the seed's full-replica shipping — the
    /// baseline the fleet-scale bench compares sync bytes against.
    pub delta_sync: bool,
}

/// The manager.
pub enum LedgerManager {
    Shared(Arc<Mutex<SharedLedger>>),
    Chain(Box<ChainReplica>),
}

impl LedgerManager {
    pub fn shared(ledger: Arc<Mutex<SharedLedger>>) -> Self {
        LedgerManager::Shared(ledger)
    }

    pub fn chain(key: NodeKey, keys: KeyStore, quorum: usize) -> Self {
        LedgerManager::Chain(Box::new(ChainReplica {
            chain: Chain::new(),
            key,
            keys,
            quorum: quorum.max(1),
            queue: VecDeque::new(),
            in_flight: None,
            delta_sync: true,
        }))
    }

    pub fn is_chain(&self) -> bool {
        matches!(self, LedgerManager::Chain(_))
    }

    // ---- read API ---------------------------------------------------------

    pub fn balance(&self, node: NodeId) -> Credits {
        match self {
            LedgerManager::Shared(l) => l.lock().unwrap().balance(node),
            LedgerManager::Chain(r) => r.chain.balance(node),
        }
    }

    pub fn stake(&self, node: NodeId) -> Credits {
        match self {
            LedgerManager::Shared(l) => l.lock().unwrap().stake(node),
            LedgerManager::Chain(r) => r.chain.stake(node),
        }
    }

    pub fn stakes(&self) -> Vec<(NodeId, Credits)> {
        match self {
            LedgerManager::Shared(l) => l.lock().unwrap().stakes(),
            LedgerManager::Chain(r) => r.chain.balances().stakes(),
        }
    }

    /// Monotonic version of the *stake table* this manager reads: changes
    /// whenever `stakes()` could return something new. Shared mode counts
    /// stake-touching batches (including other nodes' — the ledger is
    /// shared), so payment traffic leaves caches warm; chain mode counts
    /// committed blocks (coarser, but blocks are the only thing that moves
    /// replica balances). Cache-staleness key for stake snapshots.
    pub fn stake_version(&self) -> u64 {
        match self {
            LedgerManager::Shared(l) => l.lock().unwrap().stake_version(),
            LedgerManager::Chain(r) => r.chain.len() as u64,
        }
    }

    // ---- write API --------------------------------------------------------

    /// Submit an op batch. Shared mode applies now (errors are swallowed
    /// after a balance check by the caller — see Node::try_pay); chain mode
    /// queues a block proposal and may emit broadcast actions.
    pub fn submit(
        &mut self,
        ops: Vec<CreditOp>,
        me: NodeId,
        peers: &[NodeId],
        now: Time,
    ) -> Vec<Action> {
        if ops.is_empty() {
            return vec![];
        }
        match self {
            LedgerManager::Shared(l) => {
                // Validation failure = drop: the coordinator checks
                // affordability before submitting, so this only fires when a
                // concurrent spend raced us; the op batch is then void.
                let _ = l.lock().unwrap().submit(ops, me, now);
                vec![]
            }
            LedgerManager::Chain(r) => {
                r.queue.push_back(ops);
                r.try_propose(now, peers)
            }
        }
    }

    /// Handle a ledger-related message. Returns follow-up actions.
    pub fn on_message(
        &mut self,
        from: NodeId,
        msg: &Message,
        me: NodeId,
        peers: &[NodeId],
        now: Time,
    ) -> Vec<Action> {
        let LedgerManager::Chain(r) = self else {
            return vec![];
        };
        match msg {
            Message::BlockProposal { block } => {
                let ok = r.chain.validate(block, &r.keys).is_ok();
                if ok {
                    r.chain.track_pending(block.clone());
                }
                vec![Action::Send {
                    to: from,
                    msg: Message::BlockVote { block_id: block.id, accept: ok },
                }]
            }
            Message::BlockVote { block_id, accept } => {
                let in_flight_id = r.in_flight.as_ref().map(|(id, _)| *id);
                if in_flight_id != Some(*block_id) {
                    return vec![];
                }
                if !accept {
                    // A reject means our parent is stale (someone else's
                    // block landed first). Abandon and re-propose the same
                    // ops on the new head.
                    let (_, ops) = r.in_flight.take().expect("checked");
                    r.queue.push_front(ops);
                    return r.try_propose(now, peers);
                }
                let votes = match r.chain.vote(*block_id, from) {
                    Ok(v) => v,
                    Err(_) => return vec![],
                };
                // +1: our own implicit vote as proposer.
                if votes + 1 >= r.quorum {
                    let Some(block) = r.chain.commit_and_get(*block_id, &r.keys)
                    else {
                        return vec![];
                    };
                    r.in_flight = None;
                    let mut actions: Vec<Action> = peers
                        .iter()
                        .map(|p| Action::Send {
                            to: *p,
                            msg: Message::BlockCommit { block: block.clone() },
                        })
                        .collect();
                    actions.extend(r.try_propose(now, peers));
                    actions
                } else {
                    vec![]
                }
            }
            Message::ChainRequest { len, head } => {
                if (r.chain.len() as u64) <= *len {
                    return vec![];
                }
                // Delta path: the requester's chain is a strict prefix of
                // ours (its head sits at height len-1 of our chain) — ship
                // only the missing suffix. Anything else (empty requester,
                // divergent history, knob off) falls back to the full
                // snapshot, which adopt_if_longer re-audits from genesis.
                let anchored = r.delta_sync
                    && *len > 0
                    && r.chain.block_id_at(*len - 1) == Some(*head);
                let msg = if anchored {
                    Message::ChainDelta {
                        from_height: *len,
                        anchor: *head,
                        blocks: r.chain.blocks()[*len as usize..].to_vec(),
                    }
                } else {
                    Message::ChainSnapshot {
                        blocks: r.chain.blocks().to_vec(),
                    }
                };
                vec![Action::Send { to: from, msg }]
            }
            Message::ChainSnapshot { blocks } => {
                if r.chain.adopt_if_longer(blocks, &r.keys) {
                    // Anything we had in flight is now on a stale head.
                    if let Some((_, ops)) = r.in_flight.take() {
                        r.queue.push_front(ops);
                        return r.try_propose(now, peers);
                    }
                }
                vec![]
            }
            Message::ChainDelta { from_height, anchor, blocks } => {
                if r.chain.try_extend(*from_height, *anchor, blocks, &r.keys) {
                    // Same head-race handling as a snapshot adoption.
                    if let Some((_, ops)) = r.in_flight.take() {
                        r.queue.push_front(ops);
                        return r.try_propose(now, peers);
                    }
                    vec![]
                } else if *from_height + blocks.len() as u64
                    > r.chain.len() as u64
                {
                    // Our chain moved between request and reply (a commit
                    // landed), so the suffix no longer anchors — but the
                    // sender is still ahead. Re-request once; the snapshot
                    // fallback resolves any genuine divergence.
                    vec![Action::Send {
                        to: from,
                        msg: Message::ChainRequest {
                            len: r.chain.len() as u64,
                            head: r.chain.head(),
                        },
                    }]
                } else {
                    vec![]
                }
            }
            Message::BlockCommit { block } => {
                let _ = r.chain.commit_block(block.clone(), &r.keys);
                let _ = me;
                // Our own in-flight proposal (if any) now sits on a stale
                // head: abandon it and re-propose its ops on the new head.
                if let Some((_, ops)) = r.in_flight.take() {
                    r.queue.push_front(ops);
                    return r.try_propose(now, peers);
                }
                vec![]
            }
            _ => vec![],
        }
    }

    /// Re-propose on tick if a proposal stalled (e.g. lost a head race),
    /// and run one anti-entropy probe so stale replicas catch up.
    pub fn on_tick(&mut self, peers: &[NodeId], now: Time) -> Vec<Action> {
        let LedgerManager::Chain(r) = self else {
            return vec![];
        };
        let mut actions = if r.in_flight.is_none() {
            r.try_propose(now, peers)
        } else {
            vec![]
        };
        // Anti-entropy: announce our length to a rotating peer.
        if !peers.is_empty() {
            let target = peers[(now as usize) % peers.len()];
            actions.push(Action::Send {
                to: target,
                msg: Message::ChainRequest {
                    len: r.chain.len() as u64,
                    head: r.chain.head(),
                },
            });
        }
        actions
    }
}

impl ChainReplica {
    /// Propose the next queued batch if nothing is in flight.
    fn try_propose(&mut self, now: Time, peers: &[NodeId]) -> Vec<Action> {
        if self.in_flight.is_some() {
            return vec![];
        }
        let Some(ops) = self.queue.pop_front() else {
            return vec![];
        };
        let block =
            Block::create(self.chain.head(), now, ops.clone(), &self.key);
        // Validate against our own replica (ops may have become invalid).
        if self.chain.validate(&block, &self.keys).is_err() {
            // Drop the batch: it can no longer apply (e.g. stake drained).
            return self.try_propose(now, peers);
        }
        self.chain.track_pending(block.clone());
        self.in_flight = Some((block.id, ops));
        if peers.is_empty() {
            // Single-node network: self-commit immediately.
            let _ = self.chain.commit(block.id, &self.keys);
            self.in_flight = None;
            return self.try_propose(now, peers);
        }
        peers
            .iter()
            .map(|p| Action::Send {
                to: *p,
                msg: Message::BlockProposal { block: block.clone() },
            })
            .collect()
    }
}

impl Chain {
    /// Commit a pending block and return it (helper for vote handling).
    fn commit_and_get(&mut self, id: Hash256, keys: &KeyStore) -> Option<Block> {
        let block = self
            .blocks()
            .iter()
            .find(|b| b.id == id)
            .cloned()
            .or_else(|| self.pending_block(&id));
        let block = block?;
        if self.blocks().iter().any(|b| b.id == id) {
            return Some(block);
        }
        self.commit(id, keys).ok()?;
        Some(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::OpReason;

    fn mint(to: u32, amount: Credits) -> CreditOp {
        CreditOp::Mint {
            to: NodeId(to),
            amount,
            reason: OpReason::Genesis,
        }
    }

    #[test]
    fn shared_mode_applies_immediately() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut m = LedgerManager::shared(shared.clone());
        let actions = m.submit(vec![mint(0, 50)], NodeId(0), &[], 0.0);
        assert!(actions.is_empty());
        assert_eq!(m.balance(NodeId(0)), 50);
        assert_eq!(shared.lock().unwrap().balance(NodeId(0)), 50);
    }

    #[test]
    fn chain_mode_single_node_self_commits() {
        let key = NodeKey::derive(1, NodeId(0));
        let keys = KeyStore::for_network(1, 1);
        let mut m = LedgerManager::chain(key, keys, 1);
        let actions = m.submit(vec![mint(0, 50)], NodeId(0), &[], 0.0);
        assert!(actions.is_empty());
        assert_eq!(m.balance(NodeId(0)), 50);
    }

    #[test]
    fn chain_mode_propose_vote_commit_roundtrip() {
        let keys = KeyStore::for_network(1, 3);
        let mut proposer =
            LedgerManager::chain(NodeKey::derive(1, NodeId(0)), keys.clone(), 2);
        let mut voter =
            LedgerManager::chain(NodeKey::derive(1, NodeId(1)), keys.clone(), 2);
        let peers = [NodeId(1), NodeId(2)];

        // Proposer broadcasts.
        let actions = proposer.submit(vec![mint(0, 50)], NodeId(0), &peers, 0.0);
        assert_eq!(actions.len(), 2);
        let Action::Send { msg: proposal, .. } = &actions[0] else {
            panic!("expected send")
        };

        // Voter validates + votes accept.
        let votes = voter.on_message(NodeId(0), proposal, NodeId(1), &[], 0.1);
        assert_eq!(votes.len(), 1);
        let Action::Send { msg: vote, to } = &votes[0] else { panic!() };
        assert_eq!(*to, NodeId(0));

        // Proposer reaches quorum (1 vote + self = 2) and broadcasts commit.
        let commits = proposer.on_message(NodeId(1), vote, NodeId(0), &peers, 0.2);
        assert_eq!(commits.len(), 2);
        assert_eq!(proposer.balance(NodeId(0)), 50);

        // Voter applies the commit.
        let Action::Send { msg: commit, .. } = &commits[0] else { panic!() };
        voter.on_message(NodeId(0), commit, NodeId(1), &[], 0.3);
        assert_eq!(voter.balance(NodeId(0)), 50);
    }

    #[test]
    fn chain_mode_rejects_invalid_proposal() {
        let keys = KeyStore::for_network(1, 2);
        let mut voter =
            LedgerManager::chain(NodeKey::derive(1, NodeId(1)), keys, 2);
        // A transfer with no funds behind it.
        let bad_key = NodeKey::derive(1, NodeId(0));
        let block = Block::create(
            Hash256::ZERO,
            0.0,
            vec![CreditOp::Transfer {
                from: NodeId(0),
                to: NodeId(1),
                amount: 100,
                reason: OpReason::PolicyAdjust,
            }],
            &bad_key,
        );
        let actions = voter.on_message(
            NodeId(0),
            &Message::BlockProposal { block },
            NodeId(1),
            &[],
            0.0,
        );
        let Action::Send { msg: Message::BlockVote { accept, .. }, .. } =
            &actions[0]
        else {
            panic!()
        };
        assert!(!accept);
    }

    fn chain_of(m: &LedgerManager) -> &Chain {
        match m {
            LedgerManager::Chain(r) => &r.chain,
            LedgerManager::Shared(_) => panic!("chain mode expected"),
        }
    }

    /// Build a 3-block single-node chain and a replica holding only its
    /// first block.
    fn ahead_and_behind() -> (LedgerManager, LedgerManager, KeyStore) {
        let keys = KeyStore::for_network(1, 2);
        let mut ahead =
            LedgerManager::chain(NodeKey::derive(1, NodeId(0)), keys.clone(), 1);
        ahead.submit(vec![mint(0, 10)], NodeId(0), &[], 0.0);
        ahead.submit(vec![mint(0, 20)], NodeId(0), &[], 0.1);
        ahead.submit(vec![mint(1, 30)], NodeId(0), &[], 0.2);
        assert_eq!(chain_of(&ahead).len(), 3);
        let mut behind =
            LedgerManager::chain(NodeKey::derive(1, NodeId(1)), keys.clone(), 1);
        let first = chain_of(&ahead).blocks()[0].clone();
        let LedgerManager::Chain(r) = &mut behind else { unreachable!() };
        r.chain.commit_block(first, &keys).unwrap();
        (ahead, behind, keys)
    }

    #[test]
    fn anchored_request_gets_delta_and_converges() {
        let (mut ahead, mut behind, _) = ahead_and_behind();
        let req = Message::ChainRequest {
            len: 1,
            head: chain_of(&behind).head(),
        };
        let acts = ahead.on_message(NodeId(1), &req, NodeId(0), &[], 1.0);
        let Action::Send { msg, to } = &acts[0] else { panic!() };
        assert_eq!(*to, NodeId(1));
        let Message::ChainDelta { from_height, blocks, .. } = msg else {
            panic!("expected chain_delta, got {}", msg.kind())
        };
        assert_eq!(*from_height, 1);
        assert_eq!(blocks.len(), 2, "only the missing suffix travels");
        let full = Message::ChainSnapshot {
            blocks: chain_of(&ahead).blocks().to_vec(),
        };
        assert!(msg.wire_size() < full.wire_size());
        // Applying the delta converges to the full replica's state.
        behind.on_message(NodeId(0), msg, NodeId(1), &[], 1.1);
        assert_eq!(chain_of(&behind).head(), chain_of(&ahead).head());
        assert_eq!(behind.balance(NodeId(0)), ahead.balance(NodeId(0)));
        assert_eq!(behind.balance(NodeId(1)), ahead.balance(NodeId(1)));
    }

    #[test]
    fn unanchored_or_disabled_requests_fall_back_to_snapshot() {
        // Divergent head: the requester claims a height-1 head that is not
        // block 0 of the responder's chain.
        let (mut ahead, _, _) = ahead_and_behind();
        let req = Message::ChainRequest { len: 1, head: Hash256::ZERO };
        let acts = ahead.on_message(NodeId(1), &req, NodeId(0), &[], 1.0);
        let Action::Send { msg, .. } = &acts[0] else { panic!() };
        assert!(
            matches!(msg, Message::ChainSnapshot { .. }),
            "divergent history must fall back to the full snapshot"
        );
        // Empty requester: nothing to anchor, full snapshot.
        let req0 = Message::ChainRequest { len: 0, head: Hash256::ZERO };
        let acts = ahead.on_message(NodeId(1), &req0, NodeId(0), &[], 1.0);
        let Action::Send { msg, .. } = &acts[0] else { panic!() };
        assert!(matches!(msg, Message::ChainSnapshot { .. }));
        // Knob off: anchored requests get snapshots too (seed behaviour).
        let (mut ahead, behind, _) = ahead_and_behind();
        if let LedgerManager::Chain(r) = &mut ahead {
            r.delta_sync = false;
        }
        let req = Message::ChainRequest {
            len: 1,
            head: chain_of(&behind).head(),
        };
        let acts = ahead.on_message(NodeId(1), &req, NodeId(0), &[], 1.0);
        let Action::Send { msg, .. } = &acts[0] else { panic!() };
        assert!(matches!(msg, Message::ChainSnapshot { .. }));
    }

    #[test]
    fn stale_delta_triggers_one_rerequest() {
        let (mut ahead, mut behind, keys) = ahead_and_behind();
        let req = Message::ChainRequest {
            len: 1,
            head: chain_of(&behind).head(),
        };
        let acts = ahead.on_message(NodeId(1), &req, NodeId(0), &[], 1.0);
        let Action::Send { msg: delta, .. } = &acts[0] else { panic!() };
        // Before the delta arrives, the behind replica commits a different
        // block — the suffix no longer anchors.
        let fork = Block::create(
            chain_of(&behind).head(),
            0.5,
            vec![mint(1, 5)],
            &NodeKey::derive(1, NodeId(1)),
        );
        let LedgerManager::Chain(r) = &mut behind else { unreachable!() };
        r.chain.commit_block(fork, &keys).unwrap();
        let len_before = chain_of(&behind).len();
        let acts = behind.on_message(NodeId(0), delta, NodeId(1), &[], 1.1);
        assert_eq!(chain_of(&behind).len(), len_before, "nothing adopted");
        let Action::Send { msg, to } = &acts[0] else {
            panic!("expected a re-request")
        };
        assert_eq!(*to, NodeId(0));
        let Message::ChainRequest { len, head } = msg else { panic!() };
        assert_eq!(*len as usize, len_before);
        assert_eq!(*head, chain_of(&behind).head());
        // The responder now sees divergence and ships the full snapshot,
        // which wins by length and re-audits from genesis.
        let acts = ahead.on_message(NodeId(1), msg, NodeId(0), &[], 1.2);
        let Action::Send { msg: snap, .. } = &acts[0] else { panic!() };
        assert!(matches!(snap, Message::ChainSnapshot { .. }));
        behind.on_message(NodeId(0), snap, NodeId(1), &[], 1.3);
        assert_eq!(chain_of(&behind).head(), chain_of(&ahead).head());
    }

    #[test]
    fn queued_batches_propose_serially() {
        let keys = KeyStore::for_network(1, 2);
        let mut m =
            LedgerManager::chain(NodeKey::derive(1, NodeId(0)), keys, 2);
        let peers = [NodeId(1)];
        let a1 = m.submit(vec![mint(0, 10)], NodeId(0), &peers, 0.0);
        assert_eq!(a1.len(), 1); // first proposal broadcast
        let a2 = m.submit(vec![mint(0, 20)], NodeId(0), &peers, 0.1);
        assert!(a2.is_empty()); // queued behind the in-flight block
    }
}
