//! Layer-3 coordinator — the paper's system contribution.
//!
//! * [`node::Node`] — the five-manager node of Figure 2 as a sans-io state
//!   machine (`handle(Event, now) -> Vec<Action>`).
//! * [`msg::Message`] — the inter-node wire vocabulary (+ JSON codec).
//! * [`events`] — the Event/Action interface between nodes and runners.
//! * [`ledger_manager`] — shared-vs-blockchain credit ledger access.

pub mod events;
pub mod ledger_manager;
pub mod msg;
pub mod node;

pub use events::{Action, Event};
pub use ledger_manager::LedgerManager;
pub use msg::Message;
pub use node::{Node, NodeStats};
