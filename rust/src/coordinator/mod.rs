//! Layer-3 coordinator — the paper's system contribution, as a layered
//! pipeline of focused submodules around a thin composition root.
//!
//! * [`node::Node`] — the composition root: owns the state, routes
//!   `Event`s through the layers (`handle(Event, now) -> Vec<Action>`).
//! * [`dispatch`] — admission + probe/delegate/fallback state machine,
//!   decisions delegated to the pluggable `ParticipationPolicy`.
//! * [`duel`] — duel + judge settlement.
//! * [`gossip_driver`] — gossip cadence, delta/anti-entropy, leave/join.
//! * [`latency_feed`] — RTT plumbing into the live latency estimator.
//! * [`snapshot`] — cached, policy-scored stake snapshots for dispatch.
//! * [`ctx`] — the per-activation borrow bundle + memoized alive-peer view.
//! * [`msg::Message`] — the inter-node wire vocabulary (+ JSON codec).
//! * [`events`] — the Event/Action interface between nodes and runners.
//! * [`ledger_manager`] — shared-vs-blockchain credit ledger access.

mod ctx;
mod dispatch;
mod duel;
pub mod events;
mod gossip_driver;
mod latency_feed;
pub mod ledger_manager;
pub mod msg;
pub mod node;
mod snapshot;

pub use events::{Action, Event};
pub use ledger_manager::LedgerManager;
pub use msg::Message;
pub use node::{Node, NodeStats};
