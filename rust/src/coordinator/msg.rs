//! Wire messages between WWW.Serve nodes.
//!
//! The simulator passes these by value; the TCP transport serializes them as
//! JSON frames (`to_json` / `from_json` below — the paper uses ZeroMQ ROUTER
//! with the same request/response vocabulary).

use crate::crypto::{Hash256, Receipt, Signature};
use crate::gossip::{Digest, Heartbeats};
use crate::latency::RegionRtts;
use crate::ledger::Block;
use crate::reputation::RepRows;
use crate::types::{NodeId, Request, RequestId, Response};
use crate::util::json::Json;

/// Everything one node can say to another.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// "Would you take this request?" — executor-selection trust probe.
    Probe {
        req_id: RequestId,
        prompt_tokens: u32,
        output_tokens: u32,
    },
    ProbeAccept { req_id: RequestId },
    ProbeReject { req_id: RequestId },
    /// Forward a request for remote execution. `duel` marks duel copies.
    Delegate { request: Request, duel: bool },
    /// Streaming re-dispatch: a session turn delegated to a node that is
    /// not the session's KV home, shipping the resident KV cache along
    /// with the work. Semantically a [`Message::Delegate`] whose wire cost
    /// includes `kv_bytes` — the network fabric prices the transfer over
    /// `Topology` bandwidth as a real queue event, which is exactly the
    /// re-dispatch penalty KV-affine dispatch exists to avoid. Counted in
    /// `World::kv_transfer_{count,bytes}`.
    KvTransfer {
        request: Request,
        session: u64,
        kv_bytes: u64,
    },
    /// Executor-side churn NACK: a leaving executor aborts its in-flight
    /// delegations so requesters fall back locally at once instead of
    /// waiting out the response timeout (and filing a Byzantine-grade
    /// `RepEvent::Timeout` strike for an honest crash). Gated on
    /// `streaming.churn_nack`.
    ExecAbort { req_id: RequestId },
    /// The executor's answer travelling back to the originator. `receipt`
    /// is the executor's signed work receipt (`crate::crypto::Receipt`);
    /// it is `None` unless the defense layer is enabled, so the wire cost
    /// of the receipt is zero when defenses are off.
    DelegateResponse {
        response: Response,
        duel: bool,
        receipt: Option<Receipt>,
    },
    /// Push half of a full-digest gossip round (anti-entropy fallback,
    /// leave/join announcements, suspicion probes).
    Gossip { digest: Digest },
    /// Pull half (the receiver's full view coming back).
    GossipReply { digest: Digest },
    /// Push half of a regular delta round: full rows only for entries whose
    /// membership content changed since the last exchange with this peer,
    /// compact `(node, version)` pairs for plain heartbeat advances, and
    /// (rate-limited, same-region peers only) piggybacked region-latency
    /// summaries for the live RTT estimator (`crate::latency`). `rep`
    /// piggybacks reputation opinions (`crate::reputation`) — `(node,
    /// milli-score)` rows for peers the sender distrusts; empty (zero wire
    /// cost) unless the defense layer is enabled.
    GossipDelta {
        delta: Digest,
        heartbeats: Heartbeats,
        rtts: RegionRtts,
        rep: RepRows,
    },
    /// Pull half of a delta round (the receiver's delta coming back).
    GossipDeltaReply {
        delta: Digest,
        heartbeats: Heartbeats,
        rtts: RegionRtts,
        rep: RepRows,
    },
    /// Ask the two duel responses to be compared. `est_tokens` sizes the
    /// judge's own evaluation workload (reading both answers).
    JudgeAssign {
        duel_id: RequestId,
        resp_a: Response,
        resp_b: Response,
        est_tokens: u32,
    },
    /// A judge's vote.
    JudgeVerdict {
        duel_id: RequestId,
        winner: NodeId,
    },
    /// Blockchain-ledger mode: propose a block for confirmation.
    BlockProposal { block: Block },
    /// Blockchain-ledger mode: confirm a proposed block.
    BlockVote {
        block_id: crate::crypto::Hash256,
        accept: bool,
    },
    /// Blockchain-ledger mode: a quorum was reached; append.
    BlockCommit { block: Block },
    /// Blockchain-ledger mode anti-entropy: "my chain has `len` blocks and
    /// its head is `head`". The head hash lets a longer responder ship just
    /// the missing suffix ([`Message::ChainDelta`]) when the requester's
    /// chain is a prefix of its own; `Hash256::ZERO` for an empty chain.
    ChainRequest { len: u64, head: Hash256 },
    /// Blockchain-ledger mode anti-entropy: a full replica snapshot — the
    /// fallback when the requester's head does not anchor into the
    /// responder's chain (divergent history), and the correctness oracle
    /// the delta path is tested against (`rust/tests/chain_delta.rs`).
    ChainSnapshot { blocks: Vec<Block> },
    /// Blockchain-ledger mode anti-entropy: the suffix of the responder's
    /// chain starting at the requester's height. `anchor` echoes the
    /// requester's head; the receiver appends only if its chain still ends
    /// there (otherwise it re-requests and the snapshot fallback repairs).
    ChainDelta {
        from_height: u64,
        anchor: Hash256,
        blocks: Vec<Block>,
    },
}

impl Message {
    /// Short tag for logs/metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Probe { .. } => "probe",
            Message::ProbeAccept { .. } => "probe_accept",
            Message::ProbeReject { .. } => "probe_reject",
            Message::Delegate { .. } => "delegate",
            Message::KvTransfer { .. } => "kv_transfer",
            Message::ExecAbort { .. } => "exec_abort",
            Message::DelegateResponse { .. } => "delegate_response",
            Message::Gossip { .. } => "gossip",
            Message::GossipReply { .. } => "gossip_reply",
            Message::GossipDelta { .. } => "gossip_delta",
            Message::GossipDeltaReply { .. } => "gossip_delta_reply",
            Message::JudgeAssign { .. } => "judge_assign",
            Message::JudgeVerdict { .. } => "judge_verdict",
            Message::BlockProposal { .. } => "block_proposal",
            Message::BlockVote { .. } => "block_vote",
            Message::BlockCommit { .. } => "block_commit",
            Message::ChainRequest { .. } => "chain_request",
            Message::ChainSnapshot { .. } => "chain_snapshot",
            Message::ChainDelta { .. } => "chain_delta",
        }
    }

    /// Rough wire size in bytes (sim network accounting; requests/responses
    /// dominated by token payloads).
    pub fn wire_size(&self) -> usize {
        match self {
            Message::Delegate { request, .. } => {
                64 + request.payload.len() * 4 + request.prompt_tokens as usize
            }
            Message::KvTransfer { request, kv_bytes, .. } => {
                // A delegate plus the session's KV cache on the wire.
                64 + request.payload.len() * 4
                    + request.prompt_tokens as usize
                    + *kv_bytes as usize
            }
            Message::DelegateResponse { response, receipt, .. } => {
                // A receipt is two ids + two timestamps + a 32-byte digest
                // + a 32-byte signature; absent receipts cost nothing.
                64 + response.tokens.len() * 4
                    + if receipt.is_some() { 104 } else { 0 }
            }
            Message::JudgeAssign { resp_a, resp_b, .. } => {
                64 + (resp_a.tokens.len() + resp_b.tokens.len()) * 4
            }
            Message::Gossip { digest } | Message::GossipReply { digest } => {
                16 + digest.len() * 32
            }
            Message::GossipDelta { delta, heartbeats, rtts, rep }
            | Message::GossipDeltaReply { delta, heartbeats, rtts, rep } => {
                // A full row costs what a digest entry costs; a heartbeat
                // refresh is just (node id, version); a region-RTT summary
                // entry is (region, region, f64); a reputation row is
                // (node id, milli-score).
                16 + delta.len() * 32
                    + heartbeats.len() * 12
                    + rtts.len() * 16
                    + rep.len() * 8
            }
            Message::BlockProposal { block } | Message::BlockCommit { block } => {
                128 + block.ops.len() * 48
            }
            Message::ChainSnapshot { blocks } => {
                blocks.iter().map(|b| 128 + b.ops.len() * 48).sum::<usize>()
            }
            Message::ChainDelta { blocks, .. } => {
                // Height + anchor hash framing, then the same per-block cost
                // a snapshot pays — the saving is shipping only the suffix.
                48 + blocks.iter().map(|b| 128 + b.ops.len() * 48).sum::<usize>()
            }
            _ => 48,
        }
    }
}

// ---------------------------------------------------------------------------
// JSON wire codec (TCP transport; subset — ledger messages travel only in
// blockchain mode which the e2e example does not enable over TCP).
// ---------------------------------------------------------------------------

fn req_id_json(id: &RequestId) -> Json {
    Json::obj(vec![
        ("origin", Json::num(id.origin.0 as f64)),
        ("seq", Json::num(id.seq as f64)),
    ])
}

fn req_id_from(j: &Json) -> Option<RequestId> {
    Some(RequestId {
        origin: NodeId(j.get("origin").as_u64()? as u32),
        seq: j.get("seq").as_u64()?,
    })
}

fn request_json(r: &Request) -> Json {
    Json::obj(vec![
        ("id", req_id_json(&r.id)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        ("output_tokens", Json::num(r.output_tokens as f64)),
        ("submitted_at", Json::num(r.submitted_at)),
        ("slo_deadline", Json::num(r.slo_deadline)),
        ("synthetic", Json::Bool(r.synthetic)),
        (
            "payload",
            Json::Arr(r.payload.iter().map(|t| Json::num(*t as f64)).collect()),
        ),
        ("session", Json::num(r.session as f64)),
        // An infinite (absent) TTFT budget travels as null — JSON has no
        // infinity literal.
        (
            "ttft_deadline",
            if r.ttft_deadline.is_finite() {
                Json::num(r.ttft_deadline)
            } else {
                Json::Null
            },
        ),
    ])
}

fn request_from(j: &Json) -> Option<Request> {
    Some(Request {
        id: req_id_from(j.get("id"))?,
        prompt_tokens: j.get("prompt_tokens").as_u64()? as u32,
        output_tokens: j.get("output_tokens").as_u64()? as u32,
        submitted_at: j.get("submitted_at").as_f64()?,
        slo_deadline: j.get("slo_deadline").as_f64()?,
        synthetic: j.get("synthetic").as_bool()?,
        payload: j
            .get("payload")
            .as_arr()?
            .iter()
            .map(|t| t.as_u64().map(|v| v as u32))
            .collect::<Option<Vec<u32>>>()?,
        // Pre-streaming frames omit these; default to standalone.
        session: j.get("session").as_u64().unwrap_or(0),
        ttft_deadline: j
            .get("ttft_deadline")
            .as_f64()
            .unwrap_or(f64::INFINITY),
    })
}

fn response_json(r: &Response) -> Json {
    Json::obj(vec![
        ("id", req_id_json(&r.id)),
        ("executor", Json::num(r.executor.0 as f64)),
        ("quality", Json::num(r.quality)),
        ("finished_at", Json::num(r.finished_at)),
        (
            "first_token_at",
            r.first_token_at.map_or(Json::Null, Json::num),
        ),
        (
            "tokens",
            Json::Arr(r.tokens.iter().map(|t| Json::num(*t as f64)).collect()),
        ),
    ])
}

fn response_from(j: &Json) -> Option<Response> {
    Some(Response {
        id: req_id_from(j.get("id"))?,
        executor: NodeId(j.get("executor").as_u64()? as u32),
        quality: j.get("quality").as_f64()?,
        finished_at: j.get("finished_at").as_f64()?,
        // Absent/null on pre-streaming frames.
        first_token_at: j.get("first_token_at").as_f64(),
        tokens: j
            .get("tokens")
            .as_arr()?
            .iter()
            .map(|t| t.as_u64().map(|v| v as u32))
            .collect::<Option<Vec<u32>>>()?,
    })
}

fn digest_json(d: &[(NodeId, u64, bool, u64, u32)]) -> Json {
    Json::Arr(
        d.iter()
            .map(|(n, v, online, ep, region)| {
                Json::Arr(vec![
                    Json::num(n.0 as f64),
                    Json::num(*v as f64),
                    Json::Bool(*online),
                    Json::num(*ep as f64),
                    Json::num(*region as f64),
                ])
            })
            .collect(),
    )
}

fn digest_from(j: &Json) -> Option<Digest> {
    j.as_arr()?
        .iter()
        .map(|e| {
            let a = e.as_arr()?;
            Some((
                NodeId(a.first()?.as_u64()? as u32),
                a.get(1)?.as_u64()?,
                a.get(2)?.as_bool()?,
                a.get(3)?.as_u64()?,
                a.get(4)?.as_u64()? as u32,
            ))
        })
        .collect()
}

fn heartbeats_json(h: &[(NodeId, u64)]) -> Json {
    Json::Arr(
        h.iter()
            .map(|(n, v)| {
                Json::Arr(vec![Json::num(n.0 as f64), Json::num(*v as f64)])
            })
            .collect(),
    )
}

fn heartbeats_from(j: &Json) -> Option<Heartbeats> {
    j.as_arr()?
        .iter()
        .map(|e| {
            let a = e.as_arr()?;
            Some((NodeId(a.first()?.as_u64()? as u32), a.get(1)?.as_u64()?))
        })
        .collect()
}

fn rtts_json(r: &[(u32, u32, f64)]) -> Json {
    Json::Arr(
        r.iter()
            .map(|(a, b, est)| {
                Json::Arr(vec![
                    Json::num(*a as f64),
                    Json::num(*b as f64),
                    Json::num(*est),
                ])
            })
            .collect(),
    )
}

fn rtts_from(j: &Json) -> Option<RegionRtts> {
    if j.is_null() {
        // Absent summaries are valid (rate-limited piggyback).
        return Some(Vec::new());
    }
    j.as_arr()?
        .iter()
        .map(|e| {
            let a = e.as_arr()?;
            Some((
                a.first()?.as_u64()? as u32,
                a.get(1)?.as_u64()? as u32,
                a.get(2)?.as_f64()?,
            ))
        })
        .collect()
}

fn rep_json(r: &[(u32, u32)]) -> Json {
    Json::Arr(
        r.iter()
            .map(|(n, milli)| {
                Json::Arr(vec![Json::num(*n as f64), Json::num(*milli as f64)])
            })
            .collect(),
    )
}

fn rep_from(j: &Json) -> Option<RepRows> {
    if j.is_null() {
        // Absent rows are valid (defenses off, or nothing to report).
        return Some(Vec::new());
    }
    j.as_arr()?
        .iter()
        .map(|e| {
            let a = e.as_arr()?;
            Some((a.first()?.as_u64()? as u32, a.get(1)?.as_u64()? as u32))
        })
        .collect()
}

fn bytes32_json(b: &[u8; 32]) -> Json {
    Json::Arr(b.iter().map(|v| Json::num(*v as f64)).collect())
}

fn bytes32_from(j: &Json) -> Option<[u8; 32]> {
    let arr = j.as_arr()?;
    if arr.len() != 32 {
        return None;
    }
    let mut out = [0u8; 32];
    for (slot, v) in out.iter_mut().zip(arr) {
        let n = v.as_u64()?;
        if n > 255 {
            return None;
        }
        *slot = n as u8;
    }
    Some(out)
}

fn receipt_json(r: &Receipt) -> Json {
    Json::obj(vec![
        ("request", req_id_json(&r.request)),
        ("executor", Json::num(r.executor.0 as f64)),
        ("requester", Json::num(r.requester.0 as f64)),
        ("submitted_at", Json::num(r.submitted_at)),
        ("finished_at", Json::num(r.finished_at)),
        ("response_digest", bytes32_json(&r.response_digest.0)),
        ("sig", bytes32_json(&r.sig.0)),
    ])
}

/// `None` receipts travel as a `null` / absent key; the outer `Option` is
/// the parse result, the inner one the decoded field.
fn receipt_from(j: &Json) -> Option<Option<Receipt>> {
    if j.is_null() {
        return Some(None);
    }
    Some(Some(Receipt {
        request: req_id_from(j.get("request"))?,
        executor: NodeId(j.get("executor").as_u64()? as u32),
        requester: NodeId(j.get("requester").as_u64()? as u32),
        submitted_at: j.get("submitted_at").as_f64()?,
        finished_at: j.get("finished_at").as_f64()?,
        response_digest: Hash256(bytes32_from(j.get("response_digest"))?),
        sig: Signature(bytes32_from(j.get("sig"))?),
    }))
}

impl Message {
    pub fn to_json(&self) -> Json {
        match self {
            Message::Probe { req_id, prompt_tokens, output_tokens } => {
                Json::obj(vec![
                    ("type", Json::str("probe")),
                    ("req_id", req_id_json(req_id)),
                    ("prompt_tokens", Json::num(*prompt_tokens as f64)),
                    ("output_tokens", Json::num(*output_tokens as f64)),
                ])
            }
            Message::ProbeAccept { req_id } => Json::obj(vec![
                ("type", Json::str("probe_accept")),
                ("req_id", req_id_json(req_id)),
            ]),
            Message::ProbeReject { req_id } => Json::obj(vec![
                ("type", Json::str("probe_reject")),
                ("req_id", req_id_json(req_id)),
            ]),
            Message::Delegate { request, duel } => Json::obj(vec![
                ("type", Json::str("delegate")),
                ("request", request_json(request)),
                ("duel", Json::Bool(*duel)),
            ]),
            Message::KvTransfer { request, session, kv_bytes } => {
                Json::obj(vec![
                    ("type", Json::str("kv_transfer")),
                    ("request", request_json(request)),
                    ("session", Json::num(*session as f64)),
                    ("kv_bytes", Json::num(*kv_bytes as f64)),
                ])
            }
            Message::ExecAbort { req_id } => Json::obj(vec![
                ("type", Json::str("exec_abort")),
                ("req_id", req_id_json(req_id)),
            ]),
            Message::DelegateResponse { response, duel, receipt } => {
                Json::obj(vec![
                    ("type", Json::str("delegate_response")),
                    ("response", response_json(response)),
                    ("duel", Json::Bool(*duel)),
                    (
                        "receipt",
                        receipt
                            .as_ref()
                            .map_or(Json::Null, receipt_json),
                    ),
                ])
            }
            Message::Gossip { digest } => Json::obj(vec![
                ("type", Json::str("gossip")),
                ("digest", digest_json(digest)),
            ]),
            Message::GossipReply { digest } => Json::obj(vec![
                ("type", Json::str("gossip_reply")),
                ("digest", digest_json(digest)),
            ]),
            Message::GossipDelta { delta, heartbeats, rtts, rep } => {
                Json::obj(vec![
                    ("type", Json::str("gossip_delta")),
                    ("delta", digest_json(delta)),
                    ("heartbeats", heartbeats_json(heartbeats)),
                    ("rtts", rtts_json(rtts)),
                    ("rep", rep_json(rep)),
                ])
            }
            Message::GossipDeltaReply { delta, heartbeats, rtts, rep } => {
                Json::obj(vec![
                    ("type", Json::str("gossip_delta_reply")),
                    ("delta", digest_json(delta)),
                    ("heartbeats", heartbeats_json(heartbeats)),
                    ("rtts", rtts_json(rtts)),
                    ("rep", rep_json(rep)),
                ])
            }
            Message::JudgeAssign { duel_id, resp_a, resp_b, est_tokens } => {
                Json::obj(vec![
                    ("type", Json::str("judge_assign")),
                    ("duel_id", req_id_json(duel_id)),
                    ("resp_a", response_json(resp_a)),
                    ("resp_b", response_json(resp_b)),
                    ("est_tokens", Json::num(*est_tokens as f64)),
                ])
            }
            Message::JudgeVerdict { duel_id, winner } => Json::obj(vec![
                ("type", Json::str("judge_verdict")),
                ("duel_id", req_id_json(duel_id)),
                ("winner", Json::num(winner.0 as f64)),
            ]),
            // Ledger messages are sim-only in this build (DESIGN.md §8).
            Message::BlockProposal { .. }
            | Message::BlockVote { .. }
            | Message::BlockCommit { .. }
            | Message::ChainRequest { .. }
            | Message::ChainSnapshot { .. }
            | Message::ChainDelta { .. } => Json::obj(vec![(
                "type",
                Json::str("ledger_unsupported_on_wire"),
            )]),
        }
    }

    pub fn from_json(j: &Json) -> Option<Message> {
        match j.get("type").as_str()? {
            "probe" => Some(Message::Probe {
                req_id: req_id_from(j.get("req_id"))?,
                prompt_tokens: j.get("prompt_tokens").as_u64()? as u32,
                output_tokens: j.get("output_tokens").as_u64()? as u32,
            }),
            "probe_accept" => Some(Message::ProbeAccept {
                req_id: req_id_from(j.get("req_id"))?,
            }),
            "probe_reject" => Some(Message::ProbeReject {
                req_id: req_id_from(j.get("req_id"))?,
            }),
            "delegate" => Some(Message::Delegate {
                request: request_from(j.get("request"))?,
                duel: j.get("duel").as_bool()?,
            }),
            "kv_transfer" => Some(Message::KvTransfer {
                request: request_from(j.get("request"))?,
                session: j.get("session").as_u64()?,
                kv_bytes: j.get("kv_bytes").as_u64()?,
            }),
            "exec_abort" => Some(Message::ExecAbort {
                req_id: req_id_from(j.get("req_id"))?,
            }),
            "delegate_response" => Some(Message::DelegateResponse {
                response: response_from(j.get("response"))?,
                duel: j.get("duel").as_bool()?,
                receipt: receipt_from(j.get("receipt"))?,
            }),
            "gossip" => Some(Message::Gossip {
                digest: digest_from(j.get("digest"))?,
            }),
            "gossip_reply" => Some(Message::GossipReply {
                digest: digest_from(j.get("digest"))?,
            }),
            "gossip_delta" => Some(Message::GossipDelta {
                delta: digest_from(j.get("delta"))?,
                heartbeats: heartbeats_from(j.get("heartbeats"))?,
                rtts: rtts_from(j.get("rtts"))?,
                rep: rep_from(j.get("rep"))?,
            }),
            "gossip_delta_reply" => Some(Message::GossipDeltaReply {
                delta: digest_from(j.get("delta"))?,
                heartbeats: heartbeats_from(j.get("heartbeats"))?,
                rtts: rtts_from(j.get("rtts"))?,
                rep: rep_from(j.get("rep"))?,
            }),
            "judge_assign" => Some(Message::JudgeAssign {
                duel_id: req_id_from(j.get("duel_id"))?,
                resp_a: response_from(j.get("resp_a"))?,
                resp_b: response_from(j.get("resp_b"))?,
                est_tokens: j.get("est_tokens").as_u64()? as u32,
            }),
            "judge_verdict" => Some(Message::JudgeVerdict {
                duel_id: req_id_from(j.get("duel_id"))?,
                winner: NodeId(j.get("winner").as_u64()? as u32),
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            id: RequestId { origin: NodeId(1), seq: 42 },
            prompt_tokens: 100,
            output_tokens: 300,
            submitted_at: 1.5,
            slo_deadline: 60.0,
            synthetic: false,
            payload: vec![1, 2, 3],
            session: 0,
            ttft_deadline: f64::INFINITY,
        }
    }

    fn session_req() -> Request {
        Request { session: 7, ttft_deadline: 2.5, ..req() }
    }

    fn resp() -> Response {
        Response {
            id: RequestId { origin: NodeId(1), seq: 42 },
            executor: NodeId(2),
            quality: 0.77,
            finished_at: 9.25,
            first_token_at: None,
            tokens: vec![5, 6],
        }
    }

    fn signed_receipt() -> Receipt {
        let key = crate::crypto::NodeKey::derive(7, NodeId(2));
        let r = resp();
        Receipt::sign(
            &key,
            r.id,
            NodeId(1),
            1.5,
            r.finished_at,
            crate::crypto::response_digest(&r),
        )
    }

    #[test]
    fn wire_roundtrip_all_variants() {
        let msgs = vec![
            Message::Probe {
                req_id: req().id,
                prompt_tokens: 10,
                output_tokens: 20,
            },
            Message::ProbeAccept { req_id: req().id },
            Message::ProbeReject { req_id: req().id },
            Message::Delegate { request: req(), duel: true },
            Message::Delegate { request: session_req(), duel: false },
            Message::KvTransfer {
                request: session_req(),
                session: 7,
                kv_bytes: 64_000_000,
            },
            Message::ExecAbort { req_id: req().id },
            Message::DelegateResponse {
                response: resp(),
                duel: false,
                receipt: None,
            },
            Message::DelegateResponse {
                response: Response { first_token_at: Some(3.5), ..resp() },
                duel: false,
                receipt: None,
            },
            Message::DelegateResponse {
                response: resp(),
                duel: false,
                receipt: Some(signed_receipt()),
            },
            Message::Gossip { digest: vec![(NodeId(1), 4, true, 99, 2)] },
            Message::GossipReply { digest: vec![] },
            Message::GossipDelta {
                delta: vec![(NodeId(3), 7, false, 12, 1)],
                heartbeats: vec![(NodeId(4), 9), (NodeId(5), 2)],
                rtts: vec![(0, 1, 0.5), (0, 2, 1.25)],
                rep: vec![(6, 400), (7, 0)],
            },
            Message::GossipDeltaReply {
                delta: vec![],
                heartbeats: vec![],
                rtts: vec![],
                rep: vec![],
            },
            Message::JudgeAssign {
                duel_id: req().id,
                resp_a: resp(),
                resp_b: resp(),
                est_tokens: 600,
            },
            Message::JudgeVerdict { duel_id: req().id, winner: NodeId(2) },
        ];
        for m in msgs {
            let text = m.to_json().to_string();
            let parsed = Message::from_json(&Json::parse(&text).unwrap())
                .unwrap_or_else(|| panic!("roundtrip failed for {}", m.kind()));
            assert_eq!(parsed, m);
        }
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(Message::from_json(&Json::parse(r#"{"type":"nope"}"#).unwrap())
            .is_none());
        assert!(Message::from_json(&Json::parse(r#"{}"#).unwrap()).is_none());
        assert!(Message::from_json(
            &Json::parse(r#"{"type":"probe","req_id":{}}"#).unwrap()
        )
        .is_none());
    }

    #[test]
    fn wire_size_scales_with_payload() {
        let small = Message::ProbeAccept { req_id: req().id };
        let big = Message::Delegate { request: req(), duel: false };
        assert!(big.wire_size() > small.wire_size());
    }

    #[test]
    fn delta_wire_size_reflects_savings() {
        let full = Message::Gossip {
            digest: (0..50u32).map(|i| (NodeId(i), 1, true, 0, 0)).collect(),
        };
        // A steady-state delta: one membership row + a few heartbeat pairs
        // + a piggybacked region-RTT summary row.
        let delta = Message::GossipDelta {
            delta: vec![(NodeId(1), 2, true, 0, 0)],
            heartbeats: (0..8u32).map(|i| (NodeId(i), 3)).collect(),
            rtts: vec![(0, 1, 0.05)],
            rep: vec![],
        };
        assert!(
            delta.wire_size() * 8 < full.wire_size(),
            "delta {} vs full {}",
            delta.wire_size(),
            full.wire_size()
        );
        // Heartbeat pairs are strictly cheaper than full rows.
        let as_rows = Message::GossipDelta {
            delta: (0..8u32).map(|i| (NodeId(i), 3, true, 0, 0)).collect(),
            heartbeats: vec![],
            rtts: vec![],
            rep: vec![],
        };
        let as_pairs = Message::GossipDelta {
            delta: vec![],
            heartbeats: (0..8u32).map(|i| (NodeId(i), 3)).collect(),
            rtts: vec![],
            rep: vec![],
        };
        assert!(as_pairs.wire_size() < as_rows.wire_size());
    }

    #[test]
    fn defense_fields_cost_nothing_when_absent() {
        // Replay neutrality: a receipt-less response and a rep-less delta
        // weigh exactly what they did before the defense layer existed.
        let bare = Message::DelegateResponse {
            response: resp(),
            duel: false,
            receipt: None,
        };
        assert_eq!(bare.wire_size(), 64 + resp().tokens.len() * 4);
        let receipted = Message::DelegateResponse {
            response: resp(),
            duel: false,
            receipt: Some(signed_receipt()),
        };
        assert!(receipted.wire_size() > bare.wire_size());

        let no_rep = Message::GossipDelta {
            delta: vec![],
            heartbeats: vec![],
            rtts: vec![],
            rep: vec![],
        };
        let with_rep = Message::GossipDelta {
            delta: vec![],
            heartbeats: vec![],
            rtts: vec![],
            rep: vec![(3, 250)],
        };
        assert_eq!(no_rep.wire_size(), 16);
        assert_eq!(with_rep.wire_size(), 16 + 8);
    }

    #[test]
    fn kv_transfer_weighs_its_bytes() {
        // The KV payload dominates the wire cost: re-dispatching a session
        // is priced like a delegate plus the whole resident cache.
        let plain = Message::Delegate { request: session_req(), duel: false };
        let moved = Message::KvTransfer {
            request: session_req(),
            session: 7,
            kv_bytes: 1_000_000,
        };
        assert_eq!(moved.wire_size(), plain.wire_size() + 1_000_000);
        // Streaming fields cost nothing on existing messages: a session
        // request weighs exactly what a standalone one does.
        let standalone = Message::Delegate { request: req(), duel: false };
        assert_eq!(plain.wire_size(), standalone.wire_size());
    }
}
