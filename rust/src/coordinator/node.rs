//! The WWW.Serve node: Figure 2's five managers wired into one sans-io state
//! machine.
//!
//! * **Request Manager** — admission, the pending-delegation state machine
//!   (probe → delegate → response, with timeouts and local fallback).
//! * **Policy Manager** — the provider's `NodePolicy` decisions.
//! * **Ledger Manager** — credit reads/writes (`ledger_manager.rs`).
//! * **Model Manager** — the local `Backend` plus executor-side bookkeeping.
//! * **Communication Manager** — gossip membership + message emission.
//!
//! All coordination logic lives in `handle(Event, now) -> Vec<Action>`; the
//! simulator and the TCP runner are thin drivers around it.

use std::collections::HashMap;

use super::events::{Action, Event};
use super::ledger_manager::LedgerManager;
use super::msg::Message;
use crate::backend::{Backend, Completion};
use crate::duel::{self, DuelState};
use crate::gossip::{GossipConfig, PeerView};
use crate::latency::{LatencyConfig, LatencyEstimator, RegionRtts};
use crate::ledger::{CreditOp, OpReason};
use crate::policy::{NodePolicy, SystemPolicy};
use crate::pos::StakeSnapshot;
use crate::types::{
    ExecKind, NodeId, Request, RequestId, RequestRecord, Response, Time,
};
use crate::util::rng::Rng;

/// Seconds to wait for a probe answer before trying the next candidate.
const PROBE_TIMEOUT: Time = 3.0;
/// Multiple of the SLO deadline to wait for a delegated response before
/// falling back to local execution (covers executor crashes).
const RESPONSE_TIMEOUT_FACTOR: f64 = 3.0;
/// Judge evaluation output length (short comparison verdicts).
const JUDGE_OUTPUT_TOKENS: u32 = 64;

#[derive(Debug, Clone)]
enum PendingState {
    /// Waiting for a ProbeAccept/Reject from `candidate`. `sent_at` stamps
    /// the probe send so the reply measures a live RTT (and a timeout
    /// penalizes the candidate's region in the latency estimator).
    Probing {
        candidate: NodeId,
        probes_left: usize,
        sent_at: Time,
    },
    /// Waiting for the executor's response.
    AwaitingResponse { executor: NodeId },
    /// Waiting for both duel responses.
    AwaitingDuel,
}

#[derive(Debug, Clone)]
struct PendingDelegation {
    req: Request,
    state: PendingState,
    deadline: Time,
}

/// Executor-side record of who to answer for a delegated request.
#[derive(Debug, Clone, Copy)]
struct ExecTicket {
    origin: NodeId,
    duel: bool,
}

/// Judge-side record for an in-flight evaluation.
#[derive(Debug, Clone)]
struct JudgeTask {
    duel_id: RequestId,
    origin: NodeId,
    resp_a: Response,
    resp_b: Response,
}

/// Cached stake-weighted candidate snapshot (§4.1 hot path). Rebuilding it
/// per request re-collects the stake table, re-filters liveness and
/// rebuilds the sampler; at fleet scale that dominates dispatch. The cache
/// is keyed on everything the snapshot reads: the gossip view's mutation
/// clock (liveness + region tags), the ledger version (stakes), a coarse
/// time bucket that bounds heartbeat-aging staleness to one gossip
/// interval, and the locality inputs that weight the candidates — the
/// `set_locality` epoch plus the live latency estimator's version, so a
/// rerouting-sized estimate change reshapes the very next draw instead of
/// serving a stale reweighted snapshot for up to a gossip interval.
struct SnapCache {
    view_clock: u64,
    ledger_version: u64,
    time_bucket: u64,
    locality_epoch: u64,
    estimator_version: u64,
    snap: StakeSnapshot,
}

/// Counters a node keeps about itself (drives policy + metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    pub user_requests: u64,
    pub delegated_out: u64,
    pub delegated_in: u64,
    pub served_local: u64,
    pub duels_started: u64,
    pub judge_evals: u64,
    pub probe_rejects: u64,
    pub probe_timeouts: u64,
    pub fallback_local: u64,
}

pub struct Node {
    pub id: NodeId,
    pub policy: NodePolicy,
    pub system: SystemPolicy,
    pub online: bool,
    /// Topology region this node lives in (0 in single-region worlds).
    pub region: u32,
    /// Live per-region one-way latency estimator: EWMA over observed probe
    /// and gossip RTTs, seeded from the topology's pristine
    /// expected-latency matrix as cold-start prior. `None` = no locality
    /// information, so dispatch stays region-blind regardless of
    /// `latency_penalty`.
    lat: Option<LatencyEstimator>,
    /// Bumped on every `set_locality` — part of the snapshot-cache key.
    locality_epoch: u64,
    /// Gossip push send-times awaiting a pull reply, per peer (RTT feed
    /// for the estimator). Only *unambiguous* exchanges are measured: a
    /// second push while one is still unanswered clears the stamp and
    /// skips measurement for that round, because a reply could then match
    /// either push (empty counter-deltas routinely leave pushes
    /// unanswered, and mis-attribution would skew the EWMA in whichever
    /// direction the stamp erred).
    gossip_sent_at: HashMap<NodeId, Time>,
    /// Last time region-RTT summaries were piggybacked to each peer
    /// (`LatencyConfig::share_every` rate limit).
    rtts_sent_at: HashMap<NodeId, Time>,
    backend: Box<dyn Backend>,
    pub view: PeerView,
    ledger: LedgerManager,
    rng: Rng,
    pending: HashMap<RequestId, PendingDelegation>,
    duels: HashMap<RequestId, DuelState>,
    exec_tickets: HashMap<RequestId, ExecTicket>,
    judge_tasks: HashMap<RequestId, JudgeTask>,
    /// Synthetic request sequence (judge evals and other self-generated
    /// work carry our own origin with high seq numbers).
    synth_seq: u64,
    last_gossip: Time,
    /// Gossip rounds completed — drives the delta/anti-entropy cadence.
    gossip_round: u64,
    /// Lazily rebuilt stake snapshot (see [`SnapCache`]).
    snap_cache: Option<SnapCache>,
    pub stats: NodeStats,
}

impl Node {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: NodeId,
        policy: NodePolicy,
        system: SystemPolicy,
        backend: Box<dyn Backend>,
        mut ledger: LedgerManager,
        gossip_cfg: GossipConfig,
        seed: u64,
        now: Time,
    ) -> Node {
        // Join the economy: genesis grant + initial stake — unless the
        // ledger already carries our genesis (blockchain mode pre-commits a
        // network-wide genesis block to every replica).
        if ledger.balance(id) + ledger.stake(id) == 0 {
            let mut genesis = vec![CreditOp::Mint {
                to: id,
                amount: system.genesis_credits,
                reason: OpReason::Genesis,
            }];
            let stake = policy.stake.min(system.genesis_credits);
            if stake > 0 {
                genesis.push(CreditOp::Stake { node: id, amount: stake });
            }
            // At construction there are no peers to broadcast to yet; in
            // chain mode a genesis block self-commits on an empty peer list.
            let _ = ledger.submit(genesis, id, &[], now);
        }

        Node {
            id,
            policy,
            system,
            online: true,
            region: 0,
            lat: None,
            locality_epoch: 0,
            gossip_sent_at: HashMap::new(),
            rtts_sent_at: HashMap::new(),
            backend,
            view: PeerView::new(id, gossip_cfg, now),
            ledger,
            rng: Rng::new(seed ^ (0x9E37 + id.0 as u64)),
            pending: HashMap::new(),
            duels: HashMap::new(),
            exec_tickets: HashMap::new(),
            judge_tasks: HashMap::new(),
            synth_seq: 1 << 40,
            last_gossip: now - 1e9,
            gossip_round: 0,
            snap_cache: None,
            stats: NodeStats::default(),
        }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn ledger(&self) -> &LedgerManager {
        &self.ledger
    }

    pub fn ledger_mut(&mut self) -> &mut LedgerManager {
        &mut self.ledger
    }

    pub fn credits(&self) -> u64 {
        self.ledger.balance(self.id) + self.ledger.stake(self.id)
    }

    /// Peers currently believed alive.
    fn alive_peers(&self, now: Time) -> Vec<NodeId> {
        self.view.alive_peers(now)
    }

    /// Broadcast peers for ledger submissions. Only chain mode sends ledger
    /// messages; shared mode applies in place and must not pay a per-payment
    /// alive-peer allocation on the hot path.
    fn ledger_peers(&self, now: Time) -> Vec<NodeId> {
        if self.ledger.is_chain() {
            self.view.alive_peers(now)
        } else {
            Vec::new()
        }
    }

    // ---- locality (topology awareness) --------------------------------------

    /// Install this node's region and the pristine inter-region latency
    /// matrix as the live estimator's cold-start prior (the simulator
    /// derives it from its `Topology`; the TCP runner would bootstrap from
    /// configuration). Makes `latency_penalty` effective: from here on,
    /// dispatch scores peers with *measured* EWMA latency seeded from this
    /// prior. An empty matrix clears locality (region-blind dispatch).
    pub fn set_locality(
        &mut self,
        region: u32,
        prior: Vec<Vec<f64>>,
        cfg: LatencyConfig,
    ) {
        self.region = region;
        self.lat = if prior.is_empty() {
            None
        } else {
            Some(LatencyEstimator::new(region, prior, cfg))
        };
        self.locality_epoch += 1;
        self.view.set_region(region);
    }

    /// Read access to the live latency estimator (None = region-blind).
    pub fn latency_estimator(&self) -> Option<&LatencyEstimator> {
        self.lat.as_ref()
    }

    /// Mutable access for tests and external instrumentation (a TCP runner
    /// measuring transport-level RTTs can feed them here directly).
    pub fn latency_estimator_mut(&mut self) -> Option<&mut LatencyEstimator> {
        self.lat.as_mut()
    }

    /// Live one-way latency estimate to `peer` per its gossiped region tag
    /// (0.0 when we have no locality information). Peers with no known
    /// region tag — or a garbage one — get the estimator's *conservative*
    /// estimate (worst own-row prior), never region 0's row: an unknown
    /// peer must not accidentally score as the best-connected one.
    fn expected_latency_to(&self, peer: NodeId, now: Time) -> f64 {
        let Some(est) = &self.lat else {
            return 0.0;
        };
        match self.view.region_of(peer) {
            Some(r) => est.expected_from_me(r, now),
            None => est.conservative(),
        }
    }

    /// Latency estimate to the nearest live peer — the `should_offload`
    /// locality term. `Some(0.0)` in flat worlds and for region-blind
    /// policies (no iteration, no RNG impact, no wasted hot-path scan);
    /// `None` when locality is active but **no live peer exists** — the
    /// caller must treat that as an explicit serve-locally case rather
    /// than feeding a sentinel into the offload damping math. Scans the
    /// view's online index in place — no per-request allocation.
    fn nearest_peer_latency(&self, now: Time) -> Option<f64> {
        if self.policy.latency_penalty <= 0.0 || self.lat.is_none() {
            return Some(0.0);
        }
        self.view
            .online_peers()
            .iter()
            .copied()
            .filter(|p| self.view.is_alive(*p, now))
            .map(|p| self.expected_latency_to(p, now))
            .reduce(f64::min)
    }

    /// Feed a measured request→reply round trip with `peer` into the live
    /// latency estimator (no-op without locality information or when the
    /// peer's region is unknown).
    fn observe_peer_rtt(&mut self, peer: NodeId, rtt: Time, now: Time) {
        let Some(region) = self.view.region_of(peer) else {
            return;
        };
        if let Some(est) = self.lat.as_mut() {
            est.observe_rtt(region, rtt, now);
        }
    }

    /// A probe deadline expired: the candidate — or the path to it — is
    /// dead or drastically slow. Feed the timeout floor as a penalty
    /// observation so dispatch sheds the region within a few timeouts,
    /// long before gossip liveness aging notices.
    fn observe_probe_timeout(&mut self, candidate: NodeId, now: Time) {
        let Some(region) = self.view.region_of(candidate) else {
            return;
        };
        if let Some(est) = self.lat.as_mut() {
            est.observe_timeout(region, PROBE_TIMEOUT, now);
        }
    }

    /// Evidence that the path to `peer`'s region is alive without a clean
    /// latency sample (delegation responses mix network and compute time).
    fn touch_peer(&mut self, peer: NodeId, now: Time) {
        let Some(region) = self.view.region_of(peer) else {
            return;
        };
        if let Some(est) = self.lat.as_mut() {
            est.touch(region, now);
        }
    }

    /// Stamp an outgoing gossip push so the pull reply measures a live
    /// RTT — but only when no earlier push to this peer is still
    /// unanswered. If one is, a future reply could match either push, so
    /// the stamp is cleared and this round goes unmeasured; the next
    /// uncontended push re-arms it. Gossip targets rotate, so consecutive
    /// pushes to the same peer are the exception and most exchanges stay
    /// measurable.
    fn stamp_gossip_push(&mut self, peer: NodeId, now: Time) {
        match self.gossip_sent_at.entry(peer) {
            std::collections::hash_map::Entry::Occupied(e) => {
                e.remove(); // ambiguous attribution: skip this round
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(now);
            }
        }
    }

    /// Match an incoming gossip pull reply against its push stamp and feed
    /// the estimator. Samples slower than [`PROBE_TIMEOUT`] are discarded:
    /// paths that slow are the probe-timeout penalty's job, and a stamp
    /// that old may predate a partition heal.
    fn observe_gossip_reply(&mut self, peer: NodeId, now: Time) {
        if let Some(t0) = self.gossip_sent_at.remove(&peer) {
            let rtt = (now - t0).max(0.0);
            if rtt <= PROBE_TIMEOUT {
                self.observe_peer_rtt(peer, rtt, now);
            }
        }
    }

    /// Region-RTT summaries to piggyback on a gossip delta to `peer`:
    /// same-region peers only (they share our vantage point), rate-limited
    /// to one summary per [`LatencyConfig::share_every`] seconds per peer
    /// so the byte overhead stays negligible at fleet scale.
    fn rtts_for(&mut self, peer: NodeId, now: Time) -> RegionRtts {
        let Some(est) = &self.lat else {
            return Vec::new();
        };
        if self.view.region_of(peer) != Some(est.my_region()) {
            return Vec::new();
        }
        let due = self
            .rtts_sent_at
            .get(&peer)
            .is_none_or(|t| now - *t >= est.config().share_every);
        if !due {
            return Vec::new();
        }
        let rtts = est.share(now);
        if !rtts.is_empty() {
            self.rtts_sent_at.insert(peer, now);
        }
        rtts
    }

    // ---- the event loop ----------------------------------------------------

    pub fn handle(&mut self, event: Event, now: Time) -> Vec<Action> {
        if !self.online {
            // Offline nodes drop everything except Join.
            if matches!(event, Event::Join) {
                return self.on_join(now);
            }
            return vec![];
        }
        let mut actions = match event {
            Event::UserRequest(req) => self.on_user_request(req, now),
            Event::Message { from, msg } => self.on_message(from, msg, now),
            Event::Tick => self.on_tick(now),
            Event::BackendWake => vec![],
            Event::Leave => return self.on_leave(now),
            Event::Join => vec![], // already online
        };
        // Collect backend completions on every activation.
        actions.extend(self.pump_backend(now));
        // Keep the runner informed of the next backend event.
        if let Some(t) = self.backend.next_event() {
            actions.push(Action::WakeAt(t));
        }
        actions
    }

    // ---- request admission + scheduling (Request/Policy managers) ----------

    fn on_user_request(&mut self, req: Request, now: Time) -> Vec<Action> {
        self.stats.user_requests += 1;
        let util = self.backend.utilization();
        let qlen = self.backend.queue_len();
        // No live peer at all is an explicit serve-locally case — never a
        // sentinel distance fed through the offload damping roll.
        let offload = match self.nearest_peer_latency(now) {
            Some(near) => {
                self.policy.should_offload(util, qlen, near, &mut self.rng)
            }
            None => false,
        };
        if !offload {
            return self.execute_locally(req, ExecKind::Local, now);
        }
        self.try_delegate(req, now)
    }

    /// Start the delegation state machine (PoS sample → probe). Falls back
    /// to local execution when no viable peer or unaffordable.
    fn try_delegate(&mut self, req: Request, now: Time) -> Vec<Action> {
        // Can we afford the offload payment?
        if self.ledger.balance(self.id) < self.system.base_reward {
            self.stats.fallback_local += 1;
            return self.execute_locally(req, ExecKind::Local, now);
        }
        self.refresh_snapshot(now);
        let candidates =
            self.snap_cache.as_ref().map_or(0, |c| c.snap.len());
        if candidates == 0 {
            self.stats.fallback_local += 1;
            return self.execute_locally(req, ExecKind::Local, now);
        }

        // Duel roll (§4.2): a fraction p_d of delegated requests go to two
        // executors directly.
        if self.rng.chance(self.system.duel_rate) && candidates >= 2 {
            return self.start_duel(req, now);
        }

        let candidate = {
            let cache = self.snap_cache.as_ref().expect("refreshed above");
            cache.snap.sample(&mut self.rng)
        };
        let Some(candidate) = candidate else {
            self.stats.fallback_local += 1;
            return self.execute_locally(req, ExecKind::Local, now);
        };
        let probe = Message::Probe {
            req_id: req.id,
            prompt_tokens: req.prompt_tokens,
            output_tokens: req.output_tokens,
        };
        self.pending.insert(
            req.id,
            PendingDelegation {
                req,
                state: PendingState::Probing {
                    candidate,
                    probes_left: self.system.max_probes.saturating_sub(1),
                    sent_at: now,
                },
                deadline: now + PROBE_TIMEOUT,
            },
        );
        vec![Action::Send { to: candidate, msg: probe }]
    }

    fn start_duel(&mut self, req: Request, now: Time) -> Vec<Action> {
        let execs = {
            let cache =
                self.snap_cache.as_ref().expect("refreshed in try_delegate");
            cache.snap.sample_distinct(&mut self.rng, 2)
        };
        if execs.len() < 2 {
            self.stats.fallback_local += 1;
            return self.execute_locally(req, ExecKind::Local, now);
        }
        self.stats.duels_started += 1;
        self.stats.delegated_out += 1;
        let duel = DuelState::new(req.clone(), [execs[0], execs[1]], now);
        self.pending.insert(
            req.id,
            PendingDelegation {
                req: req.clone(),
                state: PendingState::AwaitingDuel,
                deadline: now + req.slo_deadline * RESPONSE_TIMEOUT_FACTOR,
            },
        );
        self.duels.insert(req.id, duel);
        execs
            .into_iter()
            .map(|to| Action::Send {
                to,
                msg: Message::Delegate { request: req.clone(), duel: true },
            })
            .collect()
    }

    /// Ensure the cached stake-weighted, liveness-filtered snapshot of
    /// delegation candidates is current (see [`SnapCache`]). With locality
    /// information and a positive `latency_penalty`, each candidate's stake
    /// is damped by `1 / (1 + penalty * latency)` using the **live** EWMA
    /// latency estimate to the candidate's region — nearer peers win ties,
    /// distant continents fade from selection, and an observably degraded
    /// or partitioned path fades within a few observations (§4.1 made
    /// WAN-aware and reactive). Flat worlds skip the reweight entirely.
    /// The rebuilt snapshot is alias-prepared, so every subsequent draw is
    /// O(1).
    fn refresh_snapshot(&mut self, now: Time) {
        let view_clock = self.view.clock();
        let ledger_version = self.ledger.stake_version();
        let interval = self.view.config().interval.max(1e-6);
        let time_bucket = (now / interval) as u64;
        let locality_epoch = self.locality_epoch;
        let estimator_version = self.lat.as_ref().map_or(0, |l| l.version());
        if let Some(c) = &self.snap_cache {
            if c.view_clock == view_clock
                && c.ledger_version == ledger_version
                && c.time_bucket == time_bucket
                && c.locality_epoch == locality_epoch
                && c.estimator_version == estimator_version
            {
                return;
            }
        }
        let mut snap = StakeSnapshot::new(&self.ledger.stakes(), Some(self.id));
        snap.retain(|n| self.view.is_alive(n, now));
        if self.policy.latency_penalty > 0.0 && self.lat.is_some() {
            let penalty = self.policy.latency_penalty;
            snap.reweight(|n| {
                1.0 / (1.0 + penalty * self.expected_latency_to(n, now))
            });
        }
        snap.prepare();
        self.snap_cache = Some(SnapCache {
            view_clock,
            ledger_version,
            time_bucket,
            locality_epoch,
            estimator_version,
            snap,
        });
    }

    /// Put a request on our own backend.
    fn execute_locally(
        &mut self,
        req: Request,
        kind: ExecKind,
        now: Time,
    ) -> Vec<Action> {
        if kind == ExecKind::Local {
            self.stats.served_local += 1;
        }
        self.backend.submit(req, kind, now);
        vec![]
    }

    // ---- message handling (Communication manager) ---------------------------

    fn on_message(&mut self, from: NodeId, msg: Message, now: Time) -> Vec<Action> {
        match msg {
            Message::Probe { req_id, .. } => {
                let util = self.backend.utilization();
                let qlen = self.backend.queue_len();
                let accept =
                    self.policy.should_accept(util, qlen, &mut self.rng);
                let reply = if accept {
                    Message::ProbeAccept { req_id }
                } else {
                    Message::ProbeReject { req_id }
                };
                vec![Action::Send { to: from, msg: reply }]
            }
            Message::ProbeAccept { req_id } => self.on_probe_accept(from, req_id, now),
            Message::ProbeReject { req_id } => self.on_probe_reject(from, req_id, now),
            Message::Delegate { request, duel } => {
                self.stats.delegated_in += 1;
                self.exec_tickets
                    .insert(request.id, ExecTicket { origin: from, duel });
                let kind = if duel { ExecKind::Duel } else { ExecKind::Delegated };
                self.execute_locally(request, kind, now)
            }
            Message::DelegateResponse { response, duel } => {
                // The executor's answer proves the path to its region is
                // alive (its timing mixes compute with network, so it only
                // refreshes estimator freshness, not the EWMA).
                self.touch_peer(from, now);
                self.on_delegate_response(response, duel, now)
            }
            Message::Gossip { digest } => {
                self.view.merge(&digest, now);
                let reply = self.view.digest();
                self.view.mark_synced(from);
                vec![Action::Send {
                    to: from,
                    msg: Message::GossipReply { digest: reply },
                }]
            }
            Message::GossipReply { digest } => {
                // Pull half of a push-pull we initiated: a measured gossip
                // round trip for the estimator.
                self.observe_gossip_reply(from, now);
                self.view.merge(&digest, now);
                vec![]
            }
            Message::GossipDelta { delta, heartbeats, rtts } => {
                if let Some(est) = self.lat.as_mut() {
                    est.merge(&rtts, now);
                }
                let mut fresh = self.view.merge(&delta, now);
                fresh.extend(self.view.merge_heartbeats(&heartbeats, now));
                fresh.sort_unstable();
                // Pull half: our own delta back to the initiator, minus
                // whatever we just accepted from it (no echo). An empty
                // exchange is skipped — nothing to learn, no bytes burned.
                let (delta, heartbeats) =
                    self.view.delta_for_excluding(from, now, &fresh);
                if delta.is_empty() && heartbeats.is_empty() {
                    vec![]
                } else {
                    let rtts = self.rtts_for(from, now);
                    vec![Action::Send {
                        to: from,
                        msg: Message::GossipDeltaReply {
                            delta,
                            heartbeats,
                            rtts,
                        },
                    }]
                }
            }
            Message::GossipDeltaReply { delta, heartbeats, rtts } => {
                self.observe_gossip_reply(from, now);
                if let Some(est) = self.lat.as_mut() {
                    est.merge(&rtts, now);
                }
                self.view.merge(&delta, now);
                self.view.merge_heartbeats(&heartbeats, now);
                vec![]
            }
            Message::JudgeAssign { duel_id, resp_a, resp_b, est_tokens } => {
                self.on_judge_assign(from, duel_id, resp_a, resp_b, est_tokens, now)
            }
            Message::JudgeVerdict { duel_id, winner } => {
                self.on_judge_verdict(from, duel_id, winner, now)
            }
            m @ (Message::BlockProposal { .. }
            | Message::BlockVote { .. }
            | Message::BlockCommit { .. }
            | Message::ChainRequest { .. }
            | Message::ChainSnapshot { .. }) => {
                let peers = self.alive_peers(now);
                self.ledger.on_message(from, &m, self.id, &peers, now)
            }
        }
    }

    fn on_probe_accept(
        &mut self,
        from: NodeId,
        req_id: RequestId,
        now: Time,
    ) -> Vec<Action> {
        let Some(p) = self.pending.get_mut(&req_id) else {
            return vec![]; // stale (already timed out / answered)
        };
        let PendingState::Probing { candidate, sent_at, .. } = p.state else {
            return vec![];
        };
        if candidate != from {
            return vec![]; // answer from a node we no longer care about
        }
        self.stats.delegated_out += 1;
        let req = p.req.clone();
        p.state = PendingState::AwaitingResponse { executor: from };
        p.deadline = now + req.slo_deadline * RESPONSE_TIMEOUT_FACTOR;
        // The probe round trip is a clean network RTT sample.
        self.observe_peer_rtt(from, (now - sent_at).max(0.0), now);
        vec![Action::Send {
            to: from,
            msg: Message::Delegate { request: req, duel: false },
        }]
    }

    fn on_probe_reject(
        &mut self,
        from: NodeId,
        req_id: RequestId,
        now: Time,
    ) -> Vec<Action> {
        let (req, probes_left, sent_at) = {
            let Some(p) = self.pending.get(&req_id) else {
                return vec![];
            };
            let PendingState::Probing { candidate, probes_left, sent_at } =
                p.state
            else {
                return vec![];
            };
            if candidate != from {
                return vec![];
            }
            (p.req.clone(), probes_left, sent_at)
        };
        // A reject still answers the probe: same clean RTT sample.
        self.observe_peer_rtt(from, (now - sent_at).max(0.0), now);
        self.stats.probe_rejects += 1;
        if probes_left == 0 {
            self.pending.remove(&req_id);
            self.stats.fallback_local += 1;
            return self.execute_locally(req, ExecKind::Local, now);
        }
        // Try another candidate.
        self.refresh_snapshot(now);
        let next = {
            let cache = self.snap_cache.as_ref().expect("refreshed above");
            cache.snap.sample(&mut self.rng)
        };
        match next {
            Some(c) => {
                let probe = Message::Probe {
                    req_id,
                    prompt_tokens: req.prompt_tokens,
                    output_tokens: req.output_tokens,
                };
                let p = self.pending.get_mut(&req_id).expect("checked above");
                p.state = PendingState::Probing {
                    candidate: c,
                    probes_left: probes_left - 1,
                    sent_at: now,
                };
                p.deadline = now + PROBE_TIMEOUT;
                vec![Action::Send { to: c, msg: probe }]
            }
            None => {
                self.pending.remove(&req_id);
                self.stats.fallback_local += 1;
                self.execute_locally(req, ExecKind::Local, now)
            }
        }
    }

    fn on_delegate_response(
        &mut self,
        response: Response,
        duel: bool,
        now: Time,
    ) -> Vec<Action> {
        if duel {
            return self.on_duel_response(response, now);
        }
        let Some(p) = self.pending.remove(&response.id) else {
            return vec![]; // stale (timed out, user already answered)
        };
        let PendingState::AwaitingResponse { executor } = p.state else {
            self.pending.insert(response.id, p);
            return vec![];
        };
        // Pay the executor (credits-for-offloading).
        let peers = self.ledger_peers(now);
        let mut actions = self.ledger.submit(
            vec![CreditOp::Transfer {
                from: self.id,
                to: executor,
                amount: self.system.base_reward,
                reason: OpReason::OffloadPayment(response.id),
            }],
            self.id,
            &peers,
            now,
        );
        actions.push(Action::Done(RequestRecord {
            id: p.req.id,
            origin: self.id,
            executor,
            kind: ExecKind::Delegated,
            prompt_tokens: p.req.prompt_tokens,
            output_tokens: p.req.output_tokens,
            submitted_at: p.req.submitted_at,
            completed_at: now,
            slo_deadline: p.req.slo_deadline,
            synthetic: p.req.synthetic,
        }));
        actions
    }

    fn on_duel_response(&mut self, response: Response, now: Time) -> Vec<Action> {
        let executor = response.executor;
        let (first, both_in, req, execs) = {
            let Some(d) = self.duels.get_mut(&response.id) else {
                return vec![];
            };
            let first = d.responses.is_empty() && !d.user_answered;
            let both_in = d.add_response(response.clone());
            if first {
                d.user_answered = true;
            }
            (first, both_in, d.request.clone(), d.executors)
        };
        let mut actions = Vec::new();

        if first {
            // The user takes the first answer; the duel settles afterwards.
            actions.push(Action::Done(RequestRecord {
                id: req.id,
                origin: self.id,
                executor,
                kind: ExecKind::Delegated,
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.output_tokens,
                submitted_at: req.submitted_at,
                completed_at: now,
                slo_deadline: req.slo_deadline,
                synthetic: req.synthetic,
            }));
            // Both executors get the base payment (both did the work).
            let peers = self.ledger_peers(now);
            let ops = execs
                .iter()
                .map(|e| CreditOp::Transfer {
                    from: self.id,
                    to: *e,
                    amount: self.system.base_reward,
                    reason: OpReason::OffloadPayment(req.id),
                })
                .collect();
            actions.extend(self.ledger.submit(ops, self.id, &peers, now));
        } else {
            // The slower duel copy: synthetic overhead record (§7.1).
            actions.push(Action::Done(RequestRecord {
                id: req.id,
                origin: self.id,
                executor,
                kind: ExecKind::Duel,
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.output_tokens,
                submitted_at: req.submitted_at,
                completed_at: now,
                slo_deadline: req.slo_deadline,
                synthetic: true,
            }));
        }

        if both_in {
            actions.extend(self.dispatch_judges(response.id, now));
        }
        actions
    }

    fn dispatch_judges(&mut self, duel_id: RequestId, now: Time) -> Vec<Action> {
        self.refresh_snapshot(now);
        // Judges: PoS-sampled, excluding the two executors (impartiality).
        // Duels are rare, so cloning the cached snapshot for the exclusion
        // filter is fine; the per-request path never clones.
        let mut pool = self
            .snap_cache
            .as_ref()
            .expect("refreshed above")
            .snap
            .clone();
        let d = self.duels.get_mut(&duel_id).expect("duel exists");
        let execs = d.executors;
        pool.retain(|n| n != execs[0] && n != execs[1]);
        let judges = pool.sample_distinct(&mut self.rng, self.system.judges);
        if judges.is_empty() {
            // No impartial judges available — settle as a wash (no
            // redistribution), keep the duel out of stats.
            self.duels.remove(&duel_id);
            self.pending.remove(&duel_id);
            return vec![];
        }
        d.assign_judges(judges.clone());
        let (a, b) = (d.responses[0].clone(), d.responses[1].clone());
        let est = d.request.output_tokens.saturating_mul(2).clamp(64, 8192);
        judges
            .into_iter()
            .map(|j| Action::Send {
                to: j,
                msg: Message::JudgeAssign {
                    duel_id,
                    resp_a: a.clone(),
                    resp_b: b.clone(),
                    est_tokens: est,
                },
            })
            .collect()
    }

    fn on_judge_assign(
        &mut self,
        from: NodeId,
        duel_id: RequestId,
        resp_a: Response,
        resp_b: Response,
        est_tokens: u32,
        now: Time,
    ) -> Vec<Action> {
        self.stats.judge_evals += 1;
        // Judging costs real compute: enqueue a synthetic evaluation request
        // on our own backend (reading both answers + a short verdict).
        let seq = self.synth_seq;
        self.synth_seq += 1;
        let eval_req = Request {
            id: RequestId { origin: self.id, seq },
            prompt_tokens: est_tokens,
            output_tokens: JUDGE_OUTPUT_TOKENS,
            submitted_at: now,
            slo_deadline: f64::INFINITY,
            synthetic: true,
            payload: vec![],
        };
        self.judge_tasks.insert(
            eval_req.id,
            JudgeTask { duel_id, origin: from, resp_a, resp_b },
        );
        self.execute_locally(eval_req, ExecKind::Judge, now)
    }

    fn on_judge_verdict(
        &mut self,
        from: NodeId,
        duel_id: RequestId,
        winner: NodeId,
        now: Time,
    ) -> Vec<Action> {
        let Some(d) = self.duels.get_mut(&duel_id) else {
            return vec![];
        };
        let Some(outcome) = d.add_verdict(from, winner) else {
            return vec![];
        };
        // Settle: winner reward, loser slash, judge rewards (§4.2).
        let judges = d.judges.clone();
        self.duels.remove(&duel_id);
        self.pending.remove(&duel_id);
        let mut ops = vec![
            CreditOp::Mint {
                to: outcome.winner,
                amount: self.system.duel_reward,
                reason: OpReason::DuelWin(duel_id),
            },
            CreditOp::Slash {
                from: outcome.loser,
                amount: self.system.duel_penalty,
                reason: OpReason::DuelLoss(duel_id),
            },
        ];
        for j in judges {
            ops.push(CreditOp::Mint {
                to: j,
                amount: self.system.judge_reward,
                reason: OpReason::JudgeReward(duel_id),
            });
        }
        let peers = self.ledger_peers(now);
        let mut actions = self.ledger.submit(ops, self.id, &peers, now);
        actions.push(Action::DuelSettled(outcome));
        actions
    }

    // ---- backend pump (Model manager) ---------------------------------------

    fn pump_backend(&mut self, now: Time) -> Vec<Action> {
        let completions = self.backend.advance(now);
        let mut actions = Vec::new();
        for c in completions {
            actions.extend(self.on_completion(c, now));
        }
        actions
    }

    fn on_completion(&mut self, c: Completion, _now: Time) -> Vec<Action> {
        match c.kind {
            ExecKind::Local => {
                // Our own user's request, served locally.
                vec![Action::Done(RequestRecord {
                    id: c.request.id,
                    origin: self.id,
                    executor: self.id,
                    kind: ExecKind::Local,
                    prompt_tokens: c.request.prompt_tokens,
                    output_tokens: c.request.output_tokens,
                    submitted_at: c.request.submitted_at,
                    completed_at: c.finished_at,
                    slo_deadline: c.request.slo_deadline,
                    synthetic: c.request.synthetic,
                })]
            }
            ExecKind::Delegated | ExecKind::Duel => {
                let Some(ticket) = self.exec_tickets.remove(&c.request.id) else {
                    return vec![];
                };
                let quality =
                    duel::draw_response_quality(self.backend.quality(), &mut self.rng);
                let response = Response {
                    id: c.request.id,
                    executor: self.id,
                    quality,
                    finished_at: c.finished_at,
                    tokens: vec![],
                };
                vec![Action::Send {
                    to: ticket.origin,
                    msg: Message::DelegateResponse {
                        response,
                        duel: ticket.duel,
                    },
                }]
            }
            ExecKind::Judge => {
                let Some(task) = self.judge_tasks.remove(&c.request.id) else {
                    return vec![];
                };
                let winner =
                    duel::judge_compare(&task.resp_a, &task.resp_b, &mut self.rng);
                vec![
                    Action::Send {
                        to: task.origin,
                        msg: Message::JudgeVerdict {
                            duel_id: task.duel_id,
                            winner,
                        },
                    },
                    // Judge work is synthetic overhead (§7.1 accounting).
                    Action::Done(RequestRecord {
                        id: c.request.id,
                        origin: self.id,
                        executor: self.id,
                        kind: ExecKind::Judge,
                        prompt_tokens: c.request.prompt_tokens,
                        output_tokens: c.request.output_tokens,
                        submitted_at: c.request.submitted_at,
                        completed_at: c.finished_at,
                        slo_deadline: c.request.slo_deadline,
                        synthetic: true,
                    }),
                ]
            }
        }
    }

    // ---- tick: gossip + timeouts --------------------------------------------

    /// The single gossip-broadcast path: one wave to `targets`, shared by
    /// the regular tick round, leave/join announcements and suspicion
    /// probes. `full` sends the complete digest (anti-entropy form, built
    /// once and cloned per target); otherwise each target gets its own
    /// delta, and empty exchanges are skipped entirely.
    fn gossip_send(
        &mut self,
        targets: &[NodeId],
        full: bool,
        now: Time,
    ) -> Vec<Action> {
        let mut out = Vec::with_capacity(targets.len());
        if full {
            if targets.is_empty() {
                return out;
            }
            let digest = self.view.digest();
            for t in targets {
                self.view.mark_synced(*t);
                self.stamp_gossip_push(*t, now);
                out.push(Action::Send {
                    to: *t,
                    msg: Message::Gossip { digest: digest.clone() },
                });
            }
        } else {
            for t in targets {
                let (delta, heartbeats) = self.view.delta_for(*t, now);
                if delta.is_empty() && heartbeats.is_empty() {
                    continue;
                }
                let rtts = self.rtts_for(*t, now);
                self.stamp_gossip_push(*t, now);
                out.push(Action::Send {
                    to: *t,
                    msg: Message::GossipDelta { delta, heartbeats, rtts },
                });
            }
        }
        out
    }

    fn on_tick(&mut self, now: Time) -> Vec<Action> {
        let mut actions = Vec::new();

        // Gossip round (§A.2): deltas on regular rounds, the full digest on
        // the first and every `anti_entropy_every`-th round, and always for
        // the suspicion probe (a heal must pull the whole view back in).
        if now - self.last_gossip >= self.view.config().interval {
            self.last_gossip = now;
            self.gossip_round += 1;
            self.view.heartbeat(now);
            let ae = self.view.config().anti_entropy_every;
            let full = ae <= 1 || self.gossip_round % ae == 1;
            let (regular, suspect) =
                self.view.pick_round_targets(&mut self.rng, now);
            actions.extend(self.gossip_send(&regular, full, now));
            if let Some(s) = suspect {
                actions.extend(self.gossip_send(&[s], true, now));
            }
        }

        // Ledger retries (chain mode head races). Shared mode has no ledger
        // traffic — skip the per-tick alive-peer allocation.
        if self.ledger.is_chain() {
            let peers = self.alive_peers(now);
            actions.extend(self.ledger.on_tick(&peers, now));
        }

        // Stake maintenance (user-level policy, §4.3): a rational provider
        // tops its stake back up to its declared target after duel slashes —
        // staying out of the PoS pool earns nothing. Providers whose balance
        // has drained cannot refill and fade out of selection, which is
        // exactly the Theorem-5.8 phase-out dynamic.
        if !self.policy.requester_only {
            let stake = self.ledger.stake(self.id);
            let balance = self.ledger.balance(self.id);
            if stake < self.policy.stake && balance > 0 {
                let amount = (self.policy.stake - stake).min(balance);
                let peers = self.ledger_peers(now);
                actions.extend(self.ledger.submit(
                    vec![CreditOp::Stake { node: self.id, amount }],
                    self.id,
                    &peers,
                    now,
                ));
            }
        }

        // Queue rebalancing: while overloaded, pull our own newest waiting
        // requests back out of the backend and re-dispatch them through the
        // market (user-level policy, §4.3 — "offload tasks once local
        // workload surpasses a predefined threshold").
        if !self.policy.requester_only {
            let util = self.backend.utilization();
            let qlen = self.backend.queue_len();
            if util >= self.policy.target_utilization
                && qlen > self.policy.queue_threshold
            {
                let excess = qlen - self.policy.queue_threshold;
                for req in self.backend.steal_queued(excess.min(4)) {
                    if self.rng.chance(self.policy.offload_freq) {
                        actions.extend(self.try_delegate(req, now));
                    } else {
                        self.backend.submit(req, ExecKind::Local, now);
                    }
                }
            }
        }

        // Timeout scan.
        let expired: Vec<RequestId> = self
            .pending
            .iter()
            .filter(|(_, p)| now >= p.deadline)
            .map(|(id, _)| *id)
            .collect();
        for id in expired {
            let p = self.pending.remove(&id).expect("just listed");
            match p.state {
                PendingState::Probing { candidate, .. } => {
                    // Probe never answered: the candidate died or the path
                    // to its region is down. Penalize the region in the
                    // latency estimator and serve locally.
                    self.stats.probe_timeouts += 1;
                    self.stats.fallback_local += 1;
                    self.observe_probe_timeout(candidate, now);
                    actions.extend(self.execute_locally(
                        p.req,
                        ExecKind::Local,
                        now,
                    ));
                }
                PendingState::AwaitingResponse { .. } => {
                    // Executor vanished mid-flight: local fallback.
                    self.stats.fallback_local += 1;
                    actions.extend(self.execute_locally(
                        p.req,
                        ExecKind::Local,
                        now,
                    ));
                }
                PendingState::AwaitingDuel => {
                    let d = self.duels.remove(&id);
                    if let Some(d) = d {
                        if !d.user_answered {
                            // Neither executor answered: local fallback.
                            self.stats.fallback_local += 1;
                            actions.extend(self.execute_locally(
                                p.req,
                                ExecKind::Local,
                                now,
                            ));
                        }
                        // Else: user already has an answer; abandon the duel
                        // (no settlement) — a judge or executor died.
                    }
                }
            }
        }
        actions
    }

    // ---- dynamic participation ----------------------------------------------

    fn on_leave(&mut self, now: Time) -> Vec<Action> {
        self.online = false;
        self.view.announce_leave(now);
        // Goodbye gossip so the network learns quickly (Fig. 5b) — always
        // the full digest (our departure is membership news).
        let peers = self.view.alive_peers(now);
        self.gossip_send(&peers, true, now)
    }

    fn on_join(&mut self, now: Time) -> Vec<Action> {
        self.online = true;
        self.view.heartbeat(now); // version bump flips us back online
        // Bootstrap peers are contactable again, and the per-peer delta
        // floors reset: after downtime we no longer know what peers saw.
        self.view.refresh(now);
        self.last_gossip = now;
        let targets = self.view.pick_targets(&mut self.rng, now);
        let mut actions = self.gossip_send(&targets, true, now);
        if let Some(t) = self.backend.next_event() {
            actions.push(Action::WakeAt(t));
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Profile, SimBackend};
    use crate::ledger::Ledger;
    use crate::ledger::SharedLedger;
    use std::sync::{Arc, Mutex};

    fn mk_node(
        id: u32,
        policy: NodePolicy,
        shared: &Arc<Mutex<SharedLedger>>,
    ) -> Node {
        Node::new(
            NodeId(id),
            policy,
            SystemPolicy::default(),
            Box::new(SimBackend::new(Profile::test(50.0, 4))),
            LedgerManager::shared(shared.clone()),
            GossipConfig::default(),
            42,
            0.0,
        )
    }

    fn user_req(origin: u32, seq: u64, now: Time) -> Request {
        Request {
            id: RequestId { origin: NodeId(origin), seq },
            prompt_tokens: 100,
            output_tokens: 100,
            submitted_at: now,
            slo_deadline: 60.0,
            synthetic: false,
            payload: vec![],
        }
    }

    #[test]
    fn genesis_grants_credits_and_stake() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let n = mk_node(0, NodePolicy::default(), &shared);
        let sys = SystemPolicy::default();
        assert_eq!(
            n.ledger().balance(NodeId(0)),
            sys.genesis_credits - NodePolicy::default().stake
        );
        assert_eq!(n.ledger().stake(NodeId(0)), NodePolicy::default().stake);
    }

    #[test]
    fn idle_node_serves_locally() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n = mk_node(0, NodePolicy::default(), &shared);
        let actions = n.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        // No sends (no offload — idle backend), just a wake for completion.
        assert!(actions
            .iter()
            .all(|a| matches!(a, Action::WakeAt(_))));
        // Run to completion.
        let done = n.handle(Event::BackendWake, 100.0);
        let recs: Vec<_> = done
            .iter()
            .filter_map(|a| match a {
                Action::Done(r) => Some(r),
                _ => None,
            })
            .collect();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].executor, NodeId(0));
        assert_eq!(recs[0].kind, ExecKind::Local);
        assert!(!recs[0].synthetic);
    }

    #[test]
    fn pressured_node_probes_staked_peer() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        // Node 1 exists in the ledger (stakes) and in node 0's view.
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0, // always offload
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        // duel_rate 0 for a deterministic single probe
        n0.system.duel_rate = 0.0;
        let actions = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let sends: Vec<_> = actions
            .iter()
            .filter_map(|a| match a {
                Action::Send { to, msg } => Some((*to, msg.kind())),
                _ => None,
            })
            .collect();
        assert_eq!(sends, vec![(NodeId(1), "probe")]);
    }

    #[test]
    fn full_delegation_roundtrip_pays_executor() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        n1.policy.accept_freq = 1.0;

        let bal0 = shared.lock().unwrap().balance(NodeId(0));
        let bal1 = shared.lock().unwrap().balance(NodeId(1));

        // 0 -> probe -> 1
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let Action::Send { msg: probe, .. } = &a[0] else { panic!() };
        // 1 -> accept -> 0
        let a = n1.handle(
            Event::Message { from: NodeId(0), msg: probe.clone() },
            0.1,
        );
        let Action::Send { msg: accept, .. } = &a[0] else { panic!() };
        assert_eq!(accept.kind(), "probe_accept");
        // 0 -> delegate -> 1
        let a = n0.handle(
            Event::Message { from: NodeId(1), msg: accept.clone() },
            0.2,
        );
        let Action::Send { msg: delegate, .. } = &a[0] else { panic!() };
        assert_eq!(delegate.kind(), "delegate");
        // 1 executes...
        n1.handle(
            Event::Message { from: NodeId(0), msg: delegate.clone() },
            0.3,
        );
        let a = n1.handle(Event::BackendWake, 100.0);
        let Some(Action::Send { to, msg: resp }) = a
            .iter()
            .find(|x| matches!(x, Action::Send { .. }))
        else {
            panic!("no response sent: {a:?}")
        };
        assert_eq!(*to, NodeId(0));
        assert_eq!(resp.kind(), "delegate_response");
        // 0 receives the response: record + payment.
        let a = n0.handle(
            Event::Message { from: NodeId(1), msg: resp.clone() },
            100.1,
        );
        let rec = a
            .iter()
            .find_map(|x| match x {
                Action::Done(r) => Some(r),
                _ => None,
            })
            .expect("completion record");
        assert_eq!(rec.executor, NodeId(1));
        assert_eq!(rec.kind, ExecKind::Delegated);
        let pay = SystemPolicy::default().base_reward;
        assert_eq!(shared.lock().unwrap().balance(NodeId(0)), bal0 - pay);
        assert_eq!(shared.lock().unwrap().balance(NodeId(1)), bal1 + pay);
    }

    #[test]
    fn probe_reject_falls_back_after_retries() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.system.max_probes = 2;
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);

        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let Action::Send { msg: Message::Probe { req_id, .. }, .. } = a[0]
        else {
            panic!()
        };
        // First reject -> re-probe (only node 1 is available, so again 1).
        let a = n0.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::ProbeReject { req_id },
            },
            0.1,
        );
        assert!(a.iter().any(
            |x| matches!(x, Action::Send { msg: Message::Probe { .. }, .. })
        ));
        // Second reject -> local fallback (probes exhausted).
        let a = n0.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::ProbeReject { req_id },
            },
            0.2,
        );
        assert!(a
            .iter()
            .all(|x| !matches!(x, Action::Send { msg: Message::Probe { .. }, .. })));
        assert_eq!(n0.backend().running_len(), 1);
        assert_eq!(n0.stats.fallback_local, 1);
    }

    #[test]
    fn probe_timeout_falls_back_locally() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert_eq!(n0.backend().running_len(), 0);
        // Silence until past PROBE_TIMEOUT.
        n0.handle(Event::Tick, PROBE_TIMEOUT + 0.5);
        assert_eq!(n0.backend().running_len(), 1);
    }

    #[test]
    fn duel_roundtrip_settles_credits() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut nodes: Vec<Node> = (0..5)
            .map(|i| {
                let mut n = mk_node(i, NodePolicy::default(), &shared);
                n.policy.accept_freq = 1.0;
                // The hand-rolled pump below advances time in 50 s jumps
                // with no gossip rounds, so disable heartbeat aging.
                n.view = PeerView::new(
                    NodeId(i),
                    crate::gossip::GossipConfig { suspect_after: 1e12, ..Default::default() },
                    0.0,
                );
                n
            })
            .collect();
        // Node 0 always duels.
        nodes[0].system.duel_rate = 1.0;
        nodes[0].policy.target_utilization = 0.0;
        nodes[0].policy.offload_freq = 1.0;
        for i in 1..5u32 {
            nodes[0].view.merge(&vec![(NodeId(i), 1, true, 0, 0)], 0.0);
        }

        // Kick off: two Delegate{duel} sends.
        let a = nodes[0].handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let delegates: Vec<(NodeId, Message)> = a
            .iter()
            .filter_map(|x| match x {
                Action::Send { to, msg: m @ Message::Delegate { .. } } => {
                    Some((*to, m.clone()))
                }
                _ => None,
            })
            .collect();
        assert_eq!(delegates.len(), 2);

        // Pump the whole network until quiet (mini event loop).
        let mut inbox: Vec<(NodeId, NodeId, Message)> = delegates
            .iter()
            .map(|(to, m)| (*to, NodeId(0), m.clone()))
            .collect();
        let mut t = 1.0;
        let mut settled = None;
        let mut guard = 0;
        while !inbox.is_empty() && guard < 1000 {
            guard += 1;
            let (to, from, msg) = inbox.remove(0);
            let actions = nodes[to.0 as usize].handle(
                Event::Message { from, msg },
                t,
            );
            // Also run backends forward generously.
            t += 50.0;
            for (i, n) in nodes.iter_mut().enumerate() {
                for act in n.handle(Event::BackendWake, t) {
                    match act {
                        Action::Send { to, msg } => {
                            inbox.push((to, NodeId(i as u32), msg))
                        }
                        Action::DuelSettled(o) => settled = Some(o),
                        _ => {}
                    }
                }
            }
            for act in actions {
                match act {
                    Action::Send { to: t2, msg } => inbox.push((t2, to, msg)),
                    Action::DuelSettled(o) => settled = Some(o),
                    _ => {}
                }
            }
        }
        let outcome = settled.expect("duel settled");
        assert_ne!(outcome.winner, outcome.loser);
        // Winner got R_add minted on top of base pay; loser lost stake.
        let sys = SystemPolicy::default();
        let pol = NodePolicy::default();
        let (winner_total, loser_stake) = {
            let l = shared.lock().unwrap();
            (
                l.balance(outcome.winner) + l.stake(outcome.winner),
                l.stake(outcome.loser),
            )
        };
        assert_eq!(
            winner_total,
            sys.genesis_credits + sys.base_reward + sys.duel_reward
        );
        assert_eq!(loser_stake, pol.stake - sys.duel_penalty);
    }

    #[test]
    fn offline_node_drops_events_until_join() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n = mk_node(0, NodePolicy::default(), &shared);
        n.handle(Event::Leave, 1.0);
        assert!(!n.online);
        let a = n.handle(Event::UserRequest(user_req(0, 0, 2.0)), 2.0);
        assert!(a.is_empty());
        assert_eq!(n.backend().running_len(), 0);
        n.handle(Event::Join, 3.0);
        assert!(n.online);
        n.handle(Event::UserRequest(user_req(0, 1, 4.0)), 4.0);
        assert_eq!(n.backend().running_len(), 1);
    }

    #[test]
    fn leave_gossips_goodbye() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n = mk_node(0, NodePolicy::default(), &shared);
        n.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        let a = n.handle(Event::Leave, 1.0);
        assert!(a.iter().any(|x| matches!(
            x,
            Action::Send { to: NodeId(1), msg: Message::Gossip { .. } }
        )));
        // Our own digest must mark us offline.
        let e = n.view.entry(NodeId(0)).unwrap();
        assert!(!e.online);
    }

    #[test]
    fn requester_only_node_always_delegates() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(0, NodePolicy::requester_only(), &shared);
        n0.system.duel_rate = 0.0;
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert!(a
            .iter()
            .any(|x| matches!(x, Action::Send { msg: Message::Probe { .. }, .. })));
        assert_eq!(n0.backend().running_len(), 0);
    }

    #[test]
    fn snapshot_cache_tracks_liveness_and_ledger() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        let probes_to = |actions: &[Action]| -> Vec<NodeId> {
            actions
                .iter()
                .filter_map(|x| match x {
                    Action::Send { to, msg: Message::Probe { .. } } => {
                        Some(*to)
                    }
                    _ => None,
                })
                .collect()
        };
        // Two back-to-back requests: the second reuses the cached snapshot
        // (same view clock, ledger version and time bucket) and still
        // probes the live peer.
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert_eq!(probes_to(&a), vec![NodeId(1)]);
        let a = n0.handle(Event::UserRequest(user_req(0, 1, 0.0)), 0.0);
        assert_eq!(probes_to(&a), vec![NodeId(1)]);
        // The peer ages out (suspect_after 5 s): with no view mutation at
        // all, the time-bucket key alone must force a rebuild that drops
        // it — stale caches must not delegate to the dead.
        let a = n0.handle(Event::UserRequest(user_req(0, 2, 20.0)), 20.0);
        assert!(probes_to(&a).is_empty());
        assert_eq!(n0.stats.fallback_local, 1);
        // A newly staked + gossiped peer invalidates via clock/version and
        // becomes the only candidate.
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        n0.view.merge(&vec![(NodeId(2), 1, true, 0, 0)], 20.0);
        let a = n0.handle(Event::UserRequest(user_req(0, 3, 20.5)), 20.5);
        assert_eq!(probes_to(&a), vec![NodeId(2)]);
    }

    #[test]
    fn tick_gossip_uses_deltas_between_anti_entropy_rounds() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut a = mk_node(0, NodePolicy::default(), &shared);
        let mut b = mk_node(1, NodePolicy::default(), &shared);
        a.view.add_seed(NodeId(1), 0, 0, 0.0);
        b.view.add_seed(NodeId(0), 0, 0, 0.0);
        let gossip_kinds = |actions: &[Action]| -> Vec<&'static str> {
            actions
                .iter()
                .filter_map(|x| match x {
                    Action::Send { msg, .. } => Some(msg.kind()),
                    _ => None,
                })
                .collect()
        };
        // Round 1 bootstraps with the full digest (anti-entropy form)...
        let out = a.handle(Event::Tick, 1.0);
        assert_eq!(gossip_kinds(&out), vec!["gossip"]);
        // ...subsequent rounds ship deltas.
        let out = a.handle(Event::Tick, 2.0);
        assert_eq!(gossip_kinds(&out), vec!["gossip_delta"]);
        // The delta carries our heartbeat: the receiver keeps us alive
        // without ever seeing another full digest.
        let delta = out
            .iter()
            .find_map(|x| match x {
                Action::Send { msg: m @ Message::GossipDelta { .. }, .. } => {
                    Some(m.clone())
                }
                _ => None,
            })
            .expect("delta sent");
        b.handle(Event::Message { from: NodeId(0), msg: delta }, 2.1);
        assert!(b.view.is_alive(NodeId(0), 2.1));
    }

    #[test]
    fn locality_penalty_prefers_near_candidates() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        // Equal stakes: node 1 shares n0's region, node 2 is an ocean away.
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 50.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.set_locality(
            0,
            vec![vec![0.005, 0.100], vec![0.100, 0.005]],
            LatencyConfig::default(),
        );
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.view.merge(&vec![(NodeId(2), 1, true, 0, 1)], 0.0);

        let mut near = 0usize;
        let mut far = 0usize;
        for seq in 0..400u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            for act in &a {
                match act {
                    Action::Send { to, msg: Message::Probe { .. } } => {
                        if *to == NodeId(1) {
                            near += 1;
                        } else {
                            far += 1;
                        }
                    }
                    _ => {}
                }
            }
        }
        // Damping 1/(1+50*0.005)=0.8 vs 1/(1+50*0.1)=0.167: ~83% near.
        assert!(
            near > far * 2,
            "locality penalty ignored: near={near} far={far}"
        );
    }

    // ---- live latency estimation (bugfix sweep + tentpole regressions) ------

    #[test]
    fn unknown_region_peer_scores_conservative_latency() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n0 = mk_node(0, NodePolicy::default(), &shared);
        n0.set_locality(
            0,
            vec![vec![0.005, 0.100], vec![0.100, 0.005]],
            LatencyConfig::default(),
        );
        // Known near peer in our own region.
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        // Peer gossiping a garbage region tag (outside the matrix).
        n0.view.merge(&vec![(NodeId(2), 1, true, 0, 9)], 0.0);
        assert_eq!(n0.expected_latency_to(NodeId(1), 0.0), 0.005);
        // Garbage tags and wholly unknown peers both get the worst own-row
        // prior — never region 0's best-row latency.
        assert_eq!(n0.expected_latency_to(NodeId(2), 0.0), 0.100);
        assert_eq!(n0.expected_latency_to(NodeId(77), 0.0), 0.100);
    }

    fn probe_targets(actions: &[Action]) -> Vec<NodeId> {
        actions
            .iter()
            .filter_map(|x| match x {
                Action::Send { to, msg: Message::Probe { .. } } => Some(*to),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn estimator_update_reshapes_the_very_next_draw() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 200.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        // Both regions look equally fast a priori: draws split evenly.
        n0.set_locality(
            0,
            vec![vec![0.001, 0.001], vec![0.001, 0.001]],
            LatencyConfig::default(),
        );
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.view.merge(&vec![(NodeId(2), 1, true, 0, 1)], 0.0);
        let mut far0 = 0usize;
        for seq in 0..300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            far0 += probe_targets(&a).iter().filter(|t| **t == NodeId(2)).count();
        }
        assert!(far0 > 80, "equal priors must split draws: far {far0}/300");
        // Live observation: region 1 just measured a 6 s RTT. Same view
        // clock, same ledger version, same time bucket — only the
        // estimator moved, and the very next draws must see it.
        n0.latency_estimator_mut().unwrap().observe_rtt(1, 6.0, 0.0);
        let mut far1 = 0usize;
        let mut near1 = 0usize;
        for seq in 1000..1300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            for t in probe_targets(&a) {
                if t == NodeId(2) {
                    far1 += 1;
                } else {
                    near1 += 1;
                }
            }
        }
        assert!(
            far1 * 10 < far0,
            "stale snapshot served after estimator update: \
             far {far0} -> {far1}"
        );
        assert!(near1 > 150, "near candidate starved: {near1}");
    }

    #[test]
    fn set_locality_invalidates_snapshot_cache() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 200.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.set_locality(
            0,
            vec![vec![0.001, 0.001], vec![0.001, 0.001]],
            LatencyConfig::default(),
        );
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.view.merge(&vec![(NodeId(2), 1, true, 0, 1)], 0.0);
        let mut far0 = 0usize;
        for seq in 0..300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            far0 += probe_targets(&a).iter().filter(|t| **t == NodeId(2)).count();
        }
        assert!(far0 > 80, "equal matrix must split draws: far {far0}");
        // Re-declare locality with region 1 an ocean away — same instant,
        // same view clock, same ledger version. The reweighted snapshot
        // must not be served stale for up to a gossip interval.
        n0.set_locality(
            0,
            vec![vec![0.001, 1.0], vec![1.0, 0.001]],
            LatencyConfig::default(),
        );
        let mut far1 = 0usize;
        for seq in 1000..1300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            far1 += probe_targets(&a).iter().filter(|t| **t == NodeId(2)).count();
        }
        assert!(
            far1 * 10 < far0,
            "set_locality served a stale snapshot: far {far0} -> {far1}"
        );
    }

    #[test]
    fn no_live_peer_is_explicit_local_execute() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 50.0,
                ..Default::default()
            },
            &shared,
        );
        n0.set_locality(
            0,
            vec![vec![0.005, 0.100], vec![0.100, 0.005]],
            LatencyConfig::default(),
        );
        // Locality active but zero live peers: the nearest-peer term is an
        // explicit None, not a 1e6 sentinel fed into the damping math.
        assert_eq!(n0.nearest_peer_latency(0.0), None);
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert!(
            a.iter().all(|x| !matches!(x, Action::Send { .. })),
            "no-peer case must not probe: {a:?}"
        );
        assert_eq!(n0.backend().running_len(), 1, "must execute locally");
        assert_eq!(n0.stats.served_local, 1);
        // Flat/region-blind nodes keep the zero-latency fast path.
        let n_flat = mk_node(1, NodePolicy::default(), &shared);
        assert_eq!(n_flat.nearest_peer_latency(0.0), Some(0.0));
    }

    #[test]
    fn probe_replies_and_timeouts_feed_the_estimator() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.set_locality(
            0,
            vec![vec![0.005, 0.080], vec![0.080, 0.005]],
            LatencyConfig::default(),
        );
        // The only candidate lives in region 1.
        n0.view.merge(&vec![(NodeId(1), 1, true, 0, 1)], 0.0);
        let prior = n0.latency_estimator().unwrap().expected_from_me(1, 0.0);
        assert_eq!(prior, 0.080);
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        let Action::Send { msg: Message::Probe { req_id, .. }, .. } = a[0]
        else {
            panic!("expected a probe, got {a:?}")
        };
        // The reject answers 0.4 s later: a measured RTT well above the
        // 80 ms prior must raise the estimate.
        n0.handle(
            Event::Message {
                from: NodeId(1),
                msg: Message::ProbeReject { req_id },
            },
            0.4,
        );
        let after_reply =
            n0.latency_estimator().unwrap().expected_from_me(1, 0.4);
        assert!(after_reply > prior, "RTT sample ignored: {after_reply}");
        // The retry probe (sent at 0.4) is never answered: the timeout
        // penalty must push the estimate far beyond anything measured.
        n0.handle(Event::Tick, 5.0);
        assert_eq!(n0.stats.probe_timeouts, 1);
        let after_timeout =
            n0.latency_estimator().unwrap().expected_from_me(1, 5.0);
        assert!(
            after_timeout > 0.3,
            "timeout penalty too weak: {after_timeout}"
        );
    }

    #[test]
    fn gossip_deltas_piggyback_region_rtts_to_same_region_peers() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut a = mk_node(0, NodePolicy::default(), &shared);
        let mut b = mk_node(1, NodePolicy::default(), &shared);
        let prior = vec![vec![0.005, 0.080], vec![0.080, 0.005]];
        a.set_locality(0, prior.clone(), LatencyConfig::default());
        b.set_locality(0, prior, LatencyConfig::default());
        a.view.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        b.view.merge(&vec![(NodeId(0), 1, true, 0, 0)], 0.0);
        // a directly measured region 1 (say via probes).
        a.latency_estimator_mut().unwrap().observe_rtt(1, 2.0, 0.0);
        // Round 1 is the full-digest bootstrap; round 2 ships a delta with
        // the measured row piggybacked (same-region peer, first share).
        a.handle(Event::Tick, 1.0);
        let out = a.handle(Event::Tick, 2.0);
        let delta = out
            .iter()
            .find_map(|x| match x {
                Action::Send { msg: m @ Message::GossipDelta { .. }, .. } => {
                    Some(m.clone())
                }
                _ => None,
            })
            .expect("delta sent");
        let Message::GossipDelta { ref rtts, .. } = delta else {
            unreachable!()
        };
        assert!(
            !rtts.is_empty(),
            "same-region delta must carry RTT summaries"
        );
        // b merges the summary: its estimate moves off the prior with no
        // direct measurement of its own — regions without direct traffic
        // still converge.
        let before = b.latency_estimator().unwrap().expected_from_me(1, 2.1);
        b.handle(Event::Message { from: NodeId(0), msg: delta }, 2.1);
        let after = b.latency_estimator().unwrap().expected_from_me(1, 2.1);
        assert!(
            after > before,
            "piggybacked summary ignored: {before} -> {after}"
        );
    }
}
