//! The WWW.Serve node as a thin composition root: Figure 2's five managers
//! decomposed into a layered pipeline of focused submodules, with `Node`
//! owning the state and routing `Event`s through the layers.
//!
//! * [`dispatch`](super::dispatch) — admission + the probe → delegate →
//!   response state machine (Request Manager), with the accept/offload
//!   *decisions* delegated to the pluggable
//!   [`ParticipationPolicy`](crate::policy::ParticipationPolicy)
//!   (Policy Manager).
//! * [`duel`](super::duel) — duel + judge settlement (§4.2).
//! * [`gossip_driver`](super::gossip_driver) — gossip cadence,
//!   delta/anti-entropy selection, leave/join (Communication Manager).
//! * [`latency_feed`](super::latency_feed) — RTT observe/stamp/touch
//!   plumbing into the live latency estimator.
//! * [`snapshot`](super::snapshot) — cached, policy-scored stake
//!   snapshots for delegation draws (§4.1 hot path).
//! * [`ctx`](super::ctx) — the per-activation borrow bundle the layers
//!   share, plus the memoized alive-peer view for ledger paths.
//!
//! All coordination logic still flows through one interface —
//! `handle(Event, now) -> Vec<Action>` — so the simulator and the TCP
//! runner remain thin drivers around it, and a `Node` with the default
//! participation policy replays the pre-decomposition traces bit for bit
//! (`rust/tests/replay_equivalence.rs`).

use super::ctx::{Ctx, PeerScratch};
use super::dispatch::Dispatch;
use super::duel::DuelCourt;
use super::events::{Action, Event};
use super::gossip_driver::GossipDriver;
use super::latency_feed::LatencyFeed;
use super::ledger_manager::LedgerManager;
use super::msg::Message;
use super::snapshot::Snapshots;
use crate::backend::Backend;
use crate::gossip::{GossipConfig, PeerView};
use crate::latency::{LatencyConfig, LatencyEstimator};
use crate::ledger::{CreditOp, OpReason};
use crate::obs::{FlightRecorder, ObservabilityConfig, SpanKind};
use crate::policy::{
    DefaultPolicy, NodePolicy, ParticipationPolicy, SystemPolicy,
};
use crate::reputation::DefenseState;
use crate::streaming::StreamingConfig;
use crate::types::{ExecKind, NodeId, RequestRecord, Time};
use crate::util::rng::Rng;

/// Counters a node keeps about itself (drives policy + metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    pub user_requests: u64,
    pub delegated_out: u64,
    pub delegated_in: u64,
    pub served_local: u64,
    pub duels_started: u64,
    pub judge_evals: u64,
    pub probe_rejects: u64,
    pub probe_timeouts: u64,
    pub fallback_local: u64,
    /// Delegated responses whose work receipt failed verification (payment
    /// withheld; see `crate::reputation`).
    pub receipt_rejects: u64,
    /// Peer quarantine transitions this node decided on its own evidence.
    pub quarantines: u64,
    /// Piggybacked RTT rows rejected outright as junk (NaN/negative/absurd).
    pub rtts_rejected: u64,
    /// Piggybacked RTT rows clamped by the hearsay cap before ingestion.
    pub rtts_capped: u64,
    /// Delegations a leaving executor NACK'd back to us (streaming churn
    /// NACK — prompt local fallback, no reputation strike).
    pub exec_aborts: u64,
}

pub struct Node {
    pub id: NodeId,
    pub policy: NodePolicy,
    pub system: SystemPolicy,
    pub online: bool,
    /// Topology region this node lives in (0 in single-region worlds).
    pub region: u32,
    /// How this provider participates (accept/offload/scoring decisions).
    /// Defaults to [`DefaultPolicy`]; swap via
    /// [`set_participation`](Node::set_participation).
    participation: Box<dyn ParticipationPolicy>,
    backend: Box<dyn Backend>,
    pub view: PeerView,
    ledger: LedgerManager,
    rng: Rng,
    pub(crate) feed: LatencyFeed,
    pub(crate) snaps: Snapshots,
    pub(crate) dispatch: Dispatch,
    pub(crate) court: DuelCourt,
    pub(crate) gossip: GossipDriver,
    peers: PeerScratch,
    pub stats: NodeStats,
    /// Per-node span ring (see [`crate::obs`]). Starts disabled — every
    /// emission point is a no-op until
    /// [`set_observability`](Node::set_observability) arms it.
    obs: FlightRecorder,
    /// Byzantine-defense state (receipts, reputation, hearsay cap; see
    /// [`crate::reputation`]). Starts fully inert — every check is a no-op
    /// until [`set_defenses`](Node::set_defenses) arms it.
    defense: DefenseState,
    /// Streaming-session knobs (see [`crate::streaming`]). The default is
    /// `enabled: false` — session-blind dispatch, no churn NACK — until
    /// [`set_streaming`](Node::set_streaming) arms it.
    streaming: StreamingConfig,
}

impl Node {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: NodeId,
        policy: NodePolicy,
        system: SystemPolicy,
        backend: Box<dyn Backend>,
        mut ledger: LedgerManager,
        gossip_cfg: GossipConfig,
        seed: u64,
        now: Time,
    ) -> Node {
        // Join the economy: genesis grant + initial stake — unless the
        // ledger already carries our genesis (blockchain mode pre-commits a
        // network-wide genesis block to every replica).
        if ledger.balance(id) + ledger.stake(id) == 0 {
            let mut genesis = vec![CreditOp::Mint {
                to: id,
                amount: system.genesis_credits,
                reason: OpReason::Genesis,
            }];
            let stake = policy.stake.min(system.genesis_credits);
            if stake > 0 {
                genesis.push(CreditOp::Stake { node: id, amount: stake });
            }
            // At construction there are no peers to broadcast to yet; in
            // chain mode a genesis block self-commits on an empty peer list.
            let _ = ledger.submit(genesis, id, &[], now);
        }

        Node {
            id,
            policy,
            system,
            online: true,
            region: 0,
            participation: Box::new(DefaultPolicy),
            backend,
            view: PeerView::new(id, gossip_cfg, now),
            ledger,
            // detlint:allow(D003) reason="per-node RNG lineage root, derived from the world seed"
            rng: Rng::new(seed ^ (0x9E37 + id.0 as u64)),
            feed: LatencyFeed::new(),
            snaps: Snapshots::new(),
            dispatch: Dispatch::new(),
            court: DuelCourt::new(),
            gossip: GossipDriver::new(now),
            peers: PeerScratch::default(),
            stats: NodeStats::default(),
            obs: FlightRecorder::disabled(),
            defense: DefenseState::default(),
            streaming: StreamingConfig::default(),
        }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Mutable backend access for the runner-side capacity levers (the
    /// elastic controller's `set_slots`) and tests.
    pub fn backend_mut(&mut self) -> &mut dyn Backend {
        self.backend.as_mut()
    }

    pub fn ledger(&self) -> &LedgerManager {
        &self.ledger
    }

    pub fn ledger_mut(&mut self) -> &mut LedgerManager {
        &mut self.ledger
    }

    pub fn credits(&self) -> u64 {
        self.ledger.balance(self.id) + self.ledger.stake(self.id)
    }

    /// Install a participation behaviour (see
    /// [`ParticipationPolicy`]). [`DefaultPolicy`] reproduces the scalar
    /// `NodePolicy` knob behaviour draw-for-draw, so installing it is a
    /// no-op.
    pub fn set_participation(&mut self, p: Box<dyn ParticipationPolicy>) {
        self.participation = p;
    }

    pub fn participation(&self) -> &dyn ParticipationPolicy {
        self.participation.as_ref()
    }

    /// Arm (or re-arm) this node's flight recorder. With
    /// `enabled: false` this is equivalent to the default inert recorder.
    pub fn set_observability(&mut self, cfg: ObservabilityConfig) {
        self.obs = FlightRecorder::new(cfg);
    }

    /// Read access to the recorded span ring.
    pub fn flight_recorder(&self) -> &FlightRecorder {
        &self.obs
    }

    /// Arm (or re-arm) this node's Byzantine defenses. The default
    /// [`DefenseState`] is fully inert; installing one with
    /// `cfg.enabled == false` is equivalent.
    pub fn set_defenses(&mut self, state: DefenseState) {
        self.defense = state;
    }

    /// Read access to the defense layer (reputation book, config).
    pub fn defense_state(&self) -> &DefenseState {
        &self.defense
    }

    /// Arm (or re-arm) this node's streaming-session behaviour (KV-affine
    /// dispatch + churn NACK; see [`crate::streaming`]). The default
    /// config is fully inert.
    pub fn set_streaming(&mut self, cfg: StreamingConfig) {
        self.streaming = cfg;
    }

    pub fn streaming(&self) -> &StreamingConfig {
        &self.streaming
    }

    // ---- locality (topology awareness) --------------------------------------

    /// Install this node's region and the pristine inter-region latency
    /// matrix as the live estimator's cold-start prior (the simulator
    /// derives it from its `Topology`; the TCP runner would bootstrap from
    /// configuration). Makes `latency_penalty` effective: from here on,
    /// dispatch scores peers with *measured* EWMA latency seeded from this
    /// prior. An empty matrix clears locality (region-blind dispatch).
    pub fn set_locality(
        &mut self,
        region: u32,
        prior: Vec<Vec<f64>>,
        cfg: LatencyConfig,
    ) {
        self.region = region;
        self.feed.set_locality(region, prior, cfg);
        self.view.set_region(region);
    }

    /// Read access to the live latency estimator (None = region-blind).
    pub fn latency_estimator(&self) -> Option<&LatencyEstimator> {
        self.feed.estimator()
    }

    /// Mutable access for tests and external instrumentation (a TCP runner
    /// measuring transport-level RTTs can feed them here directly).
    pub fn latency_estimator_mut(&mut self) -> Option<&mut LatencyEstimator> {
        self.feed.estimator_mut()
    }

    /// Borrow-split the node into the shared substrate (one [`Ctx`]) and
    /// the three stateful layers.
    fn split(
        &mut self,
    ) -> (Ctx<'_>, &mut Dispatch, &mut DuelCourt, &mut GossipDriver) {
        let Node {
            id,
            policy,
            system,
            participation,
            backend,
            view,
            ledger,
            rng,
            feed,
            snaps,
            dispatch,
            court,
            gossip,
            peers,
            stats,
            obs,
            defense,
            streaming,
            ..
        } = self;
        (
            Ctx {
                id: *id,
                policy,
                system,
                participation: participation.as_ref(),
                backend: backend.as_mut(),
                view,
                ledger,
                rng,
                feed,
                snaps,
                stats,
                peers,
                obs,
                defense,
                streaming,
            },
            dispatch,
            court,
            gossip,
        )
    }

    // ---- the event loop ----------------------------------------------------

    pub fn handle(&mut self, event: Event, now: Time) -> Vec<Action> {
        if !self.online {
            // Offline nodes drop everything except Join.
            if matches!(event, Event::Join) {
                return self.on_join(now);
            }
            return vec![];
        }
        let mut actions = match event {
            Event::UserRequest(req) => {
                let (mut ctx, dispatch, court, _) = self.split();
                dispatch.on_user_request(&mut ctx, court, req, now)
            }
            Event::Message { from, msg } => self.on_message(from, msg, now),
            Event::Tick => self.on_tick(now),
            Event::BackendWake => vec![],
            Event::Leave => return self.on_leave(now),
            Event::Join => vec![], // already online
        };
        // Collect backend completions on every activation.
        actions.extend(self.pump_backend(now));
        // Keep the runner informed of the next backend event.
        if let Some(t) = self.backend.next_event() {
            actions.push(Action::WakeAt(t));
        }
        actions
    }

    /// Route one peer message to its layer.
    fn on_message(
        &mut self,
        from: NodeId,
        msg: Message,
        now: Time,
    ) -> Vec<Action> {
        let (mut ctx, dispatch, court, _gossip) = self.split();
        match msg {
            Message::Probe { req_id, prompt_tokens, output_tokens } => {
                Dispatch::on_probe(
                    &mut ctx,
                    from,
                    req_id,
                    prompt_tokens,
                    output_tokens,
                )
            }
            Message::ProbeAccept { req_id } => {
                dispatch.on_probe_accept(&mut ctx, from, req_id, now)
            }
            Message::ProbeReject { req_id } => {
                dispatch.on_probe_reject(&mut ctx, from, req_id, now)
            }
            Message::Delegate { request, duel } => {
                dispatch.on_delegate(&mut ctx, from, request, duel, now)
            }
            Message::KvTransfer { request, session: _, kv_bytes } => {
                // The session's KV cache traveled with the request (the
                // fabric already priced the bytes via wire size); record
                // the landing, then treat it as a plain delegation.
                ctx.obs.span(
                    request.id,
                    SpanKind::KvTransfer,
                    ctx.id,
                    Some(from),
                    now,
                    kv_bytes,
                );
                dispatch.on_delegate(&mut ctx, from, request, false, now)
            }
            Message::ExecAbort { req_id } => {
                dispatch.on_exec_abort(&mut ctx, from, req_id, now)
            }
            Message::DelegateResponse { response, duel, receipt } => {
                // The executor's answer proves the path to its region is
                // alive (its timing mixes compute with network, so it only
                // refreshes estimator freshness, not the EWMA).
                ctx.feed.touch_peer(ctx.view, from, now);
                if duel {
                    // Duel copies are judged on content; the primary copy's
                    // receipt gates the payment (see `dispatch::on_response`).
                    court.on_duel_response(
                        &mut ctx,
                        dispatch.pending_mut(),
                        response,
                        now,
                    )
                } else {
                    dispatch.on_response(&mut ctx, response, receipt, now)
                }
            }
            Message::Gossip { digest } => {
                GossipDriver::on_gossip(&mut ctx, from, &digest, now)
            }
            Message::GossipReply { digest } => {
                GossipDriver::on_gossip_reply(&mut ctx, from, &digest, now)
            }
            Message::GossipDelta { delta, heartbeats, rtts, rep } => {
                GossipDriver::on_delta(
                    &mut ctx, from, &delta, &heartbeats, &rtts, &rep, now,
                )
            }
            Message::GossipDeltaReply { delta, heartbeats, rtts, rep } => {
                GossipDriver::on_delta_reply(
                    &mut ctx, from, &delta, &heartbeats, &rtts, &rep, now,
                )
            }
            Message::JudgeAssign { duel_id, resp_a, resp_b, est_tokens } => {
                court.on_judge_assign(
                    &mut ctx, from, duel_id, resp_a, resp_b, est_tokens, now,
                )
            }
            Message::JudgeVerdict { duel_id, winner } => court.on_judge_verdict(
                &mut ctx,
                dispatch.pending_mut(),
                from,
                duel_id,
                winner,
                now,
            ),
            m @ (Message::BlockProposal { .. }
            | Message::BlockVote { .. }
            | Message::BlockCommit { .. }
            | Message::ChainRequest { .. }
            | Message::ChainSnapshot { .. }
            | Message::ChainDelta { .. }) => {
                ctx.ledger_on_message(from, &m, now)
            }
        }
    }

    // ---- tick: gossip + maintenance + timeouts ------------------------------

    fn on_tick(&mut self, now: Time) -> Vec<Action> {
        let (mut ctx, dispatch, court, gossip) = self.split();

        // Gossip round (delta/anti-entropy cadence + suspicion probe).
        let mut actions = gossip.tick(&mut ctx, now);

        // Ledger retries (chain mode head races). Shared mode has no ledger
        // traffic — skip even the memoized alive-peer lookup.
        actions.extend(ctx.ledger_tick(now));

        // Stake maintenance (user-level policy, §4.3): a rational provider
        // tops its stake back up to its declared target after duel slashes —
        // staying out of the PoS pool earns nothing. Providers whose balance
        // has drained cannot refill and fade out of selection, which is
        // exactly the Theorem-5.8 phase-out dynamic.
        let part = ctx.participation;
        if part.maintains_stake(ctx.policy) {
            let stake = ctx.ledger.stake(ctx.id);
            let balance = ctx.ledger.balance(ctx.id);
            if stake < ctx.policy.stake && balance > 0 {
                let amount = (ctx.policy.stake - stake).min(balance);
                let ops = vec![CreditOp::Stake { node: ctx.id, amount }];
                actions.extend(ctx.ledger_submit(ops, now));
            }
        }

        // Queue rebalancing: while overloaded, pull our own newest waiting
        // requests back out of the backend and re-dispatch them through the
        // market (user-level policy, §4.3 — "offload tasks once local
        // workload surpasses a predefined threshold").
        if part.rebalances_queue(ctx.policy) {
            let util = ctx.backend.utilization();
            let qlen = ctx.backend.queue_len();
            if util >= ctx.policy.target_utilization
                && qlen > ctx.policy.queue_threshold
            {
                let excess = qlen - ctx.policy.queue_threshold;
                for req in ctx.backend.steal_queued(excess.min(4)) {
                    if ctx.rng.chance(ctx.policy.offload_freq) {
                        actions.extend(
                            dispatch.try_delegate(&mut ctx, court, req, now),
                        );
                    } else {
                        ctx.backend.submit(req, ExecKind::Local, now);
                    }
                }
            }
        }

        // Timeout scan.
        actions.extend(dispatch.expire(&mut ctx, court, now));
        actions
    }

    // ---- backend pump (Model manager) ---------------------------------------

    fn pump_backend(&mut self, now: Time) -> Vec<Action> {
        let completions = self.backend.advance(now);
        if completions.is_empty() {
            return vec![];
        }
        let (mut ctx, dispatch, court, _gossip) = self.split();
        let mut actions = Vec::new();
        for c in completions {
            if let Some(t) = c.first_token_at {
                // Purely observational streaming spans: where prefill
                // actually began (after queueing) and when the first
                // token came out. Replay-neutral like every span.
                ctx.obs.span(
                    c.request.id,
                    SpanKind::PrefillStart,
                    ctx.id,
                    None,
                    c.started_at,
                    0,
                );
                ctx.obs.span(
                    c.request.id,
                    SpanKind::FirstToken,
                    ctx.id,
                    None,
                    t,
                    ((t - c.request.submitted_at).max(0.0) * 1e6) as u64,
                );
            }
            match c.kind {
                ExecKind::Local => {
                    // Our own user's request, served locally.
                    ctx.obs.span(
                        c.request.id,
                        SpanKind::ExecuteEnd,
                        ctx.id,
                        None,
                        c.finished_at,
                        super::ctx::exec_kind_code(ExecKind::Local),
                    );
                    // A locally served session turn leaves its KV here.
                    dispatch.note_session_completion(&ctx, &c.request, ctx.id);
                    actions.push(Action::Done(RequestRecord {
                        id: c.request.id,
                        origin: ctx.id,
                        executor: ctx.id,
                        kind: ExecKind::Local,
                        prompt_tokens: c.request.prompt_tokens,
                        output_tokens: c.request.output_tokens,
                        submitted_at: c.request.submitted_at,
                        completed_at: c.finished_at,
                        slo_deadline: c.request.slo_deadline,
                        synthetic: c.request.synthetic,
                        session: c.request.session,
                        ttft_deadline: c.request.ttft_deadline,
                        first_token_at: c.first_token_at,
                    }));
                }
                ExecKind::Delegated | ExecKind::Duel => {
                    actions.extend(dispatch.on_exec_completion(&mut ctx, c));
                }
                ExecKind::Judge => {
                    actions.extend(court.on_judge_completion(&mut ctx, c));
                }
            }
        }
        actions
    }

    // ---- dynamic participation ----------------------------------------------

    fn on_leave(&mut self, now: Time) -> Vec<Action> {
        self.online = false;
        let (mut ctx, dispatch, _c, gossip) = self.split();
        let mut actions = gossip.on_leave(&mut ctx, now);
        // Churn NACK (streaming): an honest leaver owes its requesters a
        // goodbye, not silence. NACK every delegation we still hold so
        // origins fall back locally at once instead of waiting out the
        // response timeout and filing a Byzantine-grade timeout strike.
        if ctx.streaming.enabled && ctx.streaming.churn_nack {
            for (req_id, origin) in dispatch.take_exec_tickets() {
                actions.push(Action::Send {
                    to: origin,
                    msg: Message::ExecAbort { req_id },
                });
            }
        }
        actions
    }

    fn on_join(&mut self, now: Time) -> Vec<Action> {
        self.online = true;
        let mut actions = {
            let (mut ctx, _d, _c, gossip) = self.split();
            gossip.on_join(&mut ctx, now)
        };
        if let Some(t) = self.backend.next_event() {
            actions.push(Action::WakeAt(t));
        }
        actions
    }
}

/// Shared constructors for the coordinator layer tests (each extracted
/// module keeps its pre-decomposition tests next to the code it pins).
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::backend::{Profile, SimBackend};
    use crate::ledger::SharedLedger;
    use crate::types::{Request, RequestId};
    use std::sync::{Arc, Mutex};

    pub fn mk_node(
        id: u32,
        policy: NodePolicy,
        shared: &Arc<Mutex<SharedLedger>>,
    ) -> Node {
        Node::new(
            NodeId(id),
            policy,
            SystemPolicy::default(),
            Box::new(SimBackend::new(Profile::test(50.0, 4))),
            LedgerManager::shared(shared.clone()),
            GossipConfig::default(),
            42,
            0.0,
        )
    }

    pub fn user_req(origin: u32, seq: u64, now: Time) -> Request {
        Request {
            id: RequestId { origin: NodeId(origin), seq },
            prompt_tokens: 100,
            output_tokens: 100,
            submitted_at: now,
            slo_deadline: 60.0,
            synthetic: false,
            payload: vec![],
            session: 0,
            ttft_deadline: f64::INFINITY,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{mk_node, user_req};
    use super::*;
    use crate::ledger::SharedLedger;
    use std::sync::{Arc, Mutex};

    #[test]
    fn genesis_grants_credits_and_stake() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let n = mk_node(0, NodePolicy::default(), &shared);
        let sys = SystemPolicy::default();
        assert_eq!(
            n.ledger().balance(NodeId(0)),
            sys.genesis_credits - NodePolicy::default().stake
        );
        assert_eq!(n.ledger().stake(NodeId(0)), NodePolicy::default().stake);
    }

    #[test]
    fn idle_node_serves_locally() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n = mk_node(0, NodePolicy::default(), &shared);
        let actions = n.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        // No sends (no offload — idle backend), just a wake for completion.
        assert!(actions
            .iter()
            .all(|a| matches!(a, Action::WakeAt(_))));
        // Run to completion.
        let done = n.handle(Event::BackendWake, 100.0);
        let recs: Vec<_> = done
            .iter()
            .filter_map(|a| match a {
                Action::Done(r) => Some(r),
                _ => None,
            })
            .collect();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].executor, NodeId(0));
        assert_eq!(recs[0].kind, ExecKind::Local);
        assert!(!recs[0].synthetic);
    }

    #[test]
    fn offline_node_drops_events_until_join() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let mut n = mk_node(0, NodePolicy::default(), &shared);
        n.handle(Event::Leave, 1.0);
        assert!(!n.online);
        let a = n.handle(Event::UserRequest(user_req(0, 0, 2.0)), 2.0);
        assert!(a.is_empty());
        assert_eq!(n.backend().running_len(), 0);
        n.handle(Event::Join, 3.0);
        assert!(n.online);
        n.handle(Event::UserRequest(user_req(0, 1, 4.0)), 4.0);
        assert_eq!(n.backend().running_len(), 1);
    }

    #[test]
    fn requester_only_node_always_delegates() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(0, NodePolicy::requester_only(), &shared);
        n0.system.duel_rate = 0.0;
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert!(a
            .iter()
            .any(|x| matches!(x, Action::Send { msg: Message::Probe { .. }, .. })));
        assert_eq!(n0.backend().running_len(), 0);
    }

}
