//! Cached, policy-scored delegation candidate snapshots (§4.1 hot path).
//!
//! Rebuilding the stake-weighted candidate set per request re-collects the
//! stake table, re-filters liveness and rebuilds the alias sampler; at
//! fleet scale that dominates dispatch. [`Snapshots`] keys the cache on
//! everything the snapshot reads: the gossip view's mutation clock
//! (liveness + region tags), the ledger's stake version, a coarse time
//! bucket that bounds heartbeat-aging staleness to one gossip interval,
//! and the latency feed's `(locality epoch, estimator version)` pair — so
//! a rerouting-sized estimate change reshapes the very next draw instead
//! of serving a stale reweighted snapshot for up to a gossip interval.
//!
//! Candidate *scoring* is delegated to the node's
//! [`ParticipationPolicy`]: the policy says whether a reweight pass runs
//! at all and what each candidate's multiplier is, given the live latency
//! estimate to it. The default policy reproduces the classic
//! `1 / (1 + latency_penalty × latency)` stake damping.

use super::latency_feed::LatencyFeed;
use super::ledger_manager::LedgerManager;
use crate::gossip::PeerView;
use crate::policy::{NodePolicy, ParticipationPolicy};
use crate::pos::StakeSnapshot;
use crate::reputation::ReputationBook;
use crate::types::{NodeId, Time};
use crate::util::rng::Rng;

struct SnapCache {
    view_clock: u64,
    ledger_version: u64,
    time_bucket: u64,
    locality_epoch: u64,
    estimator_version: u64,
    rep_version: u64,
    snap: StakeSnapshot,
}

/// Lazily rebuilt, alias-prepared stake snapshot for delegation draws.
#[derive(Default)]
pub(crate) struct Snapshots {
    cache: Option<SnapCache>,
}

impl Snapshots {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure the cached snapshot is current. With locality information
    /// and a scoring policy, each candidate's stake is damped by the
    /// policy's weight given the **live** EWMA latency estimate to the
    /// candidate's region — nearer peers win ties, distant continents fade
    /// from selection, and an observably degraded or partitioned path
    /// fades within a few observations. Flat worlds skip the reweight
    /// entirely. The rebuilt snapshot is alias-prepared, so every
    /// subsequent draw is O(1).
    ///
    /// With a reputation book (`rep`, defenses on) the snapshot is also
    /// reputation-gated: quarantined peers are dropped outright and the
    /// remaining candidates' stakes are damped by their effective
    /// reputation weight — a misbehaving peer fades from selection long
    /// before its stake drains. `rep: None` (defenses off) is bit-exactly
    /// the pre-defense snapshot.
    #[allow(clippy::too_many_arguments)]
    pub fn refresh(
        &mut self,
        id: NodeId,
        policy: &NodePolicy,
        participation: &dyn ParticipationPolicy,
        view: &PeerView,
        ledger: &LedgerManager,
        feed: &LatencyFeed,
        rep: Option<&ReputationBook>,
        now: Time,
    ) {
        let view_clock = view.clock();
        let ledger_version = ledger.stake_version();
        let interval = view.config().interval.max(1e-6);
        let time_bucket = (now / interval) as u64;
        let (locality_epoch, estimator_version) = feed.cache_key();
        let rep_version = rep.map_or(0, |b| b.version());
        if let Some(c) = &self.cache {
            if c.view_clock == view_clock
                && c.ledger_version == ledger_version
                && c.time_bucket == time_bucket
                && c.locality_epoch == locality_epoch
                && c.estimator_version == estimator_version
                && c.rep_version == rep_version
            {
                return;
            }
        }
        let mut snap = StakeSnapshot::new(&ledger.stakes(), Some(id));
        snap.retain(|n| view.is_alive(n, now));
        if let Some(book) = rep {
            snap.retain(|n| !book.is_quarantined(n));
        }
        if participation.scores_candidates(policy, feed.has_estimator()) {
            snap.reweight(|n| {
                participation.candidate_weight(
                    policy,
                    feed.expected_latency_to(view, n, now),
                )
            });
        }
        if let Some(book) = rep {
            snap.reweight(|n| book.weight(n, now));
        }
        snap.prepare();
        self.cache = Some(SnapCache {
            view_clock,
            ledger_version,
            time_bucket,
            locality_epoch,
            estimator_version,
            rep_version,
            snap,
        });
    }

    /// Candidate count of the current snapshot (0 before any refresh).
    pub fn candidates(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.snap.len())
    }

    /// Is `n` a live, non-quarantined candidate in the current snapshot?
    /// Used by KV-affine dispatch to decide whether a session's home node
    /// is still worth probing (0-candidate / pre-refresh states say no).
    pub fn contains(&self, n: NodeId) -> bool {
        self.cache.as_ref().is_some_and(|c| c.snap.nodes().contains(&n))
    }

    /// One stake-proportional draw from the prepared snapshot.
    /// Panics if no [`refresh`](Snapshots::refresh) preceded it — draws
    /// are only meaningful against a current snapshot.
    pub fn sample(&self, rng: &mut Rng) -> Option<NodeId> {
        self.cache.as_ref().expect("refresh before sampling").snap.sample(rng)
    }

    /// Draw k distinct candidates (duel executors).
    pub fn sample_distinct(&self, rng: &mut Rng, k: usize) -> Vec<NodeId> {
        self.cache
            .as_ref()
            .expect("refresh before sampling")
            .snap
            .sample_distinct(rng, k)
    }

    /// Clone the current snapshot for exclusion-filtered draws (judge
    /// committees exclude the duel executors; duels are rare, so the
    /// clone stays off the per-request path).
    pub fn clone_snapshot(&self) -> StakeSnapshot {
        self.cache.as_ref().expect("refresh before cloning").snap.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::super::events::{Action, Event};
    use super::super::msg::Message;
    use super::super::node::testutil::{mk_node, user_req};
    use crate::latency::LatencyConfig;
    use crate::ledger::SharedLedger;
    use crate::policy::NodePolicy;
    use crate::types::NodeId;
    use std::sync::{Arc, Mutex};

    fn probes_to(actions: &[Action]) -> Vec<NodeId> {
        actions
            .iter()
            .filter_map(|x| match x {
                Action::Send { to, msg: Message::Probe { .. } } => Some(*to),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn snapshot_cache_tracks_liveness_and_ledger() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        // Two back-to-back requests: the second reuses the cached snapshot
        // (same view clock, ledger version and time bucket) and still
        // probes the live peer.
        let a = n0.handle(Event::UserRequest(user_req(0, 0, 0.0)), 0.0);
        assert_eq!(probes_to(&a), vec![NodeId(1)]);
        let a = n0.handle(Event::UserRequest(user_req(0, 1, 0.0)), 0.0);
        assert_eq!(probes_to(&a), vec![NodeId(1)]);
        // The peer ages out (suspect_after 5 s): with no view mutation at
        // all, the time-bucket key alone must force a rebuild that drops
        // it — stale caches must not delegate to the dead.
        let a = n0.handle(Event::UserRequest(user_req(0, 2, 20.0)), 20.0);
        assert!(probes_to(&a).is_empty());
        assert_eq!(n0.stats.fallback_local, 1);
        // A newly staked + gossiped peer invalidates via clock/version and
        // becomes the only candidate.
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        n0.view.merge(&[(NodeId(2), 1, true, 0, 0)], 20.0);
        let a = n0.handle(Event::UserRequest(user_req(0, 3, 20.5)), 20.5);
        assert_eq!(probes_to(&a), vec![NodeId(2)]);
    }

    #[test]
    fn estimator_update_reshapes_the_very_next_draw() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 200.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        // Both regions look equally fast a priori: draws split evenly.
        n0.set_locality(
            0,
            vec![vec![0.001, 0.001], vec![0.001, 0.001]],
            LatencyConfig::default(),
        );
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.view.merge(&[(NodeId(2), 1, true, 0, 1)], 0.0);
        let mut far0 = 0usize;
        for seq in 0..300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            far0 += probes_to(&a).iter().filter(|t| **t == NodeId(2)).count();
        }
        assert!(far0 > 80, "equal priors must split draws: far {far0}/300");
        // Live observation: region 1 just measured a 6 s RTT. Same view
        // clock, same ledger version, same time bucket — only the
        // estimator moved, and the very next draws must see it.
        n0.latency_estimator_mut().unwrap().observe_rtt(1, 6.0, 0.0);
        let mut far1 = 0usize;
        let mut near1 = 0usize;
        for seq in 1000..1300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            for t in probes_to(&a) {
                if t == NodeId(2) {
                    far1 += 1;
                } else {
                    near1 += 1;
                }
            }
        }
        assert!(
            far1 * 10 < far0,
            "stale snapshot served after estimator update: \
             far {far0} -> {far1}"
        );
        assert!(near1 > 150, "near candidate starved: {near1}");
    }

    #[test]
    fn set_locality_invalidates_snapshot_cache() {
        let shared = Arc::new(Mutex::new(SharedLedger::new()));
        let _n1 = mk_node(1, NodePolicy::default(), &shared);
        let _n2 = mk_node(2, NodePolicy::default(), &shared);
        let mut n0 = mk_node(
            0,
            NodePolicy {
                target_utilization: 0.0,
                offload_freq: 1.0,
                latency_penalty: 200.0,
                ..Default::default()
            },
            &shared,
        );
        n0.system.duel_rate = 0.0;
        n0.set_locality(
            0,
            vec![vec![0.001, 0.001], vec![0.001, 0.001]],
            LatencyConfig::default(),
        );
        n0.view.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        n0.view.merge(&[(NodeId(2), 1, true, 0, 1)], 0.0);
        let mut far0 = 0usize;
        for seq in 0..300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            far0 += probes_to(&a).iter().filter(|t| **t == NodeId(2)).count();
        }
        assert!(far0 > 80, "equal matrix must split draws: far {far0}");
        // Re-declare locality with region 1 an ocean away — same instant,
        // same view clock, same ledger version. The reweighted snapshot
        // must not be served stale for up to a gossip interval.
        n0.set_locality(
            0,
            vec![vec![0.001, 1.0], vec![1.0, 0.001]],
            LatencyConfig::default(),
        );
        let mut far1 = 0usize;
        for seq in 1000..1300u64 {
            let a = n0.handle(Event::UserRequest(user_req(0, seq, 0.0)), 0.0);
            far1 += probes_to(&a).iter().filter(|t| **t == NodeId(2)).count();
        }
        assert!(
            far1 * 10 < far0,
            "set_locality served a stale snapshot: far {far0} -> {far1}"
        );
    }
}
