//! Duel-and-judge mechanism (§4.2, Figure 3).
//!
//! A fraction `p_d` of delegated requests become *duels*: the originator
//! dispatches the same request to two PoS-sampled executors, then sends both
//! responses to `k` PoS-sampled judges for pairwise comparison. The majority
//! winner earns `R_add`, the loser is slashed `P`, and each judge earns a
//! judge reward. This module holds the originator-side state machine and the
//! judge's comparison logic; message transport lives in the coordinator.
//!
//! Quality model (simulation substitution — DESIGN.md §2): an executor with
//! intrinsic quality `q_i` produces responses whose hidden quality is
//! `q_i + Normal(0, σ_resp)`; a judge perceives each with additional
//! `Normal(0, σ_judge)` noise and votes for the higher perception. The
//! resulting class-level win rates reproduce Figure 6's measured 0.57 /
//! 0.53 / 0.39 style gaps.

use std::collections::BTreeMap;

use crate::types::{NodeId, Request, Response, Time};
use crate::util::rng::Rng;

/// Response-generation noise (variation between a node's own answers).
/// Calibrated, together with the tier quality gaps in
/// `backend::profiles`, so class-level duel win rates land near Figure 6a's
/// measured 0.57 / 0.53 / 0.39 — LLM-judge comparisons on reasoning answers
/// are *noisy* (a 0.6B model still wins 39% of its duels in the paper).
pub const SIGMA_RESPONSE: f64 = 0.40;
/// Judge perception noise (inter-rater disagreement).
pub const SIGMA_JUDGE: f64 = 0.08;

/// Draw the hidden quality of a response from a node with intrinsic q.
pub fn draw_response_quality(q: f64, rng: &mut Rng) -> f64 {
    rng.normal_ms(q, SIGMA_RESPONSE)
}

/// A judge's pairwise comparison: returns the executor it votes for.
pub fn judge_compare(a: &Response, b: &Response, rng: &mut Rng) -> NodeId {
    let pa = a.quality + rng.normal_ms(0.0, SIGMA_JUDGE);
    let pb = b.quality + rng.normal_ms(0.0, SIGMA_JUDGE);
    if pa >= pb {
        a.executor
    } else {
        b.executor
    }
}

/// Progress of one duel at its originator.
#[derive(Debug, Clone)]
pub struct DuelState {
    pub request: Request,
    pub executors: [NodeId; 2],
    pub responses: Vec<Response>,
    pub judges: Vec<NodeId>,
    pub verdicts: Vec<(NodeId, NodeId)>, // (judge, voted-for executor)
    /// Whether the user has already been answered (first response wins the
    /// latency race; the duel settles afterwards).
    pub user_answered: bool,
    pub started_at: Time,
}

/// Outcome of a settled duel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DuelOutcome {
    pub winner: NodeId,
    pub loser: NodeId,
    /// Votes for the winner (out of the verdicts received).
    pub votes_for_winner: usize,
    pub votes_total: usize,
}

impl DuelState {
    pub fn new(request: Request, executors: [NodeId; 2], now: Time) -> Self {
        DuelState {
            request,
            executors,
            responses: Vec::with_capacity(2),
            judges: Vec::new(),
            verdicts: Vec::new(),
            user_answered: false,
            started_at: now,
        }
    }

    /// Record an executor response. Returns true when both are in.
    pub fn add_response(&mut self, resp: Response) -> bool {
        if self.executors.contains(&resp.executor)
            && !self.responses.iter().any(|r| r.executor == resp.executor)
        {
            self.responses.push(resp);
        }
        self.responses.len() == 2
    }

    pub fn assign_judges(&mut self, judges: Vec<NodeId>) {
        self.judges = judges;
    }

    /// Record a verdict. Returns the outcome once all judges have voted.
    pub fn add_verdict(&mut self, judge: NodeId, winner: NodeId) -> Option<DuelOutcome> {
        if !self.judges.contains(&judge)
            || self.verdicts.iter().any(|(j, _)| *j == judge)
            || !self.executors.contains(&winner)
        {
            return None; // unsolicited / duplicate / nonsense vote
        }
        self.verdicts.push((judge, winner));
        if self.verdicts.len() == self.judges.len() {
            Some(self.tally())
        } else {
            None
        }
    }

    /// Majority tally, decided by an explicit deterministic ladder:
    ///
    /// 1. **Vote majority** — more judge votes wins.
    /// 2. **Response quality** — on a tied vote (k=2 makes ties common, and
    ///    "no verdicts at all" is the degenerate 0–0 tie), the raw pairwise
    ///    comparison of the two responses decides: the originator casts the
    ///    deciding comparison, so ties still carry the quality signal
    ///    rather than rewarding whoever answered faster. A missing
    ///    response scores `-inf`, so a no-show can never win against any
    ///    real answer.
    /// 3. **Lower node id** — an *exact* quality tie (both responses
    ///    missing, or bit-identical qualities) goes to the lower-numbered
    ///    executor. This never depends on the sampling order of
    ///    `executors`, so the outcome is a pure function of the duel's
    ///    contents. (At runtime qualities are continuous draws, so this
    ///    rung only fires in degenerate/crafted states.)
    pub fn tally(&self) -> DuelOutcome {
        let count = |n: NodeId| {
            self.verdicts.iter().filter(|(_, w)| *w == n).count()
        };
        let (a, b) = (self.executors[0], self.executors[1]);
        let (va, vb) = (count(a), count(b));
        let quality_of = |n: NodeId| {
            self.responses
                .iter()
                .find(|r| r.executor == n)
                .map(|r| r.quality)
                .unwrap_or(f64::NEG_INFINITY)
        };
        let (qa, qb) = (quality_of(a), quality_of(b));
        let a_wins = if va != vb {
            va > vb
        } else if qa != qb {
            qa > qb
        } else {
            a.0 < b.0
        };
        let (winner, loser, votes) =
            if a_wins { (a, b, va) } else { (b, a, vb) };
        DuelOutcome {
            winner,
            loser,
            votes_for_winner: votes,
            votes_total: self.verdicts.len(),
        }
    }
}

/// Per-node duel statistics (Figure 6 right panels).
#[derive(Debug, Clone, Default)]
pub struct DuelStats {
    pub wins: BTreeMap<NodeId, usize>,
    pub losses: BTreeMap<NodeId, usize>,
}

impl DuelStats {
    pub fn record(&mut self, outcome: &DuelOutcome) {
        *self.wins.entry(outcome.winner).or_insert(0) += 1;
        *self.losses.entry(outcome.loser).or_insert(0) += 1;
    }

    pub fn win_rate(&self, node: NodeId) -> f64 {
        let w = self.wins.get(&node).copied().unwrap_or(0);
        let l = self.losses.get(&node).copied().unwrap_or(0);
        if w + l == 0 {
            return 0.0;
        }
        w as f64 / (w + l) as f64
    }

    pub fn total_duels(&self) -> usize {
        self.wins.values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::RequestId;

    fn req() -> Request {
        Request {
            id: RequestId { origin: NodeId(0), seq: 1 },
            prompt_tokens: 10,
            output_tokens: 10,
            submitted_at: 0.0,
            slo_deadline: 100.0,
            synthetic: false,
            payload: vec![],
            session: 0,
            ttft_deadline: f64::INFINITY,
        }
    }

    fn resp(executor: u32, quality: f64, at: Time) -> Response {
        Response {
            id: RequestId { origin: NodeId(0), seq: 1 },
            executor: NodeId(executor),
            quality,
            finished_at: at,
            first_token_at: None,
            tokens: vec![],
        }
    }

    #[test]
    fn duel_lifecycle() {
        let mut d = DuelState::new(req(), [NodeId(1), NodeId(2)], 0.0);
        assert!(!d.add_response(resp(1, 0.8, 1.0)));
        assert!(d.add_response(resp(2, 0.6, 2.0)));
        d.assign_judges(vec![NodeId(3), NodeId(4)]);
        assert!(d.add_verdict(NodeId(3), NodeId(1)).is_none());
        let out = d.add_verdict(NodeId(4), NodeId(1)).unwrap();
        assert_eq!(out.winner, NodeId(1));
        assert_eq!(out.loser, NodeId(2));
        assert_eq!(out.votes_for_winner, 2);
        assert_eq!(out.votes_total, 2);
    }

    #[test]
    fn rejects_bogus_responses_and_votes() {
        let mut d = DuelState::new(req(), [NodeId(1), NodeId(2)], 0.0);
        // Response from a non-executor ignored.
        assert!(!d.add_response(resp(9, 0.9, 1.0)));
        assert_eq!(d.responses.len(), 0);
        // Duplicate executor response ignored.
        d.add_response(resp(1, 0.8, 1.0));
        assert!(!d.add_response(resp(1, 0.9, 2.0)));
        assert_eq!(d.responses.len(), 1);
        d.add_response(resp(2, 0.5, 3.0));
        d.assign_judges(vec![NodeId(3)]);
        // Vote from a non-judge ignored.
        assert!(d.add_verdict(NodeId(8), NodeId(1)).is_none());
        // Vote for a non-executor ignored.
        assert!(d.add_verdict(NodeId(3), NodeId(7)).is_none());
        // Legit vote settles (k=1).
        assert!(d.add_verdict(NodeId(3), NodeId(1)).is_some());
        // Duplicate judge vote after settle is ignored.
        assert!(d.add_verdict(NodeId(3), NodeId(2)).is_none());
    }

    #[test]
    fn tie_goes_to_higher_quality_response() {
        let mut d = DuelState::new(req(), [NodeId(1), NodeId(2)], 0.0);
        d.add_response(resp(2, 0.7, 1.0)); // node 2 responds first...
        d.add_response(resp(1, 0.9, 2.0)); // ...but node 1's answer is better
        d.assign_judges(vec![NodeId(3), NodeId(4)]);
        d.add_verdict(NodeId(3), NodeId(1));
        let out = d.add_verdict(NodeId(4), NodeId(2)).unwrap();
        assert_eq!(out.winner, NodeId(1));
    }

    #[test]
    fn tally_with_no_verdicts_is_decided_by_quality() {
        // Degenerate state: settle forced with zero verdicts submitted
        // (e.g. a judgeless tally). The quality rung decides, explicitly.
        let mut d = DuelState::new(req(), [NodeId(1), NodeId(2)], 0.0);
        d.add_response(resp(1, 0.4, 1.0));
        d.add_response(resp(2, 0.9, 2.0));
        let out = d.tally();
        assert_eq!(out.winner, NodeId(2));
        assert_eq!(out.loser, NodeId(1));
        assert_eq!(out.votes_for_winner, 0);
        assert_eq!(out.votes_total, 0);
    }

    #[test]
    fn tally_exact_tie_goes_to_lower_node_id() {
        // Exact quality tie AND vote tie: the final rung picks the lower
        // node id regardless of executor-array order.
        for executors in [[NodeId(5), NodeId(2)], [NodeId(2), NodeId(5)]] {
            let mut d = DuelState::new(req(), executors, 0.0);
            d.add_response(resp(executors[0].0, 0.7, 1.0));
            d.add_response(resp(executors[1].0, 0.7, 2.0));
            let out = d.tally();
            assert_eq!(out.winner, NodeId(2), "order {executors:?}");
            assert_eq!(out.loser, NodeId(5));
        }
        // Both responses missing (double no-show): same deterministic rule.
        let d = DuelState::new(req(), [NodeId(9), NodeId(3)], 0.0);
        let out = d.tally();
        assert_eq!(out.winner, NodeId(3));
        assert_eq!(out.loser, NodeId(9));
        assert_eq!(out.votes_total, 0);
    }

    #[test]
    fn tally_missing_response_loses_to_any_real_answer() {
        // One executor never responded: -inf quality, so on a tied vote the
        // no-show loses even to a terrible answer.
        let mut d = DuelState::new(req(), [NodeId(1), NodeId(2)], 0.0);
        d.add_response(resp(2, 0.01, 1.0));
        d.assign_judges(vec![NodeId(3), NodeId(4)]);
        d.add_verdict(NodeId(3), NodeId(1));
        let out = d.add_verdict(NodeId(4), NodeId(2)).unwrap();
        assert_eq!(out.winner, NodeId(2));
        assert_eq!(out.loser, NodeId(1));
    }

    #[test]
    fn judge_prefers_higher_quality_statistically() {
        let mut rng = Rng::new(1);
        let a = resp(1, 0.8, 0.0);
        let b = resp(2, 0.6, 0.0);
        let n = 20_000;
        let wins_a = (0..n)
            .filter(|_| judge_compare(&a, &b, &mut rng) == NodeId(1))
            .count();
        let f = wins_a as f64 / n as f64;
        assert!(f > 0.90, "f={f}"); // 0.2 gap >> sigma_judge
    }

    #[test]
    fn close_quality_gives_close_duels() {
        let mut rng = Rng::new(2);
        let n = 50_000;
        let mut wins_a = 0;
        for _ in 0..n {
            // Draw fresh response qualities each duel (as the system does).
            let qa = draw_response_quality(0.78, &mut rng);
            let qb = draw_response_quality(0.74, &mut rng);
            let a = resp(1, qa, 0.0);
            let b = resp(2, qb, 0.0);
            if judge_compare(&a, &b, &mut rng) == NodeId(1) {
                wins_a += 1;
            }
        }
        let f = wins_a as f64 / n as f64;
        // 0.04 quality gap with σ=0.12/0.08 noise → modest edge (≈0.56-0.60),
        // the Figure-6a regime.
        assert!(f > 0.52 && f < 0.68, "f={f}");
    }

    #[test]
    fn stats_win_rates() {
        let mut s = DuelStats::default();
        let out = DuelOutcome {
            winner: NodeId(1),
            loser: NodeId(2),
            votes_for_winner: 2,
            votes_total: 2,
        };
        s.record(&out);
        s.record(&out);
        s.record(&DuelOutcome {
            winner: NodeId(2),
            loser: NodeId(1),
            votes_for_winner: 2,
            votes_total: 2,
        });
        assert!((s.win_rate(NodeId(1)) - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.win_rate(NodeId(2)) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.win_rate(NodeId(9)), 0.0);
        assert_eq!(s.total_duels(), 3);
    }
}
