//! Section 5's game-theoretic model, executable.
//!
//! Implements the payoff model (Lemma 5.5), the single-node and group-level
//! stake-share replicator dynamics (Propositions 5.6/5.7), and an ODE
//! integrator that demonstrates Theorem 5.8's convergence to a high-quality
//! equilibrium. `benches/replicator.rs` regenerates the convergence result;
//! `rust/tests/prop_replicator.rs` property-tests the simplex invariants.

/// Per-node parameters (Assumption 5.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeParams {
    /// Intrinsic probability of a high-quality response, q_i ∈ [0, 1].
    pub quality: f64,
    /// Per-request operational cost c_i > 0 (credits).
    pub cost: f64,
    /// Initial stake s_i(0) ≥ 0.
    pub stake0: f64,
}

/// System constants (Assumption 5.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemParams {
    /// Delegated request arrival rate λ.
    pub lambda: f64,
    /// Guaranteed base reward R per delegated request.
    pub base_reward: f64,
    /// Duel probability p_d.
    pub duel_rate: f64,
    /// Duel win reward R_add.
    pub duel_reward: f64,
    /// Duel loss penalty P.
    pub duel_penalty: f64,
    /// Stake-adjustment growth constant η.
    pub eta: f64,
}

impl Default for SystemParams {
    fn default() -> Self {
        SystemParams {
            lambda: 10.0,
            base_reward: 1.0,
            duel_rate: 0.1,
            duel_reward: 2.0,
            duel_penalty: 2.0,
            eta: 0.5,
        }
    }
}

/// State of the replicator system: stakes s_i(t).
#[derive(Debug, Clone)]
pub struct Replicator {
    pub nodes: Vec<NodeParams>,
    pub sys: SystemParams,
    pub stakes: Vec<f64>,
    pub t: f64,
}

impl Replicator {
    pub fn new(nodes: Vec<NodeParams>, sys: SystemParams) -> Replicator {
        let stakes = nodes.iter().map(|n| n.stake0).collect();
        Replicator { nodes, sys, stakes, t: 0.0 }
    }

    pub fn total_stake(&self) -> f64 {
        self.stakes.iter().sum()
    }

    /// PoS selection probabilities p_i(t) (Assumption 5.3).
    pub fn shares(&self) -> Vec<f64> {
        let s = self.total_stake();
        if s <= 0.0 {
            return vec![0.0; self.stakes.len()];
        }
        self.stakes.iter().map(|x| x / s).collect()
    }

    /// Selection-weighted average quality Q̄(t).
    pub fn avg_quality(&self) -> f64 {
        let p = self.shares();
        p.iter()
            .zip(&self.nodes)
            .map(|(pi, n)| pi * n.quality)
            .sum()
    }

    /// Duel win probability Q_i(t) = (1 + q_i − Q̄)/2, clamped to [0, 1].
    pub fn win_prob(&self, i: usize) -> f64 {
        (0.5 * (1.0 + self.nodes[i].quality - self.avg_quality()))
            .clamp(0.0, 1.0)
    }

    /// Per-request expected payoff Δ_i(t) (Lemma 5.5).
    pub fn delta(&self, i: usize) -> f64 {
        let n = &self.nodes[i];
        let q = self.win_prob(i);
        (self.sys.base_reward - n.cost)
            + self.sys.duel_rate
                * (q * self.sys.duel_reward - (1.0 - q) * self.sys.duel_penalty)
    }

    /// Expected payoff rate π_i(t) = λ p_i Δ_i.
    pub fn payoff_rate(&self, i: usize) -> f64 {
        self.sys.lambda * self.shares()[i] * self.delta(i)
    }

    /// Network-average payoff Δ̄(t) = Σ p_j Δ_j.
    pub fn avg_delta(&self) -> f64 {
        let p = self.shares();
        (0..self.nodes.len()).map(|j| p[j] * self.delta(j)).sum()
    }

    /// Analytic share derivative ṗ_i from Proposition 5.6.
    pub fn share_derivative(&self, i: usize) -> f64 {
        let s = self.total_stake();
        if s <= 0.0 {
            return 0.0;
        }
        let p = self.shares();
        self.sys.eta * self.sys.lambda / s
            * p[i]
            * (self.delta(i) - self.avg_delta())
    }

    /// Group-level share p_H and within/outside payoffs (Proposition 5.7).
    pub fn group_share(&self, members: &[usize]) -> f64 {
        let p = self.shares();
        members.iter().map(|i| p[*i]).sum()
    }

    pub fn group_payoffs(&self, members: &[usize]) -> (f64, f64) {
        let p = self.shares();
        let in_set: std::collections::HashSet<usize> =
            members.iter().copied().collect();
        let (mut ph, mut dh, mut dnh, mut pnh) = (0.0, 0.0, 0.0, 0.0);
        for i in 0..self.nodes.len() {
            if in_set.contains(&i) {
                ph += p[i];
                dh += p[i] * self.delta(i);
            } else {
                pnh += p[i];
                dnh += p[i] * self.delta(i);
            }
        }
        (
            if ph > 0.0 { dh / ph } else { 0.0 },
            if pnh > 0.0 { dnh / pnh } else { 0.0 },
        )
    }

    /// One Euler step of ṡ_i = η π_i (Assumption 5.4). Stakes floor at 0
    /// (a node cannot stake negative credit).
    pub fn step(&mut self, dt: f64) {
        let rates: Vec<f64> =
            (0..self.nodes.len()).map(|i| self.payoff_rate(i)).collect();
        for (s, r) in self.stakes.iter_mut().zip(rates) {
            *s = (*s + self.sys.eta * r * dt).max(0.0);
        }
        self.t += dt;
    }

    /// Integrate to time `t_end`; returns share trajectories sampled every
    /// `sample_every` time units: (times, shares[node][sample]).
    pub fn integrate(
        &mut self,
        t_end: f64,
        dt: f64,
        sample_every: f64,
    ) -> (Vec<f64>, Vec<Vec<f64>>) {
        let n = self.nodes.len();
        let mut times = Vec::new();
        let mut traj: Vec<Vec<f64>> = vec![Vec::new(); n];
        let mut next_sample = 0.0;
        while self.t < t_end {
            if self.t >= next_sample {
                let p = self.shares();
                times.push(self.t);
                for i in 0..n {
                    traj[i].push(p[i]);
                }
                next_sample += sample_every;
            }
            self.step(dt);
        }
        (times, traj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tier() -> Replicator {
        let nodes = vec![
            NodeParams { quality: 0.9, cost: 0.2, stake0: 1.0 },
            NodeParams { quality: 0.9, cost: 0.2, stake0: 1.0 },
            NodeParams { quality: 0.4, cost: 0.2, stake0: 1.0 },
            NodeParams { quality: 0.4, cost: 0.2, stake0: 1.0 },
        ];
        Replicator::new(nodes, SystemParams::default())
    }

    #[test]
    fn shares_sum_to_one() {
        let r = two_tier();
        let s: f64 = r.shares().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn win_prob_centered_at_half() {
        let r = two_tier();
        // Q̄ = 0.65; node 0: (1 + 0.9 - 0.65)/2 = 0.625
        assert!((r.win_prob(0) - 0.625).abs() < 1e-12);
        assert!((r.win_prob(2) - 0.375).abs() < 1e-12);
    }

    #[test]
    fn lemma_5_5_payoff() {
        let r = two_tier();
        let q0 = r.win_prob(0);
        let expected = (1.0 - 0.2) + 0.1 * (q0 * 2.0 - (1.0 - q0) * 2.0);
        assert!((r.delta(0) - expected).abs() < 1e-12);
    }

    #[test]
    fn high_quality_group_share_increases_monotonically() {
        // Theorem 5.8: the high-quality subset's share grows whenever its
        // average payoff exceeds the outside average.
        // Stronger duel economics than the default so the low tier is
        // strictly unprofitable — total stake then stops inflating and the
        // replicator converges quickly (with the milder defaults the same
        // limit is approached, just logarithmically in 1/S(t)).
        let mut r = two_tier();
        r.sys.duel_rate = 0.5;
        r.sys.duel_penalty = 4.0;
        let hq = [0usize, 1];
        let mut prev = r.group_share(&hq);
        for _ in 0..40_000 {
            let (dh, dnh) = r.group_payoffs(&hq);
            assert!(dh > dnh);
            r.step(0.01);
            let cur = r.group_share(&hq);
            assert!(cur >= prev - 1e-9, "share decreased: {prev} -> {cur}");
            prev = cur;
        }
        assert!(prev > 0.8, "high-quality share only reached {prev}");
    }

    #[test]
    fn proposition_5_6_derivative_matches_numeric() {
        let mut r = two_tier();
        // warm up so shares are asymmetric
        for _ in 0..100 {
            r.step(0.01);
        }
        let analytic = r.share_derivative(0);
        let p0 = r.shares()[0];
        let mut r2 = r.clone();
        let dt = 1e-5;
        r2.step(dt);
        let numeric = (r2.shares()[0] - p0) / dt;
        assert!(
            (analytic - numeric).abs() < 1e-3 * analytic.abs().max(1.0),
            "analytic {analytic} vs numeric {numeric}"
        );
    }

    #[test]
    fn equal_quality_is_stationary_in_shares() {
        let nodes = vec![
            NodeParams { quality: 0.7, cost: 0.2, stake0: 2.0 },
            NodeParams { quality: 0.7, cost: 0.2, stake0: 1.0 },
        ];
        let mut r = Replicator::new(nodes, SystemParams::default());
        let before = r.shares();
        for _ in 0..1000 {
            r.step(0.01);
        }
        let after = r.shares();
        for (b, a) in before.iter().zip(&after) {
            assert!((b - a).abs() < 1e-9, "shares drifted: {b} -> {a}");
        }
    }

    #[test]
    fn unprofitable_nodes_decay() {
        // Cost above total expected reward: stake shrinks toward zero.
        let nodes = vec![
            NodeParams { quality: 0.9, cost: 0.2, stake0: 1.0 },
            NodeParams { quality: 0.2, cost: 1.5, stake0: 1.0 },
        ];
        let mut r = Replicator::new(nodes, SystemParams::default());
        for _ in 0..5000 {
            r.step(0.01);
        }
        assert!(r.shares()[1] < 0.05, "loser share {}", r.shares()[1]);
    }

    #[test]
    fn integrate_samples_trajectories() {
        let mut r = two_tier();
        let (times, traj) = r.integrate(10.0, 0.01, 1.0);
        assert!(times.len() >= 9);
        assert_eq!(traj.len(), 4);
        for series in &traj {
            assert_eq!(series.len(), times.len());
        }
        // Simplex preserved at every sample.
        for k in 0..times.len() {
            let s: f64 = traj.iter().map(|tr| tr[k]).sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }
}
