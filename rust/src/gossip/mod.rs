//! Gossip-driven peer synchronization (§A.2, Figure 10) with delta
//! dissemination.
//!
//! Each node keeps a [`PeerView`]: per-peer status (online/offline), network
//! endpoint, and a heartbeat version counter. Every gossip round a node bumps
//! its own heartbeat, picks a small fanout of live peers, and synchronizes
//! push-pull; entries with higher versions win during [`PeerView::merge`].
//! Liveness is inferred locally: a peer whose heartbeat hasn't advanced
//! within `suspect_after` rounds-worth of time is suspected offline
//! (SWIM-style, but simple heartbeat aging suffices at the paper's scale).
//!
//! ## The delta protocol
//!
//! The seed protocol shipped the **full** view in both halves of every
//! push-pull exchange — O(n) entries per message, quadratic total traffic
//! per round across an n-node fleet. Epidemic-membership systems (SWIM-style
//! dissemination, per PAPERS.md) ship only *changes*. This module now splits
//! a round into three wire forms:
//!
//! * **Delta** (`Message::GossipDelta` / `GossipDeltaReply`) — the regular
//!   round. A per-peer *sent clock* ([`PeerView::delta_for`]) selects only
//!   entries updated since the last exchange with that peer. Entries whose
//!   *membership content* changed (online flag, endpoint, region, or a
//!   newly learned peer) travel as full 32-byte digest rows; entries that
//!   merely advanced their heartbeat travel as compact 12-byte
//!   `(node, version)` refresh pairs. A per-entry forwarding throttle
//!   (`0.4 × suspect_after`) stops every node from re-advertising every
//!   heartbeat every round — each peer still hears a refresh for every live
//!   entry a few times per suspicion window, which is all that liveness
//!   aging needs, at a small fraction of the bytes. The refresh rate a node
//!   sees for a given peer is ~`1 / throttle` regardless of fleet size, so
//!   `suspect_after` must scale with the fleet: a 5-round window is fine at
//!   a dozen nodes (direct contact dominates, and every exchange carries
//!   the sender's own heartbeat, SWIM-ping style), while 500–1000-node
//!   fleets should run 20+ rounds or pairs start flapping in and out of
//!   suspicion — `benches/fleet_scale.rs` asserts the end-of-run alive
//!   fraction alongside its byte counts for exactly this reason.
//! * **Anti-entropy fallback** (`Message::Gossip` / `GossipReply`) — every
//!   [`GossipConfig::anti_entropy_every`]-th round (and the very first,
//!   unless the view was bootstrap-sealed: seeded membership is common
//!   knowledge, and a synchronized round-one digest storm is O(n²) rows in
//!   flight at 10k nodes), the
//!   full digest is exchanged exactly as the seed protocol did. This repairs
//!   anything deltas missed (messages lost to partitions, throttled final
//!   versions of dead peers) and doubles as the correctness oracle: the
//!   convergence-equivalence property test (`rust/tests/delta_gossip.rs`)
//!   proves delta and full runs end in bit-identical views.
//! * **Suspicion probe** — unchanged, but always full-digest: one successful
//!   probe after a heal pulls the whole remote view back in.
//!
//! Byte accounting lives in `Message::wire_size`; the fleet-scale bench
//! (`benches/fleet_scale.rs`) measures the reduction (≥10x gossip bytes per
//! round at 500 nodes vs. the full-digest baseline).
//!
//! Membership queries ([`PeerView::alive_peers`],
//! [`PeerView::alive_peers_by_region`], [`PeerView::digest`]) are backed by
//! incrementally maintained sorted indexes (updated on merge) instead of
//! rebuilding and re-sorting from the entry map on every call — those sit on
//! the per-request dispatch path.
//!
//! ## Dense storage
//!
//! Node ids are dense interned `u32`s (`NodeId(i)` for world slot `i` — see
//! `util::intern` for the string boundary), so the entry table is a plain
//! `Vec` indexed by id rather than a `BTreeMap`: merges and liveness checks
//! are O(1) array hits instead of O(log n) pointer chases, and `World::new`'s
//! O(n²) bootstrap seeding becomes straight array writes. Index order *is*
//! id order, so digests and deltas keep the exact iteration order the sorted
//! map produced. A hard id ceiling ([`MAX_TRACKED_ID`]) bounds table growth
//! so a forged digest row cannot balloon memory: rows naming absurd ids are
//! dropped (a Byzantine peer could always invent ids; dense storage just
//! makes the failure mode allocation instead of noise).
//!
//! Convergence (epidemic diffusion, O(log N) rounds) is property-tested in
//! `rust/tests/prop_protocol.rs` and measured in
//! `benches/gossip_convergence.rs`.

use std::collections::BTreeMap;

use crate::types::{NodeId, Time};
use crate::util::rng::Rng;

/// What one node believes about one peer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeerEntry {
    /// Monotonic heartbeat counter, bumped by the peer itself each round.
    pub version: u64,
    /// Declared online/offline (a leaving node can gossip a graceful
    /// goodbye; crashes are caught by heartbeat aging).
    pub online: bool,
    /// Opaque endpoint (the TCP runner stores "host:port"; sim leaves 0).
    pub endpoint: u64,
    /// The peer's topology region tag (locality-aware dispatch); 0 in
    /// single-region worlds.
    pub region: u32,
    /// Local time we last saw this entry's version advance.
    pub last_seen: Time,
    /// Local mutation-clock stamp of the last change (any kind). Entries
    /// with `updated > sent[peer]` are candidates for the next delta to
    /// that peer. Local bookkeeping — never serialized.
    pub updated: u64,
    /// Local mutation-clock stamp of the last *membership* change (online
    /// flag, endpoint, region, or first sighting). Such entries travel as
    /// full digest rows and bypass the heartbeat throttle.
    pub meta_updated: u64,
    /// Local time this entry was last included in any outgoing delta
    /// (heartbeat-refresh throttle). Local bookkeeping.
    pub last_fwd: Time,
}

/// Gossip configuration knobs (system-level policy, §4.3).
#[derive(Debug, Clone, Copy)]
pub struct GossipConfig {
    /// Seconds between gossip rounds.
    pub interval: f64,
    /// Peers contacted per round.
    pub fanout: usize,
    /// Seconds without heartbeat progress before a peer is suspected dead.
    pub suspect_after: f64,
    /// Every k-th gossip round exchanges the *full* digest (anti-entropy
    /// fallback of the delta protocol). `1` (or 0) disables deltas entirely
    /// and reproduces the seed's full-view protocol — the baseline the
    /// fleet-scale bench compares against.
    pub anti_entropy_every: u64,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig {
            interval: 1.0,
            fanout: 2,
            suspect_after: 5.0,
            anti_entropy_every: 32,
        }
    }
}

/// Per-round probability of gossiping at one *suspected* peer (online per
/// its last word, but heartbeat-aged — a crash or a network partition).
/// Without this probe a healed partition would never re-merge: every
/// surviving node's alive pool is non-empty, so the empty-pool fallback
/// never fires and aged-out peers would stay invisible forever. A lost
/// probe costs one message; a successful one pulls the whole remote side's
/// view back in (SWIM-style suspicion, simplified). Only rolls — and only
/// consumes RNG draws — when suspects exist, so churn-free runs replay
/// identically to the pre-topology fabric.
pub const RESURRECT_PROB: f64 = 0.15;

/// Hard ceiling on trackable node ids. Honest worlds intern node ids
/// densely from 0, so the entry table's length tracks the fleet size; this
/// cap only matters for *forged* digest rows, bounding the allocation a
/// malicious id can force (~64 MiB of `Option<PeerEntry>` slots) instead
/// of letting a single 32-bit id demand hundreds of gigabytes.
pub const MAX_TRACKED_ID: u32 = 1 << 20;

/// One node's local membership view.
#[derive(Debug, Clone)]
pub struct PeerView {
    pub me: NodeId,
    /// Dense entry table indexed by `NodeId.0` (ids are interned world
    /// slots). `None` = never heard of. Index order is id order, so every
    /// iteration below reproduces the sorted-map order verbatim.
    entries: Vec<Option<PeerEntry>>,
    /// Present entries in `entries` (`known()` without a scan).
    num_entries: usize,
    cfg: GossipConfig,
    /// Local mutation clock: bumped on every entry change; stamps
    /// `PeerEntry::updated` / `meta_updated` and floors the per-peer `sent`
    /// map. Also the cheap invalidation key for anything derived from this
    /// view (e.g. the node's cached stake snapshot).
    clock: u64,
    /// Per-peer clock floor: our `clock` as of the last delta sent to them.
    sent: BTreeMap<NodeId, u64>,
    /// Clock value at [`seal_bootstrap`](PeerView::seal_bootstrap): deltas
    /// to never-contacted peers start here instead of at zero, so common
    /// bootstrap knowledge is not re-shipped to every first contact.
    bootstrap_clock: u64,
    /// Non-self peers whose last word was `online`, kept sorted
    /// (liveness-age filtering happens at query time).
    online_sorted: Vec<NodeId>,
    /// The same peers grouped by region tag, each group sorted.
    by_region: BTreeMap<u32, Vec<NodeId>>,
}

/// A serializable digest exchanged during a gossip round.
pub type Digest = Vec<(NodeId, u64, bool, u64, u32)>; // (node, version, online, endpoint, region)

/// Compact heartbeat refreshes: `(node, version)` pairs for entries whose
/// only news is a newer heartbeat (12 wire bytes vs. 32 for a digest row).
pub type Heartbeats = Vec<(NodeId, u64)>;

fn sorted_insert(v: &mut Vec<NodeId>, n: NodeId) {
    if let Err(i) = v.binary_search(&n) {
        v.insert(i, n);
    }
}

fn sorted_remove(v: &mut Vec<NodeId>, n: NodeId) {
    if let Ok(i) = v.binary_search(&n) {
        v.remove(i);
    }
}

impl PeerView {
    pub fn new(me: NodeId, cfg: GossipConfig, now: Time) -> Self {
        let mut entries: Vec<Option<PeerEntry>> =
            vec![None; me.0 as usize + 1];
        entries[me.0 as usize] = Some(PeerEntry {
            version: 1,
            online: true,
            endpoint: 0,
            region: 0,
            last_seen: now,
            updated: 1,
            meta_updated: 1,
            last_fwd: f64::NEG_INFINITY,
        });
        PeerView {
            me,
            entries,
            num_entries: 1,
            cfg,
            clock: 1,
            sent: BTreeMap::new(),
            bootstrap_clock: 0,
            online_sorted: Vec::new(),
            by_region: BTreeMap::new(),
        }
    }

    /// Slot lookup — O(1) array hit (ids are dense interned world slots).
    fn get(&self, peer: NodeId) -> Option<&PeerEntry> {
        self.entries.get(peer.0 as usize).and_then(|s| s.as_ref())
    }

    /// Grow the table so `node` has a slot. Returns `false` (and allocates
    /// nothing) for ids past [`MAX_TRACKED_ID`] — the forged-row guard.
    fn ensure_slot(&mut self, node: NodeId) -> bool {
        if node.0 >= MAX_TRACKED_ID {
            return false;
        }
        let idx = node.0 as usize;
        if idx >= self.entries.len() {
            self.entries.resize(idx + 1, None);
        }
        true
    }

    /// All known node ids (including self), ascending — the dense-table
    /// replacement for the old sorted-id vector.
    pub fn known_ids(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| NodeId(i as u32)))
    }

    pub fn config(&self) -> GossipConfig {
        self.cfg
    }

    /// Mutation clock: changes whenever anything that can affect derived
    /// queries changed — gossiped content on any merge/heartbeat, and the
    /// `last_seen` refresh of a rejoin (see [`refresh`](PeerView::refresh)).
    /// Cheap staleness key for caches derived from this view.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    fn self_entry_mut(&mut self) -> &mut PeerEntry {
        self.entries[self.me.0 as usize]
            .as_mut()
            .expect("self entry exists")
    }

    // ---- incremental index maintenance (online/by-region) -------------------

    fn index_insert(&mut self, n: NodeId, region: u32) {
        sorted_insert(&mut self.online_sorted, n);
        sorted_insert(self.by_region.entry(region).or_default(), n);
    }

    fn index_remove(&mut self, n: NodeId, region: u32) {
        sorted_remove(&mut self.online_sorted, n);
        if let Some(group) = self.by_region.get_mut(&region) {
            sorted_remove(group, n);
            if group.is_empty() {
                self.by_region.remove(&region);
            }
        }
    }

    /// Seed knowledge of a bootstrap peer (e.g. from the config file).
    pub fn add_seed(&mut self, peer: NodeId, endpoint: u64, region: u32, now: Time) {
        if peer == self.me || self.get(peer).is_some() || !self.ensure_slot(peer)
        {
            return;
        }
        self.clock += 1;
        self.entries[peer.0 as usize] = Some(PeerEntry {
            version: 0,
            online: true,
            endpoint,
            region,
            last_seen: now,
            updated: self.clock,
            meta_updated: self.clock,
            last_fwd: f64::NEG_INFINITY,
        });
        self.num_entries += 1;
        self.index_insert(peer, region);
    }

    /// Declare our own region (gossiped out with every digest).
    pub fn set_region(&mut self, region: u32) {
        self.clock += 1;
        let clock = self.clock;
        let e = self.self_entry_mut();
        e.region = region;
        e.updated = clock;
        e.meta_updated = clock;
    }

    /// The region tag we last heard for `peer` (None if unknown peer).
    pub fn region_of(&self, peer: NodeId) -> Option<u32> {
        self.get(peer).map(|e| e.region)
    }

    /// Bump our own heartbeat (start of each gossip round). A heartbeat
    /// asserts liveness, so it also clears any prior offline announcement
    /// (the leave -> rejoin cycle of Figure 5).
    pub fn heartbeat(&mut self, now: Time) {
        self.clock += 1;
        let clock = self.clock;
        let e = self.self_entry_mut();
        e.version += 1;
        e.last_seen = now;
        e.updated = clock;
        if !e.online {
            // Coming back from a graceful leave is membership news — it must
            // travel as a full digest row, never as a heartbeat pair.
            e.online = true;
            e.meta_updated = clock;
        }
    }

    /// Gracefully announce our departure (gossiped out before leaving).
    pub fn announce_leave(&mut self, now: Time) {
        self.clock += 1;
        let clock = self.clock;
        let e = self.self_entry_mut();
        e.version += 1;
        e.online = false;
        e.last_seen = now;
        e.updated = clock;
        e.meta_updated = clock;
    }

    /// Optimistically refresh contactability of known online peers — used
    /// when (re)joining after downtime: our `last_seen` clocks are stale,
    /// but bootstrap peers are worth contacting so the join gossip can
    /// propagate (they'll age out again if truly gone). Also forgets the
    /// per-peer delta floors: after downtime we no longer know what our
    /// peers have seen, so the next deltas start from scratch.
    pub fn refresh(&mut self, now: Time) {
        // `last_seen` feeds `is_alive`, so anything keyed on the mutation
        // clock (alive-peer scratch, stake-snapshot cache) must see this
        // as a change even though no gossiped content moved.
        self.clock += 1;
        let me = self.me.0 as usize;
        for (i, slot) in self.entries.iter_mut().enumerate() {
            if let Some(e) = slot {
                if i != me && e.online {
                    e.last_seen = now;
                }
                e.last_fwd = f64::NEG_INFINITY;
            }
        }
        self.sent.clear();
    }

    pub fn set_endpoint(&mut self, endpoint: u64) {
        self.clock += 1;
        let clock = self.clock;
        let e = self.self_entry_mut();
        e.endpoint = endpoint;
        e.updated = clock;
        e.meta_updated = clock;
    }

    /// Is `peer` believed alive right now? (online flag + heartbeat age)
    pub fn is_alive(&self, peer: NodeId, now: Time) -> bool {
        match self.get(peer) {
            None => false,
            Some(e) => {
                e.online && (now - e.last_seen) <= self.cfg.suspect_after
            }
        }
    }

    /// All peers (excluding self) believed alive. Sorted by id; backed by
    /// the incrementally maintained online index (no per-call sort).
    pub fn alive_peers(&self, now: Time) -> Vec<NodeId> {
        let mut out = Vec::new();
        self.alive_peers_into(now, &mut out);
        out
    }

    /// [`alive_peers`](PeerView::alive_peers) into a caller-owned buffer —
    /// hot paths that consult the alive set repeatedly per event (ledger
    /// broadcast targets) reuse one allocation via the coordinator's
    /// peer scratch instead of collecting a fresh `Vec` per call.
    pub fn alive_peers_into(&self, now: Time, out: &mut Vec<NodeId>) {
        out.clear();
        out.extend(
            self.online_sorted
                .iter()
                .copied()
                .filter(|n| self.is_alive(*n, now)),
        );
    }

    /// Non-self peers whose last word was `online`, sorted by id — the
    /// superset `alive_peers` filters by heartbeat age. Exposed so hot
    /// paths can scan without allocating.
    pub fn online_peers(&self) -> &[NodeId] {
        &self.online_sorted
    }

    /// All alive peers (excluding self) grouped by their region tag —
    /// deterministic order (sorted groups, maintained incrementally).
    pub fn alive_peers_by_region(&self, now: Time) -> BTreeMap<u32, Vec<NodeId>> {
        let mut out = BTreeMap::new();
        for (region, group) in &self.by_region {
            let alive: Vec<NodeId> = group
                .iter()
                .copied()
                .filter(|n| self.is_alive(*n, now))
                .collect();
            if !alive.is_empty() {
                out.insert(*region, alive);
            }
        }
        out
    }

    pub fn endpoint(&self, peer: NodeId) -> Option<u64> {
        self.get(peer).map(|e| e.endpoint)
    }

    pub fn entry(&self, peer: NodeId) -> Option<&PeerEntry> {
        self.get(peer)
    }

    pub fn known(&self) -> usize {
        self.num_entries
    }

    /// Choose gossip targets for this round: the regular alive-pool fanout
    /// plus (occasionally) one suspicion probe. See [`pick_round_targets`]
    /// for the split the delta protocol needs.
    ///
    /// [`pick_round_targets`]: PeerView::pick_round_targets
    pub fn pick_targets(&self, rng: &mut Rng, now: Time) -> Vec<NodeId> {
        let (mut targets, suspect) = self.pick_round_targets(rng, now);
        targets.extend(suspect);
        targets
    }

    /// Like [`pick_targets`](PeerView::pick_targets) but keeps the suspicion
    /// probe separate: regular targets receive deltas, the probe always
    /// receives the full digest (a heal must pull the whole remote view
    /// back). If nobody looks alive (e.g. we were offline past everyone's
    /// heartbeat window, or we just booted from stale seeds), fall back to
    /// probing *known* peers — an unreachable target costs one lost message,
    /// while never probing would leave the node isolated forever.
    pub fn pick_round_targets(
        &self,
        rng: &mut Rng,
        now: Time,
    ) -> (Vec<NodeId>, Option<NodeId>) {
        let mut pool = self.alive_peers(now);
        let fallback = pool.is_empty();
        if fallback {
            pool = self.known_ids().filter(|n| *n != self.me).collect();
        }
        if pool.is_empty() {
            return (vec![], None);
        }
        let idx = rng.sample_distinct(pool.len(), self.cfg.fanout);
        let targets: Vec<NodeId> = idx.into_iter().map(|i| pool[i]).collect();
        // Suspicion probe: occasionally add one heartbeat-aged peer that
        // never said goodbye, so crashed-and-recovered nodes and healed
        // partitions can rejoin (see [`RESURRECT_PROB`]). Skipped in
        // fallback mode — the pool already holds every known peer.
        let mut suspect = None;
        if !fallback {
            let suspects: Vec<NodeId> = self
                .online_sorted
                .iter()
                .copied()
                .filter(|n| !self.is_alive(*n, now))
                .collect();
            if !suspects.is_empty() && rng.chance(RESURRECT_PROB) {
                suspect = Some(suspects[rng.below(suspects.len())]);
            }
        }
        (targets, suspect)
    }

    /// Serialize the full view for transmission (anti-entropy rounds,
    /// leave/join announcements, suspicion probes). Sorted by node id.
    pub fn digest(&self) -> Digest {
        self.entries
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                slot.as_ref().map(|e| {
                    (NodeId(i as u32), e.version, e.online, e.endpoint, e.region)
                })
            })
            .collect()
    }

    /// Build the delta for `peer`: full digest rows for entries whose
    /// membership content changed since the last exchange with them, plus
    /// compact heartbeat pairs for entries that merely advanced — the
    /// latter rate-limited per entry (across all peers) to
    /// `0.4 × suspect_after` seconds. Advances the per-peer sent floor.
    ///
    /// Throttle-skipped entries are *not* retransmitted later unless they
    /// change again; a live peer's next heartbeat re-qualifies it, and the
    /// final frozen version of a dead peer is exactly what liveness aging
    /// wants to miss. Full anti-entropy rounds repair every other loss.
    pub fn delta_for(
        &mut self,
        peer: NodeId,
        now: Time,
    ) -> (Digest, Heartbeats) {
        self.delta_for_excluding(peer, now, &[])
    }

    /// [`delta_for`](PeerView::delta_for) minus `exclude` — the pull half of
    /// a delta exchange passes the entries it just accepted from the push,
    /// so they are not echoed straight back to the peer that sent them.
    /// `exclude` must be sorted (binary-searched per candidate entry).
    pub fn delta_for_excluding(
        &mut self,
        peer: NodeId,
        now: Time,
        exclude: &[NodeId],
    ) -> (Digest, Heartbeats) {
        debug_assert!(exclude.windows(2).all(|w| w[0] <= w[1]));
        let floor =
            self.sent.get(&peer).copied().unwrap_or(self.bootstrap_clock);
        let throttle = 0.4 * self.cfg.suspect_after;
        let me = self.me;
        let mut delta: Digest = Vec::new();
        let mut heartbeats: Heartbeats = Vec::new();
        for idx in 0..self.entries.len() {
            let n = NodeId(idx as u32);
            // Never tell a peer about itself (its self-entry is
            // authoritative — the receiver would discard it anyway).
            if n == peer || exclude.binary_search(&n).is_ok() {
                continue;
            }
            let Some(e) = self.entries[idx].as_mut() else {
                continue;
            };
            if e.updated <= floor {
                continue;
            }
            if e.meta_updated > floor {
                delta.push((n, e.version, e.online, e.endpoint, e.region));
                e.last_fwd = now;
            } else if n == me || now - e.last_fwd >= throttle {
                // Our own heartbeat is exempt from the throttle: every
                // exchange carries direct liveness evidence for its sender
                // (SWIM's ping-ack, for 12 bytes), which keeps small fleets
                // — where direct contact dominates — flap-free.
                heartbeats.push((n, e.version));
                e.last_fwd = now;
            }
        }
        self.sent.insert(peer, self.clock);
        (delta, heartbeats)
    }

    /// Record that `peer` just received our full digest (anti-entropy and
    /// probe paths): subsequent deltas to them start from the current clock.
    pub fn mark_synced(&mut self, peer: NodeId) {
        self.sent.insert(peer, self.clock);
    }

    /// Declare the current contents common knowledge: deltas to peers we
    /// have never exchanged with start from this point instead of from
    /// zero. The simulator calls this after seeding every node with the
    /// same bootstrap membership — without it, every first contact would
    /// re-ship the entire seeded view as membership rows, and a bench
    /// window would degenerate into an O(n²) full exchange.
    pub fn seal_bootstrap(&mut self) {
        self.bootstrap_clock = self.clock;
    }

    /// Whether [`seal_bootstrap`](PeerView::seal_bootstrap) ran on a
    /// non-empty view. A sealed view's membership is common knowledge, so
    /// the gossip driver skips the round-one full digest — at 10k nodes
    /// that round would otherwise put ~n² digest rows in flight at one
    /// simulated instant (every node ticks at the same time), which is
    /// gigabytes of transient allocation for zero information.
    pub fn bootstrap_sealed(&self) -> bool {
        self.bootstrap_clock > 0
    }

    /// Merge a received digest; higher version wins. Returns the nodes whose
    /// entries changed (new information learned).
    pub fn merge(
        &mut self,
        digest: &[(NodeId, u64, bool, u64, u32)],
        now: Time,
    ) -> Vec<NodeId> {
        let mut changed = Vec::new();
        for (node, version, online, endpoint, region) in digest {
            if self.merge_entry(*node, *version, *online, *endpoint, *region, now)
            {
                changed.push(*node);
            }
        }
        changed
    }

    /// Merge compact heartbeat refreshes. Only known, online entries can be
    /// refreshed: a version bump with the online flag down could be a
    /// graceful leave, which always travels as a full digest row — a bare
    /// `(node, version)` pair must never resurrect an offline entry.
    /// Unknown nodes are skipped (anti-entropy will teach them properly).
    pub fn merge_heartbeats(
        &mut self,
        hbs: &[(NodeId, u64)],
        now: Time,
    ) -> Vec<NodeId> {
        let mut changed = Vec::new();
        for (node, version) in hbs {
            if *node == self.me {
                continue;
            }
            let Some(e) = self
                .entries
                .get_mut(node.0 as usize)
                .and_then(|s| s.as_mut())
            else {
                continue;
            };
            if !e.online || *version <= e.version {
                continue;
            }
            self.clock += 1;
            e.version = *version;
            e.last_seen = now;
            e.updated = self.clock;
            changed.push(*node);
        }
        changed
    }

    fn merge_entry(
        &mut self,
        node: NodeId,
        version: u64,
        online: bool,
        endpoint: u64,
        region: u32,
        now: Time,
    ) -> bool {
        if node == self.me {
            // Nobody can overwrite our self-entry (our version is
            // authoritative — prevents spoofed "you are offline").
            return false;
        }
        if !self.ensure_slot(node) {
            // Forged id beyond the tracking ceiling — drop the row rather
            // than let it force an absurd allocation.
            return false;
        }
        let idx = node.0 as usize;
        let is_new = self.entries[idx].is_none();
        if is_new {
            // Learn the peer's existence even when the version check below
            // rejects the payload (seed digests carry version 0): knowing an
            // id is enough to probe it later.
            self.clock += 1;
            self.entries[idx] = Some(PeerEntry {
                version: 0,
                online: false,
                endpoint,
                region,
                last_seen: now - self.cfg.suspect_after - 1.0,
                updated: self.clock,
                meta_updated: self.clock,
                last_fwd: f64::NEG_INFINITY,
            });
            self.num_entries += 1;
        }
        let e = self.entries[idx].as_mut().expect("just ensured");
        if version <= e.version {
            return false;
        }
        let (old_online, old_region) = (e.online, e.region);
        let meta = is_new
            || old_online != online
            || e.endpoint != endpoint
            || old_region != region;
        self.clock += 1;
        e.version = version;
        e.online = online;
        e.endpoint = endpoint;
        e.region = region;
        e.last_seen = now;
        e.updated = self.clock;
        if meta {
            e.meta_updated = self.clock;
        }
        // Keep the online/by-region indexes in step.
        match (is_new || !old_online, online) {
            (true, true) => self.index_insert(node, region),
            (false, false) => self.index_remove(node, old_region),
            (false, true) if old_region != region => {
                self.index_remove(node, old_region);
                self.index_insert(node, region);
            }
            _ => {}
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GossipConfig {
        GossipConfig {
            interval: 1.0,
            fanout: 2,
            suspect_after: 5.0,
            anti_entropy_every: 16,
        }
    }

    #[test]
    fn self_entry_always_alive_view() {
        let v = PeerView::new(NodeId(0), cfg(), 0.0);
        assert_eq!(v.known(), 1);
        assert!(v.alive_peers(0.0).is_empty());
    }

    #[test]
    fn merge_learns_new_peers() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let mut b = PeerView::new(NodeId(1), cfg(), 0.0);
        b.heartbeat(0.5);
        let changed = a.merge(&b.digest(), 1.0);
        assert_eq!(changed, vec![NodeId(1)]);
        assert!(a.is_alive(NodeId(1), 1.0));
    }

    #[test]
    fn higher_version_wins_lower_ignored() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let digest_v5: Digest = vec![(NodeId(2), 5, true, 7, 1)];
        let digest_v3: Digest = vec![(NodeId(2), 3, false, 9, 2)];
        a.merge(&digest_v5, 1.0);
        let changed = a.merge(&digest_v3, 2.0);
        assert!(changed.is_empty());
        let e = a.entry(NodeId(2)).unwrap();
        assert_eq!(e.version, 5);
        assert!(e.online);
        assert_eq!(e.endpoint, 7);
    }

    #[test]
    fn self_entry_cannot_be_spoofed() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let spoof: Digest = vec![(NodeId(0), 99, false, 0, 3)];
        a.merge(&spoof, 1.0);
        let e = a.entry(NodeId(0)).unwrap();
        assert_eq!(e.version, 1);
        assert!(e.online);
    }

    #[test]
    fn heartbeat_aging_suspects_silent_peer() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&[(NodeId(1), 4, true, 0, 0)], 0.0);
        assert!(a.is_alive(NodeId(1), 4.9));
        assert!(!a.is_alive(NodeId(1), 5.1));
        // Progress resets the clock.
        a.merge(&[(NodeId(1), 5, true, 0, 0)], 6.0);
        assert!(a.is_alive(NodeId(1), 10.0));
    }

    #[test]
    fn graceful_leave_propagates() {
        let mut leaver = PeerView::new(NodeId(1), cfg(), 0.0);
        leaver.heartbeat(0.1);
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&leaver.digest(), 0.2);
        assert!(a.is_alive(NodeId(1), 0.5));
        leaver.announce_leave(0.6);
        a.merge(&leaver.digest(), 0.7);
        assert!(!a.is_alive(NodeId(1), 0.8));
    }

    #[test]
    fn endpoint_update_via_version_bump() {
        // Figure 10's "Node 3 changed address" case.
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&[(NodeId(3), 2, true, 1111, 0)], 0.0);
        a.merge(&[(NodeId(3), 3, true, 2222, 0)], 1.0);
        assert_eq!(a.endpoint(NodeId(3)), Some(2222));
    }

    #[test]
    fn pick_targets_only_alive_and_bounded() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        for i in 1..=5u32 {
            a.merge(&[(NodeId(i), 1, true, 0, 0)], 0.0);
        }
        a.merge(&[(NodeId(9), 1, false, 0, 0)], 0.0); // offline
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let t = a.pick_targets(&mut rng, 1.0);
            assert!(t.len() <= 2);
            assert!(!t.contains(&NodeId(9)));
            assert!(!t.contains(&NodeId(0)));
        }
    }

    #[test]
    fn pairwise_rounds_converge() {
        // 8 nodes, push-pull with random pairs: everyone should learn
        // everyone within a few rounds (epidemic diffusion).
        let n = 8u32;
        let mut views: Vec<PeerView> =
            (0..n).map(|i| PeerView::new(NodeId(i), cfg(), 0.0)).collect();
        // Ring bootstrap: i knows i+1.
        for i in 0..n as usize {
            let peer = NodeId(((i + 1) % n as usize) as u32);
            views[i].add_seed(peer, 0, 0, 0.0);
        }
        let mut rng = Rng::new(7);
        for round in 0..6 {
            let now = round as f64;
            for v in views.iter_mut() {
                v.heartbeat(now);
            }
            for i in 0..n as usize {
                let targets = views[i].pick_targets(&mut rng, now);
                for t in targets {
                    // push-pull
                    let d = views[i].digest();
                    views[t.0 as usize].merge(&d, now);
                    let back = views[t.0 as usize].digest();
                    views[i].merge(&back, now);
                }
            }
        }
        for v in &views {
            assert_eq!(v.known(), n as usize, "node {} incomplete", v.me);
        }
    }

    #[test]
    fn suspicion_probe_reaches_aged_peer_but_not_leavers() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&[(NodeId(1), 5, true, 0, 0)], 10.0); // stays alive
        a.merge(&[(NodeId(2), 5, true, 0, 0)], 0.0); // will age out
        a.merge(&[(NodeId(3), 5, false, 0, 0)], 0.0); // graceful goodbye
        let mut rng = Rng::new(6);
        let mut probed_suspect = 0;
        for _ in 0..300 {
            let t = a.pick_targets(&mut rng, 10.0);
            assert!(!t.contains(&NodeId(3)), "leaver must not be probed");
            if t.contains(&NodeId(2)) {
                probed_suspect += 1;
            }
        }
        assert!(
            probed_suspect > 10,
            "aged peer never suspicion-probed ({probed_suspect}/300)"
        );
    }

    #[test]
    fn region_tags_ride_digests() {
        let mut b = PeerView::new(NodeId(1), cfg(), 0.0);
        b.set_region(2);
        b.heartbeat(0.1);
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&b.digest(), 0.2);
        assert_eq!(a.region_of(NodeId(1)), Some(2));
        // Region changes propagate with a version bump, like endpoints.
        b.set_region(3);
        b.heartbeat(0.3);
        a.merge(&b.digest(), 0.4);
        assert_eq!(a.region_of(NodeId(1)), Some(3));
        assert_eq!(a.region_of(NodeId(42)), None);
    }

    #[test]
    fn alive_peers_grouped_by_region() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&[(NodeId(1), 1, true, 0, 0)], 0.0);
        a.merge(&[(NodeId(2), 1, true, 0, 1)], 0.0);
        a.merge(&[(NodeId(3), 1, true, 0, 1)], 0.0);
        a.merge(&[(NodeId(4), 1, false, 0, 1)], 0.0); // offline
        let by = a.alive_peers_by_region(1.0);
        assert_eq!(by[&0], vec![NodeId(1)]);
        assert_eq!(by[&1], vec![NodeId(2), NodeId(3)]);
        assert_eq!(by.len(), 2);
        // Aged-out peers drop from every group.
        assert!(a.alive_peers_by_region(100.0).is_empty());
    }

    // ---- incremental-index and delta-protocol units -------------------------

    /// Brute-force recompute of alive peers from the raw entries, to pin
    /// the incrementally maintained indexes against.
    fn alive_brute(v: &PeerView, now: Time) -> Vec<NodeId> {
        let mut out: Vec<NodeId> = v
            .known_ids()
            .filter(|n| *n != v.me && v.is_alive(*n, now))
            .collect();
        out.sort();
        out
    }

    #[test]
    fn indexes_track_entries_through_churn() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let mut rng = Rng::new(99);
        for step in 0..500u64 {
            let node = NodeId(1 + (rng.below(10) as u32));
            let version = step + 1;
            let online = rng.chance(0.8);
            let region = rng.below(3) as u32;
            a.merge(&[(node, version, online, 0, region)], step as f64 * 0.1);
            let now = step as f64 * 0.1;
            assert_eq!(a.alive_peers(now), alive_brute(&a, now), "step {step}");
            let by = a.alive_peers_by_region(now);
            let flat: Vec<NodeId> =
                by.values().flatten().copied().collect::<Vec<_>>();
            let mut flat_sorted = flat.clone();
            flat_sorted.sort();
            assert_eq!(flat_sorted, alive_brute(&a, now), "regions step {step}");
            for (region, group) in &by {
                for n in group {
                    assert_eq!(a.region_of(*n), Some(*region));
                }
            }
        }
    }

    #[test]
    fn digest_sorted_without_resort() {
        let mut a = PeerView::new(NodeId(5), cfg(), 0.0);
        for i in [9u32, 2, 7, 1, 30, 4] {
            a.merge(&[(NodeId(i), 3, true, i as u64, 0)], 0.0);
        }
        let d = a.digest();
        let ids: Vec<u32> = d.iter().map(|(n, ..)| n.0).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        assert_eq!(d.len(), 7); // 6 peers + self
    }

    #[test]
    fn first_delta_is_full_then_only_changes() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.add_seed(NodeId(1), 0, 0, 0.0);
        a.merge(&[(NodeId(2), 4, true, 0, 1)], 0.0);
        // First contact: everything travels as full rows — except the
        // peer's own entry, which it is authoritative for.
        let (delta, hbs) = a.delta_for(NodeId(1), 0.0);
        assert_eq!(delta.len(), 2, "self + node 2, never the peer itself");
        assert!(delta.iter().all(|(n, ..)| *n != NodeId(1)));
        assert!(hbs.is_empty());
        // Nothing changed since: empty delta.
        let (delta, hbs) = a.delta_for(NodeId(1), 0.5);
        assert!(delta.is_empty() && hbs.is_empty());
        // A heartbeat-only advance travels as a compact pair...
        a.merge(&[(NodeId(2), 5, true, 0, 1)], 3.0);
        let (delta, hbs) = a.delta_for(NodeId(1), 3.0);
        assert!(delta.is_empty());
        assert_eq!(hbs, vec![(NodeId(2), 5)]);
        // ...while a membership change travels as a full row.
        a.merge(&[(NodeId(2), 6, false, 0, 1)], 6.0);
        let (delta, hbs) = a.delta_for(NodeId(1), 6.0);
        assert_eq!(delta, vec![(NodeId(2), 6, false, 0, 1)]);
        assert!(hbs.is_empty());
    }

    #[test]
    fn heartbeat_throttle_rate_limits_per_entry() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&[(NodeId(2), 1, true, 0, 0)], 0.0);
        // Drain first contact with both peers (full rows, throttle armed).
        let _ = a.delta_for(NodeId(1), 0.0);
        let _ = a.delta_for(NodeId(3), 0.0);
        // Past the throttle window (2s at suspect_after 5) a heartbeat-only
        // advance flows as a compact pair...
        a.merge(&[(NodeId(2), 2, true, 0, 0)], 2.5);
        let (_, hbs) = a.delta_for(NodeId(1), 2.5);
        assert_eq!(hbs, vec![(NodeId(2), 2)]);
        // ...and re-arms the throttle for *every* peer: a fresh bump right
        // after is withheld from the other peer too.
        a.merge(&[(NodeId(2), 3, true, 0, 0)], 2.6);
        let (delta, hbs) = a.delta_for(NodeId(3), 2.6);
        assert!(delta.is_empty() && hbs.is_empty(), "throttle spans peers");
        // Once the window passes the refresh flows again.
        a.merge(&[(NodeId(2), 4, true, 0, 0)], 5.0);
        let (_, hbs) = a.delta_for(NodeId(3), 5.0);
        assert_eq!(hbs, vec![(NodeId(2), 4)]);
    }

    #[test]
    fn heartbeat_pairs_never_resurrect_or_invent() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&[(NodeId(1), 5, false, 0, 0)], 0.0); // left
        // A bare heartbeat for an offline entry must not flip it online.
        let changed = a.merge_heartbeats(&[(NodeId(1), 9)], 1.0);
        assert!(changed.is_empty());
        assert!(!a.is_alive(NodeId(1), 1.0));
        assert_eq!(a.entry(NodeId(1)).unwrap().version, 5);
        // Unknown nodes are skipped, not invented.
        let changed = a.merge_heartbeats(&[(NodeId(7), 3)], 1.0);
        assert!(changed.is_empty());
        assert!(a.entry(NodeId(7)).is_none());
        // Known online entries refresh version + liveness.
        a.merge(&[(NodeId(2), 1, true, 0, 0)], 0.0);
        let changed = a.merge_heartbeats(&[(NodeId(2), 4)], 4.9);
        assert_eq!(changed, vec![NodeId(2)]);
        assert!(a.is_alive(NodeId(2), 9.0));
        assert_eq!(a.entry(NodeId(2)).unwrap().version, 4);
    }

    #[test]
    fn forged_giant_ids_are_dropped_not_allocated() {
        // Digest rows naming ids past the tracking ceiling must be ignored
        // outright: a Byzantine peer must not be able to force a
        // multi-gigabyte dense-table allocation with a single 32-bit id.
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let changed = a.merge(&[(NodeId(u32::MAX), 5, true, 0, 0)], 0.0);
        assert!(changed.is_empty());
        assert!(a.entry(NodeId(u32::MAX)).is_none());
        assert_eq!(a.known(), 1);
        a.add_seed(NodeId(MAX_TRACKED_ID), 0, 0, 0.0);
        assert_eq!(a.known(), 1, "seed past ceiling ignored too");
        // Ordinary ids still merge normally.
        let changed = a.merge(&[(NodeId(1000), 5, true, 0, 0)], 0.0);
        assert_eq!(changed, vec![NodeId(1000)]);
        assert!(a.is_alive(NodeId(1000), 0.5));
    }

    #[test]
    fn known_ids_ascending_and_complete() {
        let mut a = PeerView::new(NodeId(5), cfg(), 0.0);
        for i in [9u32, 2, 7, 30] {
            a.merge(&[(NodeId(i), 3, true, 0, 0)], 0.0);
        }
        let ids: Vec<u32> = a.known_ids().map(|n| n.0).collect();
        assert_eq!(ids, vec![2, 5, 7, 9, 30]);
        assert_eq!(a.known(), ids.len());
    }

    #[test]
    fn clock_changes_iff_content_changes() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let c0 = a.clock();
        a.merge(&[(NodeId(1), 2, true, 0, 0)], 0.0);
        assert!(a.clock() > c0);
        let c1 = a.clock();
        // A stale digest changes nothing — clock must hold still.
        a.merge(&[(NodeId(1), 2, true, 0, 0)], 1.0);
        assert_eq!(a.clock(), c1);
        a.heartbeat(2.0);
        assert!(a.clock() > c1);
    }
}
