//! Gossip-driven peer synchronization (§A.2, Figure 10).
//!
//! Each node keeps a [`PeerView`]: per-peer status (online/offline), network
//! endpoint, and a heartbeat version counter. Every gossip round a node bumps
//! its own heartbeat, picks a small fanout of live peers, and exchanges views
//! push-pull; entries with higher versions win during [`PeerView::merge`].
//! Liveness is inferred locally: a peer whose heartbeat hasn't advanced
//! within `suspect_after` rounds-worth of time is suspected offline
//! (SWIM-style, but simple heartbeat aging suffices at the paper's scale).
//!
//! Convergence (epidemic diffusion, O(log N) rounds) is property-tested in
//! `rust/tests/prop_gossip.rs` and measured in `benches/gossip_convergence.rs`.

use std::collections::{BTreeMap, HashMap};

use crate::types::{NodeId, Time};
use crate::util::rng::Rng;

/// What one node believes about one peer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeerEntry {
    /// Monotonic heartbeat counter, bumped by the peer itself each round.
    pub version: u64,
    /// Declared online/offline (a leaving node can gossip a graceful
    /// goodbye; crashes are caught by heartbeat aging).
    pub online: bool,
    /// Opaque endpoint (the TCP runner stores "host:port"; sim leaves 0).
    pub endpoint: u64,
    /// The peer's topology region tag (locality-aware dispatch); 0 in
    /// single-region worlds.
    pub region: u32,
    /// Local time we last saw this entry's version advance.
    pub last_seen: Time,
}

/// Gossip configuration knobs (system-level policy, §4.3).
#[derive(Debug, Clone, Copy)]
pub struct GossipConfig {
    /// Seconds between gossip rounds.
    pub interval: f64,
    /// Peers contacted per round.
    pub fanout: usize,
    /// Seconds without heartbeat progress before a peer is suspected dead.
    pub suspect_after: f64,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig { interval: 1.0, fanout: 2, suspect_after: 5.0 }
    }
}

/// Per-round probability of gossiping at one *suspected* peer (online per
/// its last word, but heartbeat-aged — a crash or a network partition).
/// Without this probe a healed partition would never re-merge: every
/// surviving node's alive pool is non-empty, so the empty-pool fallback
/// never fires and aged-out peers would stay invisible forever. A lost
/// probe costs one message; a successful one pulls the whole remote side's
/// view back in (SWIM-style suspicion, simplified). Only rolls — and only
/// consumes RNG draws — when suspects exist, so churn-free runs replay
/// identically to the pre-topology fabric.
pub const RESURRECT_PROB: f64 = 0.15;

/// One node's local membership view.
#[derive(Debug, Clone)]
pub struct PeerView {
    pub me: NodeId,
    entries: HashMap<NodeId, PeerEntry>,
    cfg: GossipConfig,
}

/// A serializable digest exchanged during a gossip round.
pub type Digest = Vec<(NodeId, u64, bool, u64, u32)>; // (node, version, online, endpoint, region)

impl PeerView {
    pub fn new(me: NodeId, cfg: GossipConfig, now: Time) -> Self {
        let mut entries = HashMap::new();
        entries.insert(
            me,
            PeerEntry {
                version: 1,
                online: true,
                endpoint: 0,
                region: 0,
                last_seen: now,
            },
        );
        PeerView { me, entries, cfg }
    }

    pub fn config(&self) -> GossipConfig {
        self.cfg
    }

    /// Seed knowledge of a bootstrap peer (e.g. from the config file).
    pub fn add_seed(&mut self, peer: NodeId, endpoint: u64, region: u32, now: Time) {
        self.entries.entry(peer).or_insert(PeerEntry {
            version: 0,
            online: true,
            endpoint,
            region,
            last_seen: now,
        });
    }

    /// Declare our own region (gossiped out with every digest).
    pub fn set_region(&mut self, region: u32) {
        self.entries.get_mut(&self.me).expect("self entry exists").region =
            region;
    }

    /// The region tag we last heard for `peer` (None if unknown peer).
    pub fn region_of(&self, peer: NodeId) -> Option<u32> {
        self.entries.get(&peer).map(|e| e.region)
    }

    /// Bump our own heartbeat (start of each gossip round). A heartbeat
    /// asserts liveness, so it also clears any prior offline announcement
    /// (the leave -> rejoin cycle of Figure 5).
    pub fn heartbeat(&mut self, now: Time) {
        let e = self.entries.get_mut(&self.me).expect("self entry exists");
        e.version += 1;
        e.online = true;
        e.last_seen = now;
    }

    /// Gracefully announce our departure (gossiped out before leaving).
    pub fn announce_leave(&mut self, now: Time) {
        let e = self.entries.get_mut(&self.me).expect("self entry exists");
        e.version += 1;
        e.online = false;
        e.last_seen = now;
    }

    /// Optimistically refresh contactability of known online peers — used
    /// when (re)joining after downtime: our `last_seen` clocks are stale,
    /// but bootstrap peers are worth contacting so the join gossip can
    /// propagate (they'll age out again if truly gone).
    pub fn refresh(&mut self, now: Time) {
        for (n, e) in self.entries.iter_mut() {
            if *n != self.me && e.online {
                e.last_seen = now;
            }
        }
    }

    pub fn set_endpoint(&mut self, endpoint: u64) {
        self.entries.get_mut(&self.me).expect("self entry exists").endpoint =
            endpoint;
    }

    /// Is `peer` believed alive right now? (online flag + heartbeat age)
    pub fn is_alive(&self, peer: NodeId, now: Time) -> bool {
        match self.entries.get(&peer) {
            None => false,
            Some(e) => {
                e.online && (now - e.last_seen) <= self.cfg.suspect_after
            }
        }
    }

    /// All peers (excluding self) believed alive.
    pub fn alive_peers(&self, now: Time) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self
            .entries
            .keys()
            .copied()
            .filter(|n| *n != self.me && self.is_alive(*n, now))
            .collect();
        v.sort();
        v
    }

    /// All alive peers (excluding self) grouped by their region tag —
    /// deterministic order (BTreeMap, sorted peer lists).
    pub fn alive_peers_by_region(&self, now: Time) -> BTreeMap<u32, Vec<NodeId>> {
        let mut by_region: BTreeMap<u32, Vec<NodeId>> = BTreeMap::new();
        for (n, e) in &self.entries {
            if *n != self.me && self.is_alive(*n, now) {
                by_region.entry(e.region).or_default().push(*n);
            }
        }
        for v in by_region.values_mut() {
            v.sort();
        }
        by_region
    }

    pub fn endpoint(&self, peer: NodeId) -> Option<u64> {
        self.entries.get(&peer).map(|e| e.endpoint)
    }

    pub fn entry(&self, peer: NodeId) -> Option<&PeerEntry> {
        self.entries.get(&peer)
    }

    pub fn known(&self) -> usize {
        self.entries.len()
    }

    /// Choose gossip targets for this round. If nobody looks alive (e.g. we
    /// were offline past everyone's heartbeat window, or we just booted from
    /// stale seeds), fall back to probing *known* peers — an unreachable
    /// target costs one lost message, while never probing would leave the
    /// node isolated forever.
    pub fn pick_targets(&self, rng: &mut Rng, now: Time) -> Vec<NodeId> {
        let mut pool = self.alive_peers(now);
        let fallback = pool.is_empty();
        if fallback {
            pool = self
                .entries
                .keys()
                .copied()
                .filter(|n| *n != self.me)
                .collect();
            pool.sort();
        }
        if pool.is_empty() {
            return vec![];
        }
        let idx = rng.sample_distinct(pool.len(), self.cfg.fanout);
        let mut targets: Vec<NodeId> =
            idx.into_iter().map(|i| pool[i]).collect();
        // Suspicion probe: occasionally add one heartbeat-aged peer that
        // never said goodbye, so crashed-and-recovered nodes and healed
        // partitions can rejoin (see [`RESURRECT_PROB`]). Skipped in
        // fallback mode — the pool already holds every known peer.
        if !fallback {
            let mut suspects: Vec<NodeId> = self
                .entries
                .iter()
                .filter(|(n, e)| {
                    **n != self.me && e.online && !self.is_alive(**n, now)
                })
                .map(|(n, _)| *n)
                .collect();
            if !suspects.is_empty() && rng.chance(RESURRECT_PROB) {
                suspects.sort();
                targets.push(suspects[rng.below(suspects.len())]);
            }
        }
        targets
    }

    /// Serialize the view for transmission.
    pub fn digest(&self) -> Digest {
        let mut d: Digest = self
            .entries
            .iter()
            .map(|(n, e)| (*n, e.version, e.online, e.endpoint, e.region))
            .collect();
        d.sort_by_key(|(n, ..)| *n);
        d
    }

    /// Merge a received digest; higher version wins. Returns the nodes whose
    /// entries changed (new information learned).
    pub fn merge(&mut self, digest: &Digest, now: Time) -> Vec<NodeId> {
        let mut changed = Vec::new();
        for (node, version, online, endpoint, region) in digest {
            if *node == self.me {
                // Nobody can overwrite our self-entry (our version is
                // authoritative — prevents spoofed "you are offline").
                continue;
            }
            let e = self.entries.entry(*node).or_insert(PeerEntry {
                version: 0,
                online: false,
                endpoint: *endpoint,
                region: *region,
                last_seen: now - self.cfg.suspect_after - 1.0,
            });
            if *version > e.version {
                let was = (e.version, e.online, e.endpoint, e.region);
                e.version = *version;
                e.online = *online;
                e.endpoint = *endpoint;
                e.region = *region;
                e.last_seen = now;
                if was != (*version, *online, *endpoint, *region) {
                    changed.push(*node);
                }
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GossipConfig {
        GossipConfig { interval: 1.0, fanout: 2, suspect_after: 5.0 }
    }

    #[test]
    fn self_entry_always_alive_view() {
        let v = PeerView::new(NodeId(0), cfg(), 0.0);
        assert_eq!(v.known(), 1);
        assert!(v.alive_peers(0.0).is_empty());
    }

    #[test]
    fn merge_learns_new_peers() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let mut b = PeerView::new(NodeId(1), cfg(), 0.0);
        b.heartbeat(0.5);
        let changed = a.merge(&b.digest(), 1.0);
        assert_eq!(changed, vec![NodeId(1)]);
        assert!(a.is_alive(NodeId(1), 1.0));
    }

    #[test]
    fn higher_version_wins_lower_ignored() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let digest_v5: Digest = vec![(NodeId(2), 5, true, 7, 1)];
        let digest_v3: Digest = vec![(NodeId(2), 3, false, 9, 2)];
        a.merge(&digest_v5, 1.0);
        let changed = a.merge(&digest_v3, 2.0);
        assert!(changed.is_empty());
        let e = a.entry(NodeId(2)).unwrap();
        assert_eq!(e.version, 5);
        assert!(e.online);
        assert_eq!(e.endpoint, 7);
    }

    #[test]
    fn self_entry_cannot_be_spoofed() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        let spoof: Digest = vec![(NodeId(0), 99, false, 0, 3)];
        a.merge(&spoof, 1.0);
        let e = a.entry(NodeId(0)).unwrap();
        assert_eq!(e.version, 1);
        assert!(e.online);
    }

    #[test]
    fn heartbeat_aging_suspects_silent_peer() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&vec![(NodeId(1), 4, true, 0, 0)], 0.0);
        assert!(a.is_alive(NodeId(1), 4.9));
        assert!(!a.is_alive(NodeId(1), 5.1));
        // Progress resets the clock.
        a.merge(&vec![(NodeId(1), 5, true, 0, 0)], 6.0);
        assert!(a.is_alive(NodeId(1), 10.0));
    }

    #[test]
    fn graceful_leave_propagates() {
        let mut leaver = PeerView::new(NodeId(1), cfg(), 0.0);
        leaver.heartbeat(0.1);
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&leaver.digest(), 0.2);
        assert!(a.is_alive(NodeId(1), 0.5));
        leaver.announce_leave(0.6);
        a.merge(&leaver.digest(), 0.7);
        assert!(!a.is_alive(NodeId(1), 0.8));
    }

    #[test]
    fn endpoint_update_via_version_bump() {
        // Figure 10's "Node 3 changed address" case.
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&vec![(NodeId(3), 2, true, 1111, 0)], 0.0);
        a.merge(&vec![(NodeId(3), 3, true, 2222, 0)], 1.0);
        assert_eq!(a.endpoint(NodeId(3)), Some(2222));
    }

    #[test]
    fn pick_targets_only_alive_and_bounded() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        for i in 1..=5u32 {
            a.merge(&vec![(NodeId(i), 1, true, 0, 0)], 0.0);
        }
        a.merge(&vec![(NodeId(9), 1, false, 0, 0)], 0.0); // offline
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let t = a.pick_targets(&mut rng, 1.0);
            assert!(t.len() <= 2);
            assert!(!t.contains(&NodeId(9)));
            assert!(!t.contains(&NodeId(0)));
        }
    }

    #[test]
    fn pairwise_rounds_converge() {
        // 8 nodes, push-pull with random pairs: everyone should learn
        // everyone within a few rounds (epidemic diffusion).
        let n = 8u32;
        let mut views: Vec<PeerView> =
            (0..n).map(|i| PeerView::new(NodeId(i), cfg(), 0.0)).collect();
        // Ring bootstrap: i knows i+1.
        for i in 0..n as usize {
            let peer = NodeId(((i + 1) % n as usize) as u32);
            views[i].add_seed(peer, 0, 0, 0.0);
        }
        let mut rng = Rng::new(7);
        for round in 0..6 {
            let now = round as f64;
            for i in 0..n as usize {
                views[i].heartbeat(now);
            }
            for i in 0..n as usize {
                let targets = views[i].pick_targets(&mut rng, now);
                for t in targets {
                    // push-pull
                    let d = views[i].digest();
                    views[t.0 as usize].merge(&d, now);
                    let back = views[t.0 as usize].digest();
                    views[i].merge(&back, now);
                }
            }
        }
        for v in &views {
            assert_eq!(v.known(), n as usize, "node {} incomplete", v.me);
        }
    }

    #[test]
    fn suspicion_probe_reaches_aged_peer_but_not_leavers() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&vec![(NodeId(1), 5, true, 0, 0)], 10.0); // stays alive
        a.merge(&vec![(NodeId(2), 5, true, 0, 0)], 0.0); // will age out
        a.merge(&vec![(NodeId(3), 5, false, 0, 0)], 0.0); // graceful goodbye
        let mut rng = Rng::new(6);
        let mut probed_suspect = 0;
        for _ in 0..300 {
            let t = a.pick_targets(&mut rng, 10.0);
            assert!(!t.contains(&NodeId(3)), "leaver must not be probed");
            if t.contains(&NodeId(2)) {
                probed_suspect += 1;
            }
        }
        assert!(
            probed_suspect > 10,
            "aged peer never suspicion-probed ({probed_suspect}/300)"
        );
    }

    #[test]
    fn region_tags_ride_digests() {
        let mut b = PeerView::new(NodeId(1), cfg(), 0.0);
        b.set_region(2);
        b.heartbeat(0.1);
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&b.digest(), 0.2);
        assert_eq!(a.region_of(NodeId(1)), Some(2));
        // Region changes propagate with a version bump, like endpoints.
        b.set_region(3);
        b.heartbeat(0.3);
        a.merge(&b.digest(), 0.4);
        assert_eq!(a.region_of(NodeId(1)), Some(3));
        assert_eq!(a.region_of(NodeId(42)), None);
    }

    #[test]
    fn alive_peers_grouped_by_region() {
        let mut a = PeerView::new(NodeId(0), cfg(), 0.0);
        a.merge(&vec![(NodeId(1), 1, true, 0, 0)], 0.0);
        a.merge(&vec![(NodeId(2), 1, true, 0, 1)], 0.0);
        a.merge(&vec![(NodeId(3), 1, true, 0, 1)], 0.0);
        a.merge(&vec![(NodeId(4), 1, false, 0, 1)], 0.0); // offline
        let by = a.alive_peers_by_region(1.0);
        assert_eq!(by[&0], vec![NodeId(1)]);
        assert_eq!(by[&1], vec![NodeId(2), NodeId(3)]);
        assert_eq!(by.len(), 2);
        // Aged-out peers drop from every group.
        assert!(a.alive_peers_by_region(100.0).is_empty());
    }
}
