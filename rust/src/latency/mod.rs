//! Live per-region one-way latency estimation (EWMA over observed RTTs).
//!
//! PR 1's locality-aware dispatch scored candidates with the topology's
//! *pristine* `expected_latency_matrix()` — an oracle-free but **static**
//! estimate. A live partition or congestion event never changed who got
//! picked: nodes kept delegating into a dead trans-Atlantic link until
//! per-request timeouts burned the SLO budget. This module replaces that
//! matrix with a measurement loop, the way decentralized schedulers over
//! heterogeneous WANs do (ROADMAP "Follow-on geo experiments"; PAPERS.md's
//! overlay-routing systems): nodes estimate latency from traffic they
//! already exchange and steer load away from paths that *observably*
//! degrade — and back, once they recover.
//!
//! ## Estimator model
//!
//! [`LatencyEstimator`] keeps one cell per region pair:
//!
//! * **EWMA** — each direct observation (a probe→reply or gossip push→pull
//!   round trip, halved to one-way) moves the cell's estimate by
//!   [`LatencyConfig::alpha`].
//! * **Cold-start prior** — the pristine expected-latency matrix seeds every
//!   cell. A cell with few observations blends toward the prior with weight
//!   [`LatencyConfig::prior_weight`] (pseudo-observations), so one jittery
//!   sample cannot hijack dispatch.
//! * **Staleness decay** — a cell that stops hearing evidence decays
//!   linearly back to the prior over [`LatencyConfig::decay_after`]
//!   seconds. Stale pessimism (or stale optimism) has a bounded lifetime.
//! * **Timeout penalties** — an unanswered probe is evidence too:
//!   [`LatencyEstimator::observe_timeout`] feeds the timeout floor as an
//!   observation, so a freshly partitioned region is shed within a few
//!   probe timeouts — long before gossip liveness aging notices.
//!
//! ## Region summaries on gossip
//!
//! A node only measures the pairs it talks across. So that regions with no
//! direct traffic still converge, nodes piggyback their *directly measured*
//! row on gossip deltas ([`LatencyEstimator::share`], rate-limited by
//! [`LatencyConfig::share_every`], same-region peers only) and merge
//! received summaries as weaker *indirect* observations
//! ([`LatencyEstimator::merge`]). Indirect estimates are never re-shared
//! (only cells with fresh direct evidence qualify for `share`), which
//! keeps hearsay from echoing around the region.
//!
//! ## Versioning
//!
//! Anything derived from the estimator (the node's cached stake snapshot)
//! keys on [`LatencyEstimator::version`]. To avoid invalidating that cache
//! on every jittery sample, the version bumps only when a cell's estimate
//! drifts more than [`VERSION_DRIFT`] (relative) since the last bump — big
//! swings (a timeout penalty, a heal) invalidate immediately, steady-state
//! noise does not.
//!
//! With `enabled = false` the estimator freezes at the prior (the static
//! matrix of PR 1) — the baseline the reroute bench compares against.

use crate::types::Time;

/// Region-pair latency summaries piggybacked on gossip deltas:
/// `(src_region, dst_region, one_way_seconds)` triples.
pub type RegionRtts = Vec<(u32, u32, f64)>;

/// Relative drift of a cell's estimate (vs. its value at the last version
/// bump) that triggers a new estimator version. See module docs.
pub const VERSION_DRIFT: f64 = 0.10;

/// Indirect (gossiped) observations count this fraction of a direct one,
/// both in EWMA step size and in accumulated confidence weight.
const INDIRECT_SCALE: f64 = 0.5;

/// Fraction of `decay_after` during which a cell's own direct measurement
/// outranks gossiped hearsay (indirect merges are skipped).
const DIRECT_TRUST_FRAC: f64 = 0.25;

/// Fraction of `decay_after` a direct observation stays fresh enough for
/// its cell to be included in outgoing region summaries.
const SHARE_FRESH_FRAC: f64 = 0.5;

/// Declarative knobs for the live estimator (the `latency_estimation`
/// config block; see `config::parse_experiment`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyConfig {
    /// `false` freezes every estimate at the cold-start prior — the static
    /// expected-latency-matrix behaviour, kept as the A/B baseline.
    pub enabled: bool,
    /// EWMA weight of a new direct observation (0 < alpha <= 1).
    pub alpha: f64,
    /// Seconds of evidence silence after which a cell has fully decayed
    /// back to its prior.
    pub decay_after: f64,
    /// Pseudo-observations backing the prior during cold start: with
    /// weight `w` observations accumulated, the estimate counts
    /// `w / (w + prior_weight)` against the prior.
    pub prior_weight: f64,
    /// Minimum seconds between region-summary piggybacks to the same peer
    /// (keeps the gossip-byte overhead negligible at fleet scale).
    pub share_every: f64,
}

impl Default for LatencyConfig {
    fn default() -> Self {
        LatencyConfig {
            enabled: true,
            alpha: 0.3,
            decay_after: 60.0,
            prior_weight: 1.0,
            share_every: 5.0,
        }
    }
}

impl LatencyConfig {
    /// Range-check every knob; the single source of validity used by both
    /// the config parser (mapped to a `ConfigError`) and
    /// [`validate`](Self::validate) (panicking form).
    pub fn check(&self) -> Result<(), String> {
        if !(self.alpha > 0.0 && self.alpha <= 1.0 && self.alpha.is_finite())
        {
            return Err(format!(
                "latency_estimation.alpha must be in (0, 1], got {}",
                self.alpha
            ));
        }
        if !(self.decay_after > 0.0 && self.decay_after.is_finite()) {
            return Err(format!(
                "latency_estimation.decay_after must be > 0, got {}",
                self.decay_after
            ));
        }
        if !(self.prior_weight >= 0.0 && self.prior_weight.is_finite()) {
            return Err(format!(
                "latency_estimation.prior_weight must be >= 0, got {}",
                self.prior_weight
            ));
        }
        if !(self.share_every >= 0.0 && self.share_every.is_finite()) {
            return Err(format!(
                "latency_estimation.share_every must be >= 0, got {}",
                self.share_every
            ));
        }
        Ok(())
    }

    /// Panics with a descriptive message on invalid knobs (construction
    /// and `WorldConfig::validate` paths — misconfigured experiments fail
    /// loudly; the config parser uses [`check`](Self::check) to return
    /// `Err` on malformed user input instead).
    pub fn validate(&self) {
        if let Err(msg) = self.check() {
            panic!("{msg}");
        }
    }
}

/// Per-region-pair estimator state.
#[derive(Debug, Clone, Copy)]
struct Cell {
    /// EWMA of observed one-way latency (seconds). Meaningless while
    /// `weight == 0` (no observations yet).
    est: f64,
    /// Accumulated observation weight, capped at `1 / alpha` (the EWMA's
    /// effective sample size) — drives the cold-start blend.
    weight: f64,
    /// Time of the last evidence of any kind (drives staleness decay).
    last_obs: Time,
    /// Time of the last *direct* observation (only these cells are
    /// re-shared, and fresh direct data outranks gossiped hearsay).
    last_direct: Time,
    /// `est` as of the last version bump (drift threshold anchor).
    versioned_est: f64,
}

impl Cell {
    fn empty() -> Cell {
        Cell {
            est: 0.0,
            weight: 0.0,
            last_obs: f64::NEG_INFINITY,
            last_direct: f64::NEG_INFINITY,
            versioned_est: 0.0,
        }
    }
}

/// Live per-region one-way latency estimates for one node. See module docs.
#[derive(Debug, Clone)]
pub struct LatencyEstimator {
    my_region: usize,
    n: usize,
    /// Pristine expected-latency matrix, row-major `[a * n + b]` — the
    /// cold-start prior and the decay target.
    prior: Vec<f64>,
    cells: Vec<Cell>,
    cfg: LatencyConfig,
    /// Bumped on material estimate changes — the snapshot-cache key.
    version: u64,
}

impl LatencyEstimator {
    /// Build from this node's region and the pristine expected-latency
    /// matrix (`prior[a][b]` = one-way seconds from region a to region b).
    pub fn new(
        my_region: u32,
        prior: Vec<Vec<f64>>,
        cfg: LatencyConfig,
    ) -> LatencyEstimator {
        cfg.validate();
        let n = prior.len();
        assert!(n > 0, "latency estimator: empty prior matrix");
        assert!(
            (my_region as usize) < n,
            "latency estimator: region {my_region} outside {n}x{n} prior"
        );
        let mut flat = Vec::with_capacity(n * n);
        for row in &prior {
            assert_eq!(
                row.len(),
                n,
                "latency estimator: prior matrix must be square"
            );
            for v in row {
                assert!(
                    v.is_finite() && *v >= 0.0,
                    "latency estimator: prior entries must be finite and \
                     >= 0, got {v}"
                );
                flat.push(*v);
            }
        }
        LatencyEstimator {
            my_region: my_region as usize,
            n,
            prior: flat,
            cells: vec![Cell::empty(); n * n],
            cfg,
            version: 0,
        }
    }

    pub fn my_region(&self) -> u32 {
        self.my_region as u32
    }

    pub fn num_regions(&self) -> usize {
        self.n
    }

    pub fn config(&self) -> LatencyConfig {
        self.cfg
    }

    /// Changes iff some estimate moved materially — the cheap staleness key
    /// for caches derived from this estimator (see `SnapCache`).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Current one-way estimate from region `a` to region `b`: the EWMA
    /// blended with the prior by observation confidence and staleness (see
    /// module docs). Out-of-range regions — a garbage gossip tag — get the
    /// [`conservative`](Self::conservative) estimate, never region 0's row.
    pub fn expected(&self, a: u32, b: u32, now: Time) -> f64 {
        let (a, b) = (a as usize, b as usize);
        if a >= self.n || b >= self.n {
            return self.conservative();
        }
        self.expected_idx(a * self.n + b, now)
    }

    /// One-way estimate from this node's own region to `b` (the dispatch
    /// scoring path).
    pub fn expected_from_me(&self, b: u32, now: Time) -> f64 {
        self.expected(self.my_region as u32, b, now)
    }

    /// Conservative fallback for peers whose region is unknown or invalid:
    /// the worst pristine latency out of this node's own region. Unknown
    /// must never score better than the farthest *known* region.
    pub fn conservative(&self) -> f64 {
        (0..self.n)
            .map(|b| self.prior[self.my_region * self.n + b])
            .fold(0.0, f64::max)
    }

    fn expected_idx(&self, i: usize, now: Time) -> f64 {
        let prior = self.prior[i];
        if !self.cfg.enabled {
            return prior;
        }
        let c = &self.cells[i];
        if c.weight <= 0.0 {
            return prior;
        }
        let age = (now - c.last_obs).max(0.0);
        let fresh = (1.0 - age / self.cfg.decay_after).clamp(0.0, 1.0);
        let conf = c.weight / (c.weight + self.cfg.prior_weight);
        prior + (c.est - prior) * fresh * conf
    }

    /// Feed a measured request→reply round trip with a peer in `region`
    /// (probe→accept/reject, gossip push→pull). Halved to one-way and
    /// applied to both directions of the (symmetric) pair.
    pub fn observe_rtt(&mut self, region: u32, rtt: f64, now: Time) {
        self.observe_direct(region, rtt.max(0.0) / 2.0, now);
    }

    /// An unanswered probe is evidence of a dead or drastically slow path:
    /// feed the timeout floor (`rtt >= timeout`, so one-way `>= timeout/2`)
    /// as a direct observation. A handful of these shed a freshly
    /// partitioned region from dispatch within a few gossip intervals.
    pub fn observe_timeout(&mut self, region: u32, timeout: f64, now: Time) {
        self.observe_direct(region, timeout.max(0.0) / 2.0, now);
    }

    fn observe_direct(&mut self, region: u32, one_way: f64, now: Time) {
        if !self.cfg.enabled {
            return;
        }
        let r = region as usize;
        if r >= self.n {
            return;
        }
        let my = self.my_region;
        self.update_cell(my, r, one_way, false, now);
        if r != my {
            self.update_cell(r, my, one_way, false, now);
        }
    }

    /// Evidence that the path to `region` is alive without a latency
    /// measurement (e.g. a delegation response arrived — its timing mixes
    /// network and compute, so it refreshes freshness but not the EWMA).
    /// The decay accrued so far is folded into the stored estimate first —
    /// a touch preserves the *current decayed* value and resets the decay
    /// clock; it never resurrects a stale one.
    pub fn touch(&mut self, region: u32, now: Time) {
        if !self.cfg.enabled {
            return;
        }
        let r = region as usize;
        if r >= self.n {
            return;
        }
        for i in [self.my_region * self.n + r, r * self.n + self.my_region] {
            if self.cells[i].weight > 0.0 {
                self.fold_decay(i, now);
                let c = &mut self.cells[i];
                c.last_obs = c.last_obs.max(now);
            }
        }
    }

    /// Fold the staleness decay accrued since the last evidence into the
    /// stored EWMA, anchoring it at its current *effective* (decayed)
    /// value. Called whenever new evidence arrives at a cell: without
    /// this, the first observation or touch after a long silence would
    /// reset the decay clock against the undecayed stale estimate,
    /// resurrecting a penalty (or an optimism) that had already expired.
    fn fold_decay(&mut self, i: usize, now: Time) {
        let prior = self.prior[i];
        let c = &mut self.cells[i];
        let age = (now - c.last_obs).max(0.0);
        if c.weight <= 0.0 || age <= 0.0 {
            return;
        }
        let fresh = (1.0 - age / self.cfg.decay_after).clamp(0.0, 1.0);
        c.est = prior + (c.est - prior) * fresh;
    }

    /// This node's freshly *directly measured* row, for piggybacking on
    /// gossip deltas. Indirectly learned cells never qualify — hearsay is
    /// not re-shared (no echo amplification).
    pub fn share(&self, now: Time) -> RegionRtts {
        if !self.cfg.enabled {
            return Vec::new();
        }
        let window = SHARE_FRESH_FRAC * self.cfg.decay_after;
        let my = self.my_region;
        let mut out = Vec::new();
        for b in 0..self.n {
            let i = my * self.n + b;
            let c = &self.cells[i];
            if c.weight > 0.0 && now - c.last_direct <= window {
                out.push((my as u32, b as u32, self.expected_idx(i, now)));
            }
        }
        out
    }

    /// Merge region summaries received from a peer as *indirect*
    /// observations: half the EWMA step and confidence of a direct one,
    /// and skipped entirely for cells with fresh direct measurements (own
    /// evidence outranks hearsay).
    pub fn merge(&mut self, rtts: &[(u32, u32, f64)], now: Time) {
        if !self.cfg.enabled {
            return;
        }
        let holdoff = DIRECT_TRUST_FRAC * self.cfg.decay_after;
        for (a, b, est) in rtts {
            let (a, b) = (*a as usize, *b as usize);
            if a >= self.n || b >= self.n {
                continue;
            }
            if now - self.cells[a * self.n + b].last_direct <= holdoff {
                continue;
            }
            self.update_cell(a, b, *est, true, now);
        }
    }

    fn update_cell(
        &mut self,
        a: usize,
        b: usize,
        sample: f64,
        indirect: bool,
        now: Time,
    ) {
        if !sample.is_finite() || sample < 0.0 {
            return;
        }
        let (alpha, w) = if indirect {
            (self.cfg.alpha * INDIRECT_SCALE, INDIRECT_SCALE)
        } else {
            (self.cfg.alpha, 1.0)
        };
        let cap = 1.0 / self.cfg.alpha;
        // Anchor the EWMA at its current decayed value before blending in
        // the new sample — expired staleness must not resurrect.
        self.fold_decay(a * self.n + b, now);
        let c = &mut self.cells[a * self.n + b];
        let first = c.weight <= 0.0;
        if first {
            c.est = sample;
        } else {
            c.est = alpha * sample + (1.0 - alpha) * c.est;
        }
        c.weight = (c.weight + w).min(cap);
        c.last_obs = c.last_obs.max(now);
        if !indirect {
            c.last_direct = c.last_direct.max(now);
        }
        let drift = (c.est - c.versioned_est).abs()
            > VERSION_DRIFT * c.versioned_est.abs().max(1e-4);
        if first || drift {
            c.versioned_est = c.est;
            self.version += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_region_prior() -> Vec<Vec<f64>> {
        // Region 0 is home: 5 ms intra, 100 ms to region 1.
        vec![vec![0.005, 0.100], vec![0.100, 0.005]]
    }

    fn est() -> LatencyEstimator {
        LatencyEstimator::new(0, two_region_prior(), LatencyConfig::default())
    }

    #[test]
    fn cold_start_returns_prior() {
        let e = est();
        assert_eq!(e.expected(0, 1, 10.0), 0.100);
        assert_eq!(e.expected(0, 0, 10.0), 0.005);
        assert_eq!(e.expected_from_me(1, 10.0), 0.100);
        assert_eq!(e.version(), 0);
    }

    #[test]
    fn observation_moves_estimate_and_bumps_version() {
        let mut e = est();
        // Observed 1.0 s RTT to region 1: one-way 0.5 s, blended with the
        // prior at confidence w/(w+1) = 0.5 after one observation.
        e.observe_rtt(1, 1.0, 0.0);
        let got = e.expected(0, 1, 0.0);
        let want = 0.100 + (0.5 - 0.100) * 0.5;
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
        assert!(e.version() > 0, "first observation must bump the version");
        // Symmetric pair updated too.
        assert!((e.expected(1, 0, 0.0) - want).abs() < 1e-12);
        // More observations raise confidence toward the raw EWMA.
        for _ in 0..20 {
            e.observe_rtt(1, 1.0, 0.0);
        }
        assert!(e.expected(0, 1, 0.0) > 0.35);
    }

    #[test]
    fn staleness_decays_back_to_prior() {
        let mut e = est();
        e.observe_rtt(1, 2.0, 100.0);
        let fresh = e.expected(0, 1, 100.0);
        assert!(fresh > 0.100, "penalized estimate above prior");
        // Halfway through the decay window the excursion has halved.
        let mid = e.expected(0, 1, 130.0);
        assert!(mid < fresh && mid > 0.100);
        assert!(
            ((mid - 0.100) - (fresh - 0.100) / 2.0).abs() < 1e-9,
            "linear decay: fresh {fresh}, mid {mid}"
        );
        // Past decay_after (60 s) the prior is fully restored.
        assert_eq!(e.expected(0, 1, 161.0), 0.100);
        // A touch preserves the *current decayed* value (never the stale
        // undecayed one) and resets the decay clock.
        e.observe_rtt(1, 2.0, 200.0);
        let just_before = e.expected(0, 1, 250.0);
        e.touch(1, 250.0);
        let after_touch = e.expected(0, 1, 250.0);
        assert!(
            (after_touch - just_before).abs() < 1e-9,
            "touch moved the estimate: {just_before} -> {after_touch}"
        );
        // Clock restarted at the touch: the excursion outlives the original
        // window but still decays to the prior eventually.
        assert!(e.expected(0, 1, 290.0) > 0.100);
        assert_eq!(e.expected(0, 1, 311.0), 0.100);
    }

    #[test]
    fn touch_does_not_resurrect_decayed_penalties() {
        let mut e = est();
        e.observe_rtt(1, 3.0, 0.0); // heavy penalty: one-way 1.5 s
        assert!(e.expected(0, 1, 0.0) > 0.5);
        // Long silence: fully decayed back to the prior...
        assert_eq!(e.expected(0, 1, 100.0), 0.100);
        // ...a bare liveness touch must keep it there, not resurrect 1.5 s.
        e.touch(1, 100.0);
        assert_eq!(e.expected(0, 1, 100.0), 0.100);
        assert_eq!(e.expected(0, 1, 101.0), 0.100);
        // And a fresh real sample restarts from the prior anchor, not from
        // the expired penalty.
        e.observe_rtt(1, 0.3, 101.0);
        let after = e.expected(0, 1, 101.0);
        assert!(after < 0.3, "stale penalty resurrected: {after}");
    }

    #[test]
    fn timeout_penalty_dominates_prior() {
        let mut e = est();
        for _ in 0..3 {
            e.observe_timeout(1, 3.0, 0.0);
        }
        // 3 s timeout floor -> one-way >= 1.5 s; after three penalties the
        // region scores at least 10x its 0.1 s prior.
        assert!(e.expected(0, 1, 0.0) > 1.0);
        // Intra-region estimates untouched.
        assert_eq!(e.expected(0, 0, 0.0), 0.005);
    }

    #[test]
    fn steady_observations_do_not_churn_version() {
        let mut e = est();
        e.observe_rtt(1, 0.2, 0.0);
        let v = e.version();
        // Identical samples leave the EWMA in place: no further bumps.
        for k in 0..50 {
            e.observe_rtt(1, 0.2, k as f64);
        }
        assert_eq!(e.version(), v, "steady estimates must not churn caches");
        // A big swing bumps immediately.
        e.observe_timeout(1, 3.0, 60.0);
        assert!(e.version() > v);
    }

    #[test]
    fn unknown_region_scores_conservative_not_region_zero() {
        let e = est();
        // Garbage region tag: worst own-row prior (0.100), NOT region 0's
        // cosy 0.005 intra latency.
        assert_eq!(e.expected(0, 99, 0.0), 0.100);
        assert_eq!(e.conservative(), 0.100);
    }

    #[test]
    fn share_only_fresh_direct_rows_and_merge_is_weaker() {
        let mut e = est();
        e.observe_rtt(1, 1.0, 0.0);
        let shared = e.share(0.0);
        assert_eq!(shared.len(), 1);
        assert_eq!((shared[0].0, shared[0].1), (0, 1));
        // Stale direct data (past half the decay window) stops being shared.
        assert!(e.share(31.0).is_empty());

        // A same-region peer merges the summary as an indirect observation…
        let mut other =
            LatencyEstimator::new(0, two_region_prior(), LatencyConfig::default());
        other.merge(&shared, 0.0);
        let merged = other.expected(0, 1, 0.0);
        assert!(merged > 0.100, "indirect evidence must move the estimate");
        assert!(
            merged < e.expected(0, 1, 0.0),
            "indirect evidence must count less than direct"
        );
        // …but never re-shares it (no gossip echo chamber).
        assert!(other.share(0.0).is_empty());

        // Fresh direct measurements outrank hearsay.
        let mut firsthand =
            LatencyEstimator::new(0, two_region_prior(), LatencyConfig::default());
        firsthand.observe_rtt(1, 0.2, 0.0);
        let before = firsthand.expected(0, 1, 0.0);
        firsthand.merge(&[(0, 1, 2.0)], 1.0);
        assert_eq!(firsthand.expected(0, 1, 1.0), before);
    }

    #[test]
    fn disabled_estimator_freezes_at_prior() {
        let cfg = LatencyConfig { enabled: false, ..Default::default() };
        let mut e = LatencyEstimator::new(0, two_region_prior(), cfg);
        e.observe_rtt(1, 5.0, 0.0);
        e.observe_timeout(1, 3.0, 1.0);
        e.merge(&[(0, 1, 2.0)], 2.0);
        assert_eq!(e.expected(0, 1, 3.0), 0.100, "static matrix baseline");
        assert_eq!(e.version(), 0);
        assert!(e.share(3.0).is_empty());
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn invalid_alpha_panics() {
        LatencyConfig { alpha: 0.0, ..Default::default() }.validate();
    }

    #[test]
    #[should_panic(expected = "region 5 outside")]
    fn out_of_range_home_region_panics() {
        LatencyEstimator::new(5, two_region_prior(), LatencyConfig::default());
    }
}
