//! Account state derived from applying credit ops in order.
//!
//! Both ledger implementations (shared + blockchain) reduce to this table;
//! the conservation invariant `total = minted - burned` is property-tested
//! in `rust/tests/prop_ledger.rs`.

use std::collections::BTreeMap;

use super::ops::CreditOp;
use crate::types::{Credits, NodeId};

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Account {
    /// Liquid, spendable credits.
    pub balance: Credits,
    /// Credits locked as PoS stake.
    pub stake: Credits,
}

impl Account {
    pub fn total(&self) -> Credits {
        self.balance + self.stake
    }
}

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum ApplyError {
    #[error("{node} has insufficient balance: need {need}, have {have}")]
    InsufficientBalance {
        node: NodeId,
        need: Credits,
        have: Credits,
    },
    #[error("{node} has insufficient stake: need {need}, have {have}")]
    InsufficientStake {
        node: NodeId,
        need: Credits,
        have: Credits,
    },
}

/// The materialized view of all accounts.
#[derive(Debug, Clone, Default)]
pub struct BalanceTable {
    accounts: BTreeMap<NodeId, Account>,
    /// Cumulative inflation/deflation counters (conservation accounting).
    pub minted: Credits,
    pub burned: Credits,
}

impl BalanceTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn account(&self, node: NodeId) -> Account {
        self.accounts.get(&node).copied().unwrap_or_default()
    }

    pub fn balance(&self, node: NodeId) -> Credits {
        self.account(node).balance
    }

    pub fn stake(&self, node: NodeId) -> Credits {
        self.account(node).stake
    }

    /// All (node, stake) pairs with positive stake, sorted by node —
    /// `BTreeMap` iteration is already node-ordered, so this is exactly the
    /// order the pre-migration explicit sort produced.
    pub fn stakes(&self) -> Vec<(NodeId, Credits)> {
        self.accounts
            .iter()
            .filter(|(_, a)| a.stake > 0)
            .map(|(n, a)| (*n, a.stake))
            .collect()
    }

    pub fn total_stake(&self) -> Credits {
        self.accounts.values().map(|a| a.stake).sum()
    }

    pub fn total_credits(&self) -> Credits {
        self.accounts.values().map(|a| a.total()).sum()
    }

    /// Validate without mutating.
    pub fn check(&self, op: &CreditOp) -> Result<(), ApplyError> {
        match *op {
            CreditOp::Mint { .. } => Ok(()),
            // Slashing clamps rather than failing: a node whose stake ran
            // out loses what's left (matches PoS slashing norms).
            CreditOp::Slash { .. } => Ok(()),
            // Burns clamp to the liquid balance the same way: a drained
            // provider pays the holding cost it can and fades out of the
            // market instead of voiding the batch.
            CreditOp::Burn { .. } => Ok(()),
            CreditOp::Transfer { from, amount, .. } => {
                let have = self.balance(from);
                if have < amount {
                    Err(ApplyError::InsufficientBalance {
                        node: from,
                        need: amount,
                        have,
                    })
                } else {
                    Ok(())
                }
            }
            CreditOp::Stake { node, amount } => {
                let have = self.balance(node);
                if have < amount {
                    Err(ApplyError::InsufficientBalance {
                        node,
                        need: amount,
                        have,
                    })
                } else {
                    Ok(())
                }
            }
            CreditOp::Unstake { node, amount } => {
                let have = self.stake(node);
                if have < amount {
                    Err(ApplyError::InsufficientStake {
                        node,
                        need: amount,
                        have,
                    })
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Validate and apply one op.
    pub fn apply(&mut self, op: &CreditOp) -> Result<(), ApplyError> {
        self.check(op)?;
        match *op {
            CreditOp::Mint { to, amount, .. } => {
                self.accounts.entry(to).or_default().balance += amount;
                self.minted += amount;
            }
            CreditOp::Slash { from, amount, .. } => {
                let acct = self.accounts.entry(from).or_default();
                // Clamp: slash at most the available stake.
                let cut = amount.min(acct.stake);
                acct.stake -= cut;
                self.burned += cut;
            }
            CreditOp::Burn { from, amount, .. } => {
                let acct = self.accounts.entry(from).or_default();
                // Clamp: burn at most the available liquid balance.
                let cut = amount.min(acct.balance);
                acct.balance -= cut;
                self.burned += cut;
            }
            CreditOp::Transfer { from, to, amount, .. } => {
                self.accounts.entry(from).or_default().balance -= amount;
                self.accounts.entry(to).or_default().balance += amount;
            }
            CreditOp::Stake { node, amount } => {
                let acct = self.accounts.entry(node).or_default();
                acct.balance -= amount;
                acct.stake += amount;
            }
            CreditOp::Unstake { node, amount } => {
                let acct = self.accounts.entry(node).or_default();
                acct.stake -= amount;
                acct.balance += amount;
            }
        }
        Ok(())
    }

    /// Apply a batch transactionally: all ops validate against the running
    /// state or none are applied.
    pub fn apply_all(&mut self, ops: &[CreditOp]) -> Result<(), ApplyError> {
        let mut scratch = self.clone();
        for op in ops {
            scratch.apply(op)?;
        }
        *self = scratch;
        Ok(())
    }

    /// Conservation invariant: every credit in an account was minted and not
    /// yet burned.
    pub fn conserved(&self) -> bool {
        self.total_credits() + self.burned == self.minted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::ops::OpReason;

    fn mint(to: u32, amount: Credits) -> CreditOp {
        CreditOp::Mint {
            to: NodeId(to),
            amount,
            reason: OpReason::Genesis,
        }
    }

    #[test]
    fn mint_transfer_stake_flow() {
        let mut t = BalanceTable::new();
        t.apply(&mint(0, 100)).unwrap();
        t.apply(&mint(1, 50)).unwrap();
        t.apply(&CreditOp::Stake { node: NodeId(0), amount: 40 }).unwrap();
        assert_eq!(t.balance(NodeId(0)), 60);
        assert_eq!(t.stake(NodeId(0)), 40);
        t.apply(&CreditOp::Transfer {
            from: NodeId(0),
            to: NodeId(1),
            amount: 60,
            reason: OpReason::PolicyAdjust,
        })
        .unwrap();
        assert_eq!(t.balance(NodeId(0)), 0);
        assert_eq!(t.balance(NodeId(1)), 110);
        assert!(t.conserved());
    }

    #[test]
    fn overdraft_rejected() {
        let mut t = BalanceTable::new();
        t.apply(&mint(0, 10)).unwrap();
        let err = t
            .apply(&CreditOp::Transfer {
                from: NodeId(0),
                to: NodeId(1),
                amount: 11,
                reason: OpReason::PolicyAdjust,
            })
            .unwrap_err();
        assert!(matches!(err, ApplyError::InsufficientBalance { .. }));
        assert_eq!(t.balance(NodeId(0)), 10); // unchanged
    }

    #[test]
    fn overstake_rejected() {
        let mut t = BalanceTable::new();
        t.apply(&mint(0, 10)).unwrap();
        assert!(t.apply(&CreditOp::Stake { node: NodeId(0), amount: 11 }).is_err());
        assert!(t
            .apply(&CreditOp::Unstake { node: NodeId(0), amount: 1 })
            .is_err());
    }

    #[test]
    fn slash_clamps_to_stake() {
        let mut t = BalanceTable::new();
        t.apply(&mint(0, 100)).unwrap();
        t.apply(&CreditOp::Stake { node: NodeId(0), amount: 30 }).unwrap();
        t.apply(&CreditOp::Slash {
            from: NodeId(0),
            amount: 50,
            reason: OpReason::PolicyAdjust,
        })
        .unwrap();
        assert_eq!(t.stake(NodeId(0)), 0);
        assert_eq!(t.balance(NodeId(0)), 70);
        assert_eq!(t.burned, 30);
        assert!(t.conserved());
    }

    #[test]
    fn burn_clamps_to_balance_and_conserves() {
        let mut t = BalanceTable::new();
        t.apply(&mint(0, 100)).unwrap();
        t.apply(&CreditOp::Stake { node: NodeId(0), amount: 30 }).unwrap();
        // Burn more than the liquid balance: stake is untouched, the
        // balance drains to zero, and conservation holds.
        t.apply(&CreditOp::Burn {
            from: NodeId(0),
            amount: 90,
            reason: OpReason::CapacityHold,
        })
        .unwrap();
        assert_eq!(t.balance(NodeId(0)), 0);
        assert_eq!(t.stake(NodeId(0)), 30);
        assert_eq!(t.burned, 70);
        assert!(t.conserved());
    }

    #[test]
    fn apply_all_is_transactional() {
        let mut t = BalanceTable::new();
        t.apply(&mint(0, 10)).unwrap();
        let ops = [
            CreditOp::Stake { node: NodeId(0), amount: 5 },
            CreditOp::Stake { node: NodeId(0), amount: 6 }, // fails
        ];
        assert!(t.apply_all(&ops).is_err());
        assert_eq!(t.stake(NodeId(0)), 0); // first op rolled back
        assert_eq!(t.balance(NodeId(0)), 10);
    }

    #[test]
    fn stakes_sorted_and_positive_only() {
        let mut t = BalanceTable::new();
        for (n, amt) in [(3u32, 30u64), (1, 10), (2, 0)] {
            t.apply(&mint(n, 100)).unwrap();
            if amt > 0 {
                t.apply(&CreditOp::Stake { node: NodeId(n), amount: amt })
                    .unwrap();
            }
        }
        assert_eq!(t.stakes(), vec![(NodeId(1), 10), (NodeId(3), 30)]);
        assert_eq!(t.total_stake(), 40);
    }
}
