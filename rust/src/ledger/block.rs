//! Credit blocks — Table 1 of the paper.
//!
//! | Field      | Description                    |
//! |------------|--------------------------------|
//! | Block ID   | Hash of the current block      |
//! | Parent ID  | Hash of the previous block     |
//! | Timestamp  | Time of block creation         |
//! | Operations | List of credit-related records |
//! | Proposer   | Node proposing the block       |
//! | Signature  | Digital signature              |

use super::ops::CreditOp;
use crate::crypto::{Hash256, Hasher, KeyStore, NodeKey, Signature, DOMAIN_BLOCK};
use crate::types::{NodeId, Time};

#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    pub id: Hash256,
    pub parent: Hash256,
    pub timestamp: Time,
    pub ops: Vec<CreditOp>,
    pub proposer: NodeId,
    pub signature: Signature,
}

impl Block {
    /// Hash of (parent, timestamp, ops, proposer) — the content the id and
    /// signature commit to. Domain-tagged with [`DOMAIN_BLOCK`] so a block
    /// id lives in a different hash space from work receipts.
    pub fn compute_id(
        parent: &Hash256,
        timestamp: Time,
        ops: &[CreditOp],
        proposer: NodeId,
    ) -> Hash256 {
        let mut h = Hasher::with_domain(DOMAIN_BLOCK);
        h.update(b"wwwserve-block")
            .update(&parent.0)
            .update_u64(timestamp.to_bits())
            .update_u64(ops.len() as u64);
        for op in ops {
            op.hash_into(&mut h);
        }
        h.update_u64(proposer.0 as u64);
        h.finish()
    }

    /// Build and sign a block on top of `parent`.
    pub fn create(
        parent: Hash256,
        timestamp: Time,
        ops: Vec<CreditOp>,
        key: &NodeKey,
    ) -> Block {
        let id = Self::compute_id(&parent, timestamp, &ops, key.node);
        let signature = key.sign(&id);
        Block {
            id,
            parent,
            timestamp,
            ops,
            proposer: key.node,
            signature,
        }
    }

    /// Structural validity: id matches contents and signature matches id.
    pub fn verify(&self, keys: &KeyStore) -> bool {
        let expect =
            Self::compute_id(&self.parent, self.timestamp, &self.ops, self.proposer);
        expect == self.id && keys.verify(self.proposer, &self.id, &self.signature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::ops::OpReason;

    fn setup() -> (NodeKey, KeyStore) {
        let key = NodeKey::derive(1, NodeId(0));
        let mut ks = KeyStore::new();
        ks.register(&key);
        (key, ks)
    }

    fn some_ops() -> Vec<CreditOp> {
        vec![CreditOp::Mint {
            to: NodeId(1),
            amount: 5,
            reason: OpReason::Genesis,
        }]
    }

    #[test]
    fn create_verifies() {
        let (key, ks) = setup();
        let b = Block::create(Hash256::ZERO, 1.0, some_ops(), &key);
        assert!(b.verify(&ks));
    }

    #[test]
    fn tampered_ops_detected() {
        let (key, ks) = setup();
        let mut b = Block::create(Hash256::ZERO, 1.0, some_ops(), &key);
        b.ops.push(CreditOp::Mint {
            to: NodeId(0),
            amount: 1_000_000,
            reason: OpReason::Genesis,
        });
        assert!(!b.verify(&ks));
    }

    #[test]
    fn tampered_parent_detected() {
        let (key, ks) = setup();
        let mut b = Block::create(Hash256::ZERO, 1.0, some_ops(), &key);
        b.parent = crate::crypto::sha256(b"fork");
        assert!(!b.verify(&ks));
    }

    #[test]
    fn forged_proposer_detected() {
        let (key, mut ks) = setup();
        let other = NodeKey::derive(1, NodeId(9));
        ks.register(&other);
        let mut b = Block::create(Hash256::ZERO, 1.0, some_ops(), &key);
        b.proposer = NodeId(9); // claim someone else proposed it
        assert!(!b.verify(&ks));
    }

    #[test]
    fn id_depends_on_all_fields() {
        let ops = some_ops();
        let a = Block::compute_id(&Hash256::ZERO, 1.0, &ops, NodeId(0));
        let b = Block::compute_id(&Hash256::ZERO, 2.0, &ops, NodeId(0));
        let c = Block::compute_id(&Hash256::ZERO, 1.0, &ops, NodeId(1));
        let d = Block::compute_id(&Hash256::ZERO, 1.0, &[], NodeId(0));
        assert!(a != b && a != c && a != d);
    }
}
