//! A node's local Credit Block Chain replica (§4.1).
//!
//! Happy path: a node that completed a transaction builds a block on its
//! current head, broadcasts it, peers validate independently and vote; once a
//! majority confirms, everyone appends. This module is the *replica state
//! machine* — proposal/vote transport lives in the coordinator's
//! LedgerManager. Votes are counted per block id; structural validation and
//! op-level validation both gate acceptance, so a forged or overdrafting
//! block can never enter an honest replica.

use std::collections::BTreeMap;

use super::accounts::{ApplyError, BalanceTable};
use super::block::Block;
use crate::crypto::{Hash256, KeyStore};
use crate::types::{Credits, NodeId};

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ChainError {
    #[error("block fails structural verification (hash/signature)")]
    BadBlock,
    #[error("block's parent {0} is not the current head")]
    WrongParent(Hash256),
    #[error("op validation failed: {0}")]
    BadOps(#[from] ApplyError),
    #[error("unknown block {0}")]
    UnknownBlock(Hash256),
}

/// A pending proposal gathering votes.
#[derive(Debug, Clone)]
pub struct Pending {
    pub block: Block,
    pub votes: Vec<NodeId>,
}

#[derive(Debug, Clone)]
pub struct Chain {
    blocks: Vec<Block>,
    balances: BalanceTable,
    pending: BTreeMap<Hash256, Pending>,
}

impl Chain {
    pub fn new() -> Self {
        Chain {
            blocks: Vec::new(),
            balances: BalanceTable::new(),
            pending: BTreeMap::new(),
        }
    }

    pub fn head(&self) -> Hash256 {
        self.blocks.last().map(|b| b.id).unwrap_or(Hash256::ZERO)
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    /// Id of the block at `height` (0-based); `None` past the end. The
    /// anchor check of delta chain sync: a requester whose chain has `len`
    /// blocks and head `h` is a prefix of ours iff
    /// `block_id_at(len - 1) == Some(h)`.
    pub fn block_id_at(&self, height: u64) -> Option<Hash256> {
        usize::try_from(height)
            .ok()
            .and_then(|h| self.blocks.get(h))
            .map(|b| b.id)
    }

    pub fn balances(&self) -> &BalanceTable {
        &self.balances
    }

    pub fn balance(&self, node: NodeId) -> Credits {
        self.balances.balance(node)
    }

    pub fn stake(&self, node: NodeId) -> Credits {
        self.balances.stake(node)
    }

    /// Validate a proposed block against this replica (structure + parent +
    /// op validity). Does not mutate.
    pub fn validate(&self, block: &Block, keys: &KeyStore) -> Result<(), ChainError> {
        if !block.verify(keys) {
            return Err(ChainError::BadBlock);
        }
        if block.parent != self.head() {
            return Err(ChainError::WrongParent(block.parent));
        }
        let mut scratch = self.balances.clone();
        for op in &block.ops {
            scratch.apply(op)?;
        }
        Ok(())
    }

    /// Record a (validated) proposal so votes can accumulate.
    pub fn track_pending(&mut self, block: Block) {
        self.pending
            .entry(block.id)
            .or_insert_with(|| Pending { block, votes: Vec::new() });
    }

    /// Register a confirmation vote. Returns the vote count.
    pub fn vote(&mut self, block_id: Hash256, voter: NodeId) -> Result<usize, ChainError> {
        let p = self
            .pending
            .get_mut(&block_id)
            .ok_or(ChainError::UnknownBlock(block_id))?;
        if !p.votes.contains(&voter) {
            p.votes.push(voter);
        }
        Ok(p.votes.len())
    }

    pub fn pending_block(&self, block_id: &Hash256) -> Option<Block> {
        self.pending.get(block_id).map(|p| p.block.clone())
    }

    pub fn pending_votes(&self, block_id: &Hash256) -> usize {
        self.pending.get(block_id).map(|p| p.votes.len()).unwrap_or(0)
    }

    /// Finalize: validate once more against current state and append.
    pub fn commit(&mut self, block_id: Hash256, keys: &KeyStore) -> Result<(), ChainError> {
        let p = self
            .pending
            .get(&block_id)
            .ok_or(ChainError::UnknownBlock(block_id))?;
        let block = p.block.clone();
        self.commit_block(block, keys)?;
        self.pending.remove(&block_id);
        Ok(())
    }

    /// Append a block directly (used when a peer tells us it was finalized —
    /// the replica still refuses anything invalid).
    pub fn commit_block(&mut self, block: Block, keys: &KeyStore) -> Result<(), ChainError> {
        self.validate(&block, keys)?;
        for op in &block.ops {
            self.balances
                .apply(op)
                .expect("validate() checked every op");
        }
        self.blocks.push(block);
        Ok(())
    }

    /// Full-chain audit from genesis — O(n·ops). Used by tests and the
    /// anti-entropy path when a replica joins late.
    pub fn audit(&self, keys: &KeyStore) -> bool {
        let mut parent = Hash256::ZERO;
        let mut table = BalanceTable::new();
        for b in &self.blocks {
            if b.parent != parent || !b.verify(keys) {
                return false;
            }
            for op in &b.ops {
                if table.apply(op).is_err() {
                    return false;
                }
            }
            parent = b.id;
        }
        table.conserved()
    }

    /// Append a contiguous suffix shipped by a longer replica (the delta
    /// path of chain sync). Only applies when the suffix anchors exactly at
    /// our current head (`from_height == len()` and `anchor == head()`);
    /// every block is validated through [`commit_block`](Chain::commit_block)
    /// on a scratch replica first, so a bad block mid-suffix adopts nothing.
    /// Returns true if the whole suffix was appended; callers fall back to
    /// a full [`adopt_if_longer`](Chain::adopt_if_longer) snapshot on false.
    pub fn try_extend(
        &mut self,
        from_height: u64,
        anchor: Hash256,
        blocks: &[Block],
        keys: &KeyStore,
    ) -> bool {
        if from_height != self.blocks.len() as u64
            || anchor != self.head()
            || blocks.is_empty()
        {
            return false;
        }
        let mut scratch = self.clone();
        for b in blocks {
            if scratch.commit_block(b.clone(), keys).is_err() {
                return false;
            }
        }
        self.blocks = scratch.blocks;
        self.balances = scratch.balances;
        true
    }

    /// Adopt a longer valid chain (anti-entropy for late joiners). Returns
    /// true if adopted.
    pub fn adopt_if_longer(&mut self, other: &[Block], keys: &KeyStore) -> bool {
        if other.len() <= self.blocks.len() {
            return false;
        }
        let candidate = Chain {
            blocks: other.to_vec(),
            balances: {
                let mut t = BalanceTable::new();
                for b in other {
                    for op in &b.ops {
                        if t.apply(op).is_err() {
                            return false;
                        }
                    }
                }
                t
            },
            pending: BTreeMap::new(),
        };
        if !candidate.audit(keys) {
            return false;
        }
        *self = candidate;
        true
    }
}

impl Default for Chain {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::NodeKey;
    use crate::ledger::ops::{CreditOp, OpReason};

    fn network(n: u32) -> (Vec<NodeKey>, KeyStore) {
        let keys: Vec<NodeKey> =
            (0..n).map(|i| NodeKey::derive(42, NodeId(i))).collect();
        let ks = KeyStore::for_network(42, n);
        (keys, ks)
    }

    fn genesis_ops() -> Vec<CreditOp> {
        vec![
            CreditOp::Mint { to: NodeId(0), amount: 100, reason: OpReason::Genesis },
            CreditOp::Mint { to: NodeId(1), amount: 100, reason: OpReason::Genesis },
        ]
    }

    #[test]
    fn propose_vote_commit() {
        let (keys, ks) = network(3);
        let mut chain = Chain::new();
        let b = Block::create(chain.head(), 0.0, genesis_ops(), &keys[0]);
        chain.validate(&b, &ks).unwrap();
        chain.track_pending(b.clone());
        assert_eq!(chain.vote(b.id, NodeId(1)).unwrap(), 1);
        assert_eq!(chain.vote(b.id, NodeId(2)).unwrap(), 2);
        // Duplicate vote doesn't double-count.
        assert_eq!(chain.vote(b.id, NodeId(2)).unwrap(), 2);
        chain.commit(b.id, &ks).unwrap();
        assert_eq!(chain.len(), 1);
        assert_eq!(chain.balance(NodeId(0)), 100);
        assert!(chain.audit(&ks));
    }

    #[test]
    fn rejects_wrong_parent() {
        let (keys, ks) = network(2);
        let mut chain = Chain::new();
        let b1 = Block::create(chain.head(), 0.0, genesis_ops(), &keys[0]);
        chain.commit_block(b1, &ks).unwrap();
        // A second block built on genesis (stale parent) must be rejected.
        let stale = Block::create(Hash256::ZERO, 1.0, vec![], &keys[1]);
        assert!(matches!(
            chain.validate(&stale, &ks),
            Err(ChainError::WrongParent(_))
        ));
    }

    #[test]
    fn rejects_overdraft_block() {
        let (keys, ks) = network(2);
        let mut chain = Chain::new();
        chain
            .commit_block(
                Block::create(chain.head(), 0.0, genesis_ops(), &keys[0]),
                &ks,
            )
            .unwrap();
        let bad = Block::create(
            chain.head(),
            1.0,
            vec![CreditOp::Transfer {
                from: NodeId(0),
                to: NodeId(1),
                amount: 1_000,
                reason: OpReason::PolicyAdjust,
            }],
            &keys[1],
        );
        assert!(matches!(chain.validate(&bad, &ks), Err(ChainError::BadOps(_))));
    }

    #[test]
    fn rejects_tampered_block() {
        let (keys, ks) = network(2);
        let chain = Chain::new();
        let mut b = Block::create(chain.head(), 0.0, genesis_ops(), &keys[0]);
        b.ops[0] = CreditOp::Mint {
            to: NodeId(0),
            amount: 1_000_000,
            reason: OpReason::Genesis,
        };
        assert_eq!(chain.validate(&b, &ks), Err(ChainError::BadBlock));
    }

    #[test]
    fn double_spend_across_blocks_rejected() {
        let (keys, ks) = network(2);
        let mut chain = Chain::new();
        chain
            .commit_block(
                Block::create(chain.head(), 0.0, genesis_ops(), &keys[0]),
                &ks,
            )
            .unwrap();
        let spend = |ts: f64| {
            Block::create(
                chain.head(),
                ts,
                vec![CreditOp::Transfer {
                    from: NodeId(0),
                    to: NodeId(1),
                    amount: 80,
                    reason: OpReason::PolicyAdjust,
                }],
                &keys[0],
            )
        };
        let b1 = spend(1.0);
        let b2 = spend(2.0); // same parent — a classic double-spend attempt
        chain.commit_block(b1, &ks).unwrap();
        // b2's parent is now stale; the replica refuses it.
        assert!(chain.commit_block(b2, &ks).is_err());
        assert_eq!(chain.balance(NodeId(0)), 20);
    }

    #[test]
    fn adopt_longer_chain() {
        let (keys, ks) = network(2);
        let mut a = Chain::new();
        let mut b = Chain::new();
        let blk1 = Block::create(a.head(), 0.0, genesis_ops(), &keys[0]);
        a.commit_block(blk1.clone(), &ks).unwrap();
        b.commit_block(blk1, &ks).unwrap();
        let blk2 = Block::create(
            a.head(),
            1.0,
            vec![CreditOp::Stake { node: NodeId(0), amount: 50 }],
            &keys[0],
        );
        a.commit_block(blk2, &ks).unwrap();
        assert!(b.adopt_if_longer(a.blocks(), &ks));
        assert_eq!(b.len(), 2);
        assert_eq!(b.stake(NodeId(0)), 50);
        // Shorter or equal chains are not adopted.
        assert!(!a.adopt_if_longer(b.blocks(), &ks));
    }

    #[test]
    fn try_extend_appends_anchored_suffix_only() {
        let (keys, ks) = network(2);
        let mut a = Chain::new();
        let mut b = Chain::new();
        let blk1 = Block::create(a.head(), 0.0, genesis_ops(), &keys[0]);
        a.commit_block(blk1.clone(), &ks).unwrap();
        b.commit_block(blk1, &ks).unwrap();
        let blk2 = Block::create(
            a.head(),
            1.0,
            vec![CreditOp::Stake { node: NodeId(0), amount: 50 }],
            &keys[0],
        );
        a.commit_block(blk2.clone(), &ks).unwrap();
        let blk3 = Block::create(
            a.head(),
            2.0,
            vec![CreditOp::Unstake { node: NodeId(0), amount: 10 }],
            &keys[1],
        );
        a.commit_block(blk3, &ks).unwrap();

        // b (height 1) extends with a's suffix from height 1 — identical
        // end state to a full adopt_if_longer of a's chain.
        let mut b_full = b.clone();
        let suffix = &a.blocks()[1..];
        assert!(b.try_extend(1, b.head(), suffix, &ks));
        assert!(b_full.adopt_if_longer(a.blocks(), &ks));
        assert_eq!(b.len(), b_full.len());
        assert_eq!(b.head(), b_full.head());
        assert_eq!(b.stake(NodeId(0)), b_full.stake(NodeId(0)));
        assert!(b.audit(&ks));

        // Wrong height or wrong anchor adopts nothing.
        let mut c = Chain::new();
        assert!(!c.try_extend(1, a.head(), suffix, &ks));
        assert!(!c.try_extend(0, a.head(), a.blocks(), &ks), "bad anchor");
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn try_extend_rejects_bad_suffix_atomically() {
        let (keys, ks) = network(2);
        let mut a = Chain::new();
        let blk1 = Block::create(a.head(), 0.0, genesis_ops(), &keys[0]);
        a.commit_block(blk1.clone(), &ks).unwrap();
        let good = Block::create(
            a.head(),
            1.0,
            vec![CreditOp::Stake { node: NodeId(0), amount: 50 }],
            &keys[0],
        );
        let mut tampered = Block::create(
            good.id,
            2.0,
            vec![CreditOp::Mint {
                to: NodeId(1),
                amount: 1,
                reason: OpReason::Genesis,
            }],
            &keys[1],
        );
        tampered.ops[0] = CreditOp::Mint {
            to: NodeId(1),
            amount: 9_999,
            reason: OpReason::Genesis,
        };
        let mut b = Chain::new();
        b.commit_block(blk1, &ks).unwrap();
        let head_before = b.head();
        assert!(!b.try_extend(1, b.head(), &[good, tampered], &ks));
        assert_eq!(b.len(), 1, "half-valid suffix must adopt nothing");
        assert_eq!(b.head(), head_before);
    }

    #[test]
    fn block_id_at_indexes_heights() {
        let (keys, ks) = network(1);
        let mut a = Chain::new();
        assert_eq!(a.block_id_at(0), None);
        let blk = Block::create(a.head(), 0.0, genesis_ops(), &keys[0]);
        a.commit_block(blk, &ks).unwrap();
        assert_eq!(a.block_id_at(0), Some(a.head()));
        assert_eq!(a.block_id_at(1), None);
        assert_eq!(a.block_id_at(u64::MAX), None);
    }

    #[test]
    fn adopt_rejects_invalid_history() {
        let (keys, ks) = network(2);
        let mut a = Chain::new();
        let blk1 = Block::create(a.head(), 0.0, genesis_ops(), &keys[0]);
        a.commit_block(blk1, &ks).unwrap();
        // Forge a longer but structurally-invalid chain.
        let mut forged = a.blocks().to_vec();
        let mut bad = Block::create(
            a.head(),
            1.0,
            vec![CreditOp::Mint {
                to: NodeId(1),
                amount: 1,
                reason: OpReason::Genesis,
            }],
            &keys[1],
        );
        bad.ops[0] = CreditOp::Mint {
            to: NodeId(1),
            amount: 9_999,
            reason: OpReason::Genesis,
        };
        forged.push(bad);
        let mut b = Chain::new();
        assert!(!b.adopt_if_longer(&forged, &ks));
        assert_eq!(b.len(), 0);
    }
}
