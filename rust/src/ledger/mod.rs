//! Credit-based transaction system (§4.1).
//!
//! Two interchangeable ledger modes behind the [`Ledger`] trait:
//!
//! * [`SharedLedger`] — one logically-shared balance table + op log. This is
//!   what the paper actually ran (Appendix C: "we employ a shared ledger
//!   instead of a full Credit Block Chain, simplifying implementation while
//!   preserving the essential dynamics of credit transactions").
//! * [`chain::Chain`]-based replicas — the full design of §4.1: hash-linked
//!   signed blocks, independent validation, majority confirmation. Driven by
//!   the coordinator's LedgerManager; compared against SharedLedger in
//!   `benches/ledger_ablation.rs`.

pub mod accounts;
pub mod block;
pub mod chain;
pub mod ops;

pub use accounts::{Account, ApplyError, BalanceTable};
pub use block::Block;
pub use chain::{Chain, ChainError};
pub use ops::{CreditOp, OpReason};

use crate::types::{Credits, NodeId, Time};

/// Read/submit interface the scheduler and policy layers use. They never care
/// which consistency machinery sits underneath.
pub trait Ledger {
    /// Submit a batch of ops as one atomic transaction.
    fn submit(&mut self, ops: Vec<CreditOp>, proposer: NodeId, now: Time)
        -> Result<(), ApplyError>;
    fn balance(&self, node: NodeId) -> Credits;
    fn stake(&self, node: NodeId) -> Credits;
    /// Snapshot of positive stakes, sorted by node id.
    fn stakes(&self) -> Vec<(NodeId, Credits)>;
    fn total_stake(&self) -> Credits;
}

/// The paper's Appendix-C shared ledger: a single balance table plus an
/// append-only op log (for audit parity with the blockchain mode).
#[derive(Debug, Clone, Default)]
pub struct SharedLedger {
    table: BalanceTable,
    log: Vec<(Time, NodeId, CreditOp)>,
    /// Monotonic mutation counter: bumps once per successfully applied
    /// batch. Lets readers detect staleness without re-reading the table.
    version: u64,
    /// Like `version`, but bumps only for batches that touch *stakes*
    /// (Stake/Unstake/Slash). Plain payments leave it unchanged, so the
    /// nodes' cached stake snapshots survive transfer traffic.
    stake_version: u64,
}

impl SharedLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutation counter — changes iff balances/stakes changed.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Stake-table mutation counter — changes iff some node's stake moved.
    pub fn stake_version(&self) -> u64 {
        self.stake_version
    }

    pub fn log(&self) -> &[(Time, NodeId, CreditOp)] {
        &self.log
    }

    pub fn table(&self) -> &BalanceTable {
        &self.table
    }

    /// Credit history of one node: (time, signed delta to total holdings).
    /// Used to regenerate the Figure-6 credit-over-time curves.
    pub fn history(&self, node: NodeId) -> Vec<(Time, i64)> {
        let mut out = Vec::new();
        for (t, _, op) in &self.log {
            let delta: i64 = match *op {
                CreditOp::Mint { to, amount, .. } if to == node => amount as i64,
                CreditOp::Slash { from, amount, .. }
                | CreditOp::Burn { from, amount, .. }
                    if from == node =>
                {
                    -(amount as i64)
                }
                CreditOp::Transfer { from, to, amount, .. } => {
                    if from == node && to == node {
                        0
                    } else if from == node {
                        -(amount as i64)
                    } else if to == node {
                        amount as i64
                    } else {
                        continue;
                    }
                }
                // Stake/Unstake move within the account: no change in total.
                _ => continue,
            };
            out.push((*t, delta));
        }
        out
    }
}

impl Ledger for SharedLedger {
    fn submit(
        &mut self,
        ops: Vec<CreditOp>,
        proposer: NodeId,
        now: Time,
    ) -> Result<(), ApplyError> {
        self.table.apply_all(&ops)?;
        self.version += 1;
        if ops.iter().any(|op| {
            matches!(
                op,
                CreditOp::Stake { .. }
                    | CreditOp::Unstake { .. }
                    | CreditOp::Slash { .. }
            )
        }) {
            self.stake_version += 1;
        }
        for op in ops {
            self.log.push((now, proposer, op));
        }
        Ok(())
    }

    fn balance(&self, node: NodeId) -> Credits {
        self.table.balance(node)
    }

    fn stake(&self, node: NodeId) -> Credits {
        self.table.stake(node)
    }

    fn stakes(&self) -> Vec<(NodeId, Credits)> {
        self.table.stakes()
    }

    fn total_stake(&self) -> Credits {
        self.table.total_stake()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_ledger_submit_and_history() {
        let mut l = SharedLedger::new();
        l.submit(
            vec![
                CreditOp::Mint { to: NodeId(0), amount: 100, reason: OpReason::Genesis },
                CreditOp::Stake { node: NodeId(0), amount: 60 },
            ],
            NodeId(0),
            0.0,
        )
        .unwrap();
        l.submit(
            vec![CreditOp::Transfer {
                from: NodeId(0),
                to: NodeId(1),
                amount: 25,
                reason: OpReason::PolicyAdjust,
            }],
            NodeId(0),
            1.0,
        )
        .unwrap();
        assert_eq!(l.balance(NodeId(0)), 15);
        assert_eq!(l.stake(NodeId(0)), 60);
        assert_eq!(l.balance(NodeId(1)), 25);
        assert_eq!(l.stakes(), vec![(NodeId(0), 60)]);
        // history: +100 at t0 (mint), -25 at t1 (transfer out); stake ignored
        assert_eq!(l.history(NodeId(0)), vec![(0.0, 100), (1.0, -25)]);
        assert_eq!(l.history(NodeId(1)), vec![(1.0, 25)]);
    }

    #[test]
    fn version_counters_track_the_right_mutations() {
        let mut l = SharedLedger::new();
        assert_eq!(l.version(), 0);
        assert_eq!(l.stake_version(), 0);
        l.submit(
            vec![CreditOp::Mint { to: NodeId(0), amount: 100, reason: OpReason::Genesis }],
            NodeId(0),
            0.0,
        )
        .unwrap();
        // A pure balance mutation bumps version but not stake_version.
        assert_eq!(l.version(), 1);
        assert_eq!(l.stake_version(), 0);
        l.submit(
            vec![CreditOp::Stake { node: NodeId(0), amount: 40 }],
            NodeId(0),
            1.0,
        )
        .unwrap();
        assert_eq!(l.version(), 2);
        assert_eq!(l.stake_version(), 1);
        // A failed batch bumps neither.
        let before = (l.version(), l.stake_version());
        assert!(l
            .submit(
                vec![CreditOp::Stake { node: NodeId(0), amount: 1000 }],
                NodeId(0),
                2.0,
            )
            .is_err());
        assert_eq!((l.version(), l.stake_version()), before);
    }

    #[test]
    fn failed_submit_rolls_back() {
        let mut l = SharedLedger::new();
        l.submit(
            vec![CreditOp::Mint { to: NodeId(0), amount: 10, reason: OpReason::Genesis }],
            NodeId(0),
            0.0,
        )
        .unwrap();
        let err = l.submit(
            vec![
                CreditOp::Stake { node: NodeId(0), amount: 5 },
                CreditOp::Stake { node: NodeId(0), amount: 50 },
            ],
            NodeId(0),
            1.0,
        );
        assert!(err.is_err());
        assert_eq!(l.stake(NodeId(0)), 0);
        assert_eq!(l.log().len(), 1); // only the genesis op was logged
    }
}
