//! Credit operations — the ledger's transaction vocabulary (§4.1).
//!
//! Every economic event in WWW.Serve is one of these ops, recorded either in
//! a `Block` (full blockchain mode) or the shared op log (the paper's
//! Appendix-C simplification). Amounts are integer micro-credits so replays
//! are exact.

use crate::crypto::Hasher;
use crate::types::{Credits, NodeId, RequestId};

/// Why an op happened — carried for auditability and metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpReason {
    /// Initial allocation when a node joins.
    Genesis,
    /// Payment from a delegator to the executor of an offloaded request.
    OffloadPayment(RequestId),
    /// Extra reward minted for winning a duel (R_add).
    DuelWin(RequestId),
    /// Stake slashed for losing a duel (P).
    DuelLoss(RequestId),
    /// Reward minted for serving as a judge.
    JudgeReward(RequestId),
    /// Voluntary stake adjustment by the provider's policy.
    PolicyAdjust,
    /// Holding cost for committed serving capacity (online node-hours at
    /// the full rate, idle standby at the cheap rate — see the `capacity`
    /// module's commitment economics).
    CapacityHold,
}

impl OpReason {
    /// Stable discriminant for hashing.
    fn tag(&self) -> u64 {
        match self {
            OpReason::Genesis => 0,
            OpReason::OffloadPayment(_) => 1,
            OpReason::DuelWin(_) => 2,
            OpReason::DuelLoss(_) => 3,
            OpReason::JudgeReward(_) => 4,
            OpReason::PolicyAdjust => 5,
            OpReason::CapacityHold => 6,
        }
    }

    fn request(&self) -> Option<RequestId> {
        match self {
            OpReason::OffloadPayment(r)
            | OpReason::DuelWin(r)
            | OpReason::DuelLoss(r)
            | OpReason::JudgeReward(r) => Some(*r),
            _ => None,
        }
    }
}

/// A single credit-affecting record (the "Operations" field of Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CreditOp {
    /// Create credits out of thin air (genesis allocations, duel/judge
    /// rewards — the network's inflation schedule).
    Mint {
        to: NodeId,
        amount: Credits,
        reason: OpReason,
    },
    /// Destroy credits (duel penalties are slashed from stake and burned).
    Slash {
        from: NodeId,
        amount: Credits,
        reason: OpReason,
    },
    /// Destroy liquid credits (capacity holding costs). Clamped to the
    /// available balance when applied: a drained provider pays what it
    /// has and fades out of the market rather than erroring the batch.
    Burn {
        from: NodeId,
        amount: Credits,
        reason: OpReason,
    },
    /// Move liquid balance between nodes (credits-for-offloading).
    Transfer {
        from: NodeId,
        to: NodeId,
        amount: Credits,
        reason: OpReason,
    },
    /// Move liquid balance into stake (raises PoS selection probability).
    Stake { node: NodeId, amount: Credits },
    /// Move stake back to liquid balance.
    Unstake { node: NodeId, amount: Credits },
}

impl CreditOp {
    /// Feed this op into a block hash.
    pub fn hash_into(&self, h: &mut Hasher) {
        match self {
            CreditOp::Mint { to, amount, reason } => {
                h.update(b"mint")
                    .update_u64(to.0 as u64)
                    .update_u64(*amount)
                    .update_u64(reason.tag());
            }
            CreditOp::Slash { from, amount, reason } => {
                h.update(b"slash")
                    .update_u64(from.0 as u64)
                    .update_u64(*amount)
                    .update_u64(reason.tag());
            }
            CreditOp::Burn { from, amount, reason } => {
                h.update(b"burn")
                    .update_u64(from.0 as u64)
                    .update_u64(*amount)
                    .update_u64(reason.tag());
            }
            CreditOp::Transfer { from, to, amount, reason } => {
                h.update(b"xfer")
                    .update_u64(from.0 as u64)
                    .update_u64(to.0 as u64)
                    .update_u64(*amount)
                    .update_u64(reason.tag());
            }
            CreditOp::Stake { node, amount } => {
                h.update(b"stake")
                    .update_u64(node.0 as u64)
                    .update_u64(*amount);
            }
            CreditOp::Unstake { node, amount } => {
                h.update(b"unstake")
                    .update_u64(node.0 as u64)
                    .update_u64(*amount);
            }
        }
        if let Some(req) = self.reason().and_then(|r| r.request()) {
            h.update_u64(req.origin.0 as u64).update_u64(req.seq);
        }
    }

    pub fn reason(&self) -> Option<OpReason> {
        match self {
            CreditOp::Mint { reason, .. }
            | CreditOp::Slash { reason, .. }
            | CreditOp::Burn { reason, .. }
            | CreditOp::Transfer { reason, .. } => Some(*reason),
            _ => None,
        }
    }

    /// Nodes whose accounts this op touches.
    pub fn parties(&self) -> Vec<NodeId> {
        match self {
            CreditOp::Mint { to, .. } => vec![*to],
            CreditOp::Slash { from, .. } | CreditOp::Burn { from, .. } => {
                vec![*from]
            }
            CreditOp::Transfer { from, to, .. } => vec![*from, *to],
            CreditOp::Stake { node, .. } | CreditOp::Unstake { node, .. } => {
                vec![*node]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::Hasher;
    use crate::types::RequestId;

    fn req() -> RequestId {
        RequestId { origin: NodeId(1), seq: 9 }
    }

    #[test]
    fn hash_distinguishes_ops() {
        let a = CreditOp::Mint {
            to: NodeId(1),
            amount: 10,
            reason: OpReason::Genesis,
        };
        let b = CreditOp::Mint {
            to: NodeId(1),
            amount: 11,
            reason: OpReason::Genesis,
        };
        let c = CreditOp::Slash {
            from: NodeId(1),
            amount: 10,
            reason: OpReason::DuelLoss(req()),
        };
        let h = |op: &CreditOp| {
            let mut hh = Hasher::new();
            op.hash_into(&mut hh);
            hh.finish()
        };
        assert_ne!(h(&a), h(&b));
        assert_ne!(h(&a), h(&c));
        assert_eq!(h(&a), h(&a));
    }

    #[test]
    fn parties_cover_all_variants() {
        let t = CreditOp::Transfer {
            from: NodeId(1),
            to: NodeId(2),
            amount: 5,
            reason: OpReason::OffloadPayment(req()),
        };
        assert_eq!(t.parties(), vec![NodeId(1), NodeId(2)]);
        let s = CreditOp::Stake { node: NodeId(3), amount: 5 };
        assert_eq!(s.parties(), vec![NodeId(3)]);
    }
}
