//! # WWW.Serve — decentralized LLM serving market
//!
//! Rust reproduction of *WWW.Serve: Interconnecting Global LLM Services
//! through Decentralization* (CMU, CS.DC 2026) as a three-layer
//! Rust + JAX + Pallas stack. This crate is Layer 3: the decentralized
//! coordinator — PoS request routing, the credit ledger, gossip membership,
//! and the duel-and-judge quality mechanism — plus the simulation substrate
//! used to regenerate every figure and table of the paper, and a PJRT
//! runtime that serves the AOT-compiled JAX/Pallas transformer on the real
//! request path.
//!
//! Start with [`sim::World`] (deterministic multi-node simulation),
//! [`coordinator::Node`] (the sans-io node state machine), or
//! [`runtime::Engine`] (load + execute `artifacts/*.hlo.txt`).

pub mod backend;
pub mod benchlib;
pub mod config;
pub mod coordinator;
pub mod crypto;
pub mod duel;
pub mod gametheory;
pub mod gossip;
pub mod ledger;
pub mod metrics;
pub mod net;
pub mod policy;
pub mod pos;
pub mod repro;
pub mod runtime;
pub mod schedulers;
pub mod sim;
pub mod types;
pub mod util;
pub mod workload;

pub use types::{Credits, NodeId, Request, RequestId, Response, Time, CREDIT};
