// Determinism & safety floor (docs/determinism.md): the replay contract
// rests on this crate never reaching for unsafe tricks, and on every
// must-use Result being handled — a silently dropped error on a sim path
// is exactly the kind of divergence the pinned fingerprints exist to
// catch. `unreachable_pub` is deliberately *not* in the set: the layered
// coordinator exposes `pub fn`s on `pub(crate)` structs throughout, which
// that lint rejects wholesale. The determinism-specific rules (D001–D006)
// are enforced by the in-tree `detlint` bin instead, which understands
// sim-visible scope in a way rustc lints cannot.
#![forbid(unsafe_code)]
#![deny(unused_must_use)]

//! # WWW.Serve — decentralized LLM serving market
//!
//! Rust reproduction of *WWW.Serve: Interconnecting Global LLM Services
//! through Decentralization* (CMU, CS.DC 2026) as a three-layer
//! Rust + JAX + Pallas stack. This crate is Layer 3: the decentralized
//! coordinator — PoS request routing, the credit ledger, gossip membership,
//! and the duel-and-judge quality mechanism — plus the simulation substrate
//! used to regenerate every figure and table of the paper, and a PJRT
//! runtime that serves the AOT-compiled JAX/Pallas transformer on the real
//! request path.
//!
//! Start with [`sim::World`] (deterministic multi-node simulation),
//! [`coordinator::Node`] (the sans-io node state machine), or
//! [`runtime::Engine`] (load + execute `artifacts/*.hlo.txt`).
//!
//! ## Coordinator layering & participation policies
//!
//! [`coordinator::Node`] is a thin composition root over a layered
//! pipeline of focused submodules — every `Event` still enters through
//! one interface (`handle(Event, now) -> Vec<Action>`), and each layer
//! owns one concern:
//!
//! * `coordinator::dispatch` — admission + the probe → delegate →
//!   response state machine (pending delegations, retries, local
//!   fallback, executor-side tickets, timeout scan);
//! * `coordinator::duel` — duel escalation + judge settlement (§4.2);
//! * `coordinator::gossip_driver` — gossip cadence, delta vs.
//!   anti-entropy form selection, suspicion probes, leave/join;
//! * `coordinator::latency_feed` — RTT attribution into the live
//!   [`latency`] estimator (probe/gossip stamps, timeout penalties,
//!   piggybacked same-region summaries);
//! * `coordinator::snapshot` — the cached, alias-prepared stake snapshot
//!   dispatch draws candidates from (§4.1 hot path);
//! * `coordinator::ctx` — the per-activation borrow bundle the layers
//!   share, including the memoized alive-peer view for ledger paths.
//!
//! The *decisions* at the dispatch boundary are pluggable: a
//! [`policy::ParticipationPolicy`] answers offload-or-serve,
//! accept-or-reject-a-probe, candidate scoring (weight multipliers on top
//! of stake given live latency), and the stake/queue maintenance gates —
//! the paper's "participants flexibly determine their participation
//! policies" made a first-class seam. [`policy::DefaultPolicy`]
//! reproduces the scalar `NodePolicy` knob behaviour draw-for-draw
//! (pinned by `rust/tests/replay_equivalence.rs`);
//! [`policy::RequesterOnly`], [`policy::GreedyLocal`] and
//! [`policy::SelectiveAcceptor`] are alternative personalities. Scenarios
//! mix populations declaratively: a `topology.fleet` group selects its
//! behaviour with a `"policy"` key (plus per-group `start_offline` and
//! `churn` schedules), and `benches/geo_scale.rs` part 5 reports
//! per-policy-group SLO attainment for such a mixed fleet.
//!
//! ## Geo-distributed topology
//!
//! The [`topology`] module makes the *global* in "interconnecting global
//! LLM services" first-class: named regions, a per-region-pair link matrix
//! (latency range + jitter + bandwidth), per-node placement, and a
//! scheduled scenario layer (degrade / partition / heal link events). The
//! simulator routes every message through [`topology::Topology`];
//! membership gossips region tags ([`gossip::PeerView`]), and dispatch
//! becomes locality-aware through `NodePolicy::latency_penalty` (PoS
//! candidate weights damped by expected WAN latency). Scenarios are
//! declarative: the `config` module parses a `"topology"` block, and
//! `workload::diurnal_phases` builds follow-the-sun regional load.
//! A single-region topology replays the flat-latency model bit-for-bit,
//! so the pre-topology benches and figures are unchanged. See
//! `benches/geo_scale.rs` for the three-continent scenario with a
//! mid-run trans-continental partition.
//!
//! Dispatch scores peers with **live measured latency**, not the static
//! matrix: the [`latency`] module keeps per-region-pair EWMA estimates fed
//! by probe→reply RTTs, gossip push→pull round trips and probe timeouts,
//! decaying back to the pristine expected-latency prior when evidence goes
//! stale. Nodes piggyback their directly measured rows on gossip deltas so
//! regions with no direct traffic still converge. The pristine
//! `Topology::expected_latency_matrix` is now only the estimator's
//! cold-start prior; a live partition or degrade reroutes dispatch within
//! a few gossip intervals (`benches/geo_scale.rs` reroute scenario), which
//! the frozen-prior baseline (`latency_estimation.enabled = false`)
//! demonstrably does not.
//!
//! ## Elastic capacity
//!
//! Resource *commitments* are elastic, not just behaviours: the
//! [`capacity`] module gives each `topology.fleet` group a decentralized
//! autoscaling controller (no global coordinator) that watches signals
//! the group's nodes already have — local backend utilization and queue
//! wait, the windowed SLO of the home region, and the live latency
//! estimate to the nearest remote region — and works two levers: backend
//! admission slots within a declared `[min_slots, max_slots]` commitment
//! range ([`backend::Backend::set_slots`]), and whole standby replicas
//! brought online / retired through the same join/leave churn machinery
//! fleets already use. Online capacity burns credits per node-hour while
//! idle standby is cheap (`OpReason::CapacityHold` — the paper's
//! commitment economics); `World` tracks per-node online seconds and
//! scale events. Declaratively: a `capacity` block on the fleet group
//! ([`capacity::CapacityConfig`]); the [`capacity::StaticCapacity`]
//! policy (or no block at all) replays a capacity-free trace bit for bit
//! (`rust/tests/replay_equivalence.rs`), and `benches/geo_scale.rs`
//! part 6 shows the elastic 3-region fleet riding the diurnal wave at
//! materially fewer node-hours than static peak provisioning.
//!
//! ## Fleet scale
//!
//! The event loop is sized for 10,000-node fleets: node ids and region
//! tags are **interned** to dense `u32`s at construction
//! ([`util::intern::Interner`] — strings only at config-parse and export
//! boundaries), the event queue is a **calendar queue** ([`sim::queue`])
//! with identical pop order to the old binary heap, membership gossip
//! ships **deltas** (per-peer sent clocks + compact heartbeat pairs,
//! full-digest anti-entropy as fallback and correctness oracle — see
//! [`gossip`]; bootstrap-sealed views skip the round-one digest storm),
//! blockchain anti-entropy ships **`ChainDelta` suffixes** anchored on
//! the requester's head (full [`ChainSnapshot`](coordinator::Message)
//! as fallback and oracle), dispatch runs off a **cached stake
//! snapshot** invalidated by the view's mutation clock and the ledger
//! version, and whole fleets are stamped out declaratively via the
//! `topology.fleet` config block. `benches/fleet_scale.rs` tracks
//! events/sec and gossip bytes across n ∈ {50..1000} plus a
//! horizon-capped n = 10,000 tier and a chain-sync byte-ratio section,
//! and writes the `BENCH_fleet_scale.json` perf trajectory.
//!
//! ## Observability
//!
//! The [`obs`] module adds causal request tracing and a unified metrics
//! registry, both deterministic and replay-neutral. Every request carries
//! a [`obs::TraceId`] (a splitmix64 hash of its request id — no wall
//! clock, no RNG); the coordinator layers emit typed [`obs::SpanEvent`]s
//! (admit, probe, delegate, queue, execute, timeout, duel-settle, settle,
//! scale) into per-node bounded ring buffers ([`obs::FlightRecorder`]).
//! [`sim::World`] stitches the rings into per-request span trees and
//! exports Chrome trace-event JSON (`World::write_trace`) viewable in
//! `chrome://tracing` / Perfetto, with a `slo_misses_only` mode that
//! keeps full spans only for violated requests. The
//! [`obs::MetricsRegistry`] interns labeled counters / gauges /
//! histograms (per-region dispatch pressure, per-node availability,
//! completion-latency histograms) mirrored from the `World` counters and
//! sampled into windowed series; `metrics/export.rs` dumps it as JSON.
//! Everything is gated on a declarative `observability` config block —
//! `enabled: false` (the default) replays pre-observability traces byte
//! for byte, and `enabled: true` is purely observational, so replay
//! fingerprints match either way (`rust/tests/replay_equivalence.rs`).
//! `benches/fleet_scale.rs` bounds the enabled-tracing overhead at the
//! default sample rate to < 5% events/sec.
//!
//! ## Byzantine robustness
//!
//! Open participation includes participants that misbehave. The attacker
//! side lives in `policy/byzantine.rs` as ordinary participation
//! policies, selectable per `topology.fleet` group via a `"byzantine"`
//! key: `FreeRider` (accepts delegations, silently drops them),
//! `LatencyLiar` (poisons the RTT rows it piggybacks on gossip),
//! `ResultFaker` (junk answers behind forged receipt digests) and
//! `Colluder` (faker + reputation slander). The defense side is the
//! [`reputation`] module plus hooks through the coordinator, armed by a
//! declarative `defenses` config block: **signed work receipts**
//! ([`crypto::Receipt`], verified at settlement — unreceipted or
//! mis-signed work is never paid), a **per-peer reputation book** fed by
//! first-hand evidence (delegation timeouts, receipt failures, duel
//! outcomes) that down-weights and ultimately quarantines misbehaving
//! peers out of the dispatch candidate set, bounded-influence
//! **reputation gossip**, and **hearsay capping** on gossiped RTT
//! summaries. The full threat-model table (and what is out of scope —
//! Sybil identities, judge-majority collusion) heads the [`reputation`]
//! module. With `defenses.enabled = false` (the default) and no
//! attackers, every hook is inert and replay fingerprints stay
//! bit-identical (`rust/tests/replay_equivalence.rs`);
//! `benches/byzantine.rs` sweeps the Byzantine fraction and shows SLO
//! attainment and honest-node revenue holding up with defenses on.
//!
//! ## Streaming sessions
//!
//! Requests stop being atomic point events: the [`streaming`] module's
//! declarative `streaming` config block arms token-stream semantics end
//! to end. [`workload::SessionProfile`] generates **multi-turn sessions**
//! (think-time gaps, geometric turn counts) whose turns carry a TTFT
//! deadline next to the end-to-end SLO; [`backend::SimBackend`] splits
//! admission into **prefill slots** (compute-bound, delegable) and decode
//! slots (KV-memory-bound, capped by
//! [`backend::Profile::kv_gb_per_seq`]), so a node can sell prefill
//! capacity while decode is full — and the [`capacity`] controller works
//! the prefill pool as an independent lever (`scale_prefill`). Dispatch
//! becomes **KV-affine**: a session turn probes the node already holding
//! the session's KV cache with probability
//! [`streaming::StreamingConfig::affinity_bonus`], and re-dispatching
//! away from home ships the resident cache as a
//! [`coordinator::Message::KvTransfer`] whose wire size rides the
//! [`topology`] links' finite bandwidth — blindness is priced as real
//! TTFT, counted in `World::kv_transfer_{count,bytes}`. An honest
//! executor's Leave NACKs its in-flight delegations
//! ([`coordinator::Message::ExecAbort`]) so requesters fall back locally
//! at once instead of timing out and filing an undeserved
//! `RepEvent::Timeout` strike. With `enabled: false` (the default) every
//! hook is inert and replay fingerprints stay bit-identical
//! (`rust/tests/replay_equivalence.rs`); `benches/geo_scale.rs` part 7
//! compares KV-affine vs affinity-blind dispatch on TTFT attainment and
//! KV bytes moved. See `docs/streaming.md`.
//!
//! ## Determinism contract
//!
//! Everything above is only auditable because replay is bit-exact: same
//! config + seed ⇒ same trace, same fingerprint, on any machine. The
//! contract (no wall clock, a single seeded RNG lineage rooted in
//! [`util::rng`], ordered iteration on sim-visible paths, no
//! Debug-formatted maps near codecs) is written down in
//! `docs/determinism.md` and *machine-checked* by the [`analysis`] module
//! — a dependency-free static-analysis pass run as the `detlint` bin in
//! CI, with an audited inline-exemption census. The dynamic side lives in
//! `rust/tests/replay_equivalence.rs` (pinned fingerprints) and
//! `rust/tests/determinism.rs` (same-process double runs, which surface
//! hash-iteration-order bugs that single runs miss).

pub mod analysis;
pub mod backend;
pub mod benchlib;
pub mod capacity;
pub mod config;
pub mod coordinator;
pub mod crypto;
pub mod duel;
pub mod gametheory;
pub mod gossip;
pub mod latency;
pub mod ledger;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod policy;
pub mod pos;
pub mod reputation;
pub mod repro;
pub mod runtime;
pub mod schedulers;
pub mod sim;
pub mod streaming;
pub mod topology;
pub mod types;
pub mod util;
pub mod workload;

pub use types::{Credits, NodeId, Request, RequestId, Response, Time, CREDIT};
