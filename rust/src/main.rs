//! `wwwserve` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `simulate --config exp.json` — run an experiment config (Appendix-B
//!   style) under single/centralized/decentralized scheduling; print SLO,
//!   latency and credit summaries.
//! * `setting --id 1..4 [--strategy s]` — run a Table-3 setting directly.
//! * `serve --node-id i --listen addr --peers a,b,c --artifacts dir` — run a
//!   real node over TCP with the PJRT backend (see examples/e2e_serving.rs
//!   for the orchestrated version).
//! * `generate --artifacts dir --prompt "..."` — one-shot generation
//!   through the AOT artifacts (smoke check).

use wwwserve::backend::Profile;
use wwwserve::metrics::Recorder;
use wwwserve::schedulers::{self, Strategy};
use wwwserve::sim::{NodeSetup, World, WorldConfig};
use wwwserve::workload::{Generator, Setting, SettingId};
use wwwserve::NodeId;

fn usage() -> ! {
    eprintln!(
        "usage: wwwserve <command> [options]\n\
         \n\
         commands:\n\
         \x20 simulate --config <exp.json>          run an experiment file\n\
         \x20 setting  --id <1-4> [--strategy <single|centralized|decentralized>]\n\
         \x20                                        run a Table-3 setting\n\
         \x20 generate --artifacts <dir> --prompt <text> [--max-new <n>]\n\
         \x20                                        AOT-model smoke generation\n\
         \x20 help                                   this message"
    );
    std::process::exit(2)
}

/// Tiny declarative arg parser (clap is unavailable offline — DESIGN.md §8).
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(name) = argv[i].strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .filter(|v| !v.starts_with("--"))
                    .cloned()
                    .unwrap_or_else(|| "true".to_string());
                i += if val == "true" && argv.get(i + 1).map(|v| v.starts_with("--")).unwrap_or(true) { 1 } else { 2 };
                flags.insert(name.to_string(), val);
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn required(&self, name: &str) -> &str {
        match self.get(name) {
            Some(v) => v,
            None => {
                eprintln!("missing required flag --{name}");
                usage()
            }
        }
    }
}

fn print_summary(label: &str, rec: &Recorder, horizon: f64) {
    println!(
        "{label:<16} requests {:>6}  slo {:>6.1}%  mean {:>8.2}s  p50 {:>8.2}s  p99 {:>8.2}s  tput {:>6.2} req/s  synthetic {:>5}",
        rec.user_records().count(),
        rec.slo_attainment() * 100.0,
        rec.mean_latency(),
        rec.latency_percentile(0.5).unwrap_or(f64::NAN),
        rec.latency_percentile(0.99).unwrap_or(f64::NAN),
        rec.throughput(horizon).unwrap_or(f64::NAN),
        rec.synthetic_count(),
    );
}

fn run_setting(id: SettingId, strategy: Strategy, seed: u64) {
    let setting = Setting::get(id);
    let horizon = setting.horizon;
    println!("== {} / {} (seed {seed}) ==", setting.id.name(), strategy.name());
    for (i, n) in setting.nodes.iter().enumerate() {
        println!("  node {i}: {}", n.describe());
    }
    let profiles: Vec<Profile> =
        setting.nodes.iter().map(|n| n.profile()).collect();
    let generators: Vec<Option<Generator>> = setting
        .nodes
        .iter()
        .enumerate()
        .map(|(i, n)| {
            Some(Generator::new(NodeId(i as u32), n.phases.clone()))
        })
        .collect();

    let rec = match strategy {
        Strategy::Single => {
            schedulers::run_single(profiles, generators, horizon, seed)
        }
        Strategy::Centralized => {
            schedulers::run_centralized(profiles, generators, horizon, seed)
        }
        Strategy::Decentralized => {
            let cfg = WorldConfig { seed, ..Default::default() };
            let setups: Vec<NodeSetup> = setting
                .nodes
                .iter()
                .zip(generators)
                .map(|(n, g)| {
                    let mut s = NodeSetup::new(
                        n.profile(),
                        wwwserve::policy::NodePolicy::default(),
                    );
                    if let Some(g) = g {
                        s = s.with_generator(g);
                    }
                    s
                })
                .collect();
            let mut w = World::new(cfg, setups);
            // Drain: run past the horizon so queued work completes.
            w.run_until(horizon * 4.0);
            w.recorder
        }
    };
    print_summary(strategy.name(), &rec, horizon);
}

fn cmd_setting(args: &Args) {
    let id = match args.required("id") {
        "1" => SettingId::S1,
        "2" => SettingId::S2,
        "3" => SettingId::S3,
        "4" => SettingId::S4,
        other => {
            eprintln!("unknown setting '{other}' (expected 1-4)");
            usage()
        }
    };
    let seed: u64 = args.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    match args.get("strategy") {
        Some("single") => run_setting(id, Strategy::Single, seed),
        Some("centralized") => run_setting(id, Strategy::Centralized, seed),
        Some("decentralized") => run_setting(id, Strategy::Decentralized, seed),
        Some(other) => {
            eprintln!("unknown strategy '{other}'");
            usage()
        }
        None => {
            for s in [Strategy::Single, Strategy::Centralized, Strategy::Decentralized] {
                run_setting(id, s, seed);
            }
        }
    }
}

fn cmd_simulate(args: &Args) {
    let path = args.required("config");
    let exp = match wwwserve::config::load_experiment(path) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("config error: {e}");
            std::process::exit(1)
        }
    };
    println!(
        "experiment: {} nodes, strategy {}, horizon {}s, seed {}",
        exp.setups.len(),
        exp.strategy.name(),
        exp.horizon,
        exp.seed
    );
    match exp.strategy {
        Strategy::Decentralized => {
            // World::new installs any fleet churn schedule from the config.
            let mut w = World::new(exp.world.clone(), exp.setups.clone());
            w.run_until(exp.horizon * 4.0);
            print_summary("decentralized", &w.recorder, exp.horizon);
            println!("duels settled: {}", w.duel_stats.total_duels());
            println!("messages: {} ({} bytes)", w.messages_sent, w.bytes_sent);
            for (i, c) in w.credit_totals().iter().enumerate() {
                println!("  node {i}: {c:.2} credits");
            }
        }
        s => {
            let profiles: Vec<Profile> =
                exp.setups.iter().map(|x| x.profile).collect();
            let generators: Vec<Option<Generator>> =
                exp.setups.iter().map(|x| x.generator.clone()).collect();
            let rec = if s == Strategy::Single {
                schedulers::run_single(profiles, generators, exp.horizon, exp.seed)
            } else {
                schedulers::run_centralized(profiles, generators, exp.horizon, exp.seed)
            };
            print_summary(s.name(), &rec, exp.horizon);
        }
    }
}

fn cmd_generate(args: &Args) {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let prompt = args.required("prompt");
    let max_new: usize =
        args.get("max-new").and_then(|s| s.parse().ok()).unwrap_or(32);
    let engine = match wwwserve::runtime::Engine::load(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("failed to load artifacts from '{dir}': {e}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1)
        }
    };
    // Byte-level tokenization (vocab 512: bytes + specials).
    let tokens: Vec<u32> = prompt.bytes().map(|b| b as u32).collect();
    // detlint:allow(D002) reason="CLI generation timing is human-facing output, never fed to the sim"
    let t0 = std::time::Instant::now();
    match engine.generate(&tokens, max_new) {
        Ok(out) => {
            let dt = t0.elapsed().as_secs_f64();
            println!("prompt tokens: {}", tokens.len());
            println!("generated ids: {out:?}");
            let text: String = out
                .iter()
                .map(|t| {
                    if *t < 256 {
                        (*t as u8 as char).to_string()
                    } else {
                        format!("<{t}>")
                    }
                })
                .collect();
            println!("as bytes: {text:?}");
            println!(
                "{} tokens in {:.3}s = {:.1} tok/s (PJRT CPU, tiny model)",
                out.len(),
                dt,
                out.len() as f64 / dt
            );
        }
        Err(e) => {
            eprintln!("generation failed: {e}");
            std::process::exit(1)
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "setting" => cmd_setting(&args),
        "generate" => cmd_generate(&args),
        _ => usage(),
    }
}
