//! Result export: dump run records, series and the unified metrics
//! registry as JSON for external plotting/analysis (the figures in the
//! paper are plots of exactly these streams).

use super::{Recorder, TimeSeries};
use crate::obs::{Metric, MetricsRegistry};
use crate::types::RequestRecord;
use crate::util::json::Json;

fn record_json(r: &RequestRecord) -> Json {
    Json::obj(vec![
        ("origin", Json::num(r.origin.0 as f64)),
        ("seq", Json::num(r.id.seq as f64)),
        ("executor", Json::num(r.executor.0 as f64)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        ("output_tokens", Json::num(r.output_tokens as f64)),
        ("submitted_at", Json::num(r.submitted_at)),
        ("completed_at", Json::num(r.completed_at)),
        ("latency", Json::num(r.latency())),
        ("slo_deadline", Json::num(r.slo_deadline)),
        ("slo_met", Json::Bool(r.slo_met())),
        ("synthetic", Json::Bool(r.synthetic)),
    ])
}

impl Recorder {
    /// All records as a JSON array (one object per completed request).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.all().iter().map(record_json).collect())
    }

    /// Write records to a `.json` file.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Compact run summary as JSON (the numbers the tables print).
    /// Statistics that don't exist — percentiles of an empty run, the
    /// throughput of a degenerate horizon — export as `null`, never as a
    /// fake `0.0`.
    pub fn summary_json(&self, horizon: f64) -> Json {
        let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("user_requests", Json::num(self.user_records().count() as f64)),
            ("synthetic", Json::num(self.synthetic_count() as f64)),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("mean_latency", Json::num(self.mean_latency())),
            ("p50_latency", opt(self.latency_percentile(0.5))),
            ("p99_latency", opt(self.latency_percentile(0.99))),
            ("throughput", opt(self.throughput(horizon))),
        ])
    }
}

fn metric_json(m: &Metric) -> Json {
    Json::obj(vec![
        ("name", Json::str(&m.name)),
        (
            "labels",
            Json::obj(
                m.labels
                    .iter()
                    .map(|(k, v)| (k.as_str(), Json::str(v)))
                    .collect(),
            ),
        ),
        ("kind", Json::str(m.kind.name())),
        ("value", Json::num(m.value)),
        ("count", Json::num(m.count as f64)),
        (
            "buckets",
            Json::Arr(m.buckets.iter().map(|b| Json::num(*b as f64)).collect()),
        ),
        (
            "series",
            Json::Arr(
                m.series
                    .iter()
                    .map(|(t, v)| Json::Arr(vec![Json::num(*t), Json::num(*v)]))
                    .collect(),
            ),
        ),
    ])
}

impl MetricsRegistry {
    /// Every registered metric — identity, current value, histogram
    /// buckets and windowed series — as a JSON array in registration
    /// order (deterministic, like everything else in the registry).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.all().iter().map(metric_json).collect())
    }

    /// Write the registry dump to a `.json` file.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }
}

impl TimeSeries {
    /// `[[t, v], ...]` JSON form.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|(t, v)| Json::Arr(vec![Json::num(*t), Json::num(*v)]))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{ExecKind, NodeId, RequestId};

    fn recorder() -> Recorder {
        let mut r = Recorder::new();
        r.record(RequestRecord {
            id: RequestId { origin: NodeId(0), seq: 1 },
            origin: NodeId(0),
            executor: NodeId(2),
            kind: ExecKind::Delegated,
            prompt_tokens: 10,
            output_tokens: 20,
            submitted_at: 1.0,
            completed_at: 11.0,
            slo_deadline: 15.0,
            synthetic: false,
            session: 0,
            ttft_deadline: f64::INFINITY,
            first_token_at: None,
        });
        r
    }

    #[test]
    fn records_roundtrip_through_json() {
        let j = recorder().to_json();
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        let rec = &parsed.as_arr().unwrap()[0];
        assert_eq!(rec.get("executor").as_u64(), Some(2));
        assert_eq!(rec.get("latency").as_f64(), Some(10.0));
        assert_eq!(rec.get("slo_met").as_bool(), Some(true));
    }

    #[test]
    fn summary_fields_present() {
        let s = recorder().summary_json(100.0);
        assert_eq!(s.get("user_requests").as_u64(), Some(1));
        assert!((s.get("slo_attainment").as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert!(s.get("throughput").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn write_json_creates_file() {
        let dir = std::env::temp_dir().join("wwwserve_export_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("records.json");
        recorder().write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn timeseries_json() {
        let mut ts = TimeSeries::new();
        ts.push(0.0, 1.0);
        ts.push(5.0, 2.5);
        let j = ts.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
        assert_eq!(j.as_arr().unwrap()[1].as_arr().unwrap()[1].as_f64(), Some(2.5));
    }

    #[test]
    fn record_json_roundtrips_every_field() {
        let text = recorder().to_json().to_string();
        let rec = &Json::parse(&text).unwrap().as_arr().unwrap()[0];
        assert_eq!(rec.get("origin").as_u64(), Some(0));
        assert_eq!(rec.get("seq").as_u64(), Some(1));
        assert_eq!(rec.get("executor").as_u64(), Some(2));
        assert_eq!(rec.get("prompt_tokens").as_u64(), Some(10));
        assert_eq!(rec.get("output_tokens").as_u64(), Some(20));
        assert_eq!(rec.get("submitted_at").as_f64(), Some(1.0));
        assert_eq!(rec.get("completed_at").as_f64(), Some(11.0));
        assert_eq!(rec.get("latency").as_f64(), Some(10.0));
        assert_eq!(rec.get("slo_deadline").as_f64(), Some(15.0));
        assert_eq!(rec.get("slo_met").as_bool(), Some(true));
        assert_eq!(rec.get("synthetic").as_bool(), Some(false));
    }

    #[test]
    fn summary_json_roundtrips_and_nulls_missing_statistics() {
        let text = recorder().summary_json(100.0).to_string();
        let s = Json::parse(&text).unwrap();
        assert_eq!(s.get("user_requests").as_u64(), Some(1));
        assert_eq!(s.get("p50_latency").as_f64(), Some(10.0));
        assert_eq!(s.get("p99_latency").as_f64(), Some(10.0));
        assert_eq!(s.get("throughput").as_f64(), Some(0.01));
        // An empty recorder has no percentiles; a zero horizon has no
        // throughput — both export as null, not a fake 0.0.
        let empty = Recorder::new().summary_json(0.0);
        assert!(empty.get("p50_latency").is_null());
        assert!(empty.get("p99_latency").is_null());
        assert!(empty.get("throughput").is_null());
        assert_eq!(empty.get("user_requests").as_u64(), Some(0));
    }

    #[test]
    fn filtered_recorder_composes_with_per_region_slo_summaries() {
        // Two "regions" keyed by origin parity: region 0 meets its SLOs,
        // region 1 misses them. `filtered` must compose with every
        // statistic, including the JSON summary.
        let mut r = Recorder::new();
        for seq in 0..4u64 {
            let origin = NodeId((seq % 2) as u32);
            let missed = origin == NodeId(1);
            r.record(RequestRecord {
                id: RequestId { origin, seq },
                origin,
                executor: NodeId(2),
                kind: ExecKind::Delegated,
                prompt_tokens: 10,
                output_tokens: 20,
                submitted_at: 0.0,
                completed_at: if missed { 30.0 } else { 5.0 },
                slo_deadline: 15.0,
                synthetic: false,
                session: 0,
                ttft_deadline: f64::INFINITY,
                first_token_at: None,
            });
        }
        let region = |n: u32| r.filtered(|rec| rec.origin == NodeId(n));
        assert_eq!(region(0).slo_attainment(), 1.0);
        assert_eq!(region(1).slo_attainment(), 0.0);
        let s0 = region(0).summary_json(10.0);
        assert_eq!(s0.get("user_requests").as_u64(), Some(2));
        assert_eq!(s0.get("p99_latency").as_f64(), Some(5.0));
        let s1 = region(1).summary_json(10.0);
        assert!((s1.get("slo_attainment").as_f64().unwrap()).abs() < 1e-12);
        assert_eq!(s1.get("p99_latency").as_f64(), Some(30.0));
    }

    #[test]
    fn registry_dump_roundtrips_through_json() {
        let mut reg = MetricsRegistry::new();
        let c = reg.counter("msgs", &[("region", "us")]);
        reg.set(c, 41.0);
        reg.sample(c, 1.0);
        reg.set(c, 42.0);
        reg.sample(c, 2.0);
        let h = reg.histogram("latency_s", &[]);
        reg.observe(h, 0.5);
        let parsed = Json::parse(&reg.to_json().to_string()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let m = &arr[0];
        assert_eq!(m.get("name").as_str(), Some("msgs"));
        assert_eq!(m.get("kind").as_str(), Some("counter"));
        assert_eq!(m.get("labels").get("region").as_str(), Some("us"));
        assert_eq!(m.get("value").as_f64(), Some(42.0));
        let series = m.get("series").as_arr().unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[1].as_arr().unwrap()[1].as_f64(), Some(42.0));
        let hist = &arr[1];
        assert_eq!(hist.get("kind").as_str(), Some("histogram"));
        assert_eq!(hist.get("count").as_u64(), Some(1));
        assert_eq!(
            hist.get("buckets")
                .as_arr()
                .unwrap()
                .iter()
                .filter_map(|b| b.as_u64())
                .sum::<u64>(),
            1
        );
    }
}
