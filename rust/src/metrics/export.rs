//! Result export: dump run records and series as JSON for external
//! plotting/analysis (the figures in the paper are plots of exactly these
//! streams).

use super::{Recorder, TimeSeries};
use crate::types::RequestRecord;
use crate::util::json::Json;

fn record_json(r: &RequestRecord) -> Json {
    Json::obj(vec![
        ("origin", Json::num(r.origin.0 as f64)),
        ("seq", Json::num(r.id.seq as f64)),
        ("executor", Json::num(r.executor.0 as f64)),
        ("prompt_tokens", Json::num(r.prompt_tokens as f64)),
        ("output_tokens", Json::num(r.output_tokens as f64)),
        ("submitted_at", Json::num(r.submitted_at)),
        ("completed_at", Json::num(r.completed_at)),
        ("latency", Json::num(r.latency())),
        ("slo_deadline", Json::num(r.slo_deadline)),
        ("slo_met", Json::Bool(r.slo_met())),
        ("synthetic", Json::Bool(r.synthetic)),
    ])
}

impl Recorder {
    /// All records as a JSON array (one object per completed request).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.all().iter().map(record_json).collect())
    }

    /// Write records to a `.json` file.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Compact run summary as JSON (the numbers the tables print).
    pub fn summary_json(&self, horizon: f64) -> Json {
        Json::obj(vec![
            ("user_requests", Json::num(self.user_records().count() as f64)),
            ("synthetic", Json::num(self.synthetic_count() as f64)),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("mean_latency", Json::num(self.mean_latency())),
            ("p50_latency", Json::num(self.latency_percentile(0.5))),
            ("p99_latency", Json::num(self.latency_percentile(0.99))),
            ("throughput", Json::num(self.throughput(horizon))),
        ])
    }
}

impl TimeSeries {
    /// `[[t, v], ...]` JSON form.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|(t, v)| Json::Arr(vec![Json::num(*t), Json::num(*v)]))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{ExecKind, NodeId, RequestId};

    fn recorder() -> Recorder {
        let mut r = Recorder::new();
        r.record(RequestRecord {
            id: RequestId { origin: NodeId(0), seq: 1 },
            origin: NodeId(0),
            executor: NodeId(2),
            kind: ExecKind::Delegated,
            prompt_tokens: 10,
            output_tokens: 20,
            submitted_at: 1.0,
            completed_at: 11.0,
            slo_deadline: 15.0,
            synthetic: false,
        });
        r
    }

    #[test]
    fn records_roundtrip_through_json() {
        let j = recorder().to_json();
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        let rec = &parsed.as_arr().unwrap()[0];
        assert_eq!(rec.get("executor").as_u64(), Some(2));
        assert_eq!(rec.get("latency").as_f64(), Some(10.0));
        assert_eq!(rec.get("slo_met").as_bool(), Some(true));
    }

    #[test]
    fn summary_fields_present() {
        let s = recorder().summary_json(100.0);
        assert_eq!(s.get("user_requests").as_u64(), Some(1));
        assert!((s.get("slo_attainment").as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert!(s.get("throughput").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn write_json_creates_file() {
        let dir = std::env::temp_dir().join("wwwserve_export_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("records.json");
        recorder().write_json(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn timeseries_json() {
        let mut ts = TimeSeries::new();
        ts.push(0.0, 1.0);
        ts.push(5.0, 2.5);
        let j = ts.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
        assert_eq!(j.as_arr().unwrap()[1].as_arr().unwrap()[1].as_f64(), Some(2.5));
    }
}
