//! Metrics: request lifecycle records, SLO attainment, latency statistics,
//! CDFs and windowed time series — everything the paper's figures plot.

pub mod export;

use std::collections::BTreeMap;

use crate::types::{NodeId, RequestRecord, Time};

/// Collects completed-request records during a run.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    records: Vec<RequestRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, rec: RequestRecord) {
        self.records.push(rec);
    }

    pub fn all(&self) -> &[RequestRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// User-facing records only (duel copies / judge runs excluded).
    pub fn user_records(&self) -> impl Iterator<Item = &RequestRecord> {
        self.records.iter().filter(|r| !r.synthetic)
    }

    pub fn synthetic_count(&self) -> usize {
        self.records.iter().filter(|r| r.synthetic).count()
    }

    /// A sub-recorder holding only records matching `pred` — composes with
    /// every statistic (per-region SLO attainment, per-executor latency...).
    pub fn filtered(&self, pred: impl Fn(&RequestRecord) -> bool) -> Recorder {
        Recorder {
            records: self.records.iter().filter(|r| pred(r)).cloned().collect(),
        }
    }

    /// Fraction of user requests completing within their SLO deadline.
    pub fn slo_attainment(&self) -> f64 {
        let (met, total) = self
            .user_records()
            .fold((0usize, 0usize), |(m, t), r| {
                (m + r.slo_met() as usize, t + 1)
            });
        if total == 0 {
            return 0.0;
        }
        met as f64 / total as f64
    }

    /// Fraction of user requests *carrying a TTFT budget* whose first
    /// token landed inside it (streaming sessions; see
    /// `RequestRecord::ttft_met`). 0 when no record carries a budget.
    pub fn ttft_attainment(&self) -> f64 {
        let (met, total) = self
            .user_records()
            .filter_map(|r| r.ttft_met())
            .fold((0usize, 0usize), |(m, t), met| (m + met as usize, t + 1));
        if total == 0 {
            return 0.0;
        }
        met as f64 / total as f64
    }

    /// SLO attainment as a function of a *scale factor* on each request's
    /// deadline — the x-axis sweep of Figure 4/7 ("SLO scale").
    pub fn slo_curve(&self, scales: &[f64]) -> Vec<(f64, f64)> {
        scales
            .iter()
            .map(|s| {
                let (met, total) = self.user_records().fold(
                    (0usize, 0usize),
                    |(m, t), r| {
                        let ok = r.latency() <= r.slo_deadline * s;
                        (m + ok as usize, t + 1)
                    },
                );
                let frac = if total == 0 { 0.0 } else { met as f64 / total as f64 };
                (*s, frac)
            })
            .collect()
    }

    pub fn mean_latency(&self) -> f64 {
        let (sum, n) = self
            .user_records()
            .fold((0.0f64, 0usize), |(s, n), r| (s + r.latency(), n + 1));
        if n == 0 {
            return 0.0;
        }
        sum / n as f64
    }

    pub fn latencies_sorted(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.user_records().map(|r| r.latency()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// p in [0, 1]. `None` when no user records exist — an empty
    /// recorder has no percentile, and returning `0.0` would read as a
    /// real (excellent) latency in summaries and regression gates.
    pub fn latency_percentile(&self, p: f64) -> Option<f64> {
        let v = self.latencies_sorted();
        if v.is_empty() {
            return None;
        }
        let idx = ((v.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
        Some(v[idx])
    }

    /// Empirical CDF evaluated at `points` (Figure 7-left).
    pub fn latency_cdf(&self, points: &[f64]) -> Vec<(f64, f64)> {
        let v = self.latencies_sorted();
        points
            .iter()
            .map(|x| {
                let n = v.partition_point(|l| *l <= *x);
                let f = if v.is_empty() { 0.0 } else { n as f64 / v.len() as f64 };
                (*x, f)
            })
            .collect()
    }

    /// Windowed average latency over completion times (Figure 5's black
    /// line): buckets of `window` seconds -> (window center, mean latency).
    pub fn windowed_latency(&self, window: Time) -> Vec<(Time, f64)> {
        let mut buckets: BTreeMap<i64, (f64, usize)> = BTreeMap::new();
        for r in self.user_records() {
            let b = (r.completed_at / window).floor() as i64;
            let e = buckets.entry(b).or_insert((0.0, 0));
            e.0 += r.latency();
            e.1 += 1;
        }
        buckets
            .into_iter()
            .map(|(b, (sum, n))| {
                ((b as f64 + 0.5) * window, sum / n as f64)
            })
            .collect()
    }

    /// Completed user-request count per executor (Figure 6 right panels,
    /// Figure 8a/8b "running requests" proxies).
    pub fn served_by(&self) -> BTreeMap<NodeId, usize> {
        let mut m = BTreeMap::new();
        for r in self.user_records() {
            *m.entry(r.executor).or_insert(0) += 1;
        }
        m
    }

    /// Throughput of completed user requests over the horizon. `None`
    /// when `horizon` is not a positive finite duration — dividing by
    /// zero, a negative span or infinity would silently produce `0.0`,
    /// `inf` or `NaN` and poison downstream arithmetic.
    pub fn throughput(&self, horizon: Time) -> Option<f64> {
        if horizon <= 0.0 || !horizon.is_finite() {
            return None;
        }
        Some(self.user_records().count() as f64 / horizon)
    }
}

/// An append-only (t, value) series — credit trajectories, queue depths.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub points: Vec<(Time, f64)>,
}

impl TimeSeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: Time, v: f64) {
        self.points.push((t, v));
    }

    pub fn last(&self) -> Option<(Time, f64)> {
        self.points.last().copied()
    }

    /// Downsample to at most `n` evenly-spaced points (for printing).
    pub fn downsample(&self, n: usize) -> Vec<(Time, f64)> {
        if self.points.len() <= n || n == 0 {
            return self.points.clone();
        }
        let step = self.points.len() as f64 / n as f64;
        (0..n)
            .map(|i| self.points[(i as f64 * step) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{ExecKind, NodeId, RequestId};

    fn rec(seq: u64, submitted: f64, completed: f64, deadline: f64,
           executor: u32, synthetic: bool) -> RequestRecord {
        RequestRecord {
            id: RequestId { origin: NodeId(0), seq },
            origin: NodeId(0),
            executor: NodeId(executor),
            kind: ExecKind::Local,
            prompt_tokens: 10,
            output_tokens: 10,
            submitted_at: submitted,
            completed_at: completed,
            slo_deadline: deadline,
            synthetic,
            session: 0,
            ttft_deadline: f64::INFINITY,
            first_token_at: None,
        }
    }

    fn sample() -> Recorder {
        let mut r = Recorder::new();
        r.record(rec(0, 0.0, 10.0, 15.0, 1, false)); // met
        r.record(rec(1, 0.0, 20.0, 15.0, 1, false)); // missed
        r.record(rec(2, 5.0, 20.0, 20.0, 2, false)); // met
        r.record(rec(3, 0.0, 99.0, 1.0, 2, true));   // synthetic — ignored
        r
    }

    #[test]
    fn slo_attainment_excludes_synthetic() {
        let r = sample();
        assert!((r.slo_attainment() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.synthetic_count(), 1);
    }

    #[test]
    fn ttft_attainment_counts_only_budgeted_records() {
        let mut r = sample();
        // Unbudgeted records never count, so the empty case reads 0.
        assert_eq!(r.ttft_attainment(), 0.0);
        let budget = |seq, first: Option<f64>| RequestRecord {
            ttft_deadline: 3.0,
            first_token_at: first,
            ..rec(seq, 0.0, 10.0, 15.0, 1, false)
        };
        r.record(budget(10, Some(2.0))); // met
        r.record(budget(11, Some(5.0))); // missed
        r.record(budget(12, None)); // budget but no stamp — a miss
        assert!((r.ttft_attainment() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_percentiles() {
        let r = sample();
        // latencies: 10, 20, 15
        assert!((r.mean_latency() - 15.0).abs() < 1e-12);
        let p = |q: f64| r.latency_percentile(q).unwrap();
        assert!((p(0.0) - 10.0).abs() < 1e-12);
        assert!((p(1.0) - 20.0).abs() < 1e-12);
        assert!((p(0.5) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_counts_user_records_over_positive_horizons_only() {
        let r = sample();
        // 3 user records over 30 s.
        assert_eq!(r.throughput(30.0), Some(0.1));
        // Degenerate horizons have no throughput, not a misleading 0.0
        // (or an inf/NaN that would poison downstream arithmetic).
        assert_eq!(r.throughput(0.0), None);
        assert_eq!(r.throughput(-5.0), None);
        assert_eq!(r.throughput(f64::INFINITY), None);
        assert_eq!(r.throughput(f64::NAN), None);
        // An empty recorder over a real horizon genuinely served nothing.
        assert_eq!(Recorder::new().throughput(10.0), Some(0.0));
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let r = sample();
        let cdf = r.latency_cdf(&[0.0, 10.0, 15.0, 20.0, 100.0]);
        let ys: Vec<f64> = cdf.iter().map(|(_, y)| *y).collect();
        assert_eq!(ys[0], 0.0);
        assert_eq!(*ys.last().unwrap(), 1.0);
        for w in ys.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn slo_curve_monotone_in_scale() {
        let r = sample();
        let curve = r.slo_curve(&[0.1, 0.5, 1.0, 2.0, 10.0]);
        for w in curve.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(curve.last().unwrap().1, 1.0);
    }

    #[test]
    fn windowed_latency_buckets() {
        let r = sample();
        let w = r.windowed_latency(10.0);
        // completions at 10, 20, 20 -> buckets 1 and 2
        assert_eq!(w.len(), 2);
        assert!((w[0].1 - 10.0).abs() < 1e-12);
        assert!((w[1].1 - 17.5).abs() < 1e-12);
    }

    #[test]
    fn served_by_counts() {
        let r = sample();
        let m = r.served_by();
        assert_eq!(m[&NodeId(1)], 2);
        assert_eq!(m[&NodeId(2)], 1);
    }

    #[test]
    fn filtered_subsets_statistics() {
        let r = sample();
        let by_exec1 = r.filtered(|rec| rec.executor == NodeId(1));
        assert_eq!(by_exec1.len(), 2);
        // latencies 10, 20 -> mean 15, one of two met.
        assert!((by_exec1.mean_latency() - 15.0).abs() < 1e-12);
        assert!((by_exec1.slo_attainment() - 0.5).abs() < 1e-12);
        let none = r.filtered(|_| false);
        assert_eq!(none.len(), 0);
        assert_eq!(none.slo_attainment(), 0.0);
    }

    #[test]
    fn empty_recorder_safe() {
        let r = Recorder::new();
        assert_eq!(r.slo_attainment(), 0.0);
        assert_eq!(r.mean_latency(), 0.0);
        // No records -> no percentile (not a fake 0.0 latency).
        assert_eq!(r.latency_percentile(0.5), None);
    }

    #[test]
    fn timeseries_downsample() {
        let mut ts = TimeSeries::new();
        for i in 0..100 {
            ts.push(i as f64, (i * 2) as f64);
        }
        let d = ts.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0], (0.0, 0.0));
        let full = ts.downsample(1000);
        assert_eq!(full.len(), 100);
    }
}
