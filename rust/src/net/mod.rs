//! Network transports.
//!
//! The node state machine is sans-io; this module supplies the real-socket
//! path: a length-prefixed JSON frame protocol over `std::net` TCP (the
//! offline-image substitute for the paper's ZeroMQ ROUTER — DESIGN.md §8),
//! plus the [`NodeRunner`] real-time event loop that drives a
//! [`crate::coordinator::Node`] from wall-clock time and live sockets.
//! The deterministic in-process fabric lives in [`crate::sim`].

pub mod tcp;

pub use tcp::{NodeRunner, TcpTransport};
