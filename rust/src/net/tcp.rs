//! TCP transport + real-time node runner.
//!
//! Frame format: `[sender: u32 LE][len: u32 LE][len bytes of JSON]`, one
//! connection per message (simple, robust, plenty for the e2e example's
//! localhost fabric; the paper's deployment would pool ZeroMQ sockets).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Action, Event, Message, Node};
use crate::types::{NodeId, Request, RequestRecord, Time};
use crate::util::json::Json;

#[derive(Debug, thiserror::Error)]
pub enum NetError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("bad frame")]
    BadFrame,
    #[error("unknown peer {0}")]
    UnknownPeer(NodeId),
}

/// Write one frame.
fn write_frame(stream: &mut TcpStream, from: NodeId, msg: &Message) -> Result<(), NetError> {
    let body = msg.to_json().to_string();
    stream.write_all(&from.0.to_le_bytes())?;
    stream.write_all(&(body.len() as u32).to_le_bytes())?;
    stream.write_all(body.as_bytes())?;
    Ok(())
}

/// Read one frame; None on clean EOF.
fn read_frame(stream: &mut TcpStream) -> Result<Option<(NodeId, Message)>, NetError> {
    let mut head = [0u8; 8];
    match stream.read_exact(&mut head) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let from = NodeId(u32::from_le_bytes(head[0..4].try_into().unwrap()));
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    if len > 64 << 20 {
        return Err(NetError::BadFrame);
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    let text = String::from_utf8(body).map_err(|_| NetError::BadFrame)?;
    let json = Json::parse(&text).map_err(|_| NetError::BadFrame)?;
    let msg = Message::from_json(&json).ok_or(NetError::BadFrame)?;
    Ok(Some((from, msg)))
}

/// A bound node endpoint: accepts frames from peers on a background thread,
/// sends by connecting per message.
pub struct TcpTransport {
    pub me: NodeId,
    pub local_addr: SocketAddr,
    peers: Arc<Mutex<HashMap<NodeId, SocketAddr>>>,
    incoming: mpsc::Receiver<(NodeId, Message)>,
}

impl TcpTransport {
    /// Bind to `addr` (use port 0 for ephemeral) and start accepting.
    pub fn bind(me: NodeId, addr: &str) -> Result<TcpTransport, NetError> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut stream) = stream else { continue };
                let tx = tx.clone();
                std::thread::spawn(move || {
                    while let Ok(Some(frame)) = read_frame(&mut stream) {
                        if tx.send(frame).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        Ok(TcpTransport {
            me,
            local_addr,
            peers: Arc::new(Mutex::new(HashMap::new())),
            incoming: rx,
        })
    }

    pub fn register_peer(&self, peer: NodeId, addr: SocketAddr) {
        self.peers.lock().unwrap().insert(peer, addr);
    }

    pub fn send(&self, to: NodeId, msg: &Message) -> Result<(), NetError> {
        let addr = self
            .peers
            .lock()
            .unwrap()
            .get(&to)
            .copied()
            .ok_or(NetError::UnknownPeer(to))?;
        let mut stream = TcpStream::connect(addr)?;
        write_frame(&mut stream, self.me, msg)
    }

    pub fn try_recv(&self) -> Option<(NodeId, Message)> {
        self.incoming.try_recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<(NodeId, Message)> {
        self.incoming.recv_timeout(d).ok()
    }
}

/// Drives one `Node` in real time: maps wall-clock to sim `Time`, pumps the
/// transport, fires ticks and backend wakes, executes actions.
pub struct NodeRunner {
    pub node: Node,
    pub transport: TcpTransport,
    epoch: Instant,
    tick_interval: Duration,
    last_tick: Instant,
    next_wake: Option<Time>,
    /// Completed user-visible records (the e2e harness collects these).
    pub records: Vec<RequestRecord>,
}

impl NodeRunner {
    pub fn new(node: Node, transport: TcpTransport, epoch: Instant) -> NodeRunner {
        NodeRunner {
            node,
            transport,
            epoch,
            tick_interval: Duration::from_millis(100),
            last_tick: Instant::now() - Duration::from_secs(1),
            next_wake: None,
            records: Vec::new(),
        }
    }

    pub fn now(&self) -> Time {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Inject a local user request.
    pub fn submit(&mut self, req: Request) {
        let now = self.now();
        let actions = self.node.handle(Event::UserRequest(req), now);
        self.apply(actions);
    }

    /// One pump iteration: returns true if it did any work (callers can
    /// sleep briefly when idle).
    pub fn pump(&mut self) -> bool {
        let mut busy = false;
        let now = self.now();

        if let Some((from, msg)) = self.transport.try_recv() {
            let actions = self.node.handle(Event::Message { from, msg }, now);
            self.apply(actions);
            busy = true;
        }
        if self.last_tick.elapsed() >= self.tick_interval {
            self.last_tick = Instant::now();
            let actions = self.node.handle(Event::Tick, now);
            self.apply(actions);
            busy = true;
        }
        if let Some(w) = self.next_wake {
            if now >= w {
                self.next_wake = None;
                let actions = self.node.handle(Event::BackendWake, now);
                self.apply(actions);
                busy = true;
            }
        }
        busy
    }

    fn apply(&mut self, actions: Vec<Action>) {
        for a in actions {
            match a {
                Action::Send { to, msg } => {
                    // Best-effort: a dead peer is discovered via gossip.
                    let _ = self.transport.send(to, &msg);
                }
                Action::Done(rec) => self.records.push(rec),
                Action::WakeAt(t) => {
                    self.next_wake = Some(match self.next_wake {
                        Some(w) => w.min(t),
                        None => t,
                    });
                }
                Action::DuelSettled(_) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::RequestId;

    #[test]
    fn frame_roundtrip_over_loopback() {
        let t1 = TcpTransport::bind(NodeId(1), "127.0.0.1:0").unwrap();
        let t2 = TcpTransport::bind(NodeId(2), "127.0.0.1:0").unwrap();
        t1.register_peer(NodeId(2), t2.local_addr);
        t2.register_peer(NodeId(1), t1.local_addr);

        let msg = Message::ProbeAccept {
            req_id: RequestId { origin: NodeId(1), seq: 7 },
        };
        t1.send(NodeId(2), &msg).unwrap();
        let (from, got) = t2.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(from, NodeId(1));
        assert_eq!(got, msg);
    }

    #[test]
    fn send_to_unknown_peer_errors() {
        let t = TcpTransport::bind(NodeId(0), "127.0.0.1:0").unwrap();
        let msg = Message::ProbeReject {
            req_id: RequestId { origin: NodeId(0), seq: 1 },
        };
        assert!(matches!(
            t.send(NodeId(9), &msg),
            Err(NetError::UnknownPeer(NodeId(9)))
        ));
    }

    #[test]
    fn bidirectional_burst() {
        let t1 = TcpTransport::bind(NodeId(1), "127.0.0.1:0").unwrap();
        let t2 = TcpTransport::bind(NodeId(2), "127.0.0.1:0").unwrap();
        t1.register_peer(NodeId(2), t2.local_addr);
        t2.register_peer(NodeId(1), t1.local_addr);
        for seq in 0..20u64 {
            t1.send(
                NodeId(2),
                &Message::ProbeAccept {
                    req_id: RequestId { origin: NodeId(1), seq },
                },
            )
            .unwrap();
        }
        let mut got = Vec::new();
        while got.len() < 20 {
            let (_, m) = t2.recv_timeout(Duration::from_secs(5)).expect("msg");
            if let Message::ProbeAccept { req_id } = m {
                got.push(req_id.seq);
            }
        }
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }
}
