//! Span-tree stitching and Chrome trace-event JSON export.
//!
//! [`stitch`] groups request-scoped [`SpanEvent`]s (collected from every
//! node's flight recorder plus the world-level ring) into per-request
//! [`SpanTree`]s ordered by `(t, node, seq)`. [`chrome_trace_json`]
//! renders trees + node-scoped events in the Chrome trace-event format
//! (load the file in `chrome://tracing` or <https://ui.perfetto.dev>):
//! each node becomes a process row, spans become instant events, and
//! matched `execute_start`/`execute_end` pairs become duration slices.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};
use std::io;

use super::{SpanEvent, SpanKind, TraceId};
use crate::types::{RequestId, Time};
use crate::util::json::Json;

/// All recorded hops of one request, in causal `(t, node, seq)` order.
#[derive(Debug, Clone)]
pub struct SpanTree {
    pub trace: TraceId,
    pub req: RequestId,
    pub spans: Vec<SpanEvent>,
}

impl SpanTree {
    /// The span kinds in order — convenient for hop-chain assertions.
    pub fn kinds(&self) -> Vec<SpanKind> {
        self.spans.iter().map(|s| s.kind).collect()
    }
}

/// Group request-scoped events into per-request trees. Node-scoped
/// events (`req: None`) are skipped — export them separately. Trees come
/// back ordered by request id; spans within a tree are ordered by time,
/// breaking ties by node then intra-node sequence (recorder sequences
/// are monotone per node, so same-node same-time spans keep their
/// emission order).
pub fn stitch(events: Vec<SpanEvent>) -> Vec<SpanTree> {
    let mut by_req: BTreeMap<RequestId, Vec<SpanEvent>> = BTreeMap::new();
    for e in events {
        if let Some(req) = e.req {
            by_req.entry(req).or_default().push(e);
        }
    }
    by_req
        .into_iter()
        .map(|(req, mut spans)| {
            spans.sort_by(|a, b| {
                a.t.partial_cmp(&b.t)
                    .unwrap_or(Ordering::Equal)
                    .then(a.node.0.cmp(&b.node.0))
                    .then(a.seq.cmp(&b.seq))
            });
            SpanTree { trace: spans[0].trace, req, spans }
        })
        .collect()
}

fn us(t: Time) -> Json {
    Json::num(t * 1e6)
}

fn instant_event(e: &SpanEvent) -> Json {
    let mut args = vec![("detail", Json::num(e.detail as f64))];
    match e.req {
        Some(req) => {
            args.push(("req", Json::str(&format!("{req}"))));
            args.push(("trace", Json::str(&format!("{:016x}", e.trace.0))));
        }
        None => args.push(("req", Json::Null)),
    }
    match e.peer {
        Some(p) => args.push(("peer", Json::str(&format!("{p}")))),
        None => args.push(("peer", Json::Null)),
    }
    Json::obj(vec![
        ("name", Json::str(e.kind.name())),
        ("ph", Json::str("i")),
        ("s", Json::str("t")),
        ("ts", us(e.t)),
        ("pid", Json::num(e.node.0 as f64)),
        ("tid", Json::num(e.node.0 as f64)),
        ("args", Json::obj(args)),
    ])
}

fn complete_event(start: &SpanEvent, end_t: Time) -> Json {
    let req = start.req.expect("complete events are request-scoped");
    Json::obj(vec![
        ("name", Json::str("execute")),
        ("ph", Json::str("X")),
        ("ts", us(start.t)),
        ("dur", us(end_t - start.t)),
        ("pid", Json::num(start.node.0 as f64)),
        ("tid", Json::num(start.node.0 as f64)),
        (
            "args",
            Json::obj(vec![
                ("req", Json::str(&format!("{req}"))),
                ("trace", Json::str(&format!("{:016x}", start.trace.0))),
            ]),
        ),
    ])
}

/// Render span trees plus node-scoped events as a Chrome trace-event
/// JSON document (`{"traceEvents": [...], "displayTimeUnit": "ms"}`).
pub fn chrome_trace_json(trees: &[SpanTree], node_events: &[SpanEvent]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    for tree in trees {
        // Pair execute_start/execute_end per node into duration slices.
        let mut starts: BTreeMap<u32, SpanEvent> = BTreeMap::new();
        for span in &tree.spans {
            pids.insert(span.node.0);
            events.push(instant_event(span));
            match span.kind {
                SpanKind::ExecuteStart => {
                    starts.insert(span.node.0, span.clone());
                }
                SpanKind::ExecuteEnd => {
                    if let Some(start) = starts.remove(&span.node.0) {
                        events.push(complete_event(&start, span.t));
                    }
                }
                _ => {}
            }
        }
    }
    for e in node_events {
        pids.insert(e.node.0);
        events.push(instant_event(e));
    }
    for pid in pids {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(&format!("node n{pid}")))]),
            ),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write a Chrome trace-event file for the given trees + node events.
pub fn write_chrome_trace(
    path: &str,
    trees: &[SpanTree],
    node_events: &[SpanEvent],
) -> io::Result<()> {
    let doc = chrome_trace_json(trees, node_events);
    std::fs::write(path, format!("{doc}\n"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::NodeId;

    fn rid(origin: u32, seq: u64) -> RequestId {
        RequestId { origin: NodeId(origin), seq }
    }

    fn ev(
        req: Option<RequestId>,
        kind: SpanKind,
        node: u32,
        t: Time,
        seq: u64,
    ) -> SpanEvent {
        SpanEvent {
            trace: req.map_or(TraceId(0), TraceId::from_request),
            req,
            kind,
            node: NodeId(node),
            peer: None,
            t,
            detail: 0,
            seq,
        }
    }

    #[test]
    fn stitch_groups_by_request_and_orders_spans() {
        let a = rid(0, 1);
        let b = rid(1, 1);
        let events = vec![
            ev(Some(a), SpanKind::ExecuteEnd, 1, 5.0, 2),
            ev(Some(b), SpanKind::Admit, 1, 0.5, 1),
            ev(Some(a), SpanKind::Admit, 0, 1.0, 1),
            ev(Some(a), SpanKind::ProbeSent, 0, 1.0, 2),
            ev(Some(a), SpanKind::Queue, 1, 2.0, 1),
            ev(None, SpanKind::GossipRound, 0, 0.0, 3),
        ];
        let trees = stitch(events);
        assert_eq!(trees.len(), 2);
        // BTreeMap order: origin 0 before origin 1.
        assert_eq!(trees[0].req, a);
        assert_eq!(
            trees[0].kinds(),
            vec![
                SpanKind::Admit,
                SpanKind::ProbeSent,
                SpanKind::Queue,
                SpanKind::ExecuteEnd
            ]
        );
        assert_eq!(trees[1].req, b);
        assert_eq!(trees[0].trace, TraceId::from_request(a));
    }

    #[test]
    fn chrome_trace_pairs_execute_slices_and_names_processes() {
        let a = rid(0, 1);
        let trees = stitch(vec![
            ev(Some(a), SpanKind::Admit, 0, 1.0, 1),
            ev(Some(a), SpanKind::ExecuteStart, 1, 2.0, 1),
            ev(Some(a), SpanKind::ExecuteEnd, 1, 4.5, 2),
        ]);
        let node_events = vec![ev(None, SpanKind::GossipRound, 0, 0.5, 9)];
        let doc = chrome_trace_json(&trees, &node_events);
        let evs = doc.get("traceEvents").as_arr().expect("traceEvents array");
        // 3 instants + 1 X slice + 1 node instant + 2 process_name metas.
        assert_eq!(evs.len(), 7);
        let slice = evs
            .iter()
            .find(|e| e.get("ph").as_str() == Some("X"))
            .expect("one complete event");
        assert_eq!(slice.get("name").as_str(), Some("execute"));
        assert_eq!(slice.get("ts").as_f64(), Some(2.0 * 1e6));
        assert_eq!(slice.get("dur").as_f64(), Some(2.5 * 1e6));
        assert_eq!(slice.get("pid").as_f64(), Some(1.0));
        let metas: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("M"))
            .collect();
        assert_eq!(metas.len(), 2);
        // Round-trips through the parser.
        let parsed = Json::parse(&format!("{doc}")).expect("valid JSON");
        assert_eq!(
            parsed.get("traceEvents").as_arr().map(|a| a.len()),
            Some(7)
        );
        assert_eq!(parsed.get("displayTimeUnit").as_str(), Some("ms"));
    }

    #[test]
    fn unmatched_execute_end_emits_no_slice() {
        let a = rid(0, 2);
        let trees = stitch(vec![ev(Some(a), SpanKind::ExecuteEnd, 1, 4.5, 1)]);
        let doc = chrome_trace_json(&trees, &[]);
        let evs = doc.get("traceEvents").as_arr().unwrap();
        assert!(evs.iter().all(|e| e.get("ph").as_str() != Some("X")));
    }
}
