//! Observability: causal request traces + a unified per-node metrics
//! registry, exported as a "flight recorder".
//!
//! Two halves:
//!
//! * **Causal request traces.** Every request carries a [`TraceId`]
//!   derived from its [`RequestId`] (a splitmix64 hash — no wall clock,
//!   no RNG, so traces replay bit-identically from the seed). The
//!   coordinator layers and the sim core emit typed [`SpanEvent`]s into a
//!   per-node bounded ring buffer ([`FlightRecorder`]); `sim::World`
//!   stitches the rings into per-request span trees ([`export::stitch`])
//!   and exports Chrome trace-event JSON ([`export::chrome_trace_json`]).
//!
//! * **A [`MetricsRegistry`]** of interned-key counters / gauges /
//!   histograms with per-node / per-region labels, sampled into bounded
//!   windowed time series. The `World` mirrors its ad-hoc counter fields
//!   (`events_processed`, `gossip_bytes_sent`, `messages_dropped`,
//!   `dispatch_sends`, `scale_events`, `capacity_credits_charged`, ...)
//!   into registry entries each sampling round — the registry is the
//!   *labeled, windowed view* of those counters (the public fields stay
//!   the hot-path source of truth so existing tests and benches keep
//!   reading them directly). JSON export lives in `metrics/export.rs`.
//!
//! ## Span taxonomy
//!
//! Request-scoped spans (carry the request's [`TraceId`], subject to
//! `sample_rate`):
//!
//! | kind             | emitted by                 | meaning                               |
//! |------------------|----------------------------|---------------------------------------|
//! | `admit`          | `dispatch::on_user_request`| request entered the origin node       |
//! | `probe_sent`     | `dispatch::try_delegate`   | PoS probe sent to a candidate         |
//! | `probe_acked`    | `dispatch::on_probe_accept`| candidate accepted the probe          |
//! | `probe_rejected` | `dispatch::on_probe_reject`| candidate declined (retry or fallback)|
//! | `delegate`       | `dispatch` / `duel`        | request shipped to an executor        |
//! | `queue`          | `dispatch::on_delegate`    | executor admitted the delegated work  |
//! | `execute_start`  | `ctx::execute_locally`     | submitted to the serving backend      |
//! | `execute_end`    | backend pump / completion  | backend finished generating           |
//! | `timeout`        | `dispatch::expire`         | probe/response deadline expired       |
//! | `duel_settle`    | `duel::on_judge_verdict`   | judge quorum settled a duel           |
//! | `settle`         | `dispatch::on_response`    | origin paid and recorded the result   |
//! | `receipt_reject` | `dispatch::on_response`    | executor receipt missing/forged       |
//! | `prefill_start`  | completion handlers        | backend began the prefill phase       |
//! | `first_token`    | completion handlers        | prefill→decode boundary (TTFT stamp)  |
//! | `kv_transfer`    | `Node::on_message`         | session KV shipped to a new executor (`detail` = bytes) |
//!
//! Node-scoped spans (no request; gated only on `enabled`):
//!
//! | kind             | emitted by                  | `detail`                    |
//! |------------------|-----------------------------|-----------------------------|
//! | `gossip_round`   | `gossip_driver::tick`       | round number                |
//! | `rtt_observed`   | `latency_feed`              | RTT in microseconds         |
//! | `scale`          | `World::eval_capacity`      | [`CapacityAction`] detail   |
//! | `quarantine`     | `ctx::rep_event`            | 1 = quarantined, 0 = released |
//!
//! [`CapacityAction`]: crate::capacity::CapacityAction
//!
//! ## Ring-buffer semantics
//!
//! Each recorder keeps at most `ring_capacity` spans; at capacity the
//! oldest span is evicted and `dropped()` counts it — a long run keeps
//! the *most recent* window, which is what post-mortem debugging wants.
//! Eviction is per-node and purely size-driven, so it is deterministic.
//! The `slo_misses_only` config flag filters at *stitch/export* time
//! (rings stay append-only): only traces whose request missed its SLO —
//! or never completed — survive into the export.
//!
//! ## Opening a trace
//!
//! `World::write_trace("TRACE.json")` writes Chrome trace-event JSON.
//! Open `chrome://tracing` (or <https://ui.perfetto.dev>) and load the
//! file: each node renders as a process row, request spans as instant
//! events, and matched `execute_start`/`execute_end` pairs as duration
//! slices. The `args` panel carries the request id, peer and trace id.
//!
//! ## Determinism contract
//!
//! Nothing in this module draws randomness or reads a clock: trace ids
//! hash the request id, sampling compares that hash against
//! `sample_rate`, and all buffers are bounded by plain counters. With
//! `enabled: false` every emission point is a no-op behind a branch and
//! existing replay fingerprints are bit-identical
//! (`rust/tests/replay_equivalence.rs`); with `enabled: true` recording
//! is purely observational — no queue events, no RNG draws — so the
//! fingerprints *still* match.

pub mod export;

use std::collections::{BTreeMap, VecDeque};

use crate::types::{NodeId, RequestId, Time};

/// Declarative `observability` config block knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObservabilityConfig {
    /// Master switch. `false` (the default) pins every emission point to
    /// a no-op and replays pre-observability traces byte for byte.
    pub enabled: bool,
    /// Fraction of requests traced, decided by a deterministic hash of
    /// the request id (never the node RNG — sampling must not shift the
    /// replay stream). 1.0 traces everything, 0.0 nothing.
    pub sample_rate: f64,
    /// Per-ring span capacity (oldest spans evicted beyond it).
    pub ring_capacity: usize,
    /// Export-time filter: keep full span trees only for requests that
    /// violated their SLO (or never completed).
    pub slo_misses_only: bool,
}

impl Default for ObservabilityConfig {
    fn default() -> Self {
        ObservabilityConfig {
            enabled: false,
            sample_rate: 1.0,
            ring_capacity: 4096,
            slo_misses_only: false,
        }
    }
}

impl ObservabilityConfig {
    /// Validate, returning a descriptive error (the config-parser path).
    pub fn check(&self) -> Result<(), String> {
        if !self.sample_rate.is_finite()
            || !(0.0..=1.0).contains(&self.sample_rate)
        {
            return Err(format!(
                "sample_rate must be a finite fraction in [0, 1], got {}",
                self.sample_rate
            ));
        }
        if self.ring_capacity == 0 {
            return Err("ring_capacity must be >= 1".into());
        }
        Ok(())
    }

    /// Panicking twin of [`check`](Self::check) for programmatic configs.
    pub fn validate(&self) {
        if let Err(e) = self.check() {
            panic!("ObservabilityConfig: {e}");
        }
    }
}

/// Stable causal-trace identity: a deterministic hash of the request id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Derive the trace id from a request id (splitmix64 finalizer — the
    /// same request always yields the same trace, run after run).
    pub fn from_request(id: RequestId) -> TraceId {
        let seed = (id.origin.0 as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ id.seq;
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        TraceId(z ^ (z >> 31))
    }

    /// Map the id's hash onto [0, 1) for sample-rate comparison.
    fn unit_fraction(self) -> f64 {
        (self.0 >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// The typed span vocabulary (see the module header's taxonomy table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Admit,
    ProbeSent,
    ProbeAcked,
    ProbeRejected,
    Delegate,
    Queue,
    ExecuteStart,
    ExecuteEnd,
    Timeout,
    DuelSettle,
    Settle,
    Scale,
    GossipRound,
    RttObserved,
    ReceiptReject,
    Quarantine,
    PrefillStart,
    FirstToken,
    KvTransfer,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::ProbeSent => "probe_sent",
            SpanKind::ProbeAcked => "probe_acked",
            SpanKind::ProbeRejected => "probe_rejected",
            SpanKind::Delegate => "delegate",
            SpanKind::Queue => "queue",
            SpanKind::ExecuteStart => "execute_start",
            SpanKind::ExecuteEnd => "execute_end",
            SpanKind::Timeout => "timeout",
            SpanKind::DuelSettle => "duel_settle",
            SpanKind::Settle => "settle",
            SpanKind::Scale => "scale",
            SpanKind::GossipRound => "gossip_round",
            SpanKind::RttObserved => "rtt_observed",
            SpanKind::ReceiptReject => "receipt_reject",
            SpanKind::Quarantine => "quarantine",
            SpanKind::PrefillStart => "prefill_start",
            SpanKind::FirstToken => "first_token",
            SpanKind::KvTransfer => "kv_transfer",
        }
    }
}

/// One recorded hop of a request's journey (or a node-scoped event).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    /// Causal trace this span belongs to (`TraceId(0)` for node spans).
    pub trace: TraceId,
    /// The request, when request-scoped; `None` for node-scoped spans.
    pub req: Option<RequestId>,
    pub kind: SpanKind,
    /// Node that emitted the span.
    pub node: NodeId,
    /// Counterparty, when the hop has one (probe target, executor, ...).
    pub peer: Option<NodeId>,
    /// Virtual emission time.
    pub t: Time,
    /// Kind-specific payload (gossip round, RTT µs, scale detail, ...).
    pub detail: u64,
    /// Per-recorder monotone sequence — stable intra-node ordering for
    /// same-timestamp spans.
    pub seq: u64,
}

/// Per-node bounded span ring ("flight recorder"). All emission methods
/// are no-ops unless enabled, and request-scoped emission additionally
/// respects the deterministic sample decision.
#[derive(Debug, Default)]
pub struct FlightRecorder {
    cfg: ObservabilityConfig,
    buf: VecDeque<SpanEvent>,
    seq: u64,
    dropped: u64,
}

impl FlightRecorder {
    /// The inert recorder every node starts with (`enabled: false`).
    pub fn disabled() -> Self {
        FlightRecorder {
            cfg: ObservabilityConfig::default(),
            buf: VecDeque::new(),
            seq: 0,
            dropped: 0,
        }
    }

    pub fn new(cfg: ObservabilityConfig) -> Self {
        cfg.validate();
        let cap = if cfg.enabled { cfg.ring_capacity.min(1 << 20) } else { 0 };
        FlightRecorder {
            cfg,
            buf: VecDeque::with_capacity(cap),
            seq: 0,
            dropped: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn config(&self) -> &ObservabilityConfig {
        &self.cfg
    }

    /// Deterministic sample decision for a request: enabled, and the
    /// request-id hash falls under `sample_rate`. Never consults an RNG.
    pub fn sampled(&self, req: RequestId) -> bool {
        self.cfg.enabled
            && TraceId::from_request(req).unit_fraction() < self.cfg.sample_rate
    }

    /// Emit a request-scoped span (no-op unless the request is sampled).
    pub fn span(
        &mut self,
        req: RequestId,
        kind: SpanKind,
        node: NodeId,
        peer: Option<NodeId>,
        t: Time,
        detail: u64,
    ) {
        if !self.sampled(req) {
            return;
        }
        let trace = TraceId::from_request(req);
        self.push(SpanEvent {
            trace,
            req: Some(req),
            kind,
            node,
            peer,
            t,
            detail,
            seq: 0,
        });
    }

    /// Emit a node-scoped span (gossip round, RTT sample, scale action) —
    /// gated on `enabled` only, not on per-request sampling.
    pub fn node_span(
        &mut self,
        kind: SpanKind,
        node: NodeId,
        peer: Option<NodeId>,
        t: Time,
        detail: u64,
    ) {
        if !self.cfg.enabled {
            return;
        }
        self.push(SpanEvent {
            trace: TraceId(0),
            req: None,
            kind,
            node,
            peer,
            t,
            detail,
            seq: 0,
        });
    }

    fn push(&mut self, mut ev: SpanEvent) {
        self.seq += 1;
        ev.seq = self.seq;
        if self.buf.len() >= self.cfg.ring_capacity {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    pub fn events(&self) -> impl Iterator<Item = &SpanEvent> {
        self.buf.iter()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans evicted by the ring bound so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

// ---------------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------------

/// Interned handle into a [`MetricsRegistry`] — resolve labels once,
/// update through the id on the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricId(usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone total (`set` overwrites with the mirrored counter value).
    Counter,
    /// Point-in-time level.
    Gauge,
    /// Log2-bucketed distribution over µ-unit magnitudes.
    Histogram,
}

impl MetricKind {
    pub fn name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// Windowed-series length bound: at capacity the series halves (every
/// other point kept), so memory stays bounded while the full run's shape
/// survives at coarser resolution. Deterministic — no time-based pruning.
pub const SERIES_CAP: usize = 512;

/// One registered metric: identity, current value, optional histogram
/// buckets, and the sampled time series.
#[derive(Debug, Clone)]
pub struct Metric {
    pub name: String,
    /// Sorted-insertion label pairs, e.g. `[("region", "us")]`.
    pub labels: Vec<(String, String)>,
    pub kind: MetricKind,
    /// Counter/gauge current value; histogram: sum of observations.
    pub value: f64,
    /// Histogram observation count (0 for counters/gauges).
    pub count: u64,
    /// Histogram log2 buckets over `(v * 1e6) as u64` magnitudes;
    /// `buckets[i]` counts observations with `floor(log2(µv)) == i`.
    pub buckets: Vec<u64>,
    /// `(t, value)` samples pushed by [`MetricsRegistry::sample`].
    pub series: Vec<(Time, f64)>,
}

/// Interned-key registry of counters, gauges and histograms.
///
/// Keys are `(name, labels)`; registering the same key twice returns the
/// original [`MetricId`]. `BTreeMap` interning keeps iteration (and thus
/// JSON export) deterministically ordered.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    index: BTreeMap<(String, Vec<(String, String)>), MetricId>,
    metrics: Vec<Metric>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `(name, labels)` as a metric of `kind`, returning its id.
    /// An existing key returns the already-registered id (the kind must
    /// match — mixing kinds under one key is a programming error).
    pub fn register(
        &mut self,
        kind: MetricKind,
        name: &str,
        labels: &[(&str, &str)],
    ) -> MetricId {
        let key = (
            name.to_string(),
            labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect::<Vec<_>>(),
        );
        if let Some(&id) = self.index.get(&key) {
            assert_eq!(
                self.metrics[id.0].kind, kind,
                "metric '{name}' re-registered with a different kind"
            );
            return id;
        }
        let id = MetricId(self.metrics.len());
        self.metrics.push(Metric {
            name: key.0.clone(),
            labels: key.1.clone(),
            kind,
            value: 0.0,
            count: 0,
            buckets: Vec::new(),
            series: Vec::new(),
        });
        self.index.insert(key, id);
        id
    }

    pub fn counter(&mut self, name: &str, labels: &[(&str, &str)]) -> MetricId {
        self.register(MetricKind::Counter, name, labels)
    }

    pub fn gauge(&mut self, name: &str, labels: &[(&str, &str)]) -> MetricId {
        self.register(MetricKind::Gauge, name, labels)
    }

    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
    ) -> MetricId {
        self.register(MetricKind::Histogram, name, labels)
    }

    /// Overwrite a counter/gauge's current value (the mirroring path:
    /// `World` counters are already monotone, so `set` is the counter
    /// update too).
    pub fn set(&mut self, id: MetricId, v: f64) {
        self.metrics[id.0].value = v;
    }

    /// Increment a counter/gauge.
    pub fn add(&mut self, id: MetricId, dv: f64) {
        self.metrics[id.0].value += dv;
    }

    /// Record one histogram observation (`v` is clamped at 0).
    pub fn observe(&mut self, id: MetricId, v: f64) {
        let m = &mut self.metrics[id.0];
        debug_assert_eq!(m.kind, MetricKind::Histogram);
        let v = v.max(0.0);
        m.value += v;
        m.count += 1;
        let micro = (v * 1e6) as u64;
        let bucket = (64 - micro.max(1).leading_zeros() as usize) - 1;
        if m.buckets.len() <= bucket {
            m.buckets.resize(bucket + 1, 0);
        }
        m.buckets[bucket] += 1;
    }

    /// Push the metric's current value onto its windowed series (halving
    /// the series when it reaches [`SERIES_CAP`]). A repeat sample at an
    /// unchanged timestamp is skipped — end-of-run flushes are idempotent.
    pub fn sample(&mut self, id: MetricId, t: Time) {
        let m = &mut self.metrics[id.0];
        if m.series.last().is_some_and(|(lt, _)| *lt == t) {
            return;
        }
        if m.series.len() >= SERIES_CAP {
            let halved: Vec<(Time, f64)> =
                m.series.iter().step_by(2).copied().collect();
            m.series = halved;
        }
        m.series.push((t, m.value));
    }

    /// Sample every registered metric at `t`.
    pub fn sample_all(&mut self, t: Time) {
        for id in 0..self.metrics.len() {
            self.sample(MetricId(id), t);
        }
    }

    /// Look up a metric by name + exact label set.
    pub fn get(&self, name: &str, labels: &[(&str, &str)]) -> Option<&Metric> {
        let key = (
            name.to_string(),
            labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect::<Vec<_>>(),
        );
        self.index.get(&key).map(|id| &self.metrics[id.0])
    }

    pub fn metric(&self, id: MetricId) -> &Metric {
        &self.metrics[id.0]
    }

    /// All metrics in registration order.
    pub fn all(&self) -> &[Metric] {
        &self.metrics
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rid(origin: u32, seq: u64) -> RequestId {
        RequestId { origin: NodeId(origin), seq }
    }

    fn enabled_cfg(cap: usize) -> ObservabilityConfig {
        ObservabilityConfig {
            enabled: true,
            ring_capacity: cap,
            ..Default::default()
        }
    }

    #[test]
    fn trace_ids_are_deterministic_and_distinct() {
        assert_eq!(
            TraceId::from_request(rid(2, 17)),
            TraceId::from_request(rid(2, 17))
        );
        assert_ne!(
            TraceId::from_request(rid(2, 17)),
            TraceId::from_request(rid(2, 18))
        );
        assert_ne!(
            TraceId::from_request(rid(2, 17)),
            TraceId::from_request(rid(3, 17))
        );
    }

    #[test]
    fn sampling_is_a_pure_function_of_the_request_id() {
        let cfg = ObservabilityConfig {
            enabled: true,
            sample_rate: 0.5,
            ..Default::default()
        };
        let fr = FlightRecorder::new(cfg);
        let first: Vec<bool> = (0..200).map(|s| fr.sampled(rid(1, s))).collect();
        let again: Vec<bool> = (0..200).map(|s| fr.sampled(rid(1, s))).collect();
        assert_eq!(first, again);
        let kept = first.iter().filter(|k| **k).count();
        assert!(
            (40..160).contains(&kept),
            "rate 0.5 kept {kept}/200 — hash badly skewed"
        );
        // Rate 1.0 keeps everything, 0.0 nothing; disabled keeps nothing.
        let all = FlightRecorder::new(enabled_cfg(16));
        assert!((0..50).all(|s| all.sampled(rid(0, s))));
        let none = FlightRecorder::new(ObservabilityConfig {
            enabled: true,
            sample_rate: 0.0,
            ..Default::default()
        });
        assert!((0..50).all(|s| !none.sampled(rid(0, s))));
        assert!(!FlightRecorder::disabled().sampled(rid(0, 1)));
    }

    #[test]
    fn ring_keeps_the_most_recent_window() {
        let mut fr = FlightRecorder::new(enabled_cfg(4));
        for s in 0..10u64 {
            fr.span(rid(0, s), SpanKind::Admit, NodeId(0), None, s as f64, 0);
        }
        assert_eq!(fr.len(), 4);
        assert_eq!(fr.dropped(), 6);
        let seqs: Vec<u64> =
            fr.events().map(|e| e.req.unwrap().seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        // Intra-node sequence is monotone.
        let evs: Vec<u64> = fr.events().map(|e| e.seq).collect();
        assert!(evs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut fr = FlightRecorder::disabled();
        fr.span(rid(0, 1), SpanKind::Admit, NodeId(0), None, 1.0, 0);
        fr.node_span(SpanKind::GossipRound, NodeId(0), None, 1.0, 3);
        assert!(fr.is_empty());
        assert_eq!(fr.dropped(), 0);
    }

    #[test]
    fn node_spans_skip_request_sampling() {
        let mut fr = FlightRecorder::new(ObservabilityConfig {
            enabled: true,
            sample_rate: 0.0,
            ..Default::default()
        });
        fr.span(rid(0, 1), SpanKind::Admit, NodeId(0), None, 1.0, 0);
        fr.node_span(SpanKind::GossipRound, NodeId(0), None, 1.0, 7);
        assert_eq!(fr.len(), 1);
        let ev = fr.events().next().unwrap();
        assert_eq!(ev.kind, SpanKind::GossipRound);
        assert_eq!(ev.req, None);
        assert_eq!(ev.detail, 7);
    }

    #[test]
    fn config_check_rejects_bad_knobs() {
        let ok = ObservabilityConfig::default();
        assert!(ok.check().is_ok());
        let bad_rate = |r: f64| ObservabilityConfig {
            sample_rate: r,
            ..Default::default()
        };
        assert!(bad_rate(-0.1).check().is_err());
        assert!(bad_rate(1.5).check().is_err());
        assert!(bad_rate(f64::NAN).check().is_err());
        let zero_ring = ObservabilityConfig {
            ring_capacity: 0,
            ..Default::default()
        };
        assert!(zero_ring.check().is_err());
    }

    #[test]
    #[should_panic(expected = "sample_rate")]
    fn validate_panics_on_bad_rate() {
        ObservabilityConfig { sample_rate: 2.0, ..Default::default() }
            .validate();
    }

    #[test]
    fn registry_interns_by_name_and_labels() {
        let mut reg = MetricsRegistry::new();
        let a = reg.counter("msgs", &[("region", "us")]);
        let b = reg.counter("msgs", &[("region", "eu")]);
        let a2 = reg.counter("msgs", &[("region", "us")]);
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(reg.len(), 2);
        reg.set(a, 5.0);
        reg.add(a, 2.0);
        assert_eq!(reg.get("msgs", &[("region", "us")]).unwrap().value, 7.0);
        assert_eq!(reg.get("msgs", &[("region", "eu")]).unwrap().value, 0.0);
        assert!(reg.get("msgs", &[]).is_none());
    }

    #[test]
    fn histogram_buckets_and_sums() {
        let mut reg = MetricsRegistry::new();
        let h = reg.histogram("latency_s", &[]);
        reg.observe(h, 0.5);
        reg.observe(h, 0.5);
        reg.observe(h, 4.0);
        let m = reg.get("latency_s", &[]).unwrap();
        assert_eq!(m.count, 3);
        assert!((m.value - 5.0).abs() < 1e-12);
        assert_eq!(m.buckets.iter().sum::<u64>(), 3);
        // 0.5 s -> 500_000 µ -> bucket 18; 4 s -> 4_000_000 µ -> bucket 21.
        assert_eq!(m.buckets[18], 2);
        assert_eq!(m.buckets[21], 1);
    }

    #[test]
    fn series_halves_at_capacity_and_dedupes_timestamps() {
        let mut reg = MetricsRegistry::new();
        let g = reg.gauge("depth", &[]);
        for i in 0..SERIES_CAP {
            reg.set(g, i as f64);
            reg.sample(g, i as f64);
        }
        assert_eq!(reg.metric(g).series.len(), SERIES_CAP);
        // The next sample triggers a halve, then appends.
        reg.set(g, 999.0);
        reg.sample(g, 1e6);
        let m = reg.metric(g);
        assert_eq!(m.series.len(), SERIES_CAP / 2 + 1);
        assert_eq!(*m.series.last().unwrap(), (1e6, 999.0));
        // Same-timestamp resample is a no-op.
        reg.sample(g, 1e6);
        assert_eq!(reg.metric(g).series.len(), SERIES_CAP / 2 + 1);
    }
}
