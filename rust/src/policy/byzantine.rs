//! Byzantine participation policies — the attacker side of the
//! adversarial-robustness layer (defenses live in `crate::reputation`,
//! `coordinator/dispatch.rs` receipt checks, and
//! `coordinator/latency_feed.rs` hearsay capping; the
//! `reputation` module header carries the full threat-model table).
//!
//! Each attacker is an ordinary [`ParticipationPolicy`] selected per
//! `topology.fleet` group via the declarative `"byzantine"` config key, so
//! a scenario mixes honest and misbehaving fleets the same way it mixes
//! honest personalities:
//!
//! * [`FreeRider`] — accepts every probe, then silently drops the
//!   delegated work. The requester burns its full response timeout before
//!   falling back locally; the free-rider spends zero compute.
//! * [`LatencyLiar`] — behaves honestly at the dispatch boundary but
//!   rewrites the RTT rows it piggybacks on gossip to a *plausible* tiny
//!   value, luring same-region peers into delegating toward paths that are
//!   actually slow. (Plausible, because absurd values are rejected by the
//!   always-on junk filter regardless of defenses — a competent liar stays
//!   inside the believable range.)
//! * [`ResultFaker`] — accepts work and answers fast, but at a fraction of
//!   its real quality, and signs receipts over a forged response digest.
//!   Undefended, it gets paid for junk; defended, receipt verification
//!   refuses payment and duels slash it.
//! * [`Colluder`] — a result-faker that additionally slanders other nodes
//!   in its gossiped reputation rows, trying to get honest peers
//!   quarantined. Remote-opinion influence bounding keeps slander alone
//!   below the quarantine threshold.
//!
//! RNG discipline: attacker decisions that don't need randomness draw none
//! (accept-always, drop-always), so a Byzantine world replays
//! bit-identically from its seed like any other.

use super::participation::{
    OffloadCtx, ParticipationPolicy, ProbeCtx,
};
use super::NodePolicy;
use crate::util::rng::Rng;

/// One-way latency (seconds) the liar advertises for every row it gossips:
/// fast enough to attract traffic, plausible enough to pass junk filtering.
pub const LIAR_RTT: f64 = 0.0005;

/// Quality multiplier for faked delegated work.
pub const FAKER_QUALITY: f64 = 0.25;

/// Quality multiplier for the colluder (mediocre rather than obviously
/// junk — it relies on slander, not speed, to damage the network).
pub const COLLUDER_QUALITY: f64 = 0.5;

/// Node ids a colluder slanders in its outgoing reputation rows.
pub const COLLUDER_SLANDER_IDS: u32 = 8;

/// Accepts every delegation and silently drops it (see module docs).
#[derive(Debug, Clone, Copy, Default)]
pub struct FreeRider;

impl ParticipationPolicy for FreeRider {
    fn name(&self) -> &'static str {
        "free_rider"
    }

    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool {
        // Its own users are served like any default node's.
        p.should_offload(ctx.utilization, ctx.queue_len, ctx.nearest_latency, rng)
    }

    fn accept_probe(&self, _: &NodePolicy, _: &ProbeCtx, _: &mut Rng) -> bool {
        // Dropping is free, so capacity is irrelevant: take everything.
        true
    }

    fn delivers_responses(&self) -> bool {
        false
    }
}

/// Honest dispatch behaviour + poisoned gossip RTT rows (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct LatencyLiar {
    /// The fake one-way estimate written into every outgoing row.
    pub fake_rtt: f64,
}

impl Default for LatencyLiar {
    fn default() -> Self {
        LatencyLiar { fake_rtt: LIAR_RTT }
    }
}

impl ParticipationPolicy for LatencyLiar {
    fn name(&self) -> &'static str {
        "latency_liar"
    }

    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_offload(ctx.utilization, ctx.queue_len, ctx.nearest_latency, rng)
    }

    fn accept_probe(
        &self,
        p: &NodePolicy,
        ctx: &ProbeCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_accept(ctx.utilization, ctx.queue_len, rng)
    }

    fn corrupt_rtts(&self, rtts: &mut Vec<(u32, u32, f64)>) {
        for row in rtts.iter_mut() {
            row.2 = self.fake_rtt;
        }
    }
}

/// Fast junk answers + forged receipts (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct ResultFaker {
    /// Multiplier on the backend's intrinsic quality for delegated work.
    pub quality_factor: f64,
}

impl Default for ResultFaker {
    fn default() -> Self {
        ResultFaker { quality_factor: FAKER_QUALITY }
    }
}

impl ParticipationPolicy for ResultFaker {
    fn name(&self) -> &'static str {
        "result_faker"
    }

    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_offload(ctx.utilization, ctx.queue_len, ctx.nearest_latency, rng)
    }

    fn accept_probe(&self, p: &NodePolicy, ctx: &ProbeCtx, _: &mut Rng) -> bool {
        // Greedy but capacity-bounded (it does run the work — cheaply).
        ctx.utilization < 1.0 && ctx.queue_len <= p.queue_threshold
    }

    fn quality_factor(&self) -> f64 {
        self.quality_factor
    }

    fn honest_receipts(&self) -> bool {
        false
    }
}

/// Colluding-region attacker: mediocre work plus reputation slander in
/// gossip (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct Colluder {
    pub quality_factor: f64,
}

impl Default for Colluder {
    fn default() -> Self {
        Colluder { quality_factor: COLLUDER_QUALITY }
    }
}

impl ParticipationPolicy for Colluder {
    fn name(&self) -> &'static str {
        "colluder"
    }

    fn should_offload(
        &self,
        p: &NodePolicy,
        ctx: &OffloadCtx,
        rng: &mut Rng,
    ) -> bool {
        p.should_offload(ctx.utilization, ctx.queue_len, ctx.nearest_latency, rng)
    }

    fn accept_probe(&self, p: &NodePolicy, ctx: &ProbeCtx, _: &mut Rng) -> bool {
        ctx.utilization < 1.0 && ctx.queue_len <= p.queue_threshold
    }

    fn quality_factor(&self) -> f64 {
        self.quality_factor
    }

    fn corrupt_rep(&self, rep: &mut Vec<(u32, u32)>) {
        // Slander a fixed band of node ids as worthless. Crude, but the
        // point is the defense: bounded remote influence means this alone
        // can never quarantine an honest peer.
        rep.clear();
        for n in 0..COLLUDER_SLANDER_IDS {
            rep.push((n, 0));
        }
    }
}

/// Declarative selector for the attacker policies — what the config
/// layer's fleet-group `"byzantine"` key parses into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByzantineKind {
    FreeRider,
    LatencyLiar,
    ResultFaker,
    Colluder,
}

impl ByzantineKind {
    /// Parse a config-file name. `None` for unknown names — the config
    /// layer turns that into a loud error.
    pub fn parse(s: &str) -> Option<ByzantineKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "free_rider" => ByzantineKind::FreeRider,
            "latency_liar" => ByzantineKind::LatencyLiar,
            "result_faker" => ByzantineKind::ResultFaker,
            "colluder" => ByzantineKind::Colluder,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ByzantineKind::FreeRider => "free_rider",
            ByzantineKind::LatencyLiar => "latency_liar",
            ByzantineKind::ResultFaker => "result_faker",
            ByzantineKind::Colluder => "colluder",
        }
    }

    /// Instantiate the attacker policy object.
    pub fn build(self) -> Box<dyn ParticipationPolicy> {
        match self {
            ByzantineKind::FreeRider => Box::new(FreeRider),
            ByzantineKind::LatencyLiar => Box::new(LatencyLiar::default()),
            ByzantineKind::ResultFaker => Box::new(ResultFaker::default()),
            ByzantineKind::Colluder => Box::new(Colluder::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::NodeId;

    fn probe() -> ProbeCtx {
        ProbeCtx {
            from: NodeId(7),
            prompt_tokens: 100,
            output_tokens: 500,
            utilization: 0.3,
            queue_len: 0,
        }
    }

    #[test]
    fn free_rider_accepts_everything_and_delivers_nothing() {
        let f = FreeRider;
        let p = NodePolicy { accept_freq: 0.0, ..Default::default() };
        let mut rng = Rng::new(1);
        let saturated = ProbeCtx { utilization: 1.0, queue_len: 99, ..probe() };
        assert!(f.accept_probe(&p, &saturated, &mut rng));
        assert!(!f.delivers_responses());
        // Honest-looking everywhere else.
        assert!((f.quality_factor() - 1.0).abs() < 1e-12);
        assert!(f.honest_receipts());
    }

    #[test]
    fn latency_liar_rewrites_outgoing_rows_only() {
        let l = LatencyLiar::default();
        let mut rows = vec![(0, 1, 0.08), (0, 2, 0.15)];
        l.corrupt_rtts(&mut rows);
        assert_eq!(rows, vec![(0, 1, LIAR_RTT), (0, 2, LIAR_RTT)]);
        assert!(l.delivers_responses());
        assert!(l.honest_receipts());
        // The lie is plausible: finite, positive, well under any sane
        // junk-rejection threshold.
        assert!(LIAR_RTT > 0.0 && LIAR_RTT < 1.0);
    }

    #[test]
    fn result_faker_fakes_quality_and_receipts() {
        let f = ResultFaker::default();
        assert!((f.quality_factor() - FAKER_QUALITY).abs() < 1e-12);
        assert!(!f.honest_receipts());
        assert!(f.delivers_responses());
        // Still capacity-bounded: a saturated faker declines.
        let p = NodePolicy::default();
        let mut rng = Rng::new(2);
        let full = ProbeCtx { utilization: 1.0, ..probe() };
        assert!(!f.accept_probe(&p, &full, &mut rng));
        assert!(f.accept_probe(&p, &probe(), &mut rng));
    }

    #[test]
    fn colluder_slanders_fixed_band() {
        let c = Colluder::default();
        let mut rep = vec![(3, 700)];
        c.corrupt_rep(&mut rep);
        assert_eq!(rep.len(), COLLUDER_SLANDER_IDS as usize);
        assert!(rep.iter().all(|&(_, m)| m == 0));
        assert!((c.quality_factor() - COLLUDER_QUALITY).abs() < 1e-12);
    }

    #[test]
    fn kind_parses_and_builds() {
        for (name, kind) in [
            ("free_rider", ByzantineKind::FreeRider),
            ("latency_liar", ByzantineKind::LatencyLiar),
            ("result_faker", ByzantineKind::ResultFaker),
            ("colluder", ByzantineKind::Colluder),
        ] {
            assert_eq!(ByzantineKind::parse(name), Some(kind));
            assert_eq!(kind.name(), name);
            assert_eq!(kind.build().name(), name);
        }
        assert_eq!(
            ByzantineKind::parse("FREE_RIDER"),
            Some(ByzantineKind::FreeRider)
        );
        assert!(ByzantineKind::parse("saint").is_none());
    }

    #[test]
    fn honest_policies_keep_neutral_byzantine_defaults() {
        use crate::policy::{DefaultPolicy, GreedyLocal, RequesterOnly};
        let honest: [&dyn ParticipationPolicy; 3] =
            [&DefaultPolicy, &RequesterOnly, &GreedyLocal];
        for p in honest {
            assert!(p.delivers_responses(), "{}", p.name());
            assert!((p.quality_factor() - 1.0).abs() < 1e-12, "{}", p.name());
            assert!(p.honest_receipts(), "{}", p.name());
            let mut rows = vec![(0, 1, 0.5)];
            p.corrupt_rtts(&mut rows);
            assert_eq!(rows, vec![(0, 1, 0.5)], "{}", p.name());
            let mut rep = vec![(2, 300)];
            p.corrupt_rep(&mut rep);
            assert_eq!(rep, vec![(2, 300)], "{}", p.name());
        }
    }
}
